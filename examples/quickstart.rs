//! Quickstart: the three-layer stack in ~60 lines.
//!
//! 1. load the trained weights exported by `make artifacts`;
//! 2. run the HP-memristor twin on the *digital* backend (Rust RK4);
//! 3. run the same twin on the *analogue* backend (simulated memristive
//!    solver) and compare both against the physical ground truth;
//! 4. if the PJRT artifacts are built, execute the AOT crossbar kernel.
//!
//! Run: `cargo run --release --example quickstart`

use memode::analog::system::AnalogNoise;
use memode::config::SystemConfig;
use memode::device::hp;
use memode::metrics::mre::mre;
use memode::runtime::service::PjrtService;
use memode::runtime::TensorF32;
use memode::twin::hp::HpTwin;
use memode::twin::setup::TrainedWeights;
use memode::workload::stimuli::Waveform;

fn main() -> anyhow::Result<()> {
    let cfg = SystemConfig::default();
    let weights = TrainedWeights::load(&cfg)?;

    // Ground truth: the physical HP memristor under a sine stimulus.
    let wave = Waveform::sine(1.0, 4.0);
    let truth = hp::simulate_default(&|t| wave.eval(t));
    println!("ground truth: {} samples at {} s", truth.h.len(), hp::DT);

    // Digital twin (Rust RK4 over the trained field).
    let mut digital = HpTwin::digital(&weights.hp_node);
    let h_dig = digital.simulate(&wave, hp::H0, hp::N_POINTS)?;
    println!("digital twin  MRE vs truth: {:.4}", mre(&h_dig, &truth.h));

    // Analogue twin (simulated memristive solver at the paper's hardware
    // noise operating point).
    let mut analog = HpTwin::analog(
        &weights.hp_node,
        &cfg.device,
        AnalogNoise::hardware(),
        cfg.seed,
    );
    let h_ana = analog.simulate(&wave, hp::H0, hp::N_POINTS)?;
    println!("analogue twin MRE vs truth: {:.4}", mre(&h_ana, &truth.h));

    // PJRT path (optional: needs `make artifacts`).
    match PjrtService::start(&cfg.artifacts_dir) {
        Ok(svc) => {
            let h = svc.handle();
            let v = TensorF32::from_f64(vec![32], &[0.2; 32]);
            let gp = TensorF32::new(vec![32, 32], vec![5e-5; 1024]);
            let gn = TensorF32::new(vec![32, 32], vec![1e-5; 1024]);
            let out = h.execute("crossbar_vmm", vec![v, gp, gn])?;
            // Every column current: 32 rows * 0.2 V * 40 µS = 256 µA.
            println!(
                "pjrt crossbar_vmm: column current {:.1} µA (expect 256.0)",
                out.data[0] * 1e6
            );
        }
        Err(e) => println!("pjrt path skipped: {e}"),
    }
    Ok(())
}
