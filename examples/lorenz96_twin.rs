//! Fig. 4 reproduction — the end-to-end driver of this repository.
//!
//! Runs the full 48 s Lorenz96 workload (2400 samples at 0.02 s; 1800
//! interpolation + 600 extrapolation, the paper's split) through every
//! backend and reports:
//!
//! * Fig. 4d-f — per-phase L1 error of our (analogue) system;
//! * Fig. 4g  — interpolation/extrapolation L1 across ours / LSTM / GRU /
//!   RNN, mean ± std over `--reps` trials;
//! * Lyapunov horizon — valid prediction time in Lyapunov times (the
//!   paper's "seven largest Lyapunov times" claim);
//! * Fig. 4j  — read-noise x programming-noise robustness grid
//!   (`--noise-grid`).
//!
//! All states and errors are in the paper's *normalized* units (see
//! `workload::lorenz96::SCALE`).
//!
//! Run: `cargo run --release --example lorenz96_twin [-- --reps 3 --noise-grid]`

use memode::analog::system::AnalogNoise;
use memode::config::SystemConfig;
use memode::device::noise::{FIG4J_PROG_LEVELS, FIG4J_READ_LEVELS};
use memode::device::taox::DeviceConfig;
use memode::metrics::l1::mean_l1_multi;
use memode::metrics::lyapunov;
use memode::twin::lorenz96::Lorenz96Twin;
use memode::twin::setup::TrainedWeights;
use memode::util::cli::Args;
use memode::util::stats;
use memode::workload::lorenz96 as l96;

fn split_l1(
    pred: &[Vec<f64>],
    truth: &[Vec<f64>],
) -> (f64, f64) {
    let k = l96::TRAIN_POINTS.min(pred.len());
    let interp = mean_l1_multi(&pred[..k], &truth[..k]);
    let extrap = if pred.len() > k {
        mean_l1_multi(&pred[k..], &truth[k..])
    } else {
        f64::NAN
    };
    (interp, extrap)
}

fn main() -> anyhow::Result<()> {
    let args = Args::new("lorenz96_twin", "Fig. 4 reproduction (e2e driver)")
        .opt("reps", "3", "trials per model (paper: 10)")
        .opt("steps", "2400", "total samples (paper: 2400)")
        .opt("seed", "42", "base seed")
        .flag("noise-grid", "run the Fig. 4j noise robustness grid")
        .parse_env();
    let reps = args.get_u64("reps");
    let steps = args.get_usize("steps");
    let seed = args.get_u64("seed");

    let cfg = SystemConfig::default();
    // Fig. 4 convention: the paper's Lorenz96 analogue system is an
    // experimentally grounded *simulation* — read/programming noise, no
    // yield faults (those belong to the physically deployed Fig. 2/3).
    let device = DeviceConfig { fault_rate: 0.0, ..cfg.device.clone() };
    let weights = TrainedWeights::load(&cfg)?;
    let truth = l96::simulate_normalized(steps);
    let mle = l96::max_lyapunov_exponent(l96::FORCING, l96::DIM, 1);
    println!(
        "Lorenz96 d={} F={}: MLE {:.3} (Lyapunov time {:.2} s); {} samples",
        l96::DIM,
        l96::FORCING,
        mle,
        1.0 / mle,
        steps
    );

    // ---- Fig. 4d-g: error comparison across models ----------------------
    println!(
        "\n== Fig. 4g: interpolation (0-36 s) / extrapolation (36-48 s) L1 ==",
    );
    println!(
        "{:<22} {:>10} {:>8} {:>10} {:>8} {:>9}",
        "model", "interp", "±", "extrap", "±", "VPT (LT)"
    );

    // Ours: analogue memristive solver, re-deployed per rep.
    let run_ours = |rep: u64| -> anyhow::Result<Vec<Vec<f64>>> {
        let mut twin = Lorenz96Twin::analog(
            &weights.l96_node,
            &device,
            AnalogNoise::hardware(),
            seed + rep * 1000 + 3,
        );
        twin.simulate(&l96::Y0, steps).map(|t| t.to_nested())
    };
    // Digital node + recurrent baselines (deterministic -> 1 trial each,
    // but re-run for symmetric reporting).
    type Runner<'a> = Box<dyn Fn(u64) -> anyhow::Result<Vec<Vec<f64>>> + 'a>;
    let models: Vec<(&str, Runner)> = vec![
        ("memristive node (ours)", Box::new(run_ours)),
        (
            "neural-ode (digital)",
            Box::new(|_r| {
                Lorenz96Twin::digital(&weights.l96_node)
                    .simulate(&l96::Y0, steps)
                    .map(|t| t.to_nested())
            }),
        ),
        (
            "lstm",
            Box::new(|_r| {
                Lorenz96Twin::recurrent(&weights.l96_lstm)?
                    .simulate(&l96::Y0, steps)
                    .map(|t| t.to_nested())
            }),
        ),
        (
            "gru",
            Box::new(|_r| {
                Lorenz96Twin::recurrent(&weights.l96_gru)?
                    .simulate(&l96::Y0, steps)
                    .map(|t| t.to_nested())
            }),
        ),
        (
            "rnn",
            Box::new(|_r| {
                Lorenz96Twin::recurrent(&weights.l96_rnn)?
                    .simulate(&l96::Y0, steps)
                    .map(|t| t.to_nested())
            }),
        ),
    ];
    let mut ours_sample: Option<Vec<Vec<f64>>> = None;
    for (name, run) in &models {
        let mut interp = Vec::new();
        let mut extrap = Vec::new();
        let mut vpt = Vec::new();
        for r in 0..reps {
            let pred = run(r)?;
            let (i, e) = split_l1(&pred, &truth);
            interp.push(i);
            extrap.push(e);
            vpt.push(lyapunov::horizon_in_lyapunov_times(
                lyapunov::valid_prediction_time(&pred, &truth, l96::DT, 0.4),
                mle,
            ));
            if *name == "memristive node (ours)" && ours_sample.is_none() {
                ours_sample = Some(pred);
            }
        }
        let (si, se, sv) = (
            stats::summary(&interp),
            stats::summary(&extrap),
            stats::summary(&vpt),
        );
        println!(
            "{:<22} {:>10.3} {:>8.3} {:>10.3} {:>8.3} {:>9.2}",
            name, si.mean, si.std, se.mean, se.std, sv.mean
        );
    }
    println!(
        "(paper: ours 0.512 interp / 0.321 extrap; LSTM/GRU/RNN larger; \
         valid across ~7 Lyapunov times)"
    );

    // ---- Fig. 4d-f: phase error profile of our system -------------------
    if let Some(pred) = &ours_sample {
        println!("\n== Fig. 4d: error over time (ours, dim-averaged L1) ==");
        let window = 200; // 4 s buckets
        for start in (0..pred.len()).step_by(window) {
            let end = (start + window).min(pred.len());
            let e = mean_l1_multi(&pred[start..end], &truth[start..end]);
            let phase = if start < l96::TRAIN_POINTS { "interp" } else { "extrap" };
            println!(
                "  {:>5.1}-{:>5.1} s [{}]: L1 {:>7.3} {}",
                start as f64 * l96::DT,
                end as f64 * l96::DT,
                phase,
                e,
                "#".repeat((e * 40.0).min(60.0) as usize)
            );
        }
    }

    // ---- Fig. 4j: noise robustness grid ----------------------------------
    if args.get_bool("noise-grid") {
        println!(
            "\n== Fig. 4j: extrapolation L1 under read x programming noise \
             ({} reps) ==",
            reps
        );
        print!("{:>12}", "read\\prog");
        for p in FIG4J_PROG_LEVELS {
            print!("{:>9.0}%", p * 100.0);
        }
        println!();
        for read in FIG4J_READ_LEVELS {
            print!("{:>11.0}%", read * 100.0);
            for prog in FIG4J_PROG_LEVELS {
                let mut errs = Vec::new();
                for r in 0..reps {
                    let mut twin = Lorenz96Twin::analog(
                        &weights.l96_node,
                        &device,
                        AnalogNoise { read, prog },
                        seed + r * 5000 + (read * 1e4) as u64 * 17
                            + (prog * 1e4) as u64 * 31,
                    );
                    let pred =
                        twin.simulate(&l96::Y0, steps)?.to_nested();
                    let (_, e) = split_l1(&pred, &truth);
                    errs.push(e);
                }
                print!("{:>10.3}", stats::summary(&errs).mean);
            }
            println!();
        }
        println!(
            "(paper: read noise is benign — 2 % read / 0 % prog gave L1 \
             0.317 vs 0.322 noise-free)"
        );
    }
    Ok(())
}
