//! End-to-end serving driver: the L3 coordinator under batched client load.
//!
//! Starts the PJRT runtime (if artifacts exist), registers every twin
//! route, then drives concurrent clients against a mix of routes and
//! reports accepted/completed counts, latency percentiles and throughput —
//! the serving-side view of the paper's system.
//!
//! Run: `cargo run --release --example serve [-- --requests 128 --clients 4]`

use std::sync::Arc;

use memode::config::SystemConfig;
use memode::coordinator::service::Coordinator;
use memode::runtime::service::PjrtService;
use memode::twin::setup::{build_registry, TrainedWeights};
use memode::twin::TwinRequest;
use memode::util::cli::Args;
use memode::workload::stimuli::Waveform;

fn main() -> anyhow::Result<()> {
    let args = Args::new("serve", "coordinator under batched load")
        .opt("requests", "128", "requests per client")
        .opt("clients", "4", "concurrent client threads")
        .opt("steps", "100", "samples per request")
        .flag("no-pjrt", "skip the PJRT runtime even if artifacts exist")
        .parse_env();
    let n_req = args.get_usize("requests");
    let n_clients = args.get_usize("clients");
    let steps = args.get_usize("steps");

    let cfg = SystemConfig::default();
    let weights = TrainedWeights::load(&cfg)?;
    let pjrt = if args.get_bool("no-pjrt") {
        None
    } else {
        match PjrtService::start(&cfg.artifacts_dir) {
            Ok(svc) => {
                svc.handle().preload(&["l96_step_b1", "l96_rollout"])?;
                Some(svc)
            }
            Err(e) => {
                eprintln!("pjrt unavailable ({e}); continuing without");
                None
            }
        }
    };
    let reg =
        build_registry(&cfg, &weights, pjrt.as_ref().map(|s| s.handle()))?;
    println!("routes: {}", reg.keys().join(", "));
    let coord = Arc::new(Coordinator::start(reg, &cfg.serve));

    // Client mix: mostly digital (fast), some analogue and recurrent; HP
    // twins exercise the driven path.
    let mix = [
        "lorenz96/digital",
        "lorenz96/digital",
        "lorenz96/lstm",
        "lorenz96/gru",
        "hp/digital",
        "hp/resnet",
    ];
    let t0 = std::time::Instant::now();
    let mut clients = Vec::new();
    for c in 0..n_clients {
        let coord = Arc::clone(&coord);
        clients.push(std::thread::spawn(move || {
            let mut ok = 0usize;
            let mut shed = 0usize;
            for k in 0..n_req {
                let route = mix[(c + k) % mix.len()];
                let req = if route.starts_with("hp/") {
                    TwinRequest::driven(
                        vec![],
                        steps,
                        Waveform::sine(1.0, 4.0),
                    )
                } else {
                    TwinRequest::autonomous(vec![], steps)
                };
                match coord.submit(route, req) {
                    Ok(pending) => {
                        if pending
                            .wait()
                            .map(|r| r.result.is_ok())
                            .unwrap_or(false)
                        {
                            ok += 1;
                        }
                    }
                    Err(_) => shed += 1,
                }
            }
            (ok, shed)
        }));
    }
    let mut total_ok = 0;
    let mut total_shed = 0;
    for c in clients {
        let (ok, shed) = c.join().expect("client thread");
        total_ok += ok;
        total_shed += shed;
    }
    let wall = t0.elapsed().as_secs_f64();
    let issued = n_clients * n_req;
    println!(
        "\n{} clients x {} requests ({} samples each):\n\
         \x20 completed {total_ok}/{issued} (shed {total_shed}) in {wall:.2} s \
         -> {:.1} req/s",
        n_clients,
        n_req,
        steps,
        total_ok as f64 / wall
    );
    println!("telemetry: {}", coord.stats());
    Ok(())
}
