//! Fig. 3 reproduction: the experimental digital twin of the HP memristor.
//!
//! * Fig. 3c-e — deployment statistics of the three analogue arrays
//!   (2x14, 14x14, 14x1 + bias rows);
//! * Fig. 3f/i — waveform tracking under the four stimuli (prints MRE per
//!   stimulus and the I-V Lissajous extrema);
//! * Fig. 3j  — modelling error of our system vs the recurrent-ResNet
//!   digital twin (MRE + normalized DTW, averaged over the stimuli).
//!
//! Run: `cargo run --release --example hp_twin [-- --reps 3 --steps 500]`

use memode::analog::system::AnalogNoise;
use memode::config::SystemConfig;
use memode::device::hp;
use memode::metrics::dtw::dtw_normalized;
use memode::metrics::mre::mre;
use memode::twin::hp::HpTwin;
use memode::twin::setup::TrainedWeights;
use memode::util::cli::Args;
use memode::util::stats;
use memode::workload::stimuli::Waveform;

fn main() -> anyhow::Result<()> {
    let args = Args::new("hp_twin", "Fig. 3 reproduction")
        .opt("steps", "500", "trajectory samples (paper: 500)")
        .opt("reps", "3", "repetitions per stimulus (analog re-deploys)")
        .opt("seed", "42", "base seed")
        .parse_env();
    let steps = args.get_usize("steps");
    let reps = args.get_u64("reps");
    let seed = args.get_u64("seed");

    let cfg = SystemConfig::default();
    let weights = TrainedWeights::load(&cfg)?;

    // ---- Fig. 3c-e: deployment statistics -------------------------------
    println!("== Fig. 3c-e: analogue deployment of the 3-layer field ==");
    {
        use memode::analog::system::{AnalogMlp, LayerWeights};
        let layers: Vec<LayerWeights> = weights
            .hp_node
            .layers
            .iter()
            .map(|(w, b)| LayerWeights::new(w, b))
            .collect();
        let mlp = AnalogMlp::deploy(
            &layers,
            &cfg.device,
            AnalogNoise::hardware(),
            seed,
        );
        for (l, (w, _)) in weights.hp_node.layers.iter().enumerate() {
            let eff = mlp.layer_weights(l);
            let mut errs = Vec::new();
            // `eff` carries the bias as an extra final row; compare the
            // weight rows only, index-aligned.
            let w_max = w
                .data
                .iter()
                .fold(0.0f64, |m, &x| m.max(x.abs()))
                .max(1e-12);
            for r in 0..w.rows {
                for c in 0..w.cols {
                    errs.push((eff.at(r, c) - w.at(r, c)).abs() / w_max);
                }
            }
            let s = stats::summary(&errs);
            println!(
                "  layer {l} ({}x{}): mean |dW|/Wmax {:.2} %, max {:.2} %",
                w.rows,
                w.cols,
                s.mean * 100.0,
                s.max * 100.0
            );
        }
        println!("  (paper Fig. 3e: ~2.2 % average programming error)\n");
    }

    // ---- Fig. 3f/i/j: waveform tracking ---------------------------------
    println!(
        "== Fig. 3f/j: tracking + error vs recurrent ResNet ({} samples, {} reps) ==",
        steps, reps
    );
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>12}",
        "stimulus", "ours MRE", "ours DTW", "resnet MRE", "resnet DTW"
    );
    let mut ours_mre_all = Vec::new();
    let mut ours_dtw_all = Vec::new();
    let mut res_mre_all = Vec::new();
    let mut res_dtw_all = Vec::new();
    for (name, wave) in Waveform::paper_set() {
        let truth = hp::simulate(&|t| wave.eval(t), steps, hp::DT, hp::H0, 8);
        // Our system: analogue memristive solver, re-deployed per rep.
        let mut ours_mre = Vec::new();
        let mut ours_dtw = Vec::new();
        for r in 0..reps {
            let mut twin = HpTwin::analog(
                &weights.hp_node,
                &cfg.device,
                AnalogNoise::hardware(),
                seed + 1000 * r + 7,
            );
            let h = twin.simulate(&wave, hp::H0, steps)?;
            ours_mre.push(mre(&h, &truth.h));
            ours_dtw.push(dtw_normalized(&h, &truth.h));
        }
        // Baseline: recurrent ResNet on digital hardware (deterministic).
        let mut resnet = HpTwin::resnet(&weights.hp_resnet);
        let hb = resnet.simulate(&wave, hp::H0, steps)?;
        let (rm, rd) = (mre(&hb, &truth.h), dtw_normalized(&hb, &truth.h));
        let (om, od) = (
            stats::summary(&ours_mre).mean,
            stats::summary(&ours_dtw).mean,
        );
        println!(
            "{:<14} {:>12.4} {:>12.4} {:>12.4} {:>12.4}",
            name, om, od, rm, rd
        );
        ours_mre_all.push(om);
        ours_dtw_all.push(od);
        res_mre_all.push(rm);
        res_dtw_all.push(rd);

        // Fig. 3i flavour: Lissajous extrema of the I-V loop.
        if name == "sine" {
            let i_max = truth
                .i
                .iter()
                .fold(0.0f64, |m, &x| m.max(x.abs()));
            println!(
                "    (Fig. 3i: |I|max {:.2} mA, state swing {:.2}..{:.2})",
                i_max * 1e3,
                truth.h.iter().cloned().fold(f64::INFINITY, f64::min),
                truth.h.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            );
        }
    }
    let mean = |v: &[f64]| stats::summary(v).mean;
    println!(
        "\nFig. 3j summary (mean over stimuli):\n\
         \x20 ours   MRE {:.3} DTW {:.3}   (paper: 0.17 / 0.15)\n\
         \x20 resnet MRE {:.3} DTW {:.3}   (paper: 0.61 / 0.39)",
        mean(&ours_mre_all),
        mean(&ours_dtw_all),
        mean(&res_mre_all),
        mean(&res_dtw_all)
    );
    Ok(())
}
