//! Tier-1 smoke run of the batch-throughput benchmark.
//!
//! Writes `BENCH_batch_throughput.json` (mode "smoke") at the repository
//! root so the perf trajectory is tracked by every full test run, not only
//! by explicit `cargo bench` invocations. The release-mode bench binary
//! (`cargo bench --bench batch_throughput`) overwrites the document with
//! higher-fidelity numbers and more batch sizes; CI uploads it as an
//! artifact.
//!
//! Kept to a single `#[test]` so the timing loop never shares the process
//! with concurrently running tests. Crate-level `opt-level = 2`
//! (`[profile.dev]` in Cargo.toml) keeps these debug-profile timings
//! representative of release behaviour.

use std::time::Duration;

use memode::twin::throughput::{
    default_baseline_path, default_json_path, measure, write_json, ROUTES,
};
use memode::util::bench::Bencher;

#[test]
fn throughput_smoke_writes_tracked_bench_json() {
    let bench = Bencher {
        min_iters: 3,
        target_time: Duration::from_millis(50),
        warmup: Duration::from_millis(10),
    };
    let batch_sizes = [1usize, 8, 32];
    let n_points = 12;
    let entries = measure(&batch_sizes, n_points, &bench);
    assert_eq!(entries.len(), ROUTES.len() * batch_sizes.len());
    for e in &entries {
        assert!(
            e.serial_ns_per_step > 0.0 && e.batched_ns_per_step > 0.0,
            "{} B={} produced no timing",
            e.route,
            e.batch
        );
    }
    // Regression tripwire: the analogue routes amortise device reads and
    // the variance GEMM across the batch, so batching should win at B=32.
    // The tracked acceptance line — hp/analog >= 1.5x — lives in the JSON
    // (and in the release quick-bench CI job); here we only hard-fail on a
    // catastrophic inversion (batched several times *slower* than serial),
    // which indicates a real defect rather than scheduler jitter — a tight
    // wall-clock bound in the regular test suite would turn loaded CI
    // machines into spurious red builds.
    for route in ["hp/analog", "l96/analog"] {
        let e = entries
            .iter()
            .find(|e| e.route == route && e.batch == 32)
            .unwrap();
        assert!(
            e.speedup > 0.5,
            "{route} B=32 batched path catastrophically regressed: {:.2}x \
             (serial {:.0} ns/step vs batched {:.0} ns/step)",
            e.speedup,
            e.serial_ns_per_step,
            e.batched_ns_per_step
        );
        if e.speedup < 1.5 {
            eprintln!(
                "warning: {route} B=32 speedup {:.2}x below the 1.5x \
                 acceptance target (see BENCH_batch_throughput.json)",
                e.speedup
            );
        }
    }
    let path = default_json_path();
    write_json(&path, "smoke", &entries).expect("write benchmark json");
    assert!(path.exists(), "benchmark json not written");
    // Seeding aid for the bench-regression gate (ROADMAP open item: an
    // unseeded baseline passes vacuously). Opt-in via
    // BENCH_SEED_BASELINE=1 — never on a plain `cargo test`, which would
    // dirty the tracked baseline with whatever-machine-this-is timings;
    // run on a quiet machine (release `bench_gate -- --update` remains
    // the higher-fidelity path), inspect the numbers, commit. A seeded
    // baseline is never overwritten here.
    if std::env::var("BENCH_SEED_BASELINE").as_deref() == Ok("1") {
        let baseline = default_baseline_path();
        let unseeded = match memode::util::json::from_file(&baseline) {
            Ok(doc) => match doc.get("entries").and_then(|e| e.as_arr()) {
                Some(rows) => rows.is_empty(),
                None => true,
            },
            Err(_) => true,
        };
        if unseeded {
            write_json(&baseline, "seeded-by-smoke", &entries)
                .expect("seed bench baseline");
            println!(
                "seeded bench-regression baseline at {} (was unseeded)",
                baseline.display()
            );
        }
    }
    let doc = memode::util::json::from_file(&path).unwrap();
    assert_eq!(doc.get("bench").unwrap().as_str(), Some("batch_throughput"));
    let hp32 = doc
        .get("entries")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|e| {
            e.get("route").and_then(|r| r.as_str()) == Some("hp/analog")
                && e.get("batch").and_then(|b| b.as_f64()) == Some(32.0)
        })
        .expect("hp/analog B=32 entry present");
    println!(
        "hp/analog B=32 speedup (smoke): {:.2}x",
        hp32.get("speedup").unwrap().as_f64().unwrap()
    );
}
