//! Steady-state allocation accounting for the batched request path.
//!
//! A counting global allocator wraps `System`; after a few warm-up
//! batches with response recycling (trajectory buffers handed back to the
//! twin's pool), a warm worker's `Twin::run_batch_into` must perform
//! **zero** heap allocations: grouping, stimulus/initial-state staging,
//! solver stage scratch, drive buffers, the flat lockstep rollout and the
//! per-request response trajectories are all pooled and reused. This is
//! the enforcement half of the perf invariants documented in `lib.rs`.
//!
//! Covered: HP and Lorenz96 twins on the Analog (noise-off) and Digital
//! backends, including mixed-`n_points` batches that split into two
//! compatible sub-batch groups, plus the *serial tile-sharded* analogue
//! path (states wider than one 32x32 array, per-shard column reads) —
//! sharding must not cost steady-state allocations. The parallel
//! shard-worker fan-out is excluded by design: it spawns rollout-scoped
//! threads (see `twin::shard`). A final section pins the GEMM kernel
//! dispatch layer (`util::kernel`): warm auto-dispatched `Mat` batched
//! products allocate nothing, and the explicit multicore path's per-call
//! spawn cost never grows with reuse.
//!
//! Deliberately a single `#[test]`: the counter is process-global, so no
//! other test may run (and allocate) concurrently in this binary.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use memode::analog::system::AnalogNoise;
use memode::device::taox::DeviceConfig;
use memode::models::loader::MlpWeights;
use memode::twin::hp::HpTwin;
use memode::twin::lorenz96::Lorenz96Twin;
use memode::twin::{Twin, TwinRequest, TwinResponse};
use memode::util::tensor::Mat;
use memode::workload::stimuli::Waveform;

// ---------------------------------------------------------------------------
// Counting allocator
// ---------------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

impl CountingAlloc {
    fn record() {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        Self::record();
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        Self::record();
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new: usize) -> *mut u8 {
        // A grow counts: the hot path must not re-grow warm buffers.
        Self::record();
        System.realloc(p, l, new)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Count allocations performed by `f`.
fn count_allocs(f: impl FnOnce()) -> u64 {
    ALLOCS.store(0, Ordering::SeqCst);
    ENABLED.store(true, Ordering::SeqCst);
    f();
    ENABLED.store(false, Ordering::SeqCst);
    ALLOCS.load(Ordering::SeqCst)
}

// ---------------------------------------------------------------------------
// Fixtures (exact-ReLU toy fields, deterministic)
// ---------------------------------------------------------------------------

fn quiet_device() -> DeviceConfig {
    DeviceConfig {
        fault_rate: 0.0,
        pulse_sigma: 0.0,
        read_noise: 0.0,
        ..Default::default()
    }
}

/// f(h) = -h element-wise for dimension d (the shared exact-ReLU decay
/// fixture).
fn l96_toy_weights(d: usize) -> MlpWeights {
    memode::models::loader::decay_mlp_weights(d)
}

/// f([v; h]) = 2v - h, exact via paired ReLUs (the HP toy field).
fn hp_toy_weights() -> MlpWeights {
    let w1 = Mat::from_vec(
        2,
        4,
        vec![2.0, -2.0, 0.0, 0.0, 0.0, 0.0, 1.0, -1.0],
    );
    let b1 = vec![0.0; 4];
    let w2 = Mat::from_vec(4, 1, vec![1.0, -1.0, -1.0, 1.0]);
    let b2 = vec![0.0];
    MlpWeights {
        layers: vec![(w1, b1), (w2, b2)],
        dt: 1e-3,
        kind: "node".into(),
        task: "hp".into(),
    }
}

/// Mixed-length L96 batch (splits into two compatible groups).
fn l96_requests() -> Vec<TwinRequest> {
    vec![
        TwinRequest::autonomous(vec![1.0, -0.5, 0.25], 10),
        TwinRequest::autonomous(vec![0.2, 0.1, -0.4], 16),
        TwinRequest::autonomous(vec![-1.0, 0.7, 0.0], 10),
        TwinRequest::autonomous(vec![0.6, -0.1, 0.3], 16),
        TwinRequest::autonomous(vec![0.05, 0.9, -0.8], 10),
    ]
}

/// Mixed-length driven HP batch.
fn hp_requests() -> Vec<TwinRequest> {
    vec![
        TwinRequest::driven(vec![0.3], 12, Waveform::sine(1.0, 4.0)),
        TwinRequest::driven(vec![0.5], 8, Waveform::triangular(1.0, 4.0)),
        TwinRequest::driven(vec![0.2], 12, Waveform::rectangular(1.0, 4.0)),
        TwinRequest::driven(
            vec![0.7],
            12,
            Waveform::modulated(1.0, 4.0, 1.0),
        ),
    ]
}

// ---------------------------------------------------------------------------
// The steady-state contract
// ---------------------------------------------------------------------------

/// Run `run_batch_into` to steady state (warm-up cycles with recycling),
/// then assert one more warm batch performs zero heap allocations.
fn assert_zero_alloc_steady_state<T: Twin>(
    name: &str,
    twin: &mut T,
    reqs: &[TwinRequest],
    recycle: impl Fn(&mut T, TwinResponse),
) {
    let mut out: Vec<anyhow::Result<TwinResponse>> =
        Vec::with_capacity(reqs.len());
    // Warm-up: pool buffers rotate deterministically (LIFO free list,
    // fixed group order), so capacities reach a fixed point within a few
    // cycles; five is comfortably past it.
    for cycle in 0..5 {
        out.clear();
        twin.run_batch_into(reqs, &mut out);
        assert_eq!(out.len(), reqs.len(), "{name}: arity (cycle {cycle})");
        for r in out.drain(..) {
            let resp = r.expect("warm-up request failed");
            recycle(twin, resp);
        }
    }
    // Measured warm batch.
    let n = count_allocs(|| {
        twin.run_batch_into(reqs, &mut out);
    });
    // Recycle outside the measured window, then verify the results were
    // real (all Ok, right arity) so a silently failing path can't pass.
    assert_eq!(out.len(), reqs.len(), "{name}: measured arity");
    for r in out.drain(..) {
        let resp = r.expect("measured request failed");
        assert!(!resp.trajectory.is_empty(), "{name}: empty trajectory");
        recycle(twin, resp);
    }
    assert_eq!(
        n, 0,
        "{name}: warm run_batch performed {n} heap allocations \
         (steady state must be allocation-free)"
    );
}

#[test]
fn warm_run_batch_performs_zero_heap_allocations() {
    // Lorenz96, digital RK4 backend.
    let mut twin = Lorenz96Twin::digital(&l96_toy_weights(3));
    assert_zero_alloc_steady_state(
        "l96/digital",
        &mut twin,
        &l96_requests(),
        |t, resp| t.recycle(resp),
    );

    // Lorenz96, analogue backend (noise off: deterministic device path).
    let mut twin = Lorenz96Twin::analog(
        &l96_toy_weights(3),
        &quiet_device(),
        AnalogNoise::off(),
        7,
    );
    assert_zero_alloc_steady_state(
        "l96/analog",
        &mut twin,
        &l96_requests(),
        |t, resp| t.recycle(resp),
    );

    // Lorenz96, analogue backend with the serial tile-sharded kernel: a
    // d = 34 state spans two physical tile column-groups; the warm
    // sharded path must stay allocation-free too.
    let mut twin = memode::twin::lorenz96::Lorenz96Twin::analog_opts(
        &l96_toy_weights(34),
        &quiet_device(),
        AnalogNoise::off(),
        7,
        memode::twin::lorenz96::L96AnalogOpts {
            substeps: 2,
            shards: 2,
            parallel: false,
        },
    );
    let wide_reqs: Vec<TwinRequest> = (0..4)
        .map(|k| {
            TwinRequest::autonomous(
                (0..34)
                    .map(|i| ((i + k) as f64 * 0.21).sin() * 0.5)
                    .collect(),
                if k % 2 == 0 { 6 } else { 9 },
            )
        })
        .collect();
    assert_zero_alloc_steady_state(
        "l96/analog-sharded(serial)",
        &mut twin,
        &wide_reqs,
        |t, resp| t.recycle(resp),
    );

    // Lorenz96 analogue backend serving Monte-Carlo ensembles: the lane
    // expansion, the Welford mean/std accumulator, the percentile
    // envelopes, the member trajectories and the stats container shells
    // must all come from pooled/reused scratch once warm.
    let mut twin = Lorenz96Twin::analog(
        &l96_toy_weights(3),
        &quiet_device(),
        AnalogNoise::off(),
        7,
    );
    let ens_reqs = vec![
        TwinRequest::autonomous(vec![0.4, -0.2, 0.1], 10).with_ensemble(
            memode::twin::EnsembleSpec::new(8)
                .with_percentiles(vec![10.0, 90.0])
                .with_member_trajectories(),
        ),
        TwinRequest::autonomous(vec![1.0, -0.5, 0.25], 10),
        TwinRequest::autonomous(vec![0.2, 0.1, -0.4], 16).with_ensemble(
            memode::twin::EnsembleSpec::new(4),
        ),
        TwinRequest::autonomous(vec![-1.0, 0.7, 0.0], 16),
    ];
    assert_zero_alloc_steady_state(
        "l96/analog-ensemble",
        &mut twin,
        &ens_reqs,
        |t, resp| t.recycle(resp),
    );

    // HP, digital RK4 backend (driven: per-trajectory stimulus closures).
    let mut twin = HpTwin::digital(&hp_toy_weights());
    assert_zero_alloc_steady_state(
        "hp/digital",
        &mut twin,
        &hp_requests(),
        |t, resp| t.recycle(resp),
    );

    // HP, analogue backend.
    let mut twin = HpTwin::analog(
        &hp_toy_weights(),
        &quiet_device(),
        AnalogNoise::off(),
        3,
    );
    assert_zero_alloc_steady_state(
        "hp/analog",
        &mut twin,
        &hp_requests(),
        |t, resp| t.recycle(resp),
    );

    // GEMM kernel dispatch (util::kernel): a warm auto-dispatched batched
    // product below the threading threshold must be allocation-free — the
    // MEMODE_KERNEL env parse and AVX2 detection resolve into OnceLocks on
    // the priming call, never on the hot path.
    {
        use memode::util::kernel;

        let m = Mat::from_fn(24, 48, |r, c| ((r * 31 + c * 7) as f64).sin());
        let batch = 16usize;
        let xs: Vec<f64> =
            (0..batch * 24).map(|i| (i as f64 * 0.13).cos()).collect();
        let mut ys = vec![0.0; batch * 48];
        // Priming call: caches kernel choice and thread cap.
        m.vecmat_batch_into(&xs, batch, &mut ys);
        let n = count_allocs(|| {
            m.vecmat_batch_into(&xs, batch, &mut ys);
        });
        assert_eq!(
            n, 0,
            "gemm/auto: warm single-threaded batched GEMM performed {n} \
             heap allocations"
        );

        // Threaded path: spawning scoped workers allocates per call by
        // design (documented outside lib.rs invariant 3). The warm-state
        // contract is that repeat calls don't *grow* — no buffer churn on
        // top of the fixed spawn cost — and bits never change.
        let kind = kernel::active();
        let mut y_mt = vec![0.0; batch * 48];
        m.vecmat_batch_into_with(kind, 2, &xs, batch, &mut y_mt);
        let first = count_allocs(|| {
            m.vecmat_batch_into_with(kind, 2, &xs, batch, &mut y_mt);
        });
        let second = count_allocs(|| {
            m.vecmat_batch_into_with(kind, 2, &xs, batch, &mut y_mt);
        });
        assert!(
            second <= first,
            "gemm/threaded: warm allocations grew across calls \
             ({first} -> {second})"
        );
        let same = ys
            .iter()
            .zip(&y_mt)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "gemm/threaded: output differs from single-thread");
    }
}
