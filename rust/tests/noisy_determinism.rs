//! Noisy determinism: the noise-lane contract end to end.
//!
//! With read noise **on**, a rollout with seed `s` must be bit-identical
//! across every execution form the serving layer can pick: batch sizes
//! B ∈ {1, 8, 32}, shard counts ∈ {1, 2} (serial in-solver sharding and
//! the parallel shard-worker fan-out), and arbitrary batch compositions /
//! orderings. This upgrades the PR-1..3 noise-off bit-identity suite to
//! the noisy guarantee a replayable digital twin needs: batching and
//! sharding are pure performance knobs, never part of the model.
//!
//! Test names carry the `noisy_determinism_` prefix so CI can gate them
//! in release mode with `cargo test --release -- noisy_determinism`.

use memode::analog::system::AnalogNoise;
use memode::device::taox::DeviceConfig;
use memode::models::loader::decay_mlp_weights;
use memode::twin::hp::HpTwin;
use memode::twin::lorenz96::{L96AnalogOpts, Lorenz96Twin};
use memode::twin::throughput::hp_weights;
use memode::twin::{Twin, TwinRequest, TwinResponse};
use memode::util::proptest::{check, gen_permutation, Config};
use memode::util::rng::Pcg64;
use memode::util::tensor::Trajectory;
use memode::workload::stimuli::Waveform;

const DIM: usize = 34;
const N_POINTS: usize = 4;

/// Deterministic deployment with read noise ON (fault/pulse randomness
/// off so the deployed weights depend only on the deploy seed).
fn noisy_twin(shards: usize, parallel: bool) -> Lorenz96Twin {
    let cfg = DeviceConfig {
        fault_rate: 0.0,
        pulse_sigma: 0.0,
        ..Default::default()
    };
    Lorenz96Twin::analog_opts(
        &decay_mlp_weights(DIM),
        &cfg,
        AnalogNoise { read: 0.05, prog: 0.0 },
        7,
        L96AnalogOpts { substeps: 2, shards, parallel },
    )
}

fn seeded_request(k: usize) -> TwinRequest {
    TwinRequest::autonomous(
        (0..DIM)
            .map(|i| ((i as f64) * 0.31 + (k as f64) * 0.77).sin() * 0.6)
            .collect(),
        N_POINTS,
    )
    .with_seed(10_000 + k as u64)
}

fn unwrap_all(results: Vec<anyhow::Result<TwinResponse>>) -> Vec<TwinResponse> {
    results.into_iter().map(|r| r.unwrap()).collect()
}

/// The reference: every seeded request run serially on a fresh
/// monolithic twin.
fn reference(reqs: &[TwinRequest]) -> Vec<Trajectory> {
    let mut twin = noisy_twin(1, false);
    reqs.iter().map(|r| twin.run(r).unwrap().trajectory).collect()
}

#[test]
fn noisy_determinism_across_batch_sizes_shards_and_fanout() {
    let reqs: Vec<TwinRequest> = (0..32).map(seeded_request).collect();
    let want = reference(&reqs);

    for (label, mut twin) in [
        ("monolithic", noisy_twin(1, false)),
        ("serial sharded x2", noisy_twin(2, false)),
        ("parallel fan-out x2", noisy_twin(2, true)),
    ] {
        // B = 1: one run_batch call per request.
        for (k, r) in reqs.iter().enumerate().take(4) {
            let resp = unwrap_all(twin.run_batch(std::slice::from_ref(r)));
            assert_eq!(
                resp[0].trajectory, want[k],
                "{label}: B=1 request {k} diverged"
            );
            assert_eq!(resp[0].seed, r.seed.unwrap(), "{label}: seed echo");
        }
        // B = 8 sub-batches.
        for (c, chunk) in reqs.chunks(8).enumerate() {
            let got = unwrap_all(twin.run_batch(chunk));
            for (j, g) in got.iter().enumerate() {
                assert_eq!(
                    g.trajectory,
                    want[c * 8 + j],
                    "{label}: B=8 chunk {c} request {j} diverged"
                );
            }
        }
        // B = 32, the whole set at once.
        let got = unwrap_all(twin.run_batch(&reqs));
        for (k, g) in got.iter().enumerate() {
            assert_eq!(
                g.trajectory, want[k],
                "{label}: B=32 request {k} diverged"
            );
        }
    }
}

#[test]
fn noisy_determinism_survives_shuffled_batch_composition() {
    // Randomized compositions: any subset, any order, interleaved with
    // differently-seeded strangers — every seeded trajectory must equal
    // its serial reference bit for bit. Exercised on a warm twin so
    // pooled scratch cannot leak between compositions either.
    let reqs: Vec<TwinRequest> = (0..12).map(seeded_request).collect();
    let want = reference(&reqs);
    let twin = std::cell::RefCell::new(noisy_twin(2, false));
    check(
        &Config { cases: 10, seed: 0xd1ce, ..Default::default() },
        |r: &mut Pcg64| {
            let n = 2 + r.below(11) as usize;
            let mut perm = gen_permutation(r, reqs.len());
            perm.truncate(n);
            perm
        },
        |perm: &Vec<usize>| {
            let batch: Vec<TwinRequest> =
                perm.iter().map(|&i| reqs[i].clone()).collect();
            let got = unwrap_all(twin.borrow_mut().run_batch(&batch));
            perm.iter()
                .zip(&got)
                .all(|(&i, g)| g.trajectory == want[i])
        },
    );
}

#[test]
fn noisy_determinism_replays_on_fresh_and_warm_twins() {
    // The replay story: the echoed seed reproduces the rollout on the
    // same warm twin, on a freshly built twin, and through the batched
    // path of a differently-sharded twin.
    let req = seeded_request(3);
    let mut twin = noisy_twin(1, false);
    let first = twin.run(&req).unwrap();
    let replay_req =
        TwinRequest::autonomous(req.h0.clone(), N_POINTS).with_seed(first.seed);
    let warm = twin.run(&replay_req).unwrap();
    assert_eq!(warm.trajectory, first.trajectory, "warm replay diverged");
    let mut fresh = noisy_twin(1, false);
    let again = fresh.run(&replay_req).unwrap();
    assert_eq!(again.trajectory, first.trajectory, "fresh replay diverged");
    let mut fanout = noisy_twin(2, true);
    let sharded = unwrap_all(fanout.run_batch(std::slice::from_ref(&replay_req)));
    assert_eq!(
        sharded[0].trajectory, first.trajectory,
        "fan-out replay diverged"
    );
}

/// Noisy HP twin over the trained-shape synthetic weights; like the
/// Lorenz96 builder above, deployment randomness is off so only the
/// per-request noise lane is stochastic.
fn noisy_hp_twin() -> HpTwin {
    let cfg = DeviceConfig {
        fault_rate: 0.0,
        pulse_sigma: 0.0,
        ..Default::default()
    };
    HpTwin::analog(
        &hp_weights(),
        &cfg,
        AnalogNoise { read: 0.05, prog: 0.0 },
        11,
    )
}

fn seeded_hp_request(k: usize) -> TwinRequest {
    TwinRequest::driven(
        vec![0.1 + 0.05 * k as f64],
        N_POINTS,
        Waveform::sine(1.0, 4.0),
    )
    .with_seed(20_000 + k as u64)
}

#[test]
fn noisy_determinism_hp_driven_routes_through_the_shared_core() {
    // The HP family rides the same generic core as Lorenz96 after the
    // twin-zoo refactor, so seeded noisy *driven* rollouts carry the
    // identical guarantee: serial, warm-batched, fresh-batched and
    // replayed executions are bit-identical.
    let reqs: Vec<TwinRequest> = (0..8).map(seeded_hp_request).collect();
    let mut serial = noisy_hp_twin();
    let want: Vec<Trajectory> =
        reqs.iter().map(|r| serial.run(r).unwrap().trajectory).collect();

    // Batched on the same warm twin.
    let got = unwrap_all(serial.run_batch(&reqs));
    for (k, g) in got.iter().enumerate() {
        assert_eq!(g.trajectory, want[k], "warm batched request {k} diverged");
        assert_eq!(g.seed, reqs[k].seed.unwrap(), "request {k} seed echo");
    }

    // Full batch on a fresh twin (fresh deployment, same deploy seed).
    let got = unwrap_all(noisy_hp_twin().run_batch(&reqs));
    for (k, g) in got.iter().enumerate() {
        assert_eq!(g.trajectory, want[k], "fresh batched request {k} diverged");
    }

    // Single-request replay on a fresh twin.
    let replay = noisy_hp_twin().run(&reqs[3]).unwrap();
    assert_eq!(replay.trajectory, want[3], "fresh replay diverged");

    // And the noise lane is live: a different seed must diverge.
    let other = noisy_hp_twin()
        .run(&seeded_hp_request(3).with_seed(1))
        .unwrap();
    assert_ne!(
        other.trajectory.last(),
        want[3].last(),
        "distinct seeds produced identical noisy HP trajectories"
    );
}

#[test]
fn noisy_determinism_distinct_seeds_distinct_noise() {
    // Sanity check that the noise is real: two seeds from the same
    // initial state must not produce the same trajectory tail.
    let mut twin = noisy_twin(1, false);
    let h0: Vec<f64> = (0..DIM).map(|i| (i as f64 * 0.2).sin()).collect();
    let a = twin
        .run(&TwinRequest::autonomous(h0.clone(), N_POINTS).with_seed(1))
        .unwrap();
    let b = twin
        .run(&TwinRequest::autonomous(h0, N_POINTS).with_seed(2))
        .unwrap();
    assert_ne!(
        a.trajectory.last(),
        b.trajectory.last(),
        "different seeds produced identical noisy trajectories"
    );
}
