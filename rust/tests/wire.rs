//! `docs/PROTOCOL.md` enforcement: the documented wire examples must be
//! exactly what the codec produces, byte for byte, and every JSON block
//! in the document must parse. The doc carries
//! `<!-- wire-example: NAME -->` markers in front of its canonical
//! fenced blocks; this suite re-encodes each named example with the
//! real codec and diffs against the file, so the spec cannot drift from
//! `rust/src/coordinator/wire.rs`.

use memode::coordinator::wire::{
    self, encode_error, encode_frame, encode_request, encode_response,
    ErrorCode, WireRequest, WireResponse,
};
use memode::twin::{EnsembleSpec, TwinRequest, TwinResponse};
use memode::util::json;
use memode::util::tensor::Trajectory;
use memode::workload::stimuli::Waveform;

fn protocol_md() -> String {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../docs/PROTOCOL.md");
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// The fenced block following `<!-- wire-example: name -->`, with the
/// fence lines stripped.
fn example(doc: &str, name: &str) -> String {
    let marker = format!("<!-- wire-example: {name} -->");
    let after = doc
        .split_once(&marker)
        .unwrap_or_else(|| panic!("marker '{marker}' not in PROTOCOL.md"))
        .1;
    let fence_start = after
        .find("```")
        .unwrap_or_else(|| panic!("no fence after marker '{name}'"));
    let body = &after[fence_start..];
    let first_newline = body.find('\n').expect("fence line ends");
    let rest = &body[first_newline + 1..];
    let fence_end = rest
        .find("```")
        .unwrap_or_else(|| panic!("unterminated fence for '{name}'"));
    rest[..fence_end].trim().to_string()
}

#[test]
fn frame_hex_example_matches_the_encoder() {
    let doc = protocol_md();
    let hex: Vec<u8> = example(&doc, "frame-hex")
        .split_whitespace()
        .map(|b| u8::from_str_radix(b, 16).expect("hex byte"))
        .collect();
    assert_eq!(hex, encode_frame("{}"), "frame-hex drifted from the codec");
}

#[test]
fn plain_request_example_is_canonical() {
    let doc = protocol_md();
    let w = WireRequest {
        id: 1,
        route: "lorenz96/digital".into(),
        req: TwinRequest::autonomous(vec![], 32).with_seed(7),
    };
    assert_eq!(example(&doc, "plain-request"), encode_request(&w));
}

#[test]
fn stimulus_request_example_is_canonical() {
    let doc = protocol_md();
    let w = WireRequest {
        id: 3,
        route: "hp/digital".into(),
        req: TwinRequest::driven(
            vec![0.5],
            8,
            Waveform::Sine { amp: 0.5, freq: 2.0, phase: 0.0 },
        )
        .with_seed(11),
    };
    assert_eq!(example(&doc, "stimulus-request"), encode_request(&w));
}

#[test]
fn ensemble_request_example_is_canonical() {
    let doc = protocol_md();
    let w = WireRequest {
        id: 2,
        route: "lorenz96/analog".into(),
        req: TwinRequest::autonomous(vec![], 16)
            .with_seed(99)
            .with_ensemble(
                EnsembleSpec::new(8).with_percentiles(vec![5.0, 95.0]),
            ),
    };
    assert_eq!(example(&doc, "ensemble-request"), encode_request(&w));
}

#[test]
fn ok_response_example_is_canonical() {
    let doc = protocol_md();
    let resp = TwinResponse {
        trajectory: Trajectory::from_nested(&[
            vec![1.0, 2.0],
            vec![3.0, 4.0],
        ]),
        backend: "digital",
        seed: 7,
        ensemble: None,
        degraded: false,
    };
    assert_eq!(
        example(&doc, "ok-response"),
        encode_response(1, &resp, 120, 4200)
    );
}

#[test]
fn error_response_example_is_canonical() {
    let doc = protocol_md();
    assert_eq!(
        example(&doc, "error-response"),
        encode_error(
            Some(9),
            ErrorCode::RejectedOverload,
            "route queue full",
            Some(12345),
        )
    );
}

#[test]
fn documented_requests_decode_and_reencode_identically() {
    let doc = protocol_md();
    for name in ["plain-request", "stimulus-request", "ensemble-request"] {
        let text = example(&doc, name);
        let w = wire::decode_request(text.as_bytes())
            .unwrap_or_else(|e| panic!("decoding '{name}': {}", e.msg));
        assert_eq!(encode_request(&w), text, "round-trip of '{name}'");
    }
}

#[test]
fn documented_responses_decode() {
    let doc = protocol_md();
    match wire::decode_response(example(&doc, "ok-response").as_bytes())
        .expect("ok-response decodes")
    {
        WireResponse::Ok(ok) => {
            assert_eq!(ok.id, 1);
            assert_eq!(ok.seed, 7);
            assert_eq!(ok.trajectory, vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        }
        other => panic!("expected ok, got {other:?}"),
    }
    match wire::decode_response(example(&doc, "error-response").as_bytes())
        .expect("error-response decodes")
    {
        WireResponse::Err(e) => {
            assert_eq!(e.code, ErrorCode::RejectedOverload);
            assert_eq!(e.id, Some(9));
            assert_eq!(e.seed, Some(12345));
        }
        other => panic!("expected error, got {other:?}"),
    }
}

#[test]
fn every_json_block_in_the_doc_parses() {
    let doc = protocol_md();
    let mut rest = doc.as_str();
    let mut blocks = 0;
    while let Some(start) = rest.find("```json") {
        let body = &rest[start + "```json".len()..];
        let end = body.find("```").expect("unterminated json fence");
        let block = body[..end].trim();
        json::parse(block).unwrap_or_else(|e| {
            panic!("json block {} fails to parse: {e}\n{block}", blocks + 1)
        });
        blocks += 1;
        rest = &body[end + 3..];
    }
    assert!(blocks >= 5, "expected >= 5 json examples, found {blocks}");
}
