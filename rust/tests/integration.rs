//! Cross-layer integration tests.
//!
//! These exercise the composition the unit tests cannot: PJRT artifacts vs
//! Rust-native solvers on the *same trained weights*, the analogue solver
//! vs the digital reference, and the full coordinator serving real twins.
//! All tests skip gracefully when `make artifacts` has not run.

use std::path::PathBuf;

use memode::analog::system::AnalogNoise;
use memode::config::SystemConfig;
use memode::coordinator::service::Coordinator;
use memode::device::hp;
use memode::device::taox::DeviceConfig;
use memode::metrics::l1::{l1_error, mean_l1_multi};
use memode::metrics::mre::mre;
use memode::runtime::service::PjrtService;
use memode::twin::hp::HpTwin;
use memode::twin::lorenz96::Lorenz96Twin;
use memode::twin::setup::{build_registry, TrainedWeights};
use memode::twin::TwinRequest;
use memode::workload::lorenz96 as l96;
use memode::workload::stimuli::Waveform;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn config() -> SystemConfig {
    SystemConfig { artifacts_dir: artifacts_dir(), ..Default::default() }
}

fn artifacts_built() -> bool {
    artifacts_dir().join("manifest.json").exists()
        && ["hp_node", "hp_resnet", "l96_node", "l96_rnn", "l96_gru", "l96_lstm"]
            .iter()
            .all(|n| {
                artifacts_dir().join(format!("weights/{n}.json")).exists()
            })
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_built() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

macro_rules! require_pjrt {
    () => {
        if cfg!(not(feature = "pjrt")) {
            eprintln!("skipping: built without the pjrt feature");
            return;
        }
    };
}

// ---------------------------------------------------------------------------
// PJRT vs Rust-native numerics (the central cross-layer contract)
// ---------------------------------------------------------------------------

#[test]
fn pjrt_l96_rollout_matches_rust_rk4() {
    require_artifacts!();
    require_pjrt!();
    let cfg = config();
    let weights = TrainedWeights::load(&cfg).unwrap();
    let svc = PjrtService::start(&cfg.artifacts_dir).unwrap();
    let reg =
        build_registry(&cfg, &weights, Some(svc.handle())).unwrap();

    let mut pjrt_twin = reg.create("lorenz96/pjrt").unwrap();
    let mut rust_twin = reg.create("lorenz96/digital").unwrap();
    let req = TwinRequest::autonomous(vec![], 2400);
    let a = pjrt_twin.run(&req).unwrap();
    let b = rust_twin.run(&req).unwrap();
    assert_eq!(a.trajectory.len(), 2400);
    assert_eq!(b.trajectory.len(), 2400);
    // f32 (PJRT) vs f64 (Rust) on a chaotic system: exact agreement is
    // impossible over 48 s, but the first several hundred steps must track
    // tightly — that proves both execute the same trained field + RK4.
    let horizon = 300;
    let an = a.trajectory.to_nested();
    let bn = b.trajectory.to_nested();
    let d = mean_l1_multi(&an[..horizon], &bn[..horizon]);
    assert!(d < 0.05, "pjrt vs rust divergence {d} over {horizon} steps");
}

#[test]
fn pjrt_hp_rollout_matches_rust_rk4() {
    require_artifacts!();
    require_pjrt!();
    let cfg = config();
    let weights = TrainedWeights::load(&cfg).unwrap();
    let svc = PjrtService::start(&cfg.artifacts_dir).unwrap();
    let reg =
        build_registry(&cfg, &weights, Some(svc.handle())).unwrap();

    let wave = Waveform::sine(1.0, 4.0);
    let mut pjrt_twin = reg.create("hp/pjrt").unwrap();
    let mut rust_twin = reg.create("hp/digital").unwrap();
    let req = TwinRequest::driven(vec![hp::H0], hp::N_POINTS, wave);
    let a = pjrt_twin.run(&req).unwrap();
    let b = rust_twin.run(&req).unwrap();
    let ha: Vec<f64> = a.trajectory.iter().map(|r| r[0]).collect();
    let hb: Vec<f64> = b.trajectory.iter().map(|r| r[0]).collect();
    let d = l1_error(&ha, &hb);
    assert!(d < 1e-3, "pjrt vs rust HP divergence {d}");
}

#[test]
fn pjrt_step_artifacts_consistent_with_rollout() {
    require_artifacts!();
    require_pjrt!();
    let cfg = config();
    let svc = PjrtService::start(&cfg.artifacts_dir).unwrap();
    let h = svc.handle();
    use memode::runtime::TensorF32;
    // One l96 step from Y0 must equal the second row of the rollout.
    let y0: Vec<f64> = l96::Y0.to_vec();
    let step = h
        .execute(
            "l96_step_b1",
            vec![TensorF32::from_f64(vec![6], &y0)],
        )
        .unwrap();
    let roll = h
        .execute(
            "l96_rollout",
            vec![TensorF32::from_f64(vec![6], &y0)],
        )
        .unwrap();
    for k in 0..6 {
        let a = step.data[k];
        let b = roll.data[6 + k]; // row 1
        assert!(
            (a - b).abs() < 1e-5,
            "step vs rollout row1 mismatch at {k}: {a} vs {b}"
        );
    }
    // Batched step: row 0 of a batch of identical states matches b=1.
    let batch: Vec<f64> = (0..32).flat_map(|_| y0.clone()).collect();
    let b32 = h
        .execute(
            "l96_step_b32",
            vec![TensorF32::from_f64(vec![32, 6], &batch)],
        )
        .unwrap();
    for k in 0..6 {
        assert!((b32.data[k] - step.data[k]).abs() < 1e-5);
    }
}

// ---------------------------------------------------------------------------
// Analogue vs digital on trained weights
// ---------------------------------------------------------------------------

#[test]
fn analog_hp_twin_tracks_ground_truth_at_paper_error_level() {
    require_artifacts!();
    let cfg = config();
    let weights = TrainedWeights::load(&cfg).unwrap();
    let wave = Waveform::sine(1.0, 4.0);
    let truth = hp::simulate_default(&|t| wave.eval(t));
    let mut twin = HpTwin::analog(
        &weights.hp_node,
        &cfg.device,
        AnalogNoise::hardware(),
        1234,
    );
    let h = twin.simulate(&wave, hp::H0, hp::N_POINTS).unwrap();
    let err = mre(&h, &truth.h);
    // Paper Fig. 3j: MRE 0.17. Allow headroom for seed variation.
    assert!(err < 0.5, "analog HP MRE {err}");
}

#[test]
fn analog_l96_twin_stays_on_attractor() {
    require_artifacts!();
    let cfg = config();
    let weights = TrainedWeights::load(&cfg).unwrap();
    let device = DeviceConfig { fault_rate: 0.0, ..cfg.device.clone() };
    let mut twin = Lorenz96Twin::analog(
        &weights.l96_node,
        &device,
        AnalogNoise::hardware(),
        77,
    );
    let traj = twin.simulate(&l96::Y0, 2400).unwrap();
    let truth = l96::simulate_normalized(2400);
    let l1 = mean_l1_multi(&traj.to_nested(), &truth);
    // Decorrelated-attractor L1 in normalized units is ~0.5 (the paper's
    // own interp figure); divergence off the attractor would be >> 1.
    assert!(l1 < 1.0, "analog L96 L1 {l1}");
    for row in &traj {
        for &v in row {
            assert!(v.abs() < 4.0, "state left the attractor: {v}");
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator serving real twins end to end
// ---------------------------------------------------------------------------

#[test]
fn coordinator_serves_mixed_routes_with_real_twins() {
    require_artifacts!();
    let cfg = config();
    let weights = TrainedWeights::load(&cfg).unwrap();
    let reg = build_registry(&cfg, &weights, None).unwrap();
    let coord = Coordinator::start(reg, &cfg.serve);

    let mut pending = Vec::new();
    for k in 0..12 {
        let (route, req) = if k % 2 == 0 {
            (
                "lorenz96/digital",
                TwinRequest::autonomous(vec![], 50),
            )
        } else {
            (
                "hp/digital",
                TwinRequest::driven(
                    vec![],
                    50,
                    Waveform::sine(1.0, 4.0),
                ),
            )
        };
        pending.push(coord.submit(route, req).unwrap());
    }
    for p in pending {
        let result = p.wait().unwrap();
        let resp = result.result.unwrap();
        assert_eq!(resp.trajectory.len(), 50);
    }
    let stats = coord.stats();
    assert_eq!(stats.completed, 12);
    assert_eq!(stats.failed, 0);
}

#[test]
fn coordinator_with_pjrt_routes_serves_aot_rollouts() {
    require_artifacts!();
    require_pjrt!();
    let cfg = config();
    let weights = TrainedWeights::load(&cfg).unwrap();
    let svc = PjrtService::start(&cfg.artifacts_dir).unwrap();
    svc.handle().preload(&["l96_rollout"]).unwrap();
    let reg =
        build_registry(&cfg, &weights, Some(svc.handle())).unwrap();
    let coord = Coordinator::start(reg, &cfg.serve);
    // The AOT rollout has a fixed compiled length of 2400.
    let resp = coord
        .call("lorenz96/pjrt", TwinRequest::autonomous(vec![], 2400))
        .unwrap();
    assert_eq!(resp.trajectory.len(), 2400);
    assert_eq!(resp.backend, "pjrt");
}

// ---------------------------------------------------------------------------
// Failure injection
// ---------------------------------------------------------------------------

#[test]
fn wrong_h0_dimension_is_a_job_error_not_a_crash() {
    require_artifacts!();
    let cfg = config();
    let weights = TrainedWeights::load(&cfg).unwrap();
    let reg = build_registry(&cfg, &weights, None).unwrap();
    let coord = Coordinator::start(reg, &cfg.serve);
    let bad = coord.call(
        "lorenz96/digital",
        TwinRequest::autonomous(vec![1.0, 2.0], 10),
    );
    assert!(bad.is_err());
    // The worker survives and serves the next request.
    let good = coord
        .call("lorenz96/digital", TwinRequest::autonomous(vec![], 10))
        .unwrap();
    assert_eq!(good.trajectory.len(), 10);
}

#[test]
fn backpressure_sheds_under_burst_but_completes_admitted() {
    require_artifacts!();
    let cfg = config();
    let weights = TrainedWeights::load(&cfg).unwrap();
    let reg = build_registry(&cfg, &weights, None).unwrap();
    let mut serve = cfg.serve.clone();
    serve.queue_depth = 4;
    let coord = Coordinator::start(reg, &serve);
    let mut admitted = Vec::new();
    let mut shed = 0;
    for _ in 0..32 {
        match coord
            .submit("lorenz96/digital", TwinRequest::autonomous(vec![], 200))
        {
            Ok(p) => admitted.push(p),
            Err(_) => shed += 1,
        }
    }
    assert!(shed > 0, "burst should exceed queue depth 4");
    for p in admitted {
        assert!(p.wait().unwrap().result.is_ok());
    }
}
