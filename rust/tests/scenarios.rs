//! Scenario DSL acceptance.
//!
//! Two halves:
//! * golden diagnostics — the parser's byte spans and rendered
//!   compiler-style output are pinned exactly, so a refactor cannot
//!   silently regress the `--> file:line:col` + caret pointing;
//! * round-trip — every committed `examples/scenarios/*.twin` fixture
//!   parses, builds its request, executes against the synthetic
//!   registry and satisfies its own `expect` assertions.

use memode::twin::scenario::{Scenario, Span};
use memode::twin::setup::build_synthetic_registry;
use memode::twin::Twin;

fn scenarios_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("examples")
        .join("scenarios")
}

#[test]
fn golden_unknown_directive_diagnostic() {
    let src = "twin hp/digital\nsteps 8\nstims sine 1.0 4.0\n";
    let err = Scenario::parse(src).unwrap_err();
    assert_eq!(err.span, Span::new(24, 29));
    assert_eq!(&src[err.span.start..err.span.end], "stims");
    let expected = [
        "error: unknown directive 'stims'",
        "  --> fixtures/bad.twin:3:1",
        "  |",
        "3 | stims sine 1.0 4.0",
        "  | ^^^^^",
    ]
    .join("\n");
    assert_eq!(err.render(src, "fixtures/bad.twin"), expected);
}

#[test]
fn golden_bad_argument_diagnostic_points_mid_line() {
    let src = "twin l96two/digital\nsteps twelve\n";
    let err = Scenario::parse(src).unwrap_err();
    assert_eq!(err.span, Span::new(26, 32));
    assert_eq!(&src[err.span.start..err.span.end], "twelve");
    let expected = [
        "error: expected a non-negative integer, found 'twelve'",
        "  --> bad.twin:2:7",
        "  |",
        "2 | steps twelve",
        "  |       ^^^^^^",
    ]
    .join("\n");
    assert_eq!(err.render(src, "bad.twin"), expected);
}

#[test]
fn golden_percentile_range_diagnostic() {
    let src = "twin a/b\nsteps 4\nensemble 8\npercentiles 10 120\n";
    let err = Scenario::parse(src).unwrap_err();
    assert_eq!(&src[err.span.start..err.span.end], "120");
    assert!(err.message.contains("outside 0..=100"), "{err}");
}

#[test]
fn committed_scenarios_execute_against_the_synthetic_registry() {
    let dir = scenarios_dir();
    let mut paths: Vec<_> = std::fs::read_dir(&dir)
        .expect("examples/scenarios exists")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().and_then(|x| x.to_str()) == Some("twin"))
        .collect();
    paths.sort();
    assert!(
        paths.len() >= 4,
        "expected >= 4 committed scenario fixtures, found {}",
        paths.len()
    );
    let reg = build_synthetic_registry(None);
    for path in paths {
        let name = path.display().to_string();
        let src = std::fs::read_to_string(&path).unwrap();
        let sc = Scenario::parse(&src)
            .unwrap_or_else(|e| panic!("{}", e.render(&src, &name)));
        let mut twin = reg
            .create(&sc.twin)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let resp = twin
            .run(&sc.to_request())
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        let failures = sc.check(&resp);
        assert!(failures.is_empty(), "{name}: {failures:?}");
        // Fixtures pin their seed so reruns are bit-identical; enforce
        // that convention on everything committed.
        assert_eq!(
            resp.seed,
            sc.seed.expect("committed fixtures pin a seed"),
            "{name}: response does not echo the pinned seed"
        );
    }
}

#[test]
fn committed_scenarios_route_to_registered_twins() {
    // Pure parse-level lint (what `memode scenario check` runs in CI):
    // every fixture names a synthetic-registry route.
    let reg = build_synthetic_registry(None);
    for entry in std::fs::read_dir(scenarios_dir()).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|x| x.to_str()) != Some("twin") {
            continue;
        }
        let src = std::fs::read_to_string(&path).unwrap();
        let sc = Scenario::parse(&src).unwrap();
        assert!(
            reg.contains(&sc.twin),
            "{}: route '{}' is not in the synthetic registry",
            path.display(),
            sc.twin
        );
    }
}
