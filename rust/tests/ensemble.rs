//! First-class Monte-Carlo ensembles: the ensemble invariants end to end.
//!
//! The contract (see the ensemble invariants in `lib.rs`): an ensemble
//! request with family seed `s` expands into N noise lanes inside **one**
//! batched rollout, and member `k` is bit-identical to a *standalone*
//! rollout seeded with `ensemble_member_seed(s, k)` — across batch sizes,
//! batch compositions, shard counts (serial in-solver sharding and the
//! parallel fan-out) and lane-capacity group splits. The pooled statistics
//! (mean / std / percentile envelopes) are therefore bit-identical too.
//!
//! Also here: the seed-echo regression test for serial-fallback twins —
//! a seedless request through the default `run_batch` must echo a real,
//! replayable seed, never a fake `0`.
//!
//! Test names carry the `ensemble_determinism_` prefix so CI can gate
//! them in release mode alongside the noisy-determinism suite.

use memode::analog::system::AnalogNoise;
use memode::device::taox::DeviceConfig;
use memode::models::loader::decay_mlp_weights;
use memode::twin::lorenz96::{L96AnalogOpts, Lorenz96Twin};
use memode::twin::{
    ensemble_member_seed, EnsembleSpec, Twin, TwinRequest, TwinResponse,
};
use memode::util::proptest::{check, gen_permutation, Config};
use memode::util::rng::{NoiseLane, Pcg64};
use memode::util::tensor::Trajectory;

const DIM: usize = 34;
const N_POINTS: usize = 4;

/// Deterministic deployment with read noise ON (fault/pulse randomness
/// off so the deployed weights depend only on the deploy seed).
fn noisy_twin(shards: usize, parallel: bool) -> Lorenz96Twin {
    let cfg = DeviceConfig {
        fault_rate: 0.0,
        pulse_sigma: 0.0,
        ..Default::default()
    };
    Lorenz96Twin::analog_opts(
        &decay_mlp_weights(DIM),
        &cfg,
        AnalogNoise { read: 0.05, prog: 0.0 },
        7,
        L96AnalogOpts { substeps: 2, shards, parallel },
    )
}

fn h0_of(k: usize) -> Vec<f64> {
    (0..DIM)
        .map(|i| ((i as f64) * 0.31 + (k as f64) * 0.77).sin() * 0.6)
        .collect()
}

/// Seeded ensemble request `k` with `members` lanes, full stats payload.
fn ens_request(k: usize, members: usize) -> TwinRequest {
    TwinRequest::autonomous(h0_of(k), N_POINTS)
        .with_seed(20_000 + k as u64)
        .with_ensemble(
            EnsembleSpec::new(members)
                .with_percentiles(vec![5.0, 95.0])
                .with_member_trajectories(),
        )
}

/// Seeded plain (non-ensemble) stranger request.
fn plain_request(k: usize) -> TwinRequest {
    TwinRequest::autonomous(h0_of(k), N_POINTS).with_seed(30_000 + k as u64)
}

/// Standalone reference for member `m` of ensemble request `k`: one
/// serial rollout under the derived member seed on a monolithic twin
/// (deployment is deterministic per deploy seed, so instances are
/// interchangeable — `noisy_determinism` pins that separately).
fn member_reference(
    twin: &mut Lorenz96Twin,
    k: usize,
    m: u64,
) -> Trajectory {
    twin.run(
        &TwinRequest::autonomous(h0_of(k), N_POINTS)
            .with_seed(ensemble_member_seed(20_000 + k as u64, m)),
    )
    .unwrap()
    .trajectory
}

fn unwrap_all(
    results: Vec<anyhow::Result<TwinResponse>>,
) -> Vec<TwinResponse> {
    results.into_iter().map(|r| r.unwrap()).collect()
}

#[test]
fn ensemble_determinism_member_bit_identity_across_forms() {
    let members = 8;
    // References: every member of ensembles 0 and 1 as standalone
    // derived-seed rollouts.
    let mut ref_twin = noisy_twin(1, false);
    let refs: Vec<Vec<Trajectory>> = (0..2)
        .map(|k| {
            (0..members as u64)
                .map(|m| member_reference(&mut ref_twin, k, m))
                .collect()
        })
        .collect();

    for (label, mut twin) in [
        ("monolithic", noisy_twin(1, false)),
        ("serial sharded x2", noisy_twin(2, false)),
        ("parallel fan-out x2", noisy_twin(2, true)),
    ] {
        // B = 1: a lone ensemble request is still one batched rollout.
        let got = unwrap_all(
            twin.run_batch(std::slice::from_ref(&ens_request(0, members))),
        );
        let ens = got[0].ensemble.as_ref().expect("ensemble stats");
        assert_eq!(ens.members, members);
        assert_eq!(got[0].seed, 20_000, "{label}: family seed echo");
        for (m, t) in ens.member_trajectories.iter().enumerate() {
            assert_eq!(
                *t, refs[0][m],
                "{label}: B=1 member {m} != standalone derived-seed rollout"
            );
        }
        // B = 8: two ensembles interleaved with six plain strangers.
        let batch: Vec<TwinRequest> = vec![
            plain_request(10),
            ens_request(0, members),
            plain_request(11),
            plain_request(12),
            ens_request(1, members),
            plain_request(13),
            plain_request(14),
            plain_request(15),
        ];
        let got = unwrap_all(twin.run_batch(&batch));
        for (slot, k) in [(1usize, 0usize), (4, 1)] {
            let ens = got[slot].ensemble.as_ref().expect("ensemble stats");
            for (m, t) in ens.member_trajectories.iter().enumerate() {
                assert_eq!(
                    *t, refs[k][m],
                    "{label}: B=8 ensemble {k} member {m} diverged"
                );
            }
            // Response trajectory is the mean.
            assert_eq!(got[slot].trajectory, ens.mean, "{label}: mean echo");
        }
        // Plain batch-mates are untouched by the ensemble expansion.
        let mut solo = noisy_twin(1, false);
        let want_plain = solo.run(&plain_request(10)).unwrap();
        assert_eq!(
            got[0].trajectory, want_plain.trajectory,
            "{label}: plain stranger perturbed by ensemble batch-mates"
        );
    }
}

#[test]
fn ensemble_determinism_stats_invariant_under_shuffle() {
    // Randomized batch compositions on a warm sharded twin: the pooled
    // statistics of each ensemble must be bit-identical to the reference
    // no matter which batch-mates surround it or in what order.
    let members = 6;
    let pool: Vec<TwinRequest> = vec![
        ens_request(0, members),
        plain_request(20),
        ens_request(1, members),
        plain_request(21),
        plain_request(22),
        plain_request(23),
    ];
    let mut reference = noisy_twin(2, false);
    let want: Vec<TwinResponse> = pool
        .iter()
        .map(|r| reference.run(r).unwrap())
        .collect();
    let twin = std::cell::RefCell::new(noisy_twin(2, false));
    check(
        &Config { cases: 10, seed: 0xe75e, ..Default::default() },
        |r: &mut Pcg64| {
            let n = 2 + r.below(pool.len() as u64 - 1) as usize;
            let mut perm = gen_permutation(r, pool.len());
            perm.truncate(n);
            perm
        },
        |perm: &Vec<usize>| {
            let batch: Vec<TwinRequest> =
                perm.iter().map(|&i| pool[i].clone()).collect();
            let got = unwrap_all(twin.borrow_mut().run_batch(&batch));
            perm.iter().zip(&got).all(|(&i, g)| {
                if g.trajectory != want[i].trajectory {
                    return false;
                }
                match (&g.ensemble, &want[i].ensemble) {
                    (None, None) => true,
                    (Some(a), Some(b)) => {
                        a.mean == b.mean
                            && a.std == b.std
                            && a.percentiles == b.percentiles
                            && a.member_trajectories
                                == b.member_trajectories
                    }
                    _ => false,
                }
            })
        },
    );
}

#[test]
fn ensemble_determinism_n32_sharded_with_lane_capacity_splits() {
    // Nine 32-member ensembles = 288 lanes: past MAX_SUB_BATCH_LANES
    // (256) the group planner splits the batch into two rollouts — member
    // identity must survive the split, the shard fan-out, and both.
    let members = 32;
    let batch: Vec<TwinRequest> =
        (0..9).map(|k| ens_request(k % 2, members)).collect();
    let mut twin = noisy_twin(2, true);
    let got = unwrap_all(twin.run_batch(&batch));
    let mut ref_twin = noisy_twin(1, false);
    for (slot, resp) in got.iter().enumerate() {
        let k = slot % 2;
        let ens = resp.ensemble.as_ref().expect("ensemble stats");
        assert_eq!(ens.members, members);
        assert_eq!(ens.nan_samples, 0);
        for m in [0u64, 17, 31] {
            assert_eq!(
                ens.member_trajectories[m as usize],
                member_reference(&mut ref_twin, k, m),
                "request {slot} member {m} diverged across capacity split \
                 + shard fan-out"
            );
        }
    }
    // Identical ensembles produced identical stats regardless of slot.
    let a = got[0].ensemble.as_ref().unwrap();
    let b = got[2].ensemble.as_ref().unwrap();
    assert_eq!(a.mean, b.mean);
    assert_eq!(a.std, b.std);
    assert_eq!(a.percentiles, b.percentiles);
}

#[test]
fn ensemble_determinism_hp_analog_n32() {
    // Acceptance: an N = 32 ensemble on the HP analogue twin returns
    // pooled mean/std/percentiles from one batched rollout, and member k
    // replays standalone under the derived seed.
    use memode::twin::hp::HpTwin;
    use memode::util::tensor::Mat;
    use memode::workload::stimuli::Waveform;

    // f([v; h]) = 2v - h, exact via paired ReLUs (the HP toy field).
    let w1 = Mat::from_vec(
        2,
        4,
        vec![2.0, -2.0, 0.0, 0.0, 0.0, 0.0, 1.0, -1.0],
    );
    let w2 = Mat::from_vec(4, 1, vec![1.0, -1.0, -1.0, 1.0]);
    let weights = memode::models::loader::MlpWeights {
        layers: vec![(w1, vec![0.0; 4]), (w2, vec![0.0])],
        dt: 1e-3,
        kind: "node".into(),
        task: "hp".into(),
    };
    let cfg = DeviceConfig {
        fault_rate: 0.0,
        pulse_sigma: 0.0,
        ..Default::default()
    };
    let noise = AnalogNoise { read: 0.05, prog: 0.0 };
    let mut twin = HpTwin::analog(&weights, &cfg, noise, 3);
    let members = 32;
    let req = TwinRequest::driven(vec![0.4], 6, Waveform::sine(1.0, 4.0))
        .with_seed(808)
        .with_ensemble(
            EnsembleSpec::new(members)
                .with_percentiles(vec![5.0, 95.0])
                .with_member_trajectories(),
        );
    let resp = twin.run(&req).unwrap();
    assert_eq!(resp.seed, 808);
    let ens = resp.ensemble.as_ref().expect("ensemble stats");
    assert_eq!(ens.members, members);
    assert_eq!(ens.mean.len(), 6);
    assert_eq!(ens.std.len(), 6);
    assert_eq!(ens.percentiles.len(), 2);
    assert_eq!(ens.member_trajectories.len(), members);
    assert_eq!(resp.trajectory, ens.mean);
    assert!(ens.std.row(5)[0] > 0.0, "noise produced zero spread");
    for m in [0u64, 13, 31] {
        let mut fresh = HpTwin::analog(&weights, &cfg, noise, 3);
        let standalone = fresh
            .run(
                &TwinRequest::driven(
                    vec![0.4],
                    6,
                    Waveform::sine(1.0, 4.0),
                )
                .with_seed(ensemble_member_seed(808, m)),
            )
            .unwrap();
        assert_eq!(
            ens.member_trajectories[m as usize], standalone.trajectory,
            "hp member {m} != standalone derived-seed rollout"
        );
    }
}

#[test]
fn ensemble_determinism_seed_echo_regression_serial_fallback() {
    // The seed-echo bugfix: a twin on the default serial `run_batch`
    // fallback, with genuinely seed-dependent output. Before the fix the
    // fallback handed `run` a seedless request and the twin echoed a fake
    // 0 — replaying that echoed seed did NOT reproduce the rollout.
    struct LaneEcho;
    impl Twin for LaneEcho {
        fn name(&self) -> &str {
            "lane-echo"
        }
        fn state_dim(&self) -> usize {
            1
        }
        fn dt(&self) -> f64 {
            1.0
        }
        fn default_h0(&self) -> Vec<f64> {
            vec![0.0]
        }
        fn run(
            &mut self,
            req: &TwinRequest,
        ) -> anyhow::Result<TwinResponse> {
            // No seed machinery of its own: output depends on whatever
            // seed arrives, and that seed is echoed verbatim.
            let seed = req.seed.unwrap_or(0);
            let lane = NoiseLane::from_seed(seed);
            let mut t = Trajectory::new(1);
            for i in 0..req.n_points {
                t.push_row(&[lane.normal_at(i as u64)]);
            }
            Ok(TwinResponse {
                trajectory: t,
                backend: "lane-echo",
                seed,
                ensemble: None,
                degraded: false,
            })
        }
    }

    let mut twin = LaneEcho;
    let reqs = vec![
        TwinRequest::autonomous(vec![], 6),
        TwinRequest::autonomous(vec![], 6),
    ];
    let first = unwrap_all(twin.run_batch(&reqs));
    assert_ne!(first[0].seed, 0, "fallback echoed the fake seed 0");
    assert_ne!(
        first[0].seed, first[1].seed,
        "fallback reused one seed for two requests"
    );
    assert_ne!(
        first[0].trajectory, first[1].trajectory,
        "distinct seeds must produce distinct noisy output"
    );
    // Replay: the echoed seed reproduces each rollout bit for bit,
    // through both the batched fallback and a direct `run`.
    for resp in &first {
        let replay = TwinRequest::autonomous(vec![], 6)
            .with_seed(resp.seed);
        let batched =
            unwrap_all(twin.run_batch(std::slice::from_ref(&replay)));
        assert_eq!(batched[0].trajectory, resp.trajectory);
        assert_eq!(batched[0].seed, resp.seed);
        let direct = twin.run(&replay).unwrap();
        assert_eq!(direct.trajectory, resp.trajectory);
    }
}
