//! Batched-vs-serial equivalence: the correctness contract of the batched
//! execution engine.
//!
//! With all stochastic terms off (`NoiseMode::Off` / `AnalogNoise::off`),
//! `Twin::run_batch` must reproduce per-request `Twin::run` trajectories
//! **exactly** (bit-for-bit) — batching is a throughput lever, never an
//! accuracy trade-off. Randomized properties drive mixed batches (varying
//! batch size, `n_points`, initial states, stimuli, invalid requests)
//! through both paths; an integration test drives the real pipeline
//! batcher → scheduler → `run_batch`.

use std::cell::RefCell;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use memode::analog::system::AnalogNoise;
use memode::coordinator::batcher::{BatchPolicy, Batcher};
use memode::coordinator::scheduler::Scheduler;
use memode::coordinator::telemetry::Telemetry;
use memode::coordinator::Job;
use memode::device::taox::DeviceConfig;
use memode::models::loader::MlpWeights;
use memode::twin::hp::HpTwin;
use memode::twin::lorenz96::Lorenz96Twin;
use memode::twin::registry::TwinRegistry;
use memode::twin::{Twin, TwinRequest, TwinResponse};
use memode::util::proptest::{check, Config};
use memode::util::rng::Pcg64;
use memode::util::tensor::Mat;
use memode::workload::stimuli::Waveform;

fn quiet_device() -> DeviceConfig {
    DeviceConfig {
        fault_rate: 0.0,
        pulse_sigma: 0.0,
        read_noise: 0.0,
        ..Default::default()
    }
}

/// f(h) = -h element-wise for dimension d, exact via paired ReLUs.
fn l96_toy_weights(d: usize) -> MlpWeights {
    let mut w1 = Mat::zeros(d, 2 * d);
    for i in 0..d {
        *w1.at_mut(i, 2 * i) = 1.0;
        *w1.at_mut(i, 2 * i + 1) = -1.0;
    }
    let b1 = vec![0.0; 2 * d];
    let mut w2 = Mat::zeros(2 * d, d);
    for i in 0..d {
        *w2.at_mut(2 * i, i) = -1.0;
        *w2.at_mut(2 * i + 1, i) = 1.0;
    }
    let b2 = vec![0.0; d];
    MlpWeights {
        layers: vec![(w1, b1), (w2, b2)],
        dt: 0.02,
        kind: "node".into(),
        task: "l96".into(),
    }
}

/// f([v; h]) = 2v - h, exact via paired ReLUs (the HP toy field).
fn hp_toy_weights() -> MlpWeights {
    let w1 = Mat::from_vec(
        2,
        4,
        vec![2.0, -2.0, 0.0, 0.0, 0.0, 0.0, 1.0, -1.0],
    );
    let b1 = vec![0.0; 4];
    let w2 = Mat::from_vec(4, 1, vec![1.0, -1.0, -1.0, 1.0]);
    let b2 = vec![0.0];
    MlpWeights {
        layers: vec![(w1, b1), (w2, b2)],
        dt: 1e-3,
        kind: "node".into(),
        task: "hp".into(),
    }
}

/// Serial reference vs batched execution on the same twin; errors must
/// align, successes must match bit-for-bit.
fn batch_equals_serial(twin: &mut dyn Twin, reqs: &[TwinRequest]) -> bool {
    let serial: Vec<anyhow::Result<TwinResponse>> =
        reqs.iter().map(|r| twin.run(r)).collect();
    let batched = twin.run_batch(reqs);
    if batched.len() != reqs.len() {
        return false;
    }
    batched.iter().zip(&serial).all(|(b, s)| match (b, s) {
        (Ok(b), Ok(s)) => {
            b.trajectory == s.trajectory && b.backend == s.backend
        }
        (Err(_), Err(_)) => true,
        _ => false,
    })
}

fn gen_l96_requests(rng: &mut Pcg64, dim: usize) -> Vec<TwinRequest> {
    let batch = 1 + rng.below(8) as usize;
    (0..batch)
        .map(|_| {
            let n_points = [5, 11, 23][rng.below(3) as usize];
            // Occasionally a wrong-dimension or empty h0 to exercise the
            // per-request failure isolation (empty -> default dim-6 h0,
            // which mismatches the toy dim-3 twin on both paths).
            let h0 = match rng.below(8) {
                0 => vec![],
                1 => vec![1.0; dim + 1],
                _ => (0..dim).map(|_| rng.uniform_in(-2.0, 2.0)).collect(),
            };
            TwinRequest::autonomous(h0, n_points)
        })
        .collect()
}

#[test]
fn prop_l96_digital_run_batch_reproduces_serial_exactly() {
    let twin = RefCell::new(Lorenz96Twin::digital(&l96_toy_weights(3)));
    check(
        &Config { cases: 48, ..Default::default() },
        |r| gen_l96_requests(r, 3),
        |reqs| batch_equals_serial(&mut *twin.borrow_mut(), reqs),
    );
}

#[test]
fn prop_l96_analog_run_batch_reproduces_serial_exactly() {
    // NoiseMode::Off end to end: deployment is deterministic (quiet
    // device), reads are noise-free, so batched == serial bit-for-bit.
    let twin = RefCell::new(Lorenz96Twin::analog(
        &l96_toy_weights(3),
        &quiet_device(),
        AnalogNoise::off(),
        7,
    ));
    check(
        &Config { cases: 12, ..Default::default() },
        |r| gen_l96_requests(r, 3),
        |reqs| batch_equals_serial(&mut *twin.borrow_mut(), reqs),
    );
}

#[test]
fn prop_analytic_worlds_run_batch_reproduces_serial_exactly() {
    // The closed-form worlds (Kuramoto, two-level Lorenz96) register as
    // bare `DynamicsTwin`s, so this pins the shared core's batched path
    // directly rather than through a wrapper type. Empty h0 falls back
    // to the twin's own default state, which is valid here — only the
    // wrong-dimension requests must fail, and on both paths.
    let kuramoto = RefCell::new(memode::twin::kuramoto::twin());
    check(
        &Config { cases: 12, ..Default::default() },
        |r| gen_l96_requests(r, memode::twin::kuramoto::DIM),
        |reqs| batch_equals_serial(&mut *kuramoto.borrow_mut(), reqs),
    );
    let l96two = RefCell::new(memode::twin::l96two::twin());
    check(
        &Config { cases: 12, ..Default::default() },
        |r| gen_l96_requests(r, memode::twin::l96two::DIM),
        |reqs| batch_equals_serial(&mut *l96two.borrow_mut(), reqs),
    );
}

#[test]
fn prop_hp_run_batch_reproduces_serial_exactly() {
    let waves = [
        Waveform::sine(1.0, 4.0),
        Waveform::triangular(1.0, 4.0),
        Waveform::rectangular(1.0, 4.0),
        Waveform::modulated(1.0, 4.0, 1.0),
    ];
    let gen = move |r: &mut Pcg64| -> Vec<TwinRequest> {
        let batch = 1 + r.below(8) as usize;
        (0..batch)
            .map(|_| {
                let n_points = [8, 20][r.below(2) as usize];
                let h0 = if r.below(6) == 0 {
                    vec![]
                } else {
                    vec![r.uniform_in(0.1, 0.9)]
                };
                if r.below(8) == 0 {
                    // Missing stimulus: must fail alone on both paths.
                    TwinRequest::autonomous(h0, n_points)
                } else {
                    TwinRequest::driven(
                        h0,
                        n_points,
                        waves[r.below(4) as usize],
                    )
                }
            })
            .collect()
    };
    let digital = RefCell::new(HpTwin::digital(&hp_toy_weights()));
    check(
        &Config { cases: 32, ..Default::default() },
        gen,
        |reqs| batch_equals_serial(&mut *digital.borrow_mut(), reqs),
    );
    let analog = RefCell::new(HpTwin::analog(
        &hp_toy_weights(),
        &quiet_device(),
        AnalogNoise::off(),
        3,
    ));
    check(
        &Config { cases: 8, ..Default::default() },
        gen,
        |reqs| batch_equals_serial(&mut *analog.borrow_mut(), reqs),
    );
    let resnet = RefCell::new(HpTwin::resnet(&hp_toy_weights()));
    check(
        &Config { cases: 16, ..Default::default() },
        gen,
        |reqs| batch_equals_serial(&mut *resnet.borrow_mut(), reqs),
    );
}

// ---------------------------------------------------------------------------
// Pipeline integration: batcher -> scheduler -> run_batch
// ---------------------------------------------------------------------------

struct ProbeTwin {
    inner: Lorenz96Twin,
    batch_sizes: Arc<Mutex<Vec<usize>>>,
}

impl Twin for ProbeTwin {
    fn name(&self) -> &str {
        "probe"
    }
    fn state_dim(&self) -> usize {
        self.inner.state_dim()
    }
    fn dt(&self) -> f64 {
        self.inner.dt()
    }
    fn default_h0(&self) -> Vec<f64> {
        self.inner.default_h0()
    }
    fn run(&mut self, req: &TwinRequest) -> anyhow::Result<TwinResponse> {
        self.inner.run(req)
    }
    fn run_batch(
        &mut self,
        reqs: &[TwinRequest],
    ) -> Vec<anyhow::Result<TwinResponse>> {
        self.batch_sizes.lock().unwrap().push(reqs.len());
        self.inner.run_batch(reqs)
    }
}

#[test]
fn batcher_to_scheduler_executes_whole_batch_via_run_batch() {
    let sizes: Arc<Mutex<Vec<usize>>> = Arc::default();
    let mut registry = TwinRegistry::new();
    let s2 = Arc::clone(&sizes);
    registry.register("probe", move || {
        Box::new(ProbeTwin {
            inner: Lorenz96Twin::digital(&l96_toy_weights(3)),
            batch_sizes: Arc::clone(&s2),
        })
    });
    let telemetry = Arc::new(Telemetry::new());
    let scheduler = Scheduler::start(1, registry, Arc::clone(&telemetry));

    // Fill the batcher to max_batch: the 4th push emits the batch.
    let mut batcher = Batcher::new(BatchPolicy::fixed(
        4,
        Duration::from_secs(100),
    ));
    let h0s: Vec<Vec<f64>> = (0..4)
        .map(|k| vec![k as f64 * 0.3 - 0.5, 0.1, -0.2])
        .collect();
    let mut replies = Vec::new();
    let mut emitted = None;
    for (id, h0) in h0s.iter().enumerate() {
        let (tx, rx) = mpsc::channel();
        replies.push(rx);
        let batch = batcher.push(Job {
            id: id as u64,
            route: "probe".into(),
            req: TwinRequest::autonomous(h0.clone(), 15),
            enqueued: Instant::now(),
            reply: tx,
        });
        if let Some(b) = batch {
            emitted = Some(b);
        }
    }
    let batch = emitted.expect("max_batch reached emits the batch");
    assert_eq!(batch.jobs.len(), 4);
    assert_eq!(batcher.pending_jobs(), 0);

    scheduler.dispatch(batch).unwrap();

    // Every job gets its own result, identical to a direct serial run.
    let mut reference = Lorenz96Twin::digital(&l96_toy_weights(3));
    for (rx, h0) in replies.iter().zip(&h0s) {
        let jr = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let resp = jr.result.unwrap();
        let want = reference
            .run(&TwinRequest::autonomous(h0.clone(), 15))
            .unwrap();
        assert_eq!(resp.trajectory, want.trajectory);
    }

    // The whole batch executed as one run_batch call.
    assert_eq!(*sizes.lock().unwrap(), vec![4]);
    let snap = telemetry.snapshot();
    assert_eq!(snap.batches, 1);
    assert_eq!(snap.completed, 4);
    assert!((snap.mean_batch - 4.0).abs() < 1e-9);
}
