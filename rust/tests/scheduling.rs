//! Scheduling-invariance acceptance tests: a seeded request's response
//! bytes are a pure function of the request, never of *how* the
//! coordinator ran it.
//!
//! A mixed stream (plain digital, noisy analogue, analogue ensembles,
//! tile-sharded rollouts) is pushed through a real coordinator under
//! every scheduler configuration this crate ships — work stealing
//! on/off × shard co-scheduling on/off — and under random submission
//! orders (`gen_permutation`). Every response must be bit-identical to
//! the baseline configuration's: trajectories, replay seeds, ensemble
//! means/stds/percentiles. This is the contract that lets the
//! throughput levers (stealing, co-scheduling, adaptive batching)
//! default on in production without a replay-fidelity audit.
//!
//! The suite is cheap in release but deliberately exercises parallel
//! shard workers; CI runs it release-gated (`cargo test --release
//! --test scheduling`).

use std::sync::Arc;

use memode::analog::system::AnalogNoise;
use memode::config::ServeConfig;
use memode::coordinator::service::Coordinator;
use memode::device::taox::DeviceConfig;
use memode::models::loader::decay_mlp_weights;
use memode::twin::lorenz96::{L96AnalogOpts, Lorenz96Twin};
use memode::twin::registry::TwinRegistry;
use memode::twin::{EnsembleSpec, TwinRequest, TwinResponse};
use memode::util::proptest::gen_permutation;
use memode::util::rng::Pcg64;

/// Three routes over the dim-6 decay field: plain digital, noisy
/// analogue, and a tile-sharded analogue whose co-scheduling flag is
/// set explicitly (not via the environment, so parallel tests cannot
/// interfere).
fn registry(coschedule: bool) -> TwinRegistry {
    let mut reg = TwinRegistry::new();
    let w = decay_mlp_weights(6);
    let dev = DeviceConfig {
        fault_rate: 0.0,
        pulse_sigma: 0.0,
        ..Default::default()
    };
    let noise = AnalogNoise { read: 0.02, prog: 0.0 };
    {
        let w = w.clone();
        reg.register("l96/digital", move || {
            Box::new(Lorenz96Twin::digital(&w))
        });
    }
    {
        let w = w.clone();
        let dev = dev.clone();
        reg.register("l96/analog", move || {
            Box::new(Lorenz96Twin::analog(&w, &dev, noise, 21))
        });
    }
    reg.register("l96/sharded", move || {
        let mut twin = Lorenz96Twin::analog_opts(
            &w,
            &dev,
            noise,
            42,
            L96AnalogOpts { substeps: 3, shards: 2, parallel: true },
        );
        twin.set_coschedule(coschedule);
        Box::new(twin)
    });
    reg
}

/// The seeded mixed stream. Every request carries an explicit seed so
/// the router never stamps one (stamped seeds derive from submission
/// ids, which permutations would change).
fn mixed_stream() -> Vec<(&'static str, TwinRequest)> {
    let mut reqs: Vec<(&'static str, TwinRequest)> = Vec::new();
    for (k, n_points) in [4usize, 7, 5].into_iter().enumerate() {
        reqs.push((
            "l96/digital",
            TwinRequest::autonomous(vec![0.3; 6], n_points)
                .with_seed(100 + k as u64),
        ));
    }
    for (k, n_points) in [6usize, 4, 9].into_iter().enumerate() {
        reqs.push((
            "l96/analog",
            TwinRequest::autonomous(vec![0.5; 6], n_points)
                .with_seed(200 + k as u64),
        ));
    }
    reqs.push((
        "l96/analog",
        TwinRequest::autonomous(vec![0.4; 6], 5)
            .with_seed(300)
            .with_ensemble(
                EnsembleSpec::new(3).with_percentiles(vec![50.0]),
            ),
    ));
    reqs.push((
        "l96/analog",
        TwinRequest::autonomous(vec![], 6)
            .with_seed(301)
            .with_ensemble(EnsembleSpec::new(5)),
    ));
    for (k, n_points) in [4usize, 6, 5].into_iter().enumerate() {
        reqs.push((
            "l96/sharded",
            TwinRequest::autonomous(vec![0.2; 6], n_points)
                .with_seed(400 + k as u64),
        ));
    }
    reqs.push((
        "l96/sharded",
        TwinRequest::autonomous(vec![0.6; 6], 6)
            .with_seed(500)
            .with_ensemble(
                EnsembleSpec::new(4).with_percentiles(vec![10.0, 90.0]),
            ),
    ));
    reqs
}

/// Run the whole stream through a coordinator configured with the given
/// scheduler toggles, submitting in `order`; responses come back keyed
/// by the request's original index.
fn run_stream(
    steal: bool,
    coschedule: bool,
    order: &[usize],
    reqs: &[(&'static str, TwinRequest)],
) -> Vec<TwinResponse> {
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 4,
        batch_window_s: 1e-3,
        batch_window_min_s: 1e-3,
        batch_window_max_s: 1e-3,
        steal,
        coschedule,
        queue_depth: 64,
        route_queue_depth: 64,
    };
    let coord = Arc::new(Coordinator::start(registry(coschedule), &cfg));
    let mut pending: Vec<Option<_>> =
        (0..reqs.len()).map(|_| None).collect();
    for &i in order {
        let (route, req) = &reqs[i];
        pending[i] = Some(
            coord
                .try_submit(route, req.clone())
                .expect("depth-64 gate admits the whole stream"),
        );
    }
    pending
        .into_iter()
        .map(|sub| {
            sub.expect("every index submitted")
                .wait()
                .expect("worker reply")
                .result
                .expect("every request in the stream is valid")
        })
        .collect()
}

/// Bit-identity across everything a response carries.
fn assert_identical(a: &TwinResponse, b: &TwinResponse, ctx: &str) {
    assert_eq!(a.seed, b.seed, "{ctx}: seed");
    assert_eq!(a.backend, b.backend, "{ctx}: backend");
    assert_eq!(a.trajectory, b.trajectory, "{ctx}: trajectory");
    match (&a.ensemble, &b.ensemble) {
        (None, None) => {}
        (Some(x), Some(y)) => {
            assert_eq!(x.members, y.members, "{ctx}: members");
            assert_eq!(x.mean, y.mean, "{ctx}: ensemble mean");
            assert_eq!(x.std, y.std, "{ctx}: ensemble std");
            assert_eq!(
                x.percentiles, y.percentiles,
                "{ctx}: percentiles"
            );
            assert_eq!(x.nan_samples, y.nan_samples, "{ctx}: nans");
        }
        _ => panic!("{ctx}: ensemble presence differs"),
    }
}

#[test]
fn responses_are_bit_identical_across_all_scheduler_configs() {
    let reqs = mixed_stream();
    let identity: Vec<usize> = (0..reqs.len()).collect();
    let baseline = run_stream(false, false, &identity, &reqs);
    assert_eq!(baseline.len(), reqs.len());

    let mut rng = Pcg64::new(0x5c4e_d01e, 9);
    let mut orders = vec![identity.clone()];
    orders.push(gen_permutation(&mut rng, reqs.len()));
    orders.push(gen_permutation(&mut rng, reqs.len()));

    for &(steal, coschedule) in
        &[(true, false), (false, true), (true, true), (false, false)]
    {
        for (oi, order) in orders.iter().enumerate() {
            let got = run_stream(steal, coschedule, order, &reqs);
            for (i, (a, b)) in baseline.iter().zip(&got).enumerate() {
                let ctx = format!(
                    "req {i} (steal={steal} coschedule={coschedule} \
                     order {oi})"
                );
                assert_identical(a, b, &ctx);
            }
        }
    }
}

#[test]
fn ensemble_members_replay_standalone_under_coscheduling() {
    // Member k of a co-scheduled ensemble must equal a standalone
    // rollout under ensemble_member_seed(seed, k) — the replay contract
    // cannot depend on the fused execution path.
    use memode::twin::ensemble_member_seed;
    let reqs: Vec<(&'static str, TwinRequest)> = vec![(
        "l96/sharded",
        TwinRequest::autonomous(vec![0.25; 6], 5)
            .with_seed(777)
            .with_ensemble(
                EnsembleSpec::new(3).with_member_trajectories(),
            ),
    )];
    let identity = [0usize];
    let ens = run_stream(false, true, &identity, &reqs);
    let stats = ens[0].ensemble.as_ref().expect("ensemble stats");
    assert_eq!(stats.member_trajectories.len(), 3);
    for (k, member) in stats.member_trajectories.iter().enumerate() {
        let replay: Vec<(&'static str, TwinRequest)> = vec![(
            "l96/sharded",
            TwinRequest::autonomous(vec![0.25; 6], 5)
                .with_seed(ensemble_member_seed(777, k as u64)),
        )];
        let got = run_stream(false, true, &identity, &replay);
        assert_eq!(
            &got[0].trajectory, member,
            "member {k} does not replay standalone"
        );
    }
}
