//! Property-based tests over the coordinator and numerical substrates
//! (randomized invariants via `util::proptest`; no external artifacts
//! needed — these always run).

use memode::crossbar::differential::DifferentialArray;
use memode::crossbar::mapping::WeightMapping;
use memode::crossbar::tiling::TiledMatrix;
use memode::crossbar::vmm::{NoiseMode, VmmEngine};
use memode::device::noise::NoiseSource;
use memode::device::taox::DeviceConfig;
use memode::metrics::dtw::{dtw_distance, dtw_normalized};
use memode::metrics::l1::l1_error;
use memode::metrics::mre::mre;
use memode::ode::func::FnField;
use memode::ode::{dopri5, euler, rk4};
use memode::util::json::{self, Json};
use memode::util::proptest::{check, gen_vec, gen_vec_any_len, Config};
use memode::util::rng::{NoiseLane, Pcg64};
use memode::util::tensor::Mat;

fn quiet_cfg() -> DeviceConfig {
    DeviceConfig {
        read_noise: 0.0,
        fault_rate: 0.0,
        pulse_sigma: 0.0,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// Metrics invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_dtw_identity_and_symmetry() {
    check(
        &Config { cases: 128, ..Default::default() },
        |r| gen_vec_any_len(r, 40, -2.0, 2.0),
        |v| {
            let self_d = dtw_distance(v, v);
            if self_d != 0.0 {
                return false;
            }
            // Symmetry against a shifted copy.
            let w: Vec<f64> = v.iter().map(|x| x + 0.3).collect();
            (dtw_distance(v, &w) - dtw_distance(&w, v)).abs() < 1e-9
        },
    );
}

#[test]
fn prop_dtw_invariant_to_sample_duplication() {
    // Repeating samples (time-warping) must not change the raw DTW cost.
    check(
        &Config { cases: 64, ..Default::default() },
        |r| gen_vec_any_len(r, 20, -1.0, 1.0),
        |v| {
            let mut doubled = Vec::new();
            for &x in v {
                doubled.push(x);
                doubled.push(x);
            }
            (dtw_distance(v, &doubled)).abs() < 1e-9
        },
    );
}

#[test]
fn prop_dtw_bounded_by_pointwise_l1() {
    check(
        &Config { cases: 64, ..Default::default() },
        |r| {
            let n = 5 + r.below(30) as usize;
            (gen_vec(r, n, -2.0, 2.0), gen_vec(r, n, -2.0, 2.0))
        },
        |(a, b)| {
            // DTW finds the optimal warp, so its normalized cost can never
            // exceed the pointwise mean L1 (the diagonal path) times the
            // path-length ratio.
            dtw_normalized(a, b) <= l1_error(a, b) * 0.5 + 1e-12
        },
    );
}

#[test]
fn prop_mre_scale_invariance() {
    check(
        &Config { cases: 128, ..Default::default() },
        |r| {
            let n = 2 + r.below(20) as usize;
            let truth = gen_vec(r, n, 0.5, 3.0);
            let pred = gen_vec(r, n, 0.5, 3.0);
            let scale = r.uniform_in(0.1, 50.0);
            (truth, pred, scale)
        },
        |(truth, pred, s)| {
            let a = mre(pred, truth);
            let ps: Vec<f64> = pred.iter().map(|x| x * s).collect();
            let ts: Vec<f64> = truth.iter().map(|x| x * s).collect();
            (a - mre(&ps, &ts)).abs() < 1e-9
        },
    );
}

// ---------------------------------------------------------------------------
// Crossbar invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_weight_mapping_roundtrip() {
    check(
        &Config { cases: 256, ..Default::default() },
        |r| {
            let w = r.uniform_in(-3.0, 3.0);
            let w_max = r.uniform_in(0.5, 4.0).max(w.abs());
            (w, w_max)
        },
        |&(w, w_max)| {
            let m = WeightMapping::for_weights(
                &Mat::from_vec(1, 1, vec![w_max]),
                &DeviceConfig::default(),
            );
            let (gp, gn) = m.weight_to_pair(w);
            (m.pair_to_weight(gp, gn) - w).abs() < 1e-9
        },
    );
}

#[test]
fn prop_ideal_deploy_preserves_vmm() {
    let cfg = quiet_cfg();
    check(
        &Config { cases: 24, ..Default::default() },
        |r| {
            let rows = 2 + r.below(10) as usize;
            let cols = 1 + r.below(10) as usize;
            let w = Mat::from_fn(rows, cols, |_, _| r.uniform_in(-1.0, 1.0));
            let v = gen_vec(r, rows, -0.3, 0.3);
            let seed = r.next_u64();
            (w, v, seed)
        },
        |(w, v, seed)| {
            let mut rng = Pcg64::seeded(*seed);
            let d = DifferentialArray::deploy(w, &cfg, &mut rng);
            let got = d.vmm_physical(v, &mut rng);
            let want = w.vecmat(v);
            got.iter().zip(&want).all(|(g, e)| (g - e).abs() < 1e-8)
        },
    );
}

#[test]
fn prop_tiled_vmm_equals_dense_product() {
    let cfg = quiet_cfg();
    check(
        &Config { cases: 8, ..Default::default() },
        |r| {
            let rows = 30 + r.below(50) as usize;
            let cols = 30 + r.below(50) as usize;
            let w = Mat::from_fn(rows, cols, |_, _| r.uniform_in(-1.0, 1.0));
            let v = gen_vec(r, rows, -0.2, 0.2);
            let seed = r.next_u64();
            (w, v, seed)
        },
        |(w, v, seed)| {
            let mut rng = Pcg64::seeded(*seed);
            let t = TiledMatrix::deploy(w, &cfg, &mut rng);
            let got = t.vmm_physical(v, &mut rng);
            let want = w.vecmat(v);
            got.iter().zip(&want).all(|(g, e)| (g - e).abs() < 1e-7)
        },
    );
}

#[test]
fn prop_vmm_engine_noise_is_unbiased() {
    let cfg = quiet_cfg();
    check(
        &Config { cases: 8, ..Default::default() },
        |r| {
            let n = 4 + r.below(12) as usize;
            let w = Mat::from_fn(n, n, |_, _| r.uniform_in(-1.0, 1.0));
            let v = gen_vec(r, n, -0.3, 0.3);
            let seed = r.next_u64();
            (w, v, seed)
        },
        |(w, v, seed)| {
            let mut rng = Pcg64::seeded(*seed);
            let arr = DifferentialArray::deploy(w, &cfg, &mut rng);
            let mut noisy = VmmEngine::new(
                &arr,
                NoiseSource::new(0.05),
                NoiseMode::Fast,
            );
            let clean = w.vecmat(v);
            let n_trials = 800;
            let mut acc = vec![0.0; clean.len()];
            let mut lane = NoiseLane::from_seed(*seed);
            for _ in 0..n_trials {
                let y = noisy.vmm(v, &mut lane);
                for (a, yv) in acc.iter_mut().zip(&y) {
                    *a += yv;
                }
            }
            acc.iter().zip(&clean).all(|(a, c)| {
                let mean = a / n_trials as f64;
                // 5 sigma tolerance on the mean estimate.
                (mean - c).abs() < 0.05 * (c.abs() + 0.5)
            })
        },
    );
}

// ---------------------------------------------------------------------------
// Solver invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_rk4_linear_decay_matches_closed_form() {
    check(
        &Config { cases: 64, ..Default::default() },
        |r| (r.uniform_in(0.1, 2.0), r.uniform_in(-2.0, 2.0)),
        |&(lambda, x0)| {
            let mut f = FnField::new(1, move |_t, x: &[f64], o: &mut [f64]| {
                o[0] = -lambda * x[0]
            });
            let traj = rk4::solve(&mut f, &[x0], 0.05, 21, 1);
            let want = x0 * (-lambda).exp();
            (traj[20][0] - want).abs() < 1e-5 * (1.0 + want.abs())
        },
    );
}

#[test]
fn prop_rk4_dominates_euler() {
    check(
        &Config { cases: 32, ..Default::default() },
        |r| (r.uniform_in(0.3, 2.0), r.uniform_in(0.5, 2.0)),
        |&(lambda, x0)| {
            let mut f = FnField::new(1, move |_t, x: &[f64], o: &mut [f64]| {
                o[0] = -lambda * x[0]
            });
            let exact = x0 * (-lambda).exp();
            let r4 = rk4::solve(&mut f, &[x0], 0.25, 5, 1);
            let eu = euler::solve(&mut f, &[x0], 0.25, 5, 1);
            (r4[4][0] - exact).abs() <= (eu[4][0] - exact).abs() + 1e-12
        },
    );
}

#[test]
fn prop_dopri5_matches_rk4_fine_grid() {
    check(
        &Config { cases: 16, ..Default::default() },
        |r| (r.uniform_in(0.2, 1.5), r.uniform_in(-1.0, 1.0)),
        |&(omega, x0)| {
            // Harmonic oscillator with random frequency.
            let mut f1 = FnField::new(2, move |_t, x: &[f64], o: &mut [f64]| {
                o[0] = x[1];
                o[1] = -omega * omega * x[0];
            });
            let mut f2 = FnField::new(2, move |_t, x: &[f64], o: &mut [f64]| {
                o[0] = x[1];
                o[1] = -omega * omega * x[0];
            });
            let t_out = [2.0];
            let (a, _) = dopri5::solve(
                &mut f1,
                &[x0, 0.0],
                0.0,
                2.0,
                &t_out,
                &dopri5::Options {
                    rtol: 1e-8,
                    atol: 1e-10,
                    ..Default::default()
                },
            );
            let b = rk4::solve(&mut f2, &[x0, 0.0], 2.0, 2, 2000);
            (a[0][0] - b[1][0]).abs() < 1e-5
        },
    );
}

// ---------------------------------------------------------------------------
// JSON round-trip
// ---------------------------------------------------------------------------

fn gen_json(rng: &mut Pcg64, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => Json::Num((rng.uniform_in(-1e6, 1e6) * 1e3).round() / 1e3),
        3 => {
            let n = rng.below(8);
            Json::Str(
                (0..n)
                    .map(|_| {
                        char::from_u32(32 + rng.below(90) as u32).unwrap()
                    })
                    .collect(),
            )
        }
        4 => Json::Arr(
            (0..rng.below(4)).map(|_| gen_json(rng, depth - 1)).collect(),
        ),
        _ => Json::Obj(
            (0..rng.below(4))
                .map(|k| (format!("k{k}"), gen_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    check(
        &Config { cases: 512, ..Default::default() },
        |r| gen_json(r, 3),
        |v| {
            let text = v.to_string();
            match json::parse(&text) {
                Ok(back) => back == *v,
                Err(_) => false,
            }
        },
    );
}

// ---------------------------------------------------------------------------
// GEMM kernel dispatch invariants (SIMD / scalar / threaded bit-identity)
// ---------------------------------------------------------------------------

/// Random matrix + batch with deliberate exact zeros sprinkled into the
/// inputs so the zero-input skip path is exercised in every kernel.
fn gen_gemm_case(r: &mut Pcg64) -> (Mat, Vec<f64>, usize) {
    let rows = 1 + r.below(40) as usize;
    // Bias towards tile boundaries (multiples of 32 and +/-1 around them)
    // as well as fully arbitrary widths.
    let cols = match r.below(4) {
        0 => 32,
        1 => 31 + r.below(3) as usize, // 31, 32, 33
        2 => 63 + r.below(3) as usize, // 63, 64, 65
        _ => 1 + r.below(100) as usize,
    };
    let w = Mat::from_fn(rows, cols, |_, _| r.uniform_in(-2.0, 2.0));
    let batch = 1 + r.below(8) as usize;
    let xs: Vec<f64> = (0..batch * rows)
        .map(|_| {
            if r.chance(0.2) {
                0.0
            } else {
                r.uniform_in(-1.5, 1.5)
            }
        })
        .collect();
    (w, xs, batch)
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn prop_simd_kernel_bit_identical_to_scalar() {
    use memode::util::kernel::KernelKind;
    check(
        &Config { cases: 96, ..Default::default() },
        gen_gemm_case,
        |(w, xs, batch)| {
            let mut y_sc = vec![0.0; batch * w.cols];
            let mut y_simd = vec![0.0; batch * w.cols];
            w.vecmat_batch_into_with(KernelKind::Scalar, 1, xs, *batch, &mut y_sc);
            w.vecmat_batch_into_with(KernelKind::Simd, 1, xs, *batch, &mut y_simd);
            bits(&y_sc) == bits(&y_simd)
        },
    );
}

#[test]
fn prop_threaded_split_bit_identical_to_single_thread() {
    use memode::util::kernel::KernelKind;
    check(
        &Config { cases: 48, ..Default::default() },
        |r| {
            let (w, xs, batch) = gen_gemm_case(r);
            // Thread counts beyond the batch are clamped internally;
            // include them on purpose.
            let threads = 2 + r.below(14) as usize;
            let kind = if r.chance(0.5) {
                KernelKind::Scalar
            } else {
                KernelKind::Simd
            };
            (w, xs, batch, threads, kind)
        },
        |(w, xs, batch, threads, kind)| {
            let mut y_one = vec![0.0; batch * w.cols];
            let mut y_mt = vec![0.0; batch * w.cols];
            w.vecmat_batch_into_with(*kind, 1, xs, *batch, &mut y_one);
            w.vecmat_batch_into_with(*kind, *threads, xs, *batch, &mut y_mt);
            bits(&y_one) == bits(&y_mt)
        },
    );
}

#[test]
fn prop_column_shards_kernel_independent() {
    use memode::util::kernel::KernelKind;
    check(
        &Config { cases: 96, ..Default::default() },
        |r| {
            let (w, xs, batch) = gen_gemm_case(r);
            // Random column shard [c0, c1) inside 0..cols.
            let c0 = r.below(w.cols as u64) as usize;
            let c1 = c0 + 1 + r.below((w.cols - c0) as u64) as usize;
            (w, xs, batch, c0, c1)
        },
        |(w, xs, batch, c0, c1)| {
            let width = c1 - c0;
            let mut shard_sc = vec![0.0; batch * width];
            let mut shard_simd = vec![0.0; batch * width];
            w.vecmat_batch_cols_into_with(
                KernelKind::Scalar,
                xs,
                *batch,
                *c0,
                *c1,
                &mut shard_sc,
            );
            w.vecmat_batch_cols_into_with(
                KernelKind::Simd,
                xs,
                *batch,
                *c0,
                *c1,
                &mut shard_simd,
            );
            if bits(&shard_sc) != bits(&shard_simd) {
                return false;
            }
            // Both must equal the corresponding slice of the full-width
            // product (scalar reference) — shard boundaries never shift
            // the accumulation.
            let mut full = vec![0.0; batch * w.cols];
            w.vecmat_batch_into_with(KernelKind::Scalar, 1, xs, *batch, &mut full);
            for b in 0..*batch {
                let want = &full[b * w.cols + c0..b * w.cols + c1];
                let got = &shard_sc[b * width..(b + 1) * width];
                if bits(want) != bits(got) {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_forced_scalar_override_matches_auto_dispatch() {
    use memode::util::kernel::{self, KernelKind};
    // `kernel::active()` resolves MEMODE_KERNEL once per process; whatever
    // it picked, the result must be bit-identical to an explicit scalar
    // call — the override (and auto dispatch) may change speed, never bits.
    check(
        &Config { cases: 48, ..Default::default() },
        gen_gemm_case,
        |(w, xs, batch)| {
            let mut y_auto = vec![0.0; batch * w.cols];
            let mut y_sc = vec![0.0; batch * w.cols];
            w.vecmat_batch_into(xs, *batch, &mut y_auto);
            w.vecmat_batch_into_with(KernelKind::Scalar, 1, xs, *batch, &mut y_sc);
            let _ = kernel::active(); // cached; exercised for coverage
            bits(&y_auto) == bits(&y_sc)
        },
    );
}

// ---------------------------------------------------------------------------
// Coordinator batcher conservation
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_conserves_jobs() {
    use memode::coordinator::batcher::{BatchPolicy, Batcher};
    use memode::coordinator::Job;
    use memode::twin::TwinRequest;
    use std::sync::mpsc;
    use std::time::{Duration, Instant};

    check(
        &Config { cases: 64, ..Default::default() },
        |r| {
            let n = 1 + r.below(64) as usize;
            let max_batch = 1 + r.below(8) as usize;
            let routes: Vec<u64> = (0..n).map(|_| r.below(3)).collect();
            (max_batch, routes)
        },
        |(max_batch, routes)| {
            let mut b = Batcher::new(BatchPolicy::fixed(
                *max_batch,
                Duration::from_secs(100),
            ));
            let mut out_count = 0usize;
            let mut keep_rx = Vec::new();
            for (id, route) in routes.iter().enumerate() {
                let (tx, rx) = mpsc::channel();
                keep_rx.push(rx);
                let job = Job {
                    id: id as u64,
                    route: format!("r{route}"),
                    req: TwinRequest::autonomous(vec![], 1),
                    enqueued: Instant::now(),
                    reply: tx,
                };
                if let Some(batch) = b.push(job) {
                    out_count += batch.jobs.len();
                }
            }
            for batch in b.flush(Instant::now(), true) {
                out_count += batch.jobs.len();
            }
            out_count == routes.len() && b.pending_jobs() == 0
        },
    );
}
