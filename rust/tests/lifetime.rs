//! Device-lifetime integration gates. CI runs this file in release
//! (`cargo test --release --test lifetime`) next to the determinism
//! gates; the suite stays debug-cheap (dim-3 decay system, short probe
//! horizons) so plain `cargo test` covers it too.
//!
//! Quiet device + noise-off deployments throughout: programming is exact
//! and the probe floor is the circuit-vs-RK4 integrator mismatch (pushed
//! far below every threshold by 100 circuit substeps), so each assertion
//! isolates one lifetime mechanism — drift, recalibration, yield faults.

use memode::analog::system::AnalogNoise;
use memode::device::taox::DeviceConfig;
use memode::models::loader::decay_mlp_weights;
use memode::twin::health::{probe_mre, LifetimeConfig, MonitoredTwin};
use memode::twin::lorenz96::Lorenz96Twin;
use memode::twin::{EnsembleSpec, FaultCampaign, Twin, TwinRequest};

fn quiet() -> DeviceConfig {
    DeviceConfig {
        fault_rate: 0.0,
        pulse_sigma: 0.0,
        read_noise: 0.0,
        ..Default::default()
    }
}

fn monitored(cfg: LifetimeConfig) -> MonitoredTwin {
    MonitoredTwin::lorenz96(
        &decay_mlp_weights(3),
        &quiet(),
        AnalogNoise::off(),
        11,
        100,
        cfg,
    )
}

/// Probe error of a fresh deployment aged (in one jump) to `age_s`.
fn aged_probe_error(age_s: f64) -> f64 {
    let w = decay_mlp_weights(3);
    let mut analog =
        Lorenz96Twin::analog_aging(&w, &quiet(), AnalogNoise::off(), 11, 100);
    let mut digital = Lorenz96Twin::digital(&w);
    if age_s > 0.0 {
        analog.advance_age(age_s);
    }
    let req = TwinRequest::autonomous(vec![], 50).with_seed(9);
    probe_mre(
        &analog.run(&req).unwrap().trajectory,
        &digital.run(&req).unwrap().trajectory,
    )
}

#[test]
fn probe_error_grows_with_aging_horizon() {
    let fresh = aged_probe_error(0.0);
    let mid = aged_probe_error(1e6);
    let old = aged_probe_error(1e10);
    // Fresh quiet hardware sits at the integrator floor...
    assert!(fresh < 5e-3, "floor too high: {fresh}");
    // ...and the error climbs with the horizon: log-drift plus the
    // diffusion walk, decades apart so ordering is deterministic in
    // practice despite the per-cell randomness.
    assert!(mid > fresh, "1e6 s of aging inert: {mid} vs {fresh}");
    assert!(old > mid, "1e10 s not worse than 1e6 s: {old} vs {mid}");
}

#[test]
fn recalibration_restores_probe_error_on_a_healthy_array() {
    let mut t = monitored(LifetimeConfig {
        mre_threshold: 0.005,
        probe_points: 50,
        ..Default::default()
    });
    t.advance_age(1e10);
    let after = t.probe_now().unwrap();
    let s = t.lifetime();
    assert!(s.recalibrations >= 1, "drift crossed, nobody recalibrated");
    assert!(s.recal_pulses > 0);
    assert!(s.recal_energy_j > 0.0, "pulses spent but no energy charged");
    assert!(after <= 0.005, "recalibration did not restore MRE: {after}");
    assert!(!s.degraded);
}

#[test]
fn over_faulted_array_exhausts_retries_and_degrades() {
    let mut t = monitored(LifetimeConfig {
        mre_threshold: 1e-6,
        max_retries: 2,
        max_recal_failures: 1,
        backoff_s: 1.0,
        ..Default::default()
    });
    t.inject_stuck_faults(0.6);
    let _ = t.probe_now().unwrap();
    assert!(t.is_degraded(), "stuck-heavy array never gave up");
    let s = t.lifetime();
    assert_eq!(s.recal_failures, 1);
    assert!(s.recalibrations >= 1, "degraded without attempting repair");
    // Graceful degradation: still serving, from the digital reference,
    // and every response says so.
    let r = t.run(&TwinRequest::autonomous(vec![], 5)).unwrap();
    assert!(r.degraded, "degraded response not flagged");
    assert_eq!(r.backend, "digital-rk4");
    assert_eq!(r.trajectory.len(), 5);
}

#[test]
fn fault_campaigns_replay_bit_identically_from_the_seed_pair() {
    let campaign =
        FaultCampaign::new(99).aged(1e8).with_fault_fraction(0.1);
    let req = TwinRequest::autonomous(vec![], 6)
        .with_seed(4242)
        .with_ensemble(EnsembleSpec::new(4).with_fault_campaign(campaign));
    let mut a = monitored(LifetimeConfig::default());
    let mut b = monitored(LifetimeConfig::default());
    let ra = a.run(&req).unwrap();
    let rb = b.run(&req).unwrap();
    assert_eq!(ra.seed, rb.seed, "campaign seed echo not deterministic");
    assert_eq!(
        ra.trajectory, rb.trajectory,
        "campaign not bit-replayable from (request seed, yield seed)"
    );
    let (ea, eb) =
        (ra.ensemble.as_ref().unwrap(), rb.ensemble.as_ref().unwrap());
    assert_eq!(ea.mean, eb.mean);
    assert_eq!(ea.std, eb.std);
    assert_eq!(ea.members, 4);
    assert_eq!(ra.backend, "analog-aged-campaign");
    // A different yield seed samples a different device population
    // (noise is off here, so the yield map is the only random input).
    let other_yield = TwinRequest::autonomous(vec![], 6)
        .with_seed(4242)
        .with_ensemble(EnsembleSpec::new(4).with_fault_campaign(
            FaultCampaign::new(100).aged(1e8).with_fault_fraction(0.1),
        ));
    let rc = a.run(&other_yield).unwrap();
    assert_ne!(
        rc.trajectory, ra.trajectory,
        "yield seed does not reach the sampled hardware"
    );
}
