//! Socket-level integration tests for the network front door: real TCP
//! clients against a real [`NetServer`] over real registries — the
//! acceptance scenarios of the serving layer.
//!
//! * concurrent clients served end to end over the synthetic registry
//!   (plain digital, analogue ensemble, health-monitored aged route);
//! * admission control past the queue bound: typed `rejected_overload`
//!   frames that echo a replay seed, recorded in per-route shed
//!   counters;
//! * per-connection fairness: a greedy pipeliner is throttled at the
//!   connection in-flight cap instead of monopolising the admission
//!   budget, so a polite neighbour is never shed;
//! * graceful drain completing in-flight work;
//! * per-request errors leaving the connection usable.

use std::sync::Arc;
use std::time::Duration;

use memode::config::ServeConfig;
use memode::coordinator::client::WireClient;
use memode::coordinator::net::{NetConfig, NetServer};
use memode::coordinator::service::Coordinator;
use memode::coordinator::wire::{ErrorCode, WireRequest, WireResponse};
use memode::twin::registry::TwinRegistry;
use memode::twin::setup::build_synthetic_registry;
use memode::twin::{EnsembleSpec, Twin, TwinRequest, TwinResponse};
use memode::util::tensor::Trajectory;

/// A deliberately slow single-state twin: holds the one worker busy so
/// pipelined submissions pile into (and overflow) the admission gates.
struct SlowTwin {
    delay: Duration,
}

impl Twin for SlowTwin {
    fn name(&self) -> &str {
        "slow"
    }
    fn state_dim(&self) -> usize {
        1
    }
    fn dt(&self) -> f64 {
        0.1
    }
    fn default_h0(&self) -> Vec<f64> {
        vec![0.0]
    }
    fn run(&mut self, req: &TwinRequest) -> anyhow::Result<TwinResponse> {
        std::thread::sleep(self.delay);
        Ok(TwinResponse {
            trajectory: Trajectory::zeros(1, req.n_points),
            backend: "slow",
            seed: req.seed.unwrap_or(0),
            ensemble: None,
            degraded: false,
        })
    }
}

fn start_slow_server(
    delay: Duration,
    queue_depth: usize,
) -> (Arc<Coordinator>, memode::coordinator::net::NetHandle) {
    let mut reg = TwinRegistry::new();
    reg.register("slow", move || Box::new(SlowTwin { delay }));
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 1,
        batch_window_s: 1e-4,
        batch_window_min_s: 1e-4,
        batch_window_max_s: 1e-4,
        queue_depth,
        route_queue_depth: queue_depth,
        ..Default::default()
    };
    let coord = Arc::new(Coordinator::start(reg, &cfg));
    let handle = NetServer::start(
        Arc::clone(&coord),
        NetConfig { addr: "127.0.0.1:0".into(), ..NetConfig::default() },
    )
    .expect("server starts");
    (coord, handle)
}

fn plain(id: u64, route: &str, steps: usize) -> WireRequest {
    WireRequest {
        id,
        route: route.into(),
        req: TwinRequest::autonomous(vec![], steps),
    }
}

#[test]
fn concurrent_clients_are_served_across_synthetic_routes() {
    let reg = build_synthetic_registry(None);
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        batch_window_s: 1e-3,
        batch_window_min_s: 1e-3,
        batch_window_max_s: 1e-3,
        queue_depth: 64,
        route_queue_depth: 32,
        ..Default::default()
    };
    let coord = Arc::new(Coordinator::start(reg, &cfg));
    let handle = NetServer::start(
        Arc::clone(&coord),
        NetConfig { addr: "127.0.0.1:0".into(), ..NetConfig::default() },
    )
    .expect("server starts");
    let addr = handle.addr().to_string();

    // Client A: plain digital rollouts. Client B: an analogue ensemble
    // and the health-monitored aged route. Both run concurrently over
    // their own connections.
    let addr_a = addr.clone();
    let a = std::thread::spawn(move || {
        let mut client = WireClient::connect(&addr_a).unwrap();
        for id in 0..4u64 {
            let mut w = plain(id, "lorenz96/digital", 8);
            w.req = w.req.with_seed(1000 + id);
            match client.call(&w).unwrap() {
                WireResponse::Ok(ok) => {
                    assert_eq!(ok.id, id);
                    assert_eq!(ok.seed, 1000 + id);
                    assert_eq!(ok.trajectory.len(), 8);
                    assert_eq!(ok.trajectory[0].len(), 6);
                }
                other => panic!("client A expected ok, got {other:?}"),
            }
        }
    });
    let b = std::thread::spawn(move || {
        let mut client = WireClient::connect(&addr).unwrap();
        let mut w = plain(100, "lorenz96/analog", 6);
        w.req = w
            .req
            .with_seed(7)
            .with_ensemble(EnsembleSpec::new(4).with_percentiles(vec![50.0]));
        match client.call(&w).unwrap() {
            WireResponse::Ok(ok) => {
                let e = ok.ensemble.expect("ensemble stats");
                assert_eq!(e.members, 4);
                assert_eq!(e.mean.len(), 6);
                assert_eq!(e.percentiles.len(), 1);
            }
            other => panic!("ensemble expected ok, got {other:?}"),
        }
        let w = plain(101, "lorenz96/analog-aged", 6);
        match client.call(&w).unwrap() {
            WireResponse::Ok(ok) => {
                assert_eq!(ok.id, 101);
                assert_eq!(ok.trajectory.len(), 6);
                // Server-stamped seed: echoed, replayable.
                assert!(ok.seed != 0);
            }
            other => panic!("aged route expected ok, got {other:?}"),
        }
    });
    a.join().unwrap();
    b.join().unwrap();

    let stats = coord.stats();
    assert!(stats.completed >= 6, "completed {}", stats.completed);
    let net = handle.shutdown();
    assert_eq!(net.connections, 2);
    assert_eq!(net.protocol_errors, 0);
}

#[test]
fn overload_sheds_with_typed_frames_seed_echo_and_counters() {
    let (coord, handle) =
        start_slow_server(Duration::from_millis(150), 2);
    let mut client =
        WireClient::connect(&handle.addr().to_string()).unwrap();

    // Pipeline far past the in-flight bound of 2 without reading, so
    // the admission gate must shed; then collect every response.
    const N: u64 = 10;
    for id in 0..N {
        client.send(&plain(id, "slow", 2)).unwrap();
    }
    let mut ok = 0u64;
    let mut rejected = 0u64;
    for _ in 0..N {
        match client.recv().unwrap() {
            WireResponse::Ok(_) => ok += 1,
            WireResponse::Err(e) => {
                assert_eq!(
                    e.code,
                    ErrorCode::RejectedOverload,
                    "unexpected error: {}",
                    e.message
                );
                // Sheds still echo the pre-admission replay seed.
                assert!(e.seed.is_some(), "shed without seed echo");
                assert!(e.id.is_some());
                rejected += 1;
            }
        }
    }
    assert!(ok >= 1, "nothing completed");
    assert!(rejected >= 1, "nothing was shed past a depth-2 gate");
    assert_eq!(ok + rejected, N);

    // The sheds landed in the per-route admission counters.
    let stats = coord.stats();
    let load = stats
        .route_load
        .iter()
        .find(|(r, _)| r == "slow")
        .map(|(_, l)| l)
        .expect("route counters");
    assert_eq!(load.admitted, ok);
    assert_eq!(load.shed, rejected);
    drop(client);
    let net = handle.shutdown();
    assert_eq!(net.frames_in, N);
    assert_eq!(net.frames_out, N);
    assert_eq!(net.protocol_errors, 0);
}

#[test]
fn greedy_pipeliner_is_capped_so_a_polite_client_is_never_shed() {
    // One slow worker, an admission gate of depth 4, and a per-
    // connection in-flight cap of 2. A greedy client pipelines 12
    // requests without reading; under the old greedy frame drain all 12
    // would hit the admission gate at once (4 admitted, 8 shed) and a
    // polite neighbour would find the gate full. With the fairness cap
    // the greedy connection holds at most 2 jobs in flight — its spare
    // frames wait in the server's read buffer — so the gate always has
    // room: the polite client is served, and even the greedy client
    // eventually gets 12 `ok` responses with zero sheds.
    let mut reg = TwinRegistry::new();
    reg.register("slow", move || {
        Box::new(SlowTwin { delay: Duration::from_millis(30) })
    });
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 1,
        batch_window_s: 1e-4,
        batch_window_min_s: 1e-4,
        batch_window_max_s: 1e-4,
        queue_depth: 4,
        route_queue_depth: 4,
        ..Default::default()
    };
    let coord = Arc::new(Coordinator::start(reg, &cfg));
    let handle = NetServer::start(
        Arc::clone(&coord),
        NetConfig {
            addr: "127.0.0.1:0".into(),
            conn_inflight: 2,
            ..NetConfig::default()
        },
    )
    .expect("server starts");
    let addr = handle.addr().to_string();

    let mut greedy = WireClient::connect(&addr).unwrap();
    const N: u64 = 12;
    for id in 0..N {
        greedy.send(&plain(id, "slow", 2)).unwrap();
    }
    // Let the server ingest the burst before the polite client arrives.
    std::thread::sleep(Duration::from_millis(60));

    let mut polite = WireClient::connect(&addr).unwrap();
    match polite.call(&plain(100, "slow", 2)).unwrap() {
        WireResponse::Ok(ok) => assert_eq!(ok.id, 100),
        other => {
            panic!("polite client shed behind a pipeliner: {other:?}")
        }
    }

    // The greedy client is throttled, not punished: every request
    // eventually completes, none shed at the admission gate.
    for _ in 0..N {
        match greedy.recv().unwrap() {
            WireResponse::Ok(_) => {}
            other => panic!("capped pipeliner saw a shed: {other:?}"),
        }
    }
    let stats = coord.stats();
    let load = stats
        .route_load
        .iter()
        .find(|(r, _)| r == "slow")
        .map(|(_, l)| l)
        .expect("route counters");
    assert_eq!(load.admitted, N + 1);
    assert_eq!(load.shed, 0, "fairness cap must prevent sheds");
    drop(greedy);
    drop(polite);
    let net = handle.shutdown();
    assert_eq!(net.frames_in, N + 1);
    assert_eq!(net.frames_out, N + 1);
    assert_eq!(net.protocol_errors, 0);
}

#[test]
fn graceful_drain_completes_in_flight_work() {
    let (_coord, handle) =
        start_slow_server(Duration::from_millis(200), 8);
    let mut client =
        WireClient::connect(&handle.addr().to_string()).unwrap();
    client.send(&plain(7, "slow", 3)).unwrap();
    // Let the server admit the job, then drain while it is mid-flight.
    std::thread::sleep(Duration::from_millis(50));
    let stopper = std::thread::spawn(move || handle.shutdown());
    match client.recv().expect("drained response arrives") {
        WireResponse::Ok(ok) => assert_eq!(ok.id, 7),
        other => panic!("expected the in-flight ok, got {other:?}"),
    }
    let net = stopper.join().unwrap();
    assert_eq!(net.frames_in, 1);
    assert_eq!(net.frames_out, 1);
}

#[test]
fn per_request_errors_leave_the_connection_usable() {
    let (_coord, handle) =
        start_slow_server(Duration::from_millis(1), 8);
    let mut client =
        WireClient::connect(&handle.addr().to_string()).unwrap();

    // Unknown route: typed error, connection stays up.
    match client.call(&plain(1, "no/such", 2)).unwrap() {
        WireResponse::Err(e) => {
            assert_eq!(e.code, ErrorCode::UnknownRoute);
            assert_eq!(e.id, Some(1));
        }
        other => panic!("expected unknown_route, got {other:?}"),
    }
    // Schema violation: typed error, connection stays up.
    client.send_raw(r#"{"id":2,"route":"slow"}"#).unwrap();
    match client.recv().unwrap() {
        WireResponse::Err(e) => {
            assert_eq!(e.code, ErrorCode::BadRequest);
            assert_eq!(e.id, Some(2));
        }
        other => panic!("expected bad_request, got {other:?}"),
    }
    // The same socket still serves real work afterwards.
    match client.call(&plain(3, "slow", 2)).unwrap() {
        WireResponse::Ok(ok) => assert_eq!(ok.id, 3),
        other => panic!("expected ok, got {other:?}"),
    }
    let net = handle.shutdown();
    assert_eq!(net.connections, 1);
    assert_eq!(net.protocol_errors, 1);
}
