//! Tile-sharded execution: noise-off bit-identity of sharded vs
//! monolithic Lorenz96 rollouts — the correctness contract that lets
//! states larger than one 32x32 array split across tile column-groups
//! (serial sharded kernel and parallel shard-worker fan-out), serial and
//! batched (B = 32).

use memode::analog::system::AnalogNoise;
use memode::device::taox::DeviceConfig;
use memode::models::loader::decay_mlp_weights;
use memode::twin::lorenz96::{L96AnalogOpts, Lorenz96Twin};
use memode::twin::{Twin, TwinRequest};

fn quiet_device() -> DeviceConfig {
    DeviceConfig {
        fault_rate: 0.0,
        pulse_sigma: 0.0,
        read_noise: 0.0,
        ..Default::default()
    }
}

const DIM: usize = 48;
const SUBSTEPS: usize = 4;

// With DIM = 48 the shared decay fixture spans two tile column-groups on
// the state (48 = 32 + 16) and three on the hidden layer (96 columns).
fn twin_with(shards: usize, parallel: bool) -> Lorenz96Twin {
    Lorenz96Twin::analog_opts(
        &decay_mlp_weights(DIM),
        &quiet_device(),
        AnalogNoise::off(),
        5,
        L96AnalogOpts { substeps: SUBSTEPS, shards, parallel },
    )
}

fn h0(k: usize) -> Vec<f64> {
    (0..DIM)
        .map(|i| ((i as f64) * 0.31 + (k as f64) * 0.77).sin() * 0.6)
        .collect()
}

fn batch_requests(b: usize, n_points: usize) -> Vec<TwinRequest> {
    (0..b).map(|k| TwinRequest::autonomous(h0(k), n_points)).collect()
}

#[test]
fn serial_sharded_rollout_bit_identical_to_monolithic() {
    let mut mono = twin_with(1, false);
    let mut sharded = twin_with(2, false);
    let a = mono.simulate(&h0(0), 10).unwrap();
    let b = sharded.simulate(&h0(0), 10).unwrap();
    assert_eq!(a, b, "serial sharded kernel diverged from monolithic");
}

#[test]
fn parallel_sharded_rollout_bit_identical_to_monolithic() {
    let mut mono = twin_with(1, false);
    let mut fanout = twin_with(2, true);
    let a = mono.simulate(&h0(1), 10).unwrap();
    let b = fanout.simulate(&h0(1), 10).unwrap();
    assert_eq!(a, b, "shard-worker fan-out diverged from monolithic");
    let tel = fanout.shard_telemetry().expect("fan-out backend");
    assert_eq!(tel.len(), 2, "expected 2 shard workers");
    assert!(tel.iter().all(|s| s.steps > 0 && s.device_reads > 0));
}

#[test]
fn batched_b32_sharded_rollouts_bit_identical_to_monolithic() {
    let reqs = batch_requests(32, 8);
    let mut mono = twin_with(1, false);
    let want = mono.run_batch(&reqs);

    for (label, mut twin) in [
        ("serial sharded", twin_with(2, false)),
        ("parallel fan-out", twin_with(2, true)),
    ] {
        let got = twin.run_batch(&reqs);
        assert_eq!(got.len(), want.len());
        for (k, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g.as_ref().unwrap().trajectory,
                w.as_ref().unwrap().trajectory,
                "{label}: request {k} diverged at B=32"
            );
        }
    }
}

#[test]
fn sharded_batch_isolates_bad_h0_dim() {
    let mut twin = twin_with(2, true);
    let results = twin.run_batch(&[
        TwinRequest::autonomous(h0(0), 5),
        TwinRequest::autonomous(vec![1.0, 2.0], 5),
        TwinRequest::autonomous(h0(2), 5),
    ]);
    assert!(results[0].is_ok());
    assert!(results[1].is_err(), "wrong-dim request must fail alone");
    assert!(results[2].is_ok());
}

#[test]
fn sharded_default_h0_matches_state_dim() {
    let mut twin = twin_with(2, true);
    let resp = twin.run(&TwinRequest::autonomous(vec![], 3)).unwrap();
    assert_eq!(resp.trajectory.dim(), DIM);
    assert_eq!(resp.trajectory.row(0).len(), DIM);
}
