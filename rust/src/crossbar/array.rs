//! A physical 1T1R crossbar array.
//!
//! Rows are bit lines (driven with input voltages), columns are source
//! lines (current outputs). Each cell multiplies by Ohm's law; each column
//! sums by Kirchhoff's current law. The paper's physical arrays are 32x32;
//! larger logical shapes are built from tiles ([`crate::crossbar::tiling`]).

use crate::device::programming::{program_cell, summarize, ArrayProgrammingStats, ProgrammingResult};
use crate::device::taox::{DeviceConfig, Memristor};
use crate::util::rng::Pcg64;
use crate::util::tensor::Mat;

/// Physical array-side limit of the paper's chips.
pub const PHYSICAL_SIDE: usize = 32;

/// A rows x cols crossbar of analogue memristors.
#[derive(Debug, Clone)]
pub struct CrossbarArray {
    pub rows: usize,
    pub cols: usize,
    pub cfg: DeviceConfig,
    cells: Vec<Memristor>,
}

impl CrossbarArray {
    /// Sample a fresh array (with yield faults) of the given shape.
    ///
    /// Panics if the shape exceeds the physical 32x32 limit — larger
    /// logical matrices must go through [`crate::crossbar::tiling`].
    pub fn sample(
        rows: usize,
        cols: usize,
        cfg: DeviceConfig,
        rng: &mut Pcg64,
    ) -> Self {
        assert!(
            rows <= PHYSICAL_SIDE && cols <= PHYSICAL_SIDE,
            "physical arrays are at most 32x32 (got {rows}x{cols}); use tiling"
        );
        let cells =
            (0..rows * cols).map(|_| Memristor::sample(&cfg, rng)).collect();
        Self { rows, cols, cfg, cells }
    }

    /// Sample a full physical array, then *place* the logical rows x cols
    /// matrix on its healthiest sub-grid (greedy column selection by fault
    /// count, then row selection within those columns).
    ///
    /// This is how the paper's system uses its chips: the Fig. 3 layers
    /// occupy at most 15x14 of each 32x32 array, so the mapping flow routes
    /// around the ~2.7 % nonresponsive devices. When the logical shape
    /// uses the whole array there is no freedom and this degrades to
    /// [`CrossbarArray::sample`].
    pub fn sample_healthiest(
        rows: usize,
        cols: usize,
        cfg: DeviceConfig,
        rng: &mut Pcg64,
    ) -> Self {
        assert!(
            rows <= PHYSICAL_SIDE && cols <= PHYSICAL_SIDE,
            "physical arrays are at most 32x32 (got {rows}x{cols}); use tiling"
        );
        let full = Self::sample(PHYSICAL_SIDE, PHYSICAL_SIDE, cfg.clone(), rng);
        if rows == PHYSICAL_SIDE && cols == PHYSICAL_SIDE {
            return full;
        }
        // Greedy: columns with fewest faults overall...
        let mut col_scores: Vec<(usize, usize)> = (0..PHYSICAL_SIDE)
            .map(|c| {
                let faults = (0..PHYSICAL_SIDE)
                    .filter(|&r| !full.cell(r, c).is_healthy())
                    .count();
                (faults, c)
            })
            .collect();
        col_scores.sort();
        let mut sel_cols: Vec<usize> =
            col_scores[..cols].iter().map(|&(_, c)| c).collect();
        sel_cols.sort_unstable();
        // ...then rows with fewest faults within the selected columns.
        let mut row_scores: Vec<(usize, usize)> = (0..PHYSICAL_SIDE)
            .map(|r| {
                let faults = sel_cols
                    .iter()
                    .filter(|&&c| !full.cell(r, c).is_healthy())
                    .count();
                (faults, r)
            })
            .collect();
        row_scores.sort();
        let mut sel_rows: Vec<usize> =
            row_scores[..rows].iter().map(|&(_, r)| r).collect();
        sel_rows.sort_unstable();
        let mut cells = Vec::with_capacity(rows * cols);
        for &r in &sel_rows {
            for &c in &sel_cols {
                cells.push(full.cell(r, c).clone());
            }
        }
        Self { rows, cols, cfg, cells }
    }

    /// A fault-free array (for noise-ablation experiments).
    pub fn pristine(rows: usize, cols: usize, cfg: DeviceConfig) -> Self {
        assert!(rows <= PHYSICAL_SIDE && cols <= PHYSICAL_SIDE);
        let cells = (0..rows * cols).map(|_| Memristor::new(&cfg)).collect();
        Self { rows, cols, cfg, cells }
    }

    #[inline]
    pub fn cell(&self, r: usize, c: usize) -> &Memristor {
        &self.cells[r * self.cols + c]
    }

    #[inline]
    pub fn cell_mut(&mut self, r: usize, c: usize) -> &mut Memristor {
        &mut self.cells[r * self.cols + c]
    }

    /// Program the whole array toward a target conductance map (row-major
    /// rows x cols, in Siemens). Returns per-cell programming results.
    pub fn program(
        &mut self,
        targets: &Mat,
        rng: &mut Pcg64,
    ) -> Vec<ProgrammingResult> {
        assert_eq!(targets.rows, self.rows, "target map rows mismatch");
        assert_eq!(targets.cols, self.cols, "target map cols mismatch");
        self.cells
            .iter_mut()
            .zip(&targets.data)
            .map(|(c, &g)| program_cell(c, &self.cfg, g, rng))
            .collect()
    }

    /// Program and summarise (the array-level Fig. 2k statistic).
    pub fn program_summarized(
        &mut self,
        targets: &Mat,
        rng: &mut Pcg64,
    ) -> ArrayProgrammingStats {
        let results = self.program(targets, rng);
        summarize(&results)
    }

    /// Snapshot of the *actual* (post-programming, fault-resolved)
    /// conductances as a matrix. This is what the VMM engine caches for the
    /// request path.
    pub fn conductance_matrix(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |r, c| {
            self.cell(r, c).conductance(&self.cfg)
        })
    }

    /// One fully-physical VMM: per-cell noisy reads, Ohm's-law multiply,
    /// KCL column sum. Exact but O(rows*cols) RNG draws — the reference
    /// against which the fast engine is validated.
    pub fn vmm_physical(&self, v: &[f64], rng: &mut Pcg64) -> Vec<f64> {
        assert_eq!(v.len(), self.rows, "input voltage vector length");
        let mut i_out = vec![0.0; self.cols];
        for r in 0..self.rows {
            let vr = v[r];
            if vr == 0.0 {
                continue;
            }
            for c in 0..self.cols {
                i_out[c] += vr * self.cell(r, c).read(&self.cfg, rng);
            }
        }
        i_out
    }

    /// Advance every cell's virtual age by `dt_s` (drift + diffusive walk
    /// per [`crate::device::retention::age_cell`]). Deterministic in
    /// `(cells, dt_s, rng state)` — no wall-clock reads anywhere.
    pub fn age(&mut self, dt_s: f64, rng: &mut Pcg64) {
        for cell in &mut self.cells {
            crate::device::retention::age_cell(cell, &self.cfg, dt_s, rng);
        }
    }

    /// Fraction of healthy cells.
    pub fn health(&self) -> f64 {
        let ok = self.cells.iter().filter(|c| c.is_healthy()).count();
        ok as f64 / self.cells.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_cfg() -> DeviceConfig {
        DeviceConfig { read_noise: 0.0, fault_rate: 0.0, ..Default::default() }
    }

    #[test]
    fn program_then_vmm_matches_target_linear_algebra() {
        let cfg = quiet_cfg();
        let mut rng = Pcg64::seeded(1);
        let mut arr = CrossbarArray::pristine(4, 3, cfg);
        let targets = Mat::from_fn(4, 3, |r, c| 10e-6 + (r * 3 + c) as f64 * 5e-6);
        arr.program(&targets, &mut rng);
        let v = [0.2, -0.1, 0.05, 0.3];
        let got = arr.vmm_physical(&v, &mut rng);
        let want = arr.conductance_matrix().vecmat(&v);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
        // And programming put us near the targets (2 % verify tol).
        for r in 0..4 {
            for c in 0..3 {
                let rel = (arr.conductance_matrix().at(r, c)
                    - targets.at(r, c))
                    .abs()
                    / targets.at(r, c);
                assert!(rel < 0.05, "cell ({r},{c}) err {rel}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "32x32")]
    fn oversize_array_rejected() {
        let mut rng = Pcg64::seeded(2);
        let _ = CrossbarArray::sample(33, 8, DeviceConfig::default(), &mut rng);
    }

    #[test]
    fn zero_input_draws_zero_current() {
        let cfg = DeviceConfig::default();
        let mut rng = Pcg64::seeded(3);
        let arr = CrossbarArray::sample(8, 8, cfg, &mut rng);
        let out = arr.vmm_physical(&[0.0; 8], &mut rng);
        assert!(out.iter().all(|&i| i == 0.0));
    }

    #[test]
    fn health_reflects_fault_rate() {
        let cfg = DeviceConfig { fault_rate: 0.5, ..Default::default() };
        let mut rng = Pcg64::seeded(4);
        let arr = CrossbarArray::sample(32, 32, cfg, &mut rng);
        assert!((arr.health() - 0.5).abs() < 0.1);
    }

    #[test]
    fn vmm_with_noise_is_unbiased() {
        let cfg = DeviceConfig {
            read_noise: 0.05,
            fault_rate: 0.0,
            ..Default::default()
        };
        let mut rng = Pcg64::seeded(5);
        let mut arr = CrossbarArray::pristine(8, 4, cfg);
        let targets = Mat::full(8, 4, 50e-6);
        arr.program(&targets, &mut rng);
        let v = [0.1; 8];
        let clean = arr.conductance_matrix().vecmat(&v);
        let mut acc = vec![0.0; 4];
        let n = 3000;
        for _ in 0..n {
            let out = arr.vmm_physical(&v, &mut rng);
            for (a, o) in acc.iter_mut().zip(&out) {
                *a += o;
            }
        }
        for (a, c) in acc.iter().zip(&clean) {
            let mean = a / n as f64;
            assert!((mean / c - 1.0).abs() < 0.01, "bias {}", mean / c);
        }
    }
}
