//! The 1T1R analogue crossbar array (Fig. 2f-g) and its weight mapping.
//!
//! * [`array`]        — a physical 32x32 array of [`crate::device::Memristor`]
//!   cells with programming and noisy analogue reads
//! * [`mapping`]      — signed weight <-> differential conductance mapping
//! * [`differential`] — a differential-pair array pairing two physical
//!   columns per logical output (positive / negative rails)
//! * [`vmm`]          — the request-path VMM engine: caches effective
//!   conductances and applies read noise in a moment-matched fast path
//! * [`ir_drop`]      — first-order wire-resistance (IR drop) nonideality
//! * [`tiling`]       — tiles logical matrices larger than one 32x32 array
//!   across multiple physical arrays (the paper's multi-array system)

pub mod array;
pub mod differential;
pub mod ir_drop;
pub mod mapping;
pub mod tiling;
pub mod vmm;

pub use array::CrossbarArray;
pub use differential::DifferentialArray;
pub use mapping::WeightMapping;
pub use vmm::{NoiseMode, VmmEngine};
