//! Tiling logical matrices across multiple physical 32x32 arrays.
//!
//! The paper's Fig. 4h/4i scalability sweeps evaluate hidden sizes up to
//! 512, far beyond one 32x32 array. Real systems tile: a logical
//! rows x cols matrix becomes a grid of ceil(rows/32) x ceil(cols/32)
//! physical arrays; row-tile outputs of the same column-tile share a source
//! line and sum by KCL exactly like cells within one array.

use crate::crossbar::array::PHYSICAL_SIDE;
use crate::crossbar::differential::DifferentialArray;
use crate::device::taox::DeviceConfig;
use crate::util::rng::Pcg64;
use crate::util::tensor::Mat;

/// A logical signed matrix deployed across a grid of differential arrays.
#[derive(Debug, Clone)]
pub struct TiledMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Tile grid, row-major: tiles[rt][ct].
    pub tiles: Vec<Vec<DifferentialArray>>,
}

impl TiledMatrix {
    /// Deploy `w` across as many physical arrays as needed.
    pub fn deploy(w: &Mat, cfg: &DeviceConfig, rng: &mut Pcg64) -> Self {
        let rt = w.rows.div_ceil(PHYSICAL_SIDE);
        let ct = w.cols.div_ceil(PHYSICAL_SIDE);
        let mut tiles = Vec::with_capacity(rt);
        for i in 0..rt {
            let r0 = i * PHYSICAL_SIDE;
            let r1 = (r0 + PHYSICAL_SIDE).min(w.rows);
            let mut row_tiles = Vec::with_capacity(ct);
            for j in 0..ct {
                let c0 = j * PHYSICAL_SIDE;
                let c1 = (c0 + PHYSICAL_SIDE).min(w.cols);
                let sub = Mat::from_fn(r1 - r0, c1 - c0, |r, c| {
                    w.at(r0 + r, c0 + c)
                });
                row_tiles.push(DifferentialArray::deploy(&sub, cfg, rng));
            }
            tiles.push(row_tiles);
        }
        Self { rows: w.rows, cols: w.cols, tiles }
    }

    /// Number of physical (differential) arrays used.
    pub fn n_arrays(&self) -> usize {
        self.tiles.iter().map(Vec::len).sum::<usize>() * 2
    }

    /// Reassembled effective logical weights.
    pub fn effective_weights(&self) -> Mat {
        let mut w = Mat::zeros(self.rows, self.cols);
        for (i, row_tiles) in self.tiles.iter().enumerate() {
            for (j, tile) in row_tiles.iter().enumerate() {
                let eff = tile.effective_weights();
                for r in 0..eff.rows {
                    for c in 0..eff.cols {
                        *w.at_mut(
                            i * PHYSICAL_SIDE + r,
                            j * PHYSICAL_SIDE + c,
                        ) = eff.at(r, c);
                    }
                }
            }
        }
        w
    }

    /// Variance kernel of the differential read, assembled across tiles:
    /// K(r, c) = (G+(r,c)^2 + G-(r,c)^2) / slope_tile^2. Consumed by the
    /// fast moment-matched noise path of [`crate::crossbar::vmm::VmmEngine`].
    pub fn variance_kernel(&self) -> Mat {
        let mut k = Mat::zeros(self.rows, self.cols);
        for (i, row_tiles) in self.tiles.iter().enumerate() {
            for (j, tile) in row_tiles.iter().enumerate() {
                let gp = tile.pos.conductance_matrix();
                let gn = tile.neg.conductance_matrix();
                let s = tile.mapping.slope;
                for r in 0..gp.rows {
                    for c in 0..gp.cols {
                        let a = gp.at(r, c) / s;
                        let b = gn.at(r, c) / s;
                        *k.at_mut(
                            i * PHYSICAL_SIDE + r,
                            j * PHYSICAL_SIDE + c,
                        ) = a * a + b * b;
                    }
                }
            }
        }
        k
    }

    /// Physical logical VMM: per-tile VMMs, column-tile outputs summed.
    pub fn vmm_physical(&self, v: &[f64], rng: &mut Pcg64) -> Vec<f64> {
        assert_eq!(v.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for (i, row_tiles) in self.tiles.iter().enumerate() {
            let r0 = i * PHYSICAL_SIDE;
            for (j, tile) in row_tiles.iter().enumerate() {
                let c0 = j * PHYSICAL_SIDE;
                let sub_v = &v[r0..r0 + tile.rows()];
                let out = tile.vmm_physical(sub_v, rng);
                for (k, o) in out.iter().enumerate() {
                    y[c0 + k] += o;
                }
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_cfg() -> DeviceConfig {
        DeviceConfig {
            read_noise: 0.0,
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        }
    }

    #[test]
    fn tile_grid_shape() {
        let cfg = quiet_cfg();
        let mut rng = Pcg64::seeded(1);
        let w = Mat::zeros(70, 40);
        let t = TiledMatrix::deploy(&w, &cfg, &mut rng);
        assert_eq!(t.tiles.len(), 3); // ceil(70/32)
        assert_eq!(t.tiles[0].len(), 2); // ceil(40/32)
        assert_eq!(t.n_arrays(), 12); // 6 tiles x 2 rails
    }

    #[test]
    fn small_matrix_single_tile() {
        let cfg = quiet_cfg();
        let mut rng = Pcg64::seeded(2);
        let w = Mat::zeros(14, 14);
        let t = TiledMatrix::deploy(&w, &cfg, &mut rng);
        assert_eq!(t.n_arrays(), 2);
    }

    #[test]
    fn tiled_vmm_matches_dense_product() {
        let cfg = quiet_cfg();
        let mut rng = Pcg64::seeded(3);
        let w = Mat::from_fn(64, 48, |r, c| {
            (((r * 48 + c) % 17) as f64 / 17.0) - 0.5
        });
        let t = TiledMatrix::deploy(&w, &cfg, &mut rng);
        let v: Vec<f64> =
            (0..64).map(|k| ((k % 7) as f64 / 7.0) - 0.4).collect();
        let got = t.vmm_physical(&v, &mut rng);
        let want = w.vecmat(&v);
        for (g, e) in got.iter().zip(&want) {
            assert!((g - e).abs() < 1e-8, "{g} vs {e}");
        }
    }

    #[test]
    fn effective_weights_reassemble() {
        let cfg = quiet_cfg();
        let mut rng = Pcg64::seeded(4);
        let w = Mat::from_fn(40, 33, |r, c| ((r + c) as f64 / 73.0) - 0.4);
        let t = TiledMatrix::deploy(&w, &cfg, &mut rng);
        let eff = t.effective_weights();
        assert_eq!(eff.rows, 40);
        assert_eq!(eff.cols, 33);
        // Per-tile mappings differ (per-tile w_max), but each weight must
        // still round-trip closely in the ideal config.
        for i in 0..w.data.len() {
            assert!((eff.data[i] - w.data[i]).abs() < 1e-9);
        }
    }
}
