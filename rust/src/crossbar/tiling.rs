//! Tiling logical matrices across multiple physical 32x32 arrays.
//!
//! The paper's Fig. 4h/4i scalability sweeps evaluate hidden sizes up to
//! 512, far beyond one 32x32 array. Real systems tile: a logical
//! rows x cols matrix becomes a grid of ceil(rows/32) x ceil(cols/32)
//! physical arrays; row-tile outputs of the same column-tile share a source
//! line and sum by KCL exactly like cells within one array.

use crate::crossbar::array::PHYSICAL_SIDE;
use crate::crossbar::differential::DifferentialArray;
use crate::device::taox::DeviceConfig;
use crate::util::rng::Pcg64;
use crate::util::tensor::Mat;

/// A logical signed matrix deployed across a grid of differential arrays.
#[derive(Debug, Clone)]
pub struct TiledMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Tile grid, row-major: tiles[rt][ct].
    pub tiles: Vec<Vec<DifferentialArray>>,
}

impl TiledMatrix {
    /// Deploy `w` across as many physical arrays as needed.
    pub fn deploy(w: &Mat, cfg: &DeviceConfig, rng: &mut Pcg64) -> Self {
        let rt = w.rows.div_ceil(PHYSICAL_SIDE);
        let ct = w.cols.div_ceil(PHYSICAL_SIDE);
        let mut tiles = Vec::with_capacity(rt);
        for i in 0..rt {
            let r0 = i * PHYSICAL_SIDE;
            let r1 = (r0 + PHYSICAL_SIDE).min(w.rows);
            let mut row_tiles = Vec::with_capacity(ct);
            for j in 0..ct {
                let c0 = j * PHYSICAL_SIDE;
                let c1 = (c0 + PHYSICAL_SIDE).min(w.cols);
                let sub = Mat::from_fn(r1 - r0, c1 - c0, |r, c| {
                    w.at(r0 + r, c0 + c)
                });
                row_tiles.push(DifferentialArray::deploy(&sub, cfg, rng));
            }
            tiles.push(row_tiles);
        }
        Self { rows: w.rows, cols: w.cols, tiles }
    }

    /// Number of physical (differential) arrays used.
    pub fn n_arrays(&self) -> usize {
        self.tiles.iter().map(Vec::len).sum::<usize>() * 2
    }

    /// Advance every tile's virtual age by `dt_s` (both rails, row-major
    /// tile order — the deterministic aging walk of the device-lifetime
    /// loop).
    pub fn advance_age(&mut self, dt_s: f64, rng: &mut Pcg64) {
        for row_tiles in &mut self.tiles {
            for tile in row_tiles {
                tile.age(dt_s, rng);
            }
        }
    }

    /// Reprogram the *same* tile grid toward `w` (the recalibration flow):
    /// each tile re-runs write-verify + stuck-at compensation on its
    /// existing hardware, preserving yield maps. Returns total programming
    /// pulses across all tiles (write-energy accounting).
    pub fn reprogram(
        &mut self,
        w: &Mat,
        cfg: &DeviceConfig,
        rng: &mut Pcg64,
    ) -> u64 {
        assert_eq!(w.rows, self.rows, "reprogram weight rows mismatch");
        assert_eq!(w.cols, self.cols, "reprogram weight cols mismatch");
        let mut pulses = 0;
        for (i, row_tiles) in self.tiles.iter_mut().enumerate() {
            let r0 = i * PHYSICAL_SIDE;
            for (j, tile) in row_tiles.iter_mut().enumerate() {
                let c0 = j * PHYSICAL_SIDE;
                let sub = Mat::from_fn(tile.rows(), tile.cols(), |r, c| {
                    w.at(r0 + r, c0 + c)
                });
                pulses += tile.reprogram(&sub, cfg, rng);
            }
        }
        pulses
    }

    /// Fraction of healthy cells across every rail of every tile.
    pub fn health(&self) -> f64 {
        let (mut ok, mut total) = (0.0, 0.0);
        for row_tiles in &self.tiles {
            for tile in row_tiles {
                for rail in [&tile.pos, &tile.neg] {
                    let n = (rail.rows * rail.cols) as f64;
                    ok += rail.health() * n;
                    total += n;
                }
            }
        }
        ok / total
    }

    /// Reassembled effective logical weights.
    pub fn effective_weights(&self) -> Mat {
        let mut w = Mat::zeros(self.rows, self.cols);
        for (i, row_tiles) in self.tiles.iter().enumerate() {
            for (j, tile) in row_tiles.iter().enumerate() {
                let eff = tile.effective_weights();
                for r in 0..eff.rows {
                    for c in 0..eff.cols {
                        *w.at_mut(
                            i * PHYSICAL_SIDE + r,
                            j * PHYSICAL_SIDE + c,
                        ) = eff.at(r, c);
                    }
                }
            }
        }
        w
    }

    /// Variance kernel of the differential read, assembled across tiles:
    /// K(r, c) = (G+(r,c)^2 + G-(r,c)^2) / slope_tile^2. Consumed by the
    /// fast moment-matched noise path of [`crate::crossbar::vmm::VmmEngine`].
    pub fn variance_kernel(&self) -> Mat {
        let mut k = Mat::zeros(self.rows, self.cols);
        for (i, row_tiles) in self.tiles.iter().enumerate() {
            for (j, tile) in row_tiles.iter().enumerate() {
                let gp = tile.pos.conductance_matrix();
                let gn = tile.neg.conductance_matrix();
                let s = tile.mapping.slope;
                for r in 0..gp.rows {
                    for c in 0..gp.cols {
                        let a = gp.at(r, c) / s;
                        let b = gn.at(r, c) / s;
                        *k.at_mut(
                            i * PHYSICAL_SIDE + r,
                            j * PHYSICAL_SIDE + c,
                        ) = a * a + b * b;
                    }
                }
            }
        }
        k
    }

    /// Physical logical VMM: per-tile VMMs, column-tile outputs summed.
    pub fn vmm_physical(&self, v: &[f64], rng: &mut Pcg64) -> Vec<f64> {
        assert_eq!(v.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for (i, row_tiles) in self.tiles.iter().enumerate() {
            let r0 = i * PHYSICAL_SIDE;
            for (j, tile) in row_tiles.iter().enumerate() {
                let c0 = j * PHYSICAL_SIDE;
                let sub_v = &v[r0..r0 + tile.rows()];
                let out = tile.vmm_physical(sub_v, rng);
                for (k, o) in out.iter().enumerate() {
                    y[c0 + k] += o;
                }
            }
        }
        y
    }
}

// ---------------------------------------------------------------------------
// ShardPlan: partitioning a logical dimension across tile column-groups
// ---------------------------------------------------------------------------

/// A contiguous partition of a logical dimension (a layer's output columns,
/// or the twin's state vector) into shards, each mapping to a group of
/// physical tile columns.
///
/// Shards are half-open `[start, end)` ranges in ascending order covering
/// `0..dim` exactly. When the dimension spans several physical tiles the
/// boundaries fall on [`PHYSICAL_SIDE`] multiples, so a shard owns whole
/// tile column-groups — the unit a parallel shard worker can read without
/// touching another worker's arrays. Narrow dimensions (fewer columns than
/// shards would need tiles) fall back to a near-equal element split.
///
/// The plan is pure bookkeeping: executing a shard means reading only the
/// columns in its range, with the per-element accumulation order unchanged
/// (see [`crate::util::tensor::Mat::vecmat_cols_into`]), so a sharded
/// noise-free read reassembles the monolithic read bit for bit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    dim: usize,
    /// Half-open (start, end) column ranges, ascending, covering 0..dim.
    bounds: Vec<(usize, usize)>,
}

impl ShardPlan {
    /// The trivial plan: one shard owning the whole dimension.
    pub fn single(dim: usize) -> Self {
        assert!(dim > 0, "shard plan over an empty dimension");
        Self { dim, bounds: vec![(0, dim)] }
    }

    /// Split `dim` into (up to) `n_shards` contiguous shards. The shard
    /// count is clamped to `dim` so every shard owns at least one column;
    /// when the dimension spans several physical tiles, boundaries are
    /// aligned to [`PHYSICAL_SIDE`] so shards own whole tile column-groups.
    pub fn split(dim: usize, n_shards: usize) -> Self {
        assert!(dim > 0, "shard plan over an empty dimension");
        let n_tiles = dim.div_ceil(PHYSICAL_SIDE);
        let n = n_shards.clamp(1, dim);
        if n == 1 {
            return Self::single(dim);
        }
        let mut bounds = Vec::with_capacity(n);
        if n <= n_tiles {
            // Distribute whole tile column-groups near-equally; the last
            // tile may be ragged (dim not a PHYSICAL_SIDE multiple).
            let base = n_tiles / n;
            let extra = n_tiles % n;
            let mut tile = 0;
            for s in 0..n {
                let take = base + usize::from(s < extra);
                let start = tile * PHYSICAL_SIDE;
                tile += take;
                let end = (tile * PHYSICAL_SIDE).min(dim);
                bounds.push((start, end));
            }
        } else {
            // Fewer tiles than shards: near-equal element split.
            let base = dim / n;
            let extra = dim % n;
            let mut start = 0;
            for s in 0..n {
                let end = start + base + usize::from(s < extra);
                bounds.push((start, end));
                start = end;
            }
        }
        debug_assert_eq!(bounds.last().map(|b| b.1), Some(dim));
        Self { dim, bounds }
    }

    /// The partitioned dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.bounds.len()
    }

    /// Whether the plan actually splits the dimension.
    pub fn is_sharded(&self) -> bool {
        self.bounds.len() > 1
    }

    /// Column range of shard `s`.
    pub fn range(&self, s: usize) -> std::ops::Range<usize> {
        let (start, end) = self.bounds[s];
        start..end
    }

    /// Column count of shard `s`.
    pub fn width(&self, s: usize) -> usize {
        let (start, end) = self.bounds[s];
        end - start
    }
}

/// One [`ShardPlan`] per layer width, all with the same shard count: the
/// requested `n_shards` clamped so even the narrowest layer keeps at least
/// one column per shard. This is what keeps every shard worker in lockstep
/// through the per-layer barriers of a sharded rollout.
pub fn uniform_layer_plans(widths: &[usize], n_shards: usize) -> Vec<ShardPlan> {
    let n = widths
        .iter()
        .map(|&w| ShardPlan::split(w, n_shards).n_shards())
        .min()
        .expect("at least one layer");
    widths.iter().map(|&w| ShardPlan::split(w, n)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_cfg() -> DeviceConfig {
        DeviceConfig {
            read_noise: 0.0,
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        }
    }

    #[test]
    fn tile_grid_shape() {
        let cfg = quiet_cfg();
        let mut rng = Pcg64::seeded(1);
        let w = Mat::zeros(70, 40);
        let t = TiledMatrix::deploy(&w, &cfg, &mut rng);
        assert_eq!(t.tiles.len(), 3); // ceil(70/32)
        assert_eq!(t.tiles[0].len(), 2); // ceil(40/32)
        assert_eq!(t.n_arrays(), 12); // 6 tiles x 2 rails
    }

    #[test]
    fn small_matrix_single_tile() {
        let cfg = quiet_cfg();
        let mut rng = Pcg64::seeded(2);
        let w = Mat::zeros(14, 14);
        let t = TiledMatrix::deploy(&w, &cfg, &mut rng);
        assert_eq!(t.n_arrays(), 2);
    }

    #[test]
    fn tiled_vmm_matches_dense_product() {
        let cfg = quiet_cfg();
        let mut rng = Pcg64::seeded(3);
        let w = Mat::from_fn(64, 48, |r, c| {
            (((r * 48 + c) % 17) as f64 / 17.0) - 0.5
        });
        let t = TiledMatrix::deploy(&w, &cfg, &mut rng);
        let v: Vec<f64> =
            (0..64).map(|k| ((k % 7) as f64 / 7.0) - 0.4).collect();
        let got = t.vmm_physical(&v, &mut rng);
        let want = w.vecmat(&v);
        for (g, e) in got.iter().zip(&want) {
            assert!((g - e).abs() < 1e-8, "{g} vs {e}");
        }
    }

    #[test]
    fn shard_plan_tile_aligned_when_wide() {
        // 64 columns = 2 tiles -> 2 shards of exactly one tile each.
        let p = ShardPlan::split(64, 2);
        assert_eq!(p.n_shards(), 2);
        assert_eq!(p.range(0), 0..32);
        assert_eq!(p.range(1), 32..64);
        assert!(p.is_sharded());
        // 96 columns = 3 tiles over 2 shards -> (2 tiles, 1 tile).
        let p = ShardPlan::split(96, 2);
        assert_eq!(p.range(0), 0..64);
        assert_eq!(p.range(1), 64..96);
        // Ragged final tile: 48 columns = 2 tiles -> (32, 16).
        let p = ShardPlan::split(48, 2);
        assert_eq!(p.range(0), 0..32);
        assert_eq!(p.range(1), 32..48);
    }

    #[test]
    fn shard_plan_covers_dimension_exactly() {
        for dim in [1usize, 5, 6, 31, 32, 33, 48, 64, 65, 128, 200] {
            for n in [1usize, 2, 3, 4, 7, 300] {
                let p = ShardPlan::split(dim, n);
                assert!(p.n_shards() >= 1 && p.n_shards() <= dim.min(n.max(1)));
                let mut cursor = 0;
                for s in 0..p.n_shards() {
                    let r = p.range(s);
                    assert_eq!(r.start, cursor, "dim {dim} shards {n}");
                    assert!(r.end > r.start, "empty shard: dim {dim} n {n}");
                    assert_eq!(p.width(s), r.len());
                    cursor = r.end;
                }
                assert_eq!(cursor, dim, "dim {dim} shards {n} not covered");
            }
        }
    }

    #[test]
    fn shard_plan_narrow_dim_splits_elements() {
        // 6 columns across 2 shards: no tile alignment possible.
        let p = ShardPlan::split(6, 2);
        assert_eq!(p.range(0), 0..3);
        assert_eq!(p.range(1), 3..6);
        // Shard count clamps to the dimension.
        assert_eq!(ShardPlan::split(3, 8).n_shards(), 3);
        assert!(!ShardPlan::single(10).is_sharded());
    }

    #[test]
    fn uniform_layer_plans_share_a_shard_count() {
        // Widths 96 / 48 / 6 with 4 requested shards: the 6-wide layer
        // allows 4, so every layer gets 4 shards (lockstep barriers need
        // uniform counts).
        let plans = uniform_layer_plans(&[96, 48, 6], 4);
        assert!(plans.iter().all(|p| p.n_shards() == 4));
        // A 2-wide layer caps the whole stack at 2.
        let plans = uniform_layer_plans(&[96, 2], 4);
        assert!(plans.iter().all(|p| p.n_shards() == 2));
        assert_eq!(plans[0].dim(), 96);
    }

    #[test]
    fn aging_drifts_and_reprogram_restores_across_tiles() {
        let cfg = DeviceConfig { fault_rate: 0.0, ..Default::default() };
        let mut rng = Pcg64::seeded(11);
        let w = Mat::from_fn(40, 40, |r, c| {
            (((r * 40 + c) % 13) as f64 / 13.0 - 0.5) * 0.8
        });
        let mut t = TiledMatrix::deploy(&w, &cfg, &mut rng);
        let err = |t: &TiledMatrix| {
            let eff = t.effective_weights();
            eff.data
                .iter()
                .zip(&w.data)
                .map(|(&a, &b)| (a - b).abs())
                .sum::<f64>()
                / w.data.len() as f64
        };
        let fresh = err(&t);
        t.advance_age(1e7, &mut rng);
        let aged = err(&t);
        assert!(aged > fresh, "aging did not move weights ({aged} vs {fresh})");
        let pulses = t.reprogram(&w, &cfg, &mut rng);
        assert!(pulses > 0);
        let recal = err(&t);
        assert!(recal < aged, "recal did not restore ({recal} vs {aged})");
        assert!((t.health() - 1.0).abs() < 1e-12, "fault-free grid health");
    }

    #[test]
    fn effective_weights_reassemble() {
        let cfg = quiet_cfg();
        let mut rng = Pcg64::seeded(4);
        let w = Mat::from_fn(40, 33, |r, c| ((r + c) as f64 / 73.0) - 0.4);
        let t = TiledMatrix::deploy(&w, &cfg, &mut rng);
        let eff = t.effective_weights();
        assert_eq!(eff.rows, 40);
        assert_eq!(eff.cols, 33);
        // Per-tile mappings differ (per-tile w_max), but each weight must
        // still round-trip closely in the ideal config.
        for i in 0..w.data.len() {
            assert!((eff.data[i] - w.data[i]).abs() < 1e-9);
        }
    }
}
