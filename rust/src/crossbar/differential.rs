//! Differential-pair crossbar: two physical arrays (or column groups)
//! realise one signed logical matrix.
//!
//! Programming goes through the write-verify loop with yield faults; the
//! logical VMM output is the difference of the positive- and negative-rail
//! column currents, scaled back to weight units by the mapping slope (the
//! scale folds into the next TIA stage's gain in the physical system).

use crate::crossbar::array::CrossbarArray;
use crate::crossbar::mapping::WeightMapping;
use crate::device::programming::ArrayProgrammingStats;
use crate::device::taox::DeviceConfig;
use crate::util::rng::Pcg64;
use crate::util::tensor::Mat;

/// A signed logical matrix on a differential pair of crossbars.
#[derive(Debug, Clone)]
pub struct DifferentialArray {
    pub pos: CrossbarArray,
    pub neg: CrossbarArray,
    pub mapping: WeightMapping,
    /// Programming statistics of the deployment (pos, neg).
    pub prog_stats: (ArrayProgrammingStats, ArrayProgrammingStats),
}

impl DifferentialArray {
    /// Deploy a weight matrix onto freshly sampled hardware.
    ///
    /// `rows x cols` must fit one physical array (<= 32x32); larger layers
    /// go through [`crate::crossbar::tiling::TiledMatrix`].
    ///
    /// Deployment is *fault-aware*: write-verify identifies stuck cells
    /// (they never converge), and the healthy partner rail is re-targeted
    /// to recover the intended differential weight where the conductance
    /// window allows — the standard stuck-at compensation flow of
    /// memristive accelerator mapping. Stuck-ON faults are always
    /// recoverable (the partner absorbs the offset); stuck-OFF faults on
    /// the *active* rail lose the clipped part of the weight.
    pub fn deploy(
        w: &Mat,
        cfg: &DeviceConfig,
        rng: &mut Pcg64,
    ) -> Self {
        let mapping = WeightMapping::for_weights(w, cfg);
        let (gp_t, gn_t) = mapping.map_matrix(w);
        // Fault-aware placement: logical matrices smaller than the physical
        // array land on its healthiest sub-grid (see sample_healthiest).
        let mut pos =
            CrossbarArray::sample_healthiest(w.rows, w.cols, cfg.clone(), rng);
        let mut neg =
            CrossbarArray::sample_healthiest(w.rows, w.cols, cfg.clone(), rng);
        let sp = pos.program_summarized(&gp_t, rng);
        let sn = neg.program_summarized(&gn_t, rng);
        let mut this = Self { pos, neg, mapping, prog_stats: (sp, sn) };
        this.compensate_faults(w, cfg, rng);
        this
    }

    /// Reprogram the *same* hardware toward a (possibly new) weight
    /// matrix: the recalibration flow. Stuck cells stay stuck
    /// ([`crate::device::programming::program_cell`] never alters them),
    /// so yield maps survive recalibration; drift accumulated since the
    /// last write is erased on healthy cells (each successful write-verify
    /// resets the cell's age). Returns the total number of programming
    /// pulses issued (write-energy accounting) and refreshes
    /// `prog_stats`.
    pub fn reprogram(
        &mut self,
        w: &Mat,
        cfg: &DeviceConfig,
        rng: &mut Pcg64,
    ) -> u64 {
        assert_eq!(w.rows, self.pos.rows, "reprogram weight rows mismatch");
        assert_eq!(w.cols, self.pos.cols, "reprogram weight cols mismatch");
        self.mapping = WeightMapping::for_weights(w, cfg);
        let (gp_t, gn_t) = self.mapping.map_matrix(w);
        let rp = self.pos.program(&gp_t, rng);
        let rn = self.neg.program(&gn_t, rng);
        let mut pulses: u64 = rp.iter().chain(rn.iter()).map(|r| u64::from(r.iters)).sum();
        self.prog_stats =
            (crate::device::programming::summarize(&rp), crate::device::programming::summarize(&rn));
        pulses += self.compensate_faults(w, cfg, rng);
        pulses
    }

    /// Re-target healthy rails opposite stuck cells so the differential
    /// weight is preserved: want g+ - g- = slope * w, so the healthy rail
    /// aims for `g_stuck -/+ slope * w` (clamped to the device window).
    /// Returns the programming pulses spent on compensation.
    fn compensate_faults(
        &mut self,
        w: &Mat,
        cfg: &DeviceConfig,
        rng: &mut Pcg64,
    ) -> u64 {
        use crate::device::programming::program_cell;
        let mut pulses: u64 = 0;
        let slope = self.mapping.slope;
        for r in 0..w.rows {
            for c in 0..w.cols {
                let want = slope * w.at(r, c);
                let pos_stuck = !self.pos.cell(r, c).is_healthy();
                let neg_stuck = !self.neg.cell(r, c).is_healthy();
                match (pos_stuck, neg_stuck) {
                    (true, false) => {
                        let g_stuck = self.pos.cell(r, c).conductance(cfg);
                        let target = cfg.clamp_g(g_stuck - want);
                        let r_ = program_cell(
                            self.neg.cell_mut(r, c),
                            cfg,
                            target,
                            rng,
                        );
                        pulses += u64::from(r_.iters);
                    }
                    (false, true) => {
                        let g_stuck = self.neg.cell(r, c).conductance(cfg);
                        let target = cfg.clamp_g(g_stuck + want);
                        let r_ = program_cell(
                            self.pos.cell_mut(r, c),
                            cfg,
                            target,
                            rng,
                        );
                        pulses += u64::from(r_.iters);
                    }
                    // Both stuck (rare, ~fault_rate^2) or both healthy:
                    // nothing to compensate with / for.
                    _ => {}
                }
            }
        }
        pulses
    }

    /// Advance both rails' virtual age by `dt_s`.
    pub fn age(&mut self, dt_s: f64, rng: &mut Pcg64) {
        self.pos.age(dt_s, rng);
        self.neg.age(dt_s, rng);
    }

    /// Logical weight matrix as deployed (including programming error and
    /// stuck cells) — what the twin actually computes with.
    pub fn effective_weights(&self) -> Mat {
        let gp = self.pos.conductance_matrix();
        let gn = self.neg.conductance_matrix();
        Mat::from_fn(gp.rows, gp.cols, |r, c| {
            self.mapping.pair_to_weight(gp.at(r, c), gn.at(r, c))
        })
    }

    /// Fully-physical logical VMM (per-cell reads on both rails):
    /// y = v^T (G+ - G-) / slope.
    pub fn vmm_physical(&self, v: &[f64], rng: &mut Pcg64) -> Vec<f64> {
        let ip = self.pos.vmm_physical(v, rng);
        let in_ = self.neg.vmm_physical(v, rng);
        ip.iter()
            .zip(&in_)
            .map(|(&a, &b)| (a - b) / self.mapping.slope)
            .collect()
    }

    pub fn rows(&self) -> usize {
        self.pos.rows
    }

    pub fn cols(&self) -> usize {
        self.pos.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_cfg() -> DeviceConfig {
        DeviceConfig {
            read_noise: 0.0,
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        }
    }

    #[test]
    fn ideal_deployment_reproduces_weights_exactly() {
        let cfg = quiet_cfg();
        let mut rng = Pcg64::seeded(1);
        let w = Mat::from_vec(3, 2, vec![0.4, -0.7, 0.0, 1.2, -0.05, 0.3]);
        let d = DifferentialArray::deploy(&w, &cfg, &mut rng);
        let eff = d.effective_weights();
        for i in 0..w.data.len() {
            assert!(
                (eff.data[i] - w.data[i]).abs() < 1e-9,
                "weight {i}: {} vs {}",
                eff.data[i],
                w.data[i]
            );
        }
    }

    #[test]
    fn ideal_vmm_matches_matrix_product() {
        let cfg = quiet_cfg();
        let mut rng = Pcg64::seeded(2);
        let w = Mat::from_vec(4, 3, (0..12).map(|k| (k as f64 - 6.0) / 6.0).collect());
        let d = DifferentialArray::deploy(&w, &cfg, &mut rng);
        let v = [0.3, -0.2, 0.5, 0.1];
        let got = d.vmm_physical(&v, &mut rng);
        let want = w.vecmat(&v);
        for (g, e) in got.iter().zip(&want) {
            assert!((g - e).abs() < 1e-9, "{g} vs {e}");
        }
    }

    #[test]
    fn realistic_deployment_weight_error_is_small() {
        let cfg = DeviceConfig { fault_rate: 0.0, ..Default::default() };
        let mut rng = Pcg64::seeded(3);
        let w = Mat::from_fn(14, 14, |r, c| {
            ((r * 14 + c) as f64 / 98.0 - 1.0) * 0.8
        });
        let d = DifferentialArray::deploy(&w, &cfg, &mut rng);
        let eff = d.effective_weights();
        // Relative-to-w_max deviation should be within a few percent
        // (write-verify tolerance + read margin).
        let w_max = d.mapping.w_max;
        let mut worst: f64 = 0.0;
        for i in 0..w.data.len() {
            worst = worst.max((eff.data[i] - w.data[i]).abs() / w_max);
        }
        assert!(worst < 0.08, "worst relative weight error {worst}");
    }

    #[test]
    fn stuck_cells_perturb_but_do_not_crash() {
        let cfg = DeviceConfig { fault_rate: 0.3, ..Default::default() };
        let mut rng = Pcg64::seeded(4);
        let w = Mat::from_fn(8, 8, |r, c| ((r + c) as f64 / 8.0) - 0.5);
        let d = DifferentialArray::deploy(&w, &cfg, &mut rng);
        let out = d.vmm_physical(&[0.1; 8], &mut rng);
        assert_eq!(out.len(), 8);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn stuck_on_faults_compensated_for_matching_sign() {
        // A pos-rail cell stuck ON can still represent any w in
        // [0, w_max] by re-targeting the neg rail: g- = g_max - slope*w.
        // (Opposite-sign weights are fundamentally out of the pair's
        // representable range; they clip to the nearest value, 0.)
        let cfg = DeviceConfig {
            read_noise: 0.0,
            pulse_sigma: 0.0,
            fault_rate: 0.0,
            ..Default::default()
        };
        let mut rng = Pcg64::seeded(9);
        let w = Mat::from_vec(2, 2, vec![0.3, 0.4, 0.1, 0.0]);
        let mut d = DifferentialArray::deploy(&w, &cfg, &mut rng);
        use crate::device::taox::StuckMode;
        d.pos.cell_mut(0, 0).stuck = Some(StuckMode::StuckOn);
        d.pos.cell_mut(0, 1).stuck = Some(StuckMode::StuckOn);
        d.compensate_faults(&w, &cfg, &mut rng);
        let eff = d.effective_weights();
        for i in 0..w.data.len() {
            assert!(
                (eff.data[i] - w.data[i]).abs() < 0.05 * d.mapping.w_max,
                "weight {i}: {} vs {}",
                eff.data[i],
                w.data[i]
            );
        }
    }

    #[test]
    fn unrecoverable_fault_clips_to_nearest_representable() {
        // pos stuck ON with a *negative* weight: best achievable is 0.
        let cfg = DeviceConfig {
            read_noise: 0.0,
            pulse_sigma: 0.0,
            fault_rate: 0.0,
            ..Default::default()
        };
        let mut rng = Pcg64::seeded(12);
        let w = Mat::from_vec(1, 2, vec![-0.4, 0.4]);
        let mut d = DifferentialArray::deploy(&w, &cfg, &mut rng);
        use crate::device::taox::StuckMode;
        d.pos.cell_mut(0, 0).stuck = Some(StuckMode::StuckOn);
        d.compensate_faults(&w, &cfg, &mut rng);
        let eff = d.effective_weights();
        assert!(
            eff.at(0, 0).abs() < 0.05 * d.mapping.w_max,
            "clipped weight should be ~0, got {}",
            eff.at(0, 0)
        );
    }

    #[test]
    fn reprogram_restores_drifted_weights_and_counts_pulses() {
        let cfg = DeviceConfig { fault_rate: 0.0, ..Default::default() };
        let mut rng = Pcg64::seeded(21);
        let w = Mat::from_fn(12, 12, |r, c| {
            ((r * 12 + c) as f64 / 144.0 - 0.5) * 0.9
        });
        let mut d = DifferentialArray::deploy(&w, &cfg, &mut rng);
        // Age hard enough that drift is visible, then recalibrate.
        d.age(1e7, &mut rng);
        let mean_err = |d: &DifferentialArray| {
            let eff = d.effective_weights();
            eff.data
                .iter()
                .zip(&w.data)
                .map(|(&a, &b)| (a - b).abs() / d.mapping.w_max)
                .sum::<f64>()
                / w.data.len() as f64
        };
        let aged = mean_err(&d);
        let pulses = d.reprogram(&w, &cfg, &mut rng);
        let restored = mean_err(&d);
        assert!(pulses > 0, "reprogramming issued no pulses");
        assert!(
            restored < aged,
            "reprogram did not improve fidelity ({restored} vs {aged})"
        );
        assert!(restored < 0.05, "post-recal error too large: {restored}");
    }

    #[test]
    fn reprogram_preserves_stuck_maps() {
        let cfg = DeviceConfig { fault_rate: 0.0, ..Default::default() };
        let mut rng = Pcg64::seeded(22);
        let w = Mat::from_fn(6, 6, |r, c| ((r + c) as f64 / 12.0) - 0.4);
        let mut d = DifferentialArray::deploy(&w, &cfg, &mut rng);
        use crate::device::taox::StuckMode;
        d.pos.cell_mut(1, 2).stuck = Some(StuckMode::StuckOff);
        d.neg.cell_mut(4, 3).stuck = Some(StuckMode::StuckOn);
        d.reprogram(&w, &cfg, &mut rng);
        assert!(!d.pos.cell(1, 2).is_healthy(), "stuck map lost on pos rail");
        assert!(!d.neg.cell(4, 3).is_healthy(), "stuck map lost on neg rail");
    }

    #[test]
    fn fault_compensation_improves_weight_fidelity() {
        // Statistically: compensated deployment beats leaving faults
        // alone. Build one compensated and one raw deployment on the same
        // fault pattern and compare mean weight error.
        let cfg = DeviceConfig { fault_rate: 0.1, ..Default::default() };
        let w = Mat::from_fn(16, 16, |r, c| {
            ((r * 16 + c) as f64 / 256.0) - 0.5
        });
        let mean_err = |d: &DifferentialArray| {
            let eff = d.effective_weights();
            eff.data
                .iter()
                .zip(&w.data)
                .map(|(&a, &b)| (a - b).abs() / d.mapping.w_max)
                .sum::<f64>()
                / w.data.len() as f64
        };
        // Compensated path (deploy runs compensation internally).
        let mut rng = Pcg64::seeded(10);
        let comp = DifferentialArray::deploy(&w, &cfg, &mut rng);
        // Raw path: same seed -> same sampled faults, no compensation.
        let mut rng2 = Pcg64::seeded(10);
        let mapping = WeightMapping::for_weights(&w, &cfg);
        let (gp_t, gn_t) = mapping.map_matrix(&w);
        let mut pos =
            CrossbarArray::sample(w.rows, w.cols, cfg.clone(), &mut rng2);
        let mut neg =
            CrossbarArray::sample(w.rows, w.cols, cfg.clone(), &mut rng2);
        let sp = pos.program_summarized(&gp_t, &mut rng2);
        let sn = neg.program_summarized(&gn_t, &mut rng2);
        let raw = DifferentialArray { pos, neg, mapping, prog_stats: (sp, sn) };
        let (e_comp, e_raw) = (mean_err(&comp), mean_err(&raw));
        // Mean error improves moderately; the important effect is that the
        // *w_max-scale* stuck-ON outliers (which destabilise closed-loop
        // dynamics) are eliminated entirely.
        assert!(
            e_comp < 0.9 * e_raw,
            "compensated {e_comp} not better than raw {e_raw}"
        );
        assert!(e_comp < 0.085, "compensated error too large: {e_comp}");
    }
}
