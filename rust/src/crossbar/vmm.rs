//! The request-path VMM engine.
//!
//! [`DifferentialArray::vmm_physical`] draws one RNG normal per cell per
//! read — faithful but O(n*m) RNG work. The engine instead caches the
//! deployed effective weight matrix W (and its element-wise square) once at
//! build time and computes
//!
//!   y   = v^T W                        (clean differential output)
//!   y_j += sigma * sqrt((v^2)^T W2_j) * eps_j
//!
//! which is *exactly* the distribution of summing per-cell independent
//! multiplicative Gaussian read noise (a sum of independent Gaussians is
//! Gaussian with summed variances) — at two gemv's plus one normal per
//! output. `NoiseMode::PerCell` keeps the physical path for validation.
//!
//! ## Noise lanes and draw indexing
//!
//! Every kernel takes caller-supplied per-trajectory [`NoiseLane`]s instead
//! of a shared sequential RNG, and addresses draws by **explicit index**:
//!
//! * `Fast`: output column `j` draws at lane index
//!   `cursor + col_offset + j`; one read consumes `full_cols` draws.
//! * `PerCell`: cell `(r, c)` draws at
//!   `cursor + r * full_cols + col_offset + c`; one read consumes
//!   `rows * full_cols` draws.
//!
//! `col_offset`/`full_cols` are the engine's position in the full logical
//! layer (0 / `cols` for a monolithic engine; the slice coordinates for a
//! [`VmmEngine::column_shard`]), so a shard engine reads *the same* lane
//! values the monolithic engine would produce for its columns, and a shard
//! worker that advances by the full-layer draw count stays in lockstep.
//! The shard kernels (`vmm_shard_*`) never advance — the layer-level
//! caller advances once per assembled read
//! ([`VmmEngine::draws_per_read`]). This is what makes noisy reads
//! bit-identical across serial, batched, and sharded execution; see the
//! noise-determinism invariants in `lib.rs`.
//!
//! ## Kernel independence
//!
//! Both GEMMs (the clean read over W and the variance read over W2) run
//! on the runtime-dispatched microkernels of [`crate::util::kernel`]
//! (AVX2 / scalar / threaded), which are bit-identical to each other by
//! construction. Noise is applied *after* the GEMM, addressed purely by
//! lane cursor and column index — so kernel choice can never shift which
//! draws a trajectory consumes, and seeded noisy reads replay exactly
//! across `MEMODE_KERNEL` settings, CPU generations and thread counts.
//!
//! [`DifferentialArray::vmm_physical`]: crate::crossbar::differential::DifferentialArray::vmm_physical

use crate::crossbar::differential::DifferentialArray;
use crate::device::noise::NoiseSource;
use crate::util::rng::NoiseLane;
use crate::util::tensor::Mat;

/// How read noise is realised on the fast path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NoiseMode {
    /// No read noise (ideal analogue read).
    Off,
    /// Moment-matched per-output noise (fast; distribution-identical).
    Fast,
    /// Per-cell noise through the full device model (slow; reference).
    PerCell,
}

/// Cached VMM over a deployed differential array.
#[derive(Debug, Clone)]
pub struct VmmEngine {
    /// Effective logical weights (deployment errors baked in).
    w_eff: Mat,
    /// Element-wise square of the *conductance-domain* weights divided by
    /// slope^2 — i.e. ((G+)^2 + (G-)^2)/slope^2, the variance kernel of the
    /// differential read.
    var_kernel: Mat,
    pub read_noise: NoiseSource,
    pub mode: NoiseMode,
    /// First logical layer column this engine produces (0 unless the
    /// engine is a [`VmmEngine::column_shard`] slice): lane draws index
    /// into the *full* layer's column space.
    col_offset: usize,
    /// Full logical layer width the draw-index space spans.
    full_cols: usize,
    /// Scratch for v^2 (hot path, no allocation).
    v2: Vec<f64>,
    /// Batched scratch: stacked v^2 rows (reserved once per max batch).
    v2b: Vec<f64>,
    /// Batched scratch: stacked per-output variances.
    varb: Vec<f64>,
    /// Largest batch the scratch has been reserved for. Tracking the
    /// high-water mark lets [`VmmEngine::vmm_batch_into`] reserve exactly
    /// once per new maximum instead of letting `resize` re-grow
    /// geometrically while batch sizes alternate across sub-batches.
    max_batch: usize,
}

impl VmmEngine {
    /// Build from a deployed array and a read-noise level.
    ///
    /// Note the variance kernel uses the *two rails separately*: noise on
    /// the + and - columns is independent, so variances add — using
    /// (G+ - G-)^2 would understate noise for large weights.
    pub fn new(
        arr: &DifferentialArray,
        read_noise: NoiseSource,
        mode: NoiseMode,
    ) -> Self {
        let gp = arr.pos.conductance_matrix();
        let gn = arr.neg.conductance_matrix();
        let s = arr.mapping.slope;
        let w_eff = arr.effective_weights();
        let var_kernel = Mat::from_fn(gp.rows, gp.cols, |r, c| {
            let a = gp.at(r, c) / s;
            let b = gn.at(r, c) / s;
            a * a + b * b
        });
        let v2 = vec![0.0; gp.rows];
        let full_cols = w_eff.cols;
        Self {
            w_eff,
            var_kernel,
            read_noise,
            mode,
            col_offset: 0,
            full_cols,
            v2,
            v2b: Vec::new(),
            varb: Vec::new(),
            max_batch: 0,
        }
    }

    /// Build from a tiled deployment (layers larger than one 32x32 array).
    pub fn from_tiled(
        tiled: &crate::crossbar::tiling::TiledMatrix,
        read_noise: NoiseSource,
        mode: NoiseMode,
    ) -> Self {
        let w_eff = tiled.effective_weights();
        let var_kernel = tiled.variance_kernel();
        let v2 = vec![0.0; w_eff.rows];
        let full_cols = w_eff.cols;
        Self {
            w_eff,
            var_kernel,
            read_noise,
            mode,
            col_offset: 0,
            full_cols,
            v2,
            v2b: Vec::new(),
            varb: Vec::new(),
            max_batch: 0,
        }
    }

    /// Refresh the cached weights + variance kernel from the (aged or
    /// reprogrammed) tiled deployment this engine was built from.
    ///
    /// Only the cached *values* change: `col_offset`, `full_cols`, mode,
    /// scratch and `max_batch` are untouched, so [`VmmEngine::draws_per_read`]
    /// and the draw-index scheme are provably unchanged — aging can never
    /// re-couple noise lanes to the execution schedule (the device-lifetime
    /// invariant in `lib.rs`). Cold path: runs only on explicit
    /// `advance_age` / recalibration, never inside a rollout.
    pub fn refresh_from_tiled(
        &mut self,
        tiled: &crate::crossbar::tiling::TiledMatrix,
    ) {
        assert_eq!(
            (tiled.rows, tiled.cols),
            (self.w_eff.rows, self.w_eff.cols),
            "refresh must keep the engine's shape"
        );
        assert!(
            self.col_offset == 0 && self.full_cols == self.w_eff.cols,
            "refresh_from_tiled only supports monolithic engines"
        );
        self.w_eff = tiled.effective_weights();
        self.var_kernel = tiled.variance_kernel();
    }

    /// Build an *ideal* engine straight from logical weights (no hardware
    /// sampling) — used by digital baselines and unit tests.
    pub fn ideal(w: Mat) -> Self {
        let var_kernel = w.map(|x| x * x);
        let v2 = vec![0.0; w.rows];
        let full_cols = w.cols;
        Self {
            w_eff: w,
            var_kernel,
            read_noise: NoiseSource::off(),
            mode: NoiseMode::Off,
            col_offset: 0,
            full_cols,
            v2,
            v2b: Vec::new(),
            varb: Vec::new(),
            max_batch: 0,
        }
    }

    /// Reserve the batched scratch for the largest batch seen so far.
    ///
    /// `Vec::resize` alone would also never shrink, but its growth path is
    /// geometric-amortised; reserving exactly at each new high-water mark
    /// keeps the scratch at the size actually needed and makes the warm
    /// path's no-allocation property explicit (a batch ≤ `max_batch` can
    /// never touch the allocator).
    fn ensure_batch_scratch(&mut self, batch: usize) {
        if batch > self.max_batch {
            self.max_batch = batch;
            let need_v2b = batch * self.w_eff.rows;
            if self.v2b.capacity() < need_v2b {
                self.v2b.reserve_exact(need_v2b - self.v2b.len());
            }
            let need_varb = batch * self.w_eff.cols;
            if self.varb.capacity() < need_varb {
                self.varb.reserve_exact(need_varb - self.varb.len());
            }
        }
    }

    pub fn rows(&self) -> usize {
        self.w_eff.rows
    }

    pub fn cols(&self) -> usize {
        self.w_eff.cols
    }

    pub fn weights(&self) -> &Mat {
        &self.w_eff
    }

    /// Lane draws one full-width read of this engine's logical layer
    /// consumes — what layer-level callers advance by after assembling a
    /// read from shard pieces (the non-shard kernels advance internally).
    /// Identical across a parent engine and its column shards, so every
    /// execution form moves the cursor in lockstep.
    pub fn draws_per_read(&self) -> u64 {
        match self.mode {
            NoiseMode::Off => 0,
            NoiseMode::Fast if self.read_noise.is_off() => 0,
            NoiseMode::Fast => self.full_cols as u64,
            NoiseMode::PerCell => (self.w_eff.rows * self.full_cols) as u64,
        }
    }

    /// y = v^T W with the configured read-noise model, drawing from (and
    /// advancing) the trajectory's noise lane. Allocation-free.
    pub fn vmm_into(&mut self, v: &[f64], y: &mut [f64], lane: &mut NoiseLane) {
        self.w_eff.vecmat_into(v, y);
        match self.mode {
            NoiseMode::Off => {}
            NoiseMode::Fast => {
                if self.read_noise.is_off() {
                    return;
                }
                for (dst, &src) in self.v2.iter_mut().zip(v) {
                    *dst = src * src;
                }
                // var_j = sigma^2 * (v^2)^T K_j ; add sqrt(var)*eps_j with
                // eps_j drawn at the column's full-layer lane index.
                let sigma = self.read_noise.sigma;
                let c0 = self.col_offset as u64;
                for (j, yj) in y.iter_mut().enumerate() {
                    let mut var = 0.0;
                    for r in 0..self.var_kernel.rows {
                        var += self.v2[r] * self.var_kernel.at(r, j);
                    }
                    *yj += sigma * var.sqrt() * lane.normal_at(c0 + j as u64);
                }
                lane.advance(self.full_cols as u64);
            }
            NoiseMode::PerCell => {
                // Reference path: re-draw every cell, indexed by its
                // (row, full-layer column) position so skipped zero-input
                // rows never shift other cells' draws.
                let sigma = self.read_noise.sigma;
                let fc = self.full_cols as u64;
                let c0 = self.col_offset as u64;
                y.fill(0.0);
                for r in 0..self.w_eff.rows {
                    let vr = v[r];
                    if vr == 0.0 {
                        continue;
                    }
                    let row_base = (r as u64).wrapping_mul(fc) + c0;
                    for c in 0..self.w_eff.cols {
                        // Split the logical weight back into rails using the
                        // variance kernel is not possible cell-wise; instead
                        // perturb the logical weight with the rail-correct
                        // std: std_rc = sigma * sqrt(var_kernel_rc).
                        let w = self.w_eff.at(r, c);
                        let std = sigma * self.var_kernel.at(r, c).sqrt();
                        y[c] += vr
                            * (w + std * lane.normal_at(row_base + c as u64));
                    }
                }
                lane.advance((self.w_eff.rows as u64).wrapping_mul(fc));
            }
        }
    }

    /// Allocating convenience wrapper.
    pub fn vmm(&mut self, v: &[f64], lane: &mut NoiseLane) -> Vec<f64> {
        let mut y = vec![0.0; self.cols()];
        self.vmm_into(v, &mut y, lane);
        y
    }

    /// Per-shard read: `y = v^T W[:, c0..c1]` — the columns owned by one
    /// tile column-group (`y.len() == c1 - c0`), driven by the full input
    /// vector.
    ///
    /// Per output element the floating-point accumulation order over the
    /// shared dimension is identical to [`VmmEngine::vmm_into`]
    /// ([`Mat::vecmat_cols_into`] preserves it), and the noise draws are
    /// indexed by full-layer column, so the assembled sharded read is
    /// bit-identical to the monolithic one in *every* noise mode. Shard
    /// kernels never advance the lane — the caller advances once per
    /// assembled layer read by [`VmmEngine::draws_per_read`].
    pub fn vmm_shard_into(
        &mut self,
        v: &[f64],
        c0: usize,
        c1: usize,
        y: &mut [f64],
        lane: &NoiseLane,
    ) {
        assert!(
            c0 <= c1 && c1 <= self.cols(),
            "vmm_shard: column range {c0}..{c1} outside 0..{}",
            self.cols()
        );
        self.w_eff.vecmat_cols_into(v, c0, c1, y);
        match self.mode {
            NoiseMode::Off => {}
            NoiseMode::Fast => {
                if self.read_noise.is_off() {
                    return;
                }
                for (dst, &src) in self.v2.iter_mut().zip(v) {
                    *dst = src * src;
                }
                let sigma = self.read_noise.sigma;
                let off = self.col_offset as u64;
                for (j, yj) in (c0..c1).zip(y.iter_mut()) {
                    let mut var = 0.0;
                    for r in 0..self.var_kernel.rows {
                        var += self.v2[r] * self.var_kernel.at(r, j);
                    }
                    *yj += sigma * var.sqrt() * lane.normal_at(off + j as u64);
                }
            }
            NoiseMode::PerCell => {
                let sigma = self.read_noise.sigma;
                let fc = self.full_cols as u64;
                let off = self.col_offset as u64;
                y.fill(0.0);
                for r in 0..self.w_eff.rows {
                    let vr = v[r];
                    if vr == 0.0 {
                        continue;
                    }
                    let row_base = (r as u64).wrapping_mul(fc) + off;
                    for (c, yc) in (c0..c1).zip(y.iter_mut()) {
                        let w = self.w_eff.at(r, c);
                        let std = sigma * self.var_kernel.at(r, c).sqrt();
                        *yc += vr
                            * (w + std * lane.normal_at(row_base + c as u64));
                    }
                }
            }
        }
    }

    /// Batched per-shard read: `ys[b] = vs[b]^T W[:, c0..c1]` for `batch`
    /// stacked full-width inputs (`ys: [batch * (c1-c0)]`). The multi-tile
    /// analogue of [`VmmEngine::vmm_batch_into`], restricted to one shard's
    /// tile column-group; with per-trajectory lanes the output is
    /// bit-identical to the corresponding column slice of the monolithic
    /// batched read in every noise mode. Does not advance the lanes (see
    /// [`VmmEngine::vmm_shard_into`]).
    pub fn vmm_shard_batch_into(
        &mut self,
        vs: &[f64],
        batch: usize,
        c0: usize,
        c1: usize,
        ys: &mut [f64],
        lanes: &[NoiseLane],
    ) {
        let rows = self.rows();
        let width = c1 - c0;
        assert!(
            c0 <= c1 && c1 <= self.cols(),
            "vmm_shard_batch: column range {c0}..{c1} outside 0..{}",
            self.cols()
        );
        assert_eq!(
            vs.len(),
            batch * rows,
            "vmm_shard_batch: vs length != batch * rows"
        );
        assert_eq!(
            ys.len(),
            batch * width,
            "vmm_shard_batch: ys length != batch * range width"
        );
        assert_eq!(
            lanes.len(),
            batch,
            "vmm_shard_batch: one noise lane per trajectory"
        );
        match self.mode {
            NoiseMode::Off => {
                self.w_eff.vecmat_batch_cols_into(vs, batch, c0, c1, ys);
            }
            NoiseMode::Fast => {
                self.w_eff.vecmat_batch_cols_into(vs, batch, c0, c1, ys);
                if self.read_noise.is_off() {
                    return;
                }
                self.ensure_batch_scratch(batch);
                self.v2b.resize(batch * rows, 0.0);
                for (dst, &src) in self.v2b.iter_mut().zip(vs) {
                    *dst = src * src;
                }
                self.varb.resize(batch * width, 0.0);
                self.var_kernel.vecmat_batch_cols_into(
                    &self.v2b,
                    batch,
                    c0,
                    c1,
                    &mut self.varb,
                );
                let sigma = self.read_noise.sigma;
                let off = self.col_offset as u64;
                for (b, lane) in lanes.iter().enumerate() {
                    let seg = &mut ys[b * width..(b + 1) * width];
                    let var = &self.varb[b * width..(b + 1) * width];
                    for ((j, yj), &vj) in
                        (c0..c1).zip(seg.iter_mut()).zip(var)
                    {
                        *yj += sigma
                            * vj.sqrt()
                            * lane.normal_at(off + j as u64);
                    }
                }
            }
            NoiseMode::PerCell => {
                for b in 0..batch {
                    let v = &vs[b * rows..(b + 1) * rows];
                    let y = &mut ys[b * width..(b + 1) * width];
                    self.vmm_shard_into(v, c0, c1, y, &lanes[b]);
                }
            }
        }
    }

    /// A standalone engine over one shard's tile column-group: the cached
    /// effective weights and variance kernel sliced to columns `c0..c1`,
    /// with the same noise configuration and the slice's position in the
    /// full layer recorded (`col_offset`/`full_cols`), so the shard
    /// engine's lane draws — and therefore its *noisy* reads — are
    /// bit-identical to the corresponding slice of this engine's reads.
    /// This is how the parallel shard workers each get an engine they can
    /// drive without sharing mutable state.
    pub fn column_shard(&self, c0: usize, c1: usize) -> VmmEngine {
        assert!(
            c0 < c1 && c1 <= self.cols(),
            "column_shard: range {c0}..{c1} outside 0..{}",
            self.cols()
        );
        let rows = self.w_eff.rows;
        let w_eff =
            Mat::from_fn(rows, c1 - c0, |r, c| self.w_eff.at(r, c0 + c));
        let var_kernel =
            Mat::from_fn(rows, c1 - c0, |r, c| self.var_kernel.at(r, c0 + c));
        Self {
            w_eff,
            var_kernel,
            read_noise: self.read_noise.clone(),
            mode: self.mode,
            col_offset: self.col_offset + c0,
            full_cols: self.full_cols,
            v2: vec![0.0; rows],
            v2b: Vec::new(),
            varb: Vec::new(),
            max_batch: 0,
        }
    }

    /// Batched multi-vector VMM: `ys[b] = vs[b]^T W + noise` for `batch`
    /// row-major stacked input vectors (`vs: [batch * rows]`,
    /// `ys: [batch * cols]`), with one noise lane per trajectory.
    ///
    /// This is the crossbar's multi-read amortisation: one GEMM over the
    /// cached effective weights (the matrix is traversed once per call, not
    /// once per trajectory), and in [`NoiseMode::Fast`] a second GEMM over
    /// the variance kernel replaces the per-output strided column walks of
    /// the serial path. Each trajectory's noise draws come from *its own
    /// lane at the same indices the serial read would use*, so the batched
    /// output is bit-identical to `batch` serial [`VmmEngine::vmm_into`]
    /// calls in every noise mode — regardless of batch size, composition
    /// or ordering. Advances every lane by [`VmmEngine::draws_per_read`].
    pub fn vmm_batch_into(
        &mut self,
        vs: &[f64],
        batch: usize,
        ys: &mut [f64],
        lanes: &mut [NoiseLane],
    ) {
        let rows = self.rows();
        let cols = self.cols();
        assert_eq!(
            vs.len(),
            batch * rows,
            "vmm_batch: vs length != batch * rows"
        );
        assert_eq!(
            ys.len(),
            batch * cols,
            "vmm_batch: ys length != batch * cols"
        );
        assert_eq!(
            lanes.len(),
            batch,
            "vmm_batch: one noise lane per trajectory"
        );
        match self.mode {
            NoiseMode::Off => {
                self.w_eff.vecmat_batch_into(vs, batch, ys);
            }
            NoiseMode::Fast => {
                self.w_eff.vecmat_batch_into(vs, batch, ys);
                if self.read_noise.is_off() {
                    return;
                }
                self.ensure_batch_scratch(batch);
                self.v2b.resize(batch * rows, 0.0);
                for (dst, &src) in self.v2b.iter_mut().zip(vs) {
                    *dst = src * src;
                }
                self.varb.resize(batch * cols, 0.0);
                // var[b][j] = (v_b^2)^T K_j as one contiguous GEMM, then
                // one indexed normal per (trajectory, output) from the
                // trajectory's own lane.
                self.var_kernel.vecmat_batch_into(
                    &self.v2b,
                    batch,
                    &mut self.varb,
                );
                let sigma = self.read_noise.sigma;
                let c0 = self.col_offset as u64;
                for (b, lane) in lanes.iter().enumerate() {
                    let seg = &mut ys[b * cols..(b + 1) * cols];
                    let var = &self.varb[b * cols..(b + 1) * cols];
                    for (j, (yj, &vj)) in
                        seg.iter_mut().zip(var).enumerate()
                    {
                        *yj += sigma
                            * vj.sqrt()
                            * lane.normal_at(c0 + j as u64);
                    }
                }
                let n = self.full_cols as u64;
                for lane in lanes.iter_mut() {
                    lane.advance(n);
                }
            }
            NoiseMode::PerCell => {
                // Reference path: each trajectory re-draws every cell from
                // (and advances) its own lane.
                for b in 0..batch {
                    let v = &vs[b * rows..(b + 1) * rows];
                    let y = &mut ys[b * cols..(b + 1) * cols];
                    self.vmm_into(v, y, &mut lanes[b]);
                }
            }
        }
    }

    /// Allocating convenience wrapper for [`VmmEngine::vmm_batch_into`].
    pub fn vmm_batch(
        &mut self,
        vs: &[f64],
        batch: usize,
        lanes: &mut [NoiseLane],
    ) -> Vec<f64> {
        let mut ys = vec![0.0; batch * self.cols()];
        self.vmm_batch_into(vs, batch, &mut ys, lanes);
        ys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::taox::DeviceConfig;
    use crate::util::rng::Pcg64;
    use crate::util::stats;

    fn deployed(seed: u64, read_noise: f64) -> (DifferentialArray, NoiseSource) {
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise,
            ..Default::default()
        };
        let mut rng = Pcg64::seeded(seed);
        let w = Mat::from_fn(8, 6, |r, c| ((r * 6 + c) as f64 / 24.0) - 1.0);
        (
            DifferentialArray::deploy(&w, &cfg, &mut rng),
            NoiseSource::new(read_noise),
        )
    }

    fn lanes_from(seeds: &[u64]) -> Vec<NoiseLane> {
        seeds.iter().map(|&s| NoiseLane::from_seed(s)).collect()
    }

    #[test]
    fn noise_off_matches_linear_algebra() {
        let (arr, _) = deployed(1, 0.0);
        let mut eng = VmmEngine::new(&arr, NoiseSource::off(), NoiseMode::Off);
        let v = [0.1, -0.2, 0.3, 0.0, 0.25, -0.15, 0.05, 0.4];
        let got = eng.vmm(&v, &mut NoiseLane::from_seed(2));
        let want = arr.effective_weights().vecmat(&v);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn fast_mode_matches_percell_moments() {
        // The fast (moment-matched) and per-cell noise paths must agree in
        // mean and variance — that is the correctness contract that lets the
        // hot path use two gemv's instead of n*m RNG draws.
        let (arr, noise) = deployed(3, 0.05);
        let mut fast = VmmEngine::new(&arr, noise.clone(), NoiseMode::Fast);
        let mut cell = VmmEngine::new(&arr, noise, NoiseMode::PerCell);
        let v = [0.2, -0.1, 0.3, 0.15, -0.25, 0.05, 0.1, -0.3];
        let n = 4000;
        let mut lane = NoiseLane::from_seed(4);
        let col = 2;
        let fast_samples: Vec<f64> =
            (0..n).map(|_| fast.vmm(&v, &mut lane)[col]).collect();
        let cell_samples: Vec<f64> =
            (0..n).map(|_| cell.vmm(&v, &mut lane)[col]).collect();
        let sf = stats::summary(&fast_samples);
        let sc = stats::summary(&cell_samples);
        assert!(
            (sf.mean - sc.mean).abs() < 3.0 * (sf.std + sc.std) / (n as f64).sqrt() + 1e-9,
            "means differ: {} vs {}",
            sf.mean,
            sc.mean
        );
        let ratio = sf.std / sc.std;
        assert!((ratio - 1.0).abs() < 0.1, "std ratio {ratio}");
    }

    #[test]
    fn ideal_engine_is_exact() {
        let w = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let mut eng = VmmEngine::ideal(w);
        let y = eng.vmm(&[1.0, 1.0], &mut NoiseLane::from_seed(1));
        assert_eq!(y, vec![4.0, 6.0]);
    }

    #[test]
    fn vmm_into_reuses_buffer() {
        let w = Mat::from_vec(2, 3, vec![1., 0., 0., 0., 1., 0.]);
        let mut eng = VmmEngine::ideal(w);
        let mut y = vec![9.0; 3];
        eng.vmm_into(&[2.0, 3.0], &mut y, &mut NoiseLane::from_seed(1));
        assert_eq!(y, vec![2.0, 3.0, 0.0]);
    }

    #[test]
    fn batch_off_bit_identical_to_serial() {
        // The batched execution engine's correctness contract: with noise
        // off, vmm_batch_into equals B independent serial reads exactly.
        let (arr, _) = deployed(7, 0.0);
        let mut eng = VmmEngine::new(&arr, NoiseSource::off(), NoiseMode::Off);
        let batch = 5;
        let mut vs = vec![0.0; batch * 8];
        for (k, v) in vs.iter_mut().enumerate() {
            *v = if k % 7 == 3 { 0.0 } else { (k as f64 * 0.21).cos() * 0.3 };
        }
        let mut lanes = lanes_from(&[10, 11, 12, 13, 14]);
        let ys = eng.vmm_batch(&vs, batch, &mut lanes);
        for b in 0..batch {
            let mut lane = NoiseLane::from_seed(10 + b as u64);
            let want = eng.vmm(&vs[b * 8..(b + 1) * 8], &mut lane);
            assert_eq!(&ys[b * 6..(b + 1) * 6], &want[..], "traj {b}");
        }
    }

    #[test]
    fn batch_fast_noise_bit_identical_to_serial_lanes() {
        // The noise-lane guarantee: with per-trajectory lanes the *noisy*
        // batched read reproduces each trajectory's serial read exactly.
        let (arr, noise) = deployed(11, 0.05);
        let mut eng = VmmEngine::new(&arr, noise, NoiseMode::Fast);
        let batch = 4;
        let vs: Vec<f64> =
            (0..batch * 8).map(|k| ((k as f64) * 0.13).sin() * 0.3).collect();
        let seeds = [21u64, 22, 23, 24];
        let mut lanes = lanes_from(&seeds);
        let ys = eng.vmm_batch(&vs, batch, &mut lanes);
        for (b, &s) in seeds.iter().enumerate() {
            let mut lane = NoiseLane::from_seed(s);
            let want = eng.vmm(&vs[b * 8..(b + 1) * 8], &mut lane);
            assert_eq!(&ys[b * 6..(b + 1) * 6], &want[..], "traj {b}");
            assert_eq!(lane, lanes[b], "traj {b} cursor diverged");
        }
    }

    #[test]
    fn batch_fast_noise_is_order_independent() {
        // Shuffling the batch shuffles the outputs with it: trajectory
        // draws depend only on (lane, index), never on batch position.
        let (arr, noise) = deployed(13, 0.04);
        let mut eng = VmmEngine::new(&arr, noise, NoiseMode::Fast);
        let vs: Vec<f64> =
            (0..3 * 8).map(|k| ((k as f64) * 0.29).cos() * 0.2).collect();
        let seeds = [31u64, 32, 33];
        let mut lanes = lanes_from(&seeds);
        let ys = eng.vmm_batch(&vs, 3, &mut lanes);
        // Reversed composition.
        let mut vs_rev = vec![0.0; 3 * 8];
        for b in 0..3 {
            vs_rev[b * 8..(b + 1) * 8]
                .copy_from_slice(&vs[(2 - b) * 8..(3 - b) * 8]);
        }
        let mut lanes_rev = lanes_from(&[33, 32, 31]);
        let ys_rev = eng.vmm_batch(&vs_rev, 3, &mut lanes_rev);
        for b in 0..3 {
            assert_eq!(
                &ys[b * 6..(b + 1) * 6],
                &ys_rev[(2 - b) * 6..(3 - b) * 6],
                "traj {b} depends on batch position"
            );
        }
    }

    #[test]
    fn batch_fast_noise_matches_serial_moments() {
        // Per-trajectory noise of the batched fast path must be
        // distribution-identical to the serial fast path.
        let (arr, noise) = deployed(11, 0.05);
        let mut eng = VmmEngine::new(&arr, noise, NoiseMode::Fast);
        let v = [0.2, -0.1, 0.3, 0.15, -0.25, 0.05, 0.1, -0.3];
        let batch = 4;
        let vs: Vec<f64> = (0..batch).flat_map(|_| v).collect();
        let n = 3000;
        let col = 1;
        let mut slane = NoiseLane::from_seed(12);
        let serial: Vec<f64> =
            (0..n).map(|_| eng.vmm(&v, &mut slane)[col]).collect();
        // Trajectory 2 of the batch (all trajectories share the input).
        let mut lanes = lanes_from(&[40, 41, 42, 43]);
        let batched: Vec<f64> = (0..n)
            .map(|_| eng.vmm_batch(&vs, batch, &mut lanes)[2 * 6 + col])
            .collect();
        let ss = stats::summary(&serial);
        let sb = stats::summary(&batched);
        assert!(
            (ss.mean - sb.mean).abs()
                < 3.0 * (ss.std + sb.std) / (n as f64).sqrt() + 1e-9,
            "means differ: {} vs {}",
            ss.mean,
            sb.mean
        );
        let ratio = sb.std / ss.std;
        assert!((ratio - 1.0).abs() < 0.1, "std ratio {ratio}");
    }

    #[test]
    fn batch_percell_reference_runs_per_trajectory() {
        let (arr, noise) = deployed(13, 0.03);
        let mut eng = VmmEngine::new(&arr, noise, NoiseMode::PerCell);
        let batch = 3;
        let vs: Vec<f64> = (0..batch * 8).map(|k| (k as f64) * 0.01).collect();
        // Per-trajectory lanes: batched PerCell equals the serial
        // per-trajectory loop bit for bit.
        let seeds = [50u64, 51, 52];
        let mut lanes = lanes_from(&seeds);
        let got = eng.vmm_batch(&vs, batch, &mut lanes);
        for (b, &s) in seeds.iter().enumerate() {
            let mut lane = NoiseLane::from_seed(s);
            let want = eng.vmm(&vs[b * 8..(b + 1) * 8], &mut lane);
            assert_eq!(&got[b * 6..(b + 1) * 6], &want[..], "traj {b}");
        }
    }

    #[test]
    #[should_panic(expected = "batch * rows")]
    fn batch_shape_validated() {
        let mut eng = VmmEngine::ideal(Mat::zeros(2, 2));
        let mut ys = vec![0.0; 4];
        let mut lanes = lanes_from(&[1, 2]);
        eng.vmm_batch_into(&[0.0; 3], 2, &mut ys, &mut lanes);
    }

    #[test]
    #[should_panic(expected = "one noise lane per trajectory")]
    fn batch_lane_arity_validated() {
        let mut eng = VmmEngine::ideal(Mat::zeros(2, 2));
        let mut ys = vec![0.0; 4];
        let mut lanes = lanes_from(&[1]);
        eng.vmm_batch_into(&[0.0; 4], 2, &mut ys, &mut lanes);
    }

    #[test]
    fn batched_scratch_reserved_once_for_largest_batch() {
        // Alternating batch sizes must leave the scratch reserved at the
        // high-water mark (no re-growth churn between sub-batches).
        let (arr, noise) = deployed(21, 0.05);
        let mut eng = VmmEngine::new(&arr, noise, NoiseMode::Fast);
        for &b in &[8usize, 2, 8, 1, 5, 8] {
            let vs = vec![0.1; b * 8];
            let mut lanes: Vec<NoiseLane> =
                (0..b as u64).map(NoiseLane::from_seed).collect();
            let ys = eng.vmm_batch(&vs, b, &mut lanes);
            assert_eq!(ys.len(), b * 6);
        }
        assert_eq!(eng.max_batch, 8);
        assert!(eng.v2b.capacity() >= 8 * 8, "v2b under-reserved");
        assert!(eng.varb.capacity() >= 8 * 6, "varb under-reserved");
    }

    #[test]
    fn shard_reads_reassemble_monolithic_read_noise_off() {
        let (arr, _) = deployed(31, 0.0);
        let mut eng = VmmEngine::new(&arr, NoiseSource::off(), NoiseMode::Off);
        let v = [0.2, -0.1, 0.0, 0.15, -0.25, 0.05, 0.1, -0.3];
        let full = eng.vmm(&v, &mut NoiseLane::from_seed(1));
        let lane = NoiseLane::from_seed(2);
        // 6 outputs split 0..4 / 4..6.
        let mut assembled = vec![0.0; 6];
        let (a, b) = assembled.split_at_mut(4);
        eng.vmm_shard_into(&v, 0, 4, a, &lane);
        eng.vmm_shard_into(&v, 4, 6, b, &lane);
        assert_eq!(assembled, full);
    }

    #[test]
    fn shard_fast_noise_draws_match_monolithic_in_any_order() {
        // Indexed draws: shards of one plan read the same lane values as
        // the monolithic fast read, in whatever order they execute.
        let (arr, noise) = deployed(33, 0.04);
        let mut eng = VmmEngine::new(&arr, noise, NoiseMode::Fast);
        let v = [0.2, -0.1, 0.3, 0.15, -0.25, 0.05, 0.1, -0.3];
        let mut mono_lane = NoiseLane::from_seed(5);
        let full = eng.vmm(&v, &mut mono_lane);
        let lane = NoiseLane::from_seed(5);
        let mut assembled = vec![0.0; 6];
        {
            let (a, b) = assembled.split_at_mut(3);
            // Descending shard order on purpose.
            eng.vmm_shard_into(&v, 3, 6, b, &lane);
            eng.vmm_shard_into(&v, 0, 3, a, &lane);
        }
        assert_eq!(assembled, full);
        // The layer-level advance restores lockstep with the serial read.
        let mut lane = lane;
        lane.advance(eng.draws_per_read());
        assert_eq!(lane, mono_lane);
    }

    #[test]
    fn batched_shard_reads_reassemble_monolithic_batch() {
        let (arr, _) = deployed(35, 0.0);
        let mut eng = VmmEngine::new(&arr, NoiseSource::off(), NoiseMode::Off);
        let batch = 4;
        let mut vs = vec![0.0; batch * 8];
        for (k, v) in vs.iter_mut().enumerate() {
            *v = if k % 6 == 1 { 0.0 } else { (k as f64 * 0.41).sin() * 0.4 };
        }
        let mut lanes = lanes_from(&[3, 4, 5, 6]);
        let full = eng.vmm_batch(&vs, batch, &mut lanes);
        let shard_lanes = lanes_from(&[3, 4, 5, 6]);
        let mut left = vec![0.0; batch * 4];
        let mut right = vec![0.0; batch * 2];
        eng.vmm_shard_batch_into(&vs, batch, 0, 4, &mut left, &shard_lanes);
        eng.vmm_shard_batch_into(&vs, batch, 4, 6, &mut right, &shard_lanes);
        for b in 0..batch {
            assert_eq!(&left[b * 4..(b + 1) * 4], &full[b * 6..b * 6 + 4]);
            assert_eq!(&right[b * 2..(b + 1) * 2], &full[b * 6 + 4..(b + 1) * 6]);
        }
    }

    #[test]
    fn column_shard_engine_matches_slice_of_parent() {
        let (arr, _) = deployed(37, 0.0);
        let mut parent =
            VmmEngine::new(&arr, NoiseSource::off(), NoiseMode::Off);
        let mut shard = parent.column_shard(2, 5);
        assert_eq!(shard.rows(), 8);
        assert_eq!(shard.cols(), 3);
        let v = [0.3, -0.2, 0.1, 0.0, 0.25, -0.15, 0.05, 0.4];
        let full = parent.vmm(&v, &mut NoiseLane::from_seed(1));
        let got = shard.vmm(&v, &mut NoiseLane::from_seed(2));
        assert_eq!(&got[..], &full[2..5]);
        // Batched path through the shard engine too.
        let vs: Vec<f64> = (0..2).flat_map(|_| v).collect();
        let mut lanes = lanes_from(&[4, 5]);
        let fullb = parent.vmm_batch(&vs, 2, &mut lanes);
        let mut lanes = lanes_from(&[4, 5]);
        let gotb = shard.vmm_batch(&vs, 2, &mut lanes);
        for b in 0..2 {
            assert_eq!(&gotb[b * 3..(b + 1) * 3], &fullb[b * 6 + 2..b * 6 + 5]);
        }
    }

    #[test]
    fn column_shard_noisy_reads_match_parent_slice() {
        // The fan-out contract: a standalone shard engine driven by a copy
        // of the trajectory's lane reproduces the parent's noisy read for
        // its columns exactly, and advances the lane identically.
        let (arr, noise) = deployed(39, 0.05);
        let mut parent = VmmEngine::new(&arr, noise, NoiseMode::Fast);
        let mut shard = parent.column_shard(2, 5);
        let v = [0.3, -0.2, 0.1, 0.05, 0.25, -0.15, 0.05, 0.4];
        let mut lane_p = NoiseLane::from_seed(8);
        let mut lane_s = NoiseLane::from_seed(8);
        let full = parent.vmm(&v, &mut lane_p);
        let got = shard.vmm(&v, &mut lane_s);
        assert_eq!(&got[..], &full[2..5], "noisy shard slice diverged");
        assert_eq!(lane_p, lane_s, "shard lane fell out of lockstep");
    }

    #[test]
    #[should_panic(expected = "column range")]
    fn shard_range_validated() {
        let mut eng = VmmEngine::ideal(Mat::zeros(2, 3));
        let mut y = vec![0.0; 2];
        eng.vmm_shard_into(&[0.0; 2], 2, 4, &mut y, &NoiseLane::from_seed(1));
    }

    #[test]
    fn larger_noise_larger_spread() {
        let (arr, _) = deployed(5, 0.0);
        let v = [0.2; 8];
        let spread = |sigma: f64| {
            let mut eng = VmmEngine::new(
                &arr,
                NoiseSource::new(sigma),
                NoiseMode::Fast,
            );
            let mut lane = NoiseLane::from_seed(6);
            let s: Vec<f64> =
                (0..2000).map(|_| eng.vmm(&v, &mut lane)[0]).collect();
            stats::summary(&s).std
        };
        assert!(spread(0.05) > 2.0 * spread(0.01));
    }
}
