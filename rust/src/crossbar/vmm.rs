//! The request-path VMM engine.
//!
//! [`DifferentialArray::vmm_physical`] draws one RNG normal per cell per
//! read — faithful but O(n*m) RNG work. The engine instead caches the
//! deployed effective weight matrix W (and its element-wise square) once at
//! build time and computes
//!
//!   y   = v^T W                        (clean differential output)
//!   y_j += sigma * sqrt((v^2)^T W2_j) * eps_j
//!
//! which is *exactly* the distribution of summing per-cell independent
//! multiplicative Gaussian read noise (a sum of independent Gaussians is
//! Gaussian with summed variances) — at two gemv's plus one normal per
//! output. `NoiseMode::PerCell` keeps the physical path for validation.
//!
//! [`DifferentialArray::vmm_physical`]: crate::crossbar::differential::DifferentialArray::vmm_physical

use crate::crossbar::differential::DifferentialArray;
use crate::device::noise::NoiseSource;
use crate::util::rng::Pcg64;
use crate::util::tensor::Mat;

/// How read noise is realised on the fast path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NoiseMode {
    /// No read noise (ideal analogue read).
    Off,
    /// Moment-matched per-output noise (fast; distribution-identical).
    Fast,
    /// Per-cell noise through the full device model (slow; reference).
    PerCell,
}

/// Cached VMM over a deployed differential array.
#[derive(Debug, Clone)]
pub struct VmmEngine {
    /// Effective logical weights (deployment errors baked in).
    w_eff: Mat,
    /// Element-wise square of the *conductance-domain* weights divided by
    /// slope^2 — i.e. ((G+)^2 + (G-)^2)/slope^2, the variance kernel of the
    /// differential read.
    var_kernel: Mat,
    pub read_noise: NoiseSource,
    pub mode: NoiseMode,
    /// Scratch for v^2 (hot path, no allocation).
    v2: Vec<f64>,
}

impl VmmEngine {
    /// Build from a deployed array and a read-noise level.
    ///
    /// Note the variance kernel uses the *two rails separately*: noise on
    /// the + and - columns is independent, so variances add — using
    /// (G+ - G-)^2 would understate noise for large weights.
    pub fn new(
        arr: &DifferentialArray,
        read_noise: NoiseSource,
        mode: NoiseMode,
    ) -> Self {
        let gp = arr.pos.conductance_matrix();
        let gn = arr.neg.conductance_matrix();
        let s = arr.mapping.slope;
        let w_eff = arr.effective_weights();
        let var_kernel = Mat::from_fn(gp.rows, gp.cols, |r, c| {
            let a = gp.at(r, c) / s;
            let b = gn.at(r, c) / s;
            a * a + b * b
        });
        let v2 = vec![0.0; gp.rows];
        Self { w_eff, var_kernel, read_noise, mode, v2 }
    }

    /// Build from a tiled deployment (layers larger than one 32x32 array).
    pub fn from_tiled(
        tiled: &crate::crossbar::tiling::TiledMatrix,
        read_noise: NoiseSource,
        mode: NoiseMode,
    ) -> Self {
        let w_eff = tiled.effective_weights();
        let var_kernel = tiled.variance_kernel();
        let v2 = vec![0.0; w_eff.rows];
        Self { w_eff, var_kernel, read_noise, mode, v2 }
    }

    /// Build an *ideal* engine straight from logical weights (no hardware
    /// sampling) — used by digital baselines and unit tests.
    pub fn ideal(w: Mat) -> Self {
        let var_kernel = w.map(|x| x * x);
        let v2 = vec![0.0; w.rows];
        Self {
            w_eff: w,
            var_kernel,
            read_noise: NoiseSource::off(),
            mode: NoiseMode::Off,
            v2,
        }
    }

    pub fn rows(&self) -> usize {
        self.w_eff.rows
    }

    pub fn cols(&self) -> usize {
        self.w_eff.cols
    }

    pub fn weights(&self) -> &Mat {
        &self.w_eff
    }

    /// y = v^T W with the configured read-noise model. Allocation-free.
    pub fn vmm_into(&mut self, v: &[f64], y: &mut [f64], rng: &mut Pcg64) {
        self.w_eff.vecmat_into(v, y);
        match self.mode {
            NoiseMode::Off => {}
            NoiseMode::Fast => {
                if self.read_noise.is_off() {
                    return;
                }
                for (dst, &src) in self.v2.iter_mut().zip(v) {
                    *dst = src * src;
                }
                // var_j = sigma^2 * (v^2)^T K_j ; add sqrt(var)*eps.
                let sigma = self.read_noise.sigma;
                for (j, yj) in y.iter_mut().enumerate() {
                    let mut var = 0.0;
                    for r in 0..self.var_kernel.rows {
                        var += self.v2[r] * self.var_kernel.at(r, j);
                    }
                    *yj += sigma * var.sqrt() * rng.normal();
                }
            }
            NoiseMode::PerCell => {
                // Reference path: re-draw every cell.
                let sigma = self.read_noise.sigma;
                y.fill(0.0);
                for r in 0..self.w_eff.rows {
                    let vr = v[r];
                    if vr == 0.0 {
                        continue;
                    }
                    for c in 0..self.w_eff.cols {
                        // Split the logical weight back into rails using the
                        // variance kernel is not possible cell-wise; instead
                        // perturb the logical weight with the rail-correct
                        // std: std_rc = sigma * sqrt(var_kernel_rc).
                        let w = self.w_eff.at(r, c);
                        let std = sigma * self.var_kernel.at(r, c).sqrt();
                        y[c] += vr * (w + std * rng.normal());
                    }
                }
            }
        }
    }

    /// Allocating convenience wrapper.
    pub fn vmm(&mut self, v: &[f64], rng: &mut Pcg64) -> Vec<f64> {
        let mut y = vec![0.0; self.cols()];
        self.vmm_into(v, &mut y, rng);
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::taox::DeviceConfig;
    use crate::util::stats;

    fn deployed(seed: u64, read_noise: f64) -> (DifferentialArray, NoiseSource) {
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise,
            ..Default::default()
        };
        let mut rng = Pcg64::seeded(seed);
        let w = Mat::from_fn(8, 6, |r, c| ((r * 6 + c) as f64 / 24.0) - 1.0);
        (
            DifferentialArray::deploy(&w, &cfg, &mut rng),
            NoiseSource::new(read_noise),
        )
    }

    #[test]
    fn noise_off_matches_linear_algebra() {
        let (arr, _) = deployed(1, 0.0);
        let mut eng = VmmEngine::new(&arr, NoiseSource::off(), NoiseMode::Off);
        let v = [0.1, -0.2, 0.3, 0.0, 0.25, -0.15, 0.05, 0.4];
        let got = eng.vmm(&v, &mut Pcg64::seeded(2));
        let want = arr.effective_weights().vecmat(&v);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn fast_mode_matches_percell_moments() {
        // The fast (moment-matched) and per-cell noise paths must agree in
        // mean and variance — that is the correctness contract that lets the
        // hot path use two gemv's instead of n*m RNG draws.
        let (arr, noise) = deployed(3, 0.05);
        let mut fast = VmmEngine::new(&arr, noise.clone(), NoiseMode::Fast);
        let mut cell = VmmEngine::new(&arr, noise, NoiseMode::PerCell);
        let v = [0.2, -0.1, 0.3, 0.15, -0.25, 0.05, 0.1, -0.3];
        let n = 4000;
        let mut rng = Pcg64::seeded(4);
        let col = 2;
        let fast_samples: Vec<f64> =
            (0..n).map(|_| fast.vmm(&v, &mut rng)[col]).collect();
        let cell_samples: Vec<f64> =
            (0..n).map(|_| cell.vmm(&v, &mut rng)[col]).collect();
        let sf = stats::summary(&fast_samples);
        let sc = stats::summary(&cell_samples);
        assert!(
            (sf.mean - sc.mean).abs() < 3.0 * (sf.std + sc.std) / (n as f64).sqrt() + 1e-9,
            "means differ: {} vs {}",
            sf.mean,
            sc.mean
        );
        let ratio = sf.std / sc.std;
        assert!((ratio - 1.0).abs() < 0.1, "std ratio {ratio}");
    }

    #[test]
    fn ideal_engine_is_exact() {
        let w = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let mut eng = VmmEngine::ideal(w);
        let y = eng.vmm(&[1.0, 1.0], &mut Pcg64::seeded(1));
        assert_eq!(y, vec![4.0, 6.0]);
    }

    #[test]
    fn vmm_into_reuses_buffer() {
        let w = Mat::from_vec(2, 3, vec![1., 0., 0., 0., 1., 0.]);
        let mut eng = VmmEngine::ideal(w);
        let mut y = vec![9.0; 3];
        eng.vmm_into(&[2.0, 3.0], &mut y, &mut Pcg64::seeded(1));
        assert_eq!(y, vec![2.0, 3.0, 0.0]);
    }

    #[test]
    fn larger_noise_larger_spread() {
        let (arr, _) = deployed(5, 0.0);
        let v = [0.2; 8];
        let spread = |sigma: f64| {
            let mut eng = VmmEngine::new(
                &arr,
                NoiseSource::new(sigma),
                NoiseMode::Fast,
            );
            let mut rng = Pcg64::seeded(6);
            let s: Vec<f64> =
                (0..2000).map(|_| eng.vmm(&v, &mut rng)[0]).collect();
            stats::summary(&s).std
        };
        assert!(spread(0.05) > 2.0 * spread(0.01));
    }
}
