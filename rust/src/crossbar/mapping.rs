//! Signed weight <-> differential conductance mapping (Fig. 2f).
//!
//! Each logical weight w maps to a *pair* of conductances (g+, g-) on two
//! adjacent physical columns driven with +v and -v:
//!
//!   w > 0:  g+ = g_min + |w| * slope,  g- = g_min
//!   w < 0:  g+ = g_min,                g- = g_min + |w| * slope
//!
//! so the differential current is i = v * (g+ - g-) = v * slope * w, and the
//! common-mode g_min cancels. `slope` is chosen so the largest |w| in the
//! layer uses the full conductance window; the inverse scale is applied
//! digitally... no — *analogously*, by folding it into the next stage's TIA
//! gain (see [`crate::analog::tia`]), keeping the request path fully
//! analogue as in the paper.

use crate::device::taox::DeviceConfig;
use crate::util::tensor::Mat;

/// The affine weight->conductance map for one layer.
#[derive(Debug, Clone)]
pub struct WeightMapping {
    /// Conductance per unit weight (S).
    pub slope: f64,
    /// Largest |w| the mapping supports without clipping.
    pub w_max: f64,
    /// Base (bias) conductance of the inactive rail.
    pub g_base: f64,
}

impl WeightMapping {
    /// Build a mapping that spans the device window for the given weights.
    ///
    /// If all weights are zero, a unit `w_max` is assumed (slope still
    /// finite so programming is well-defined).
    pub fn for_weights(w: &Mat, cfg: &DeviceConfig) -> Self {
        let w_max = w
            .data
            .iter()
            .fold(0.0f64, |m, &x| m.max(x.abs()))
            .max(1e-12);
        let slope = (cfg.g_max - cfg.g_min) / w_max;
        Self { slope, w_max, g_base: cfg.g_min }
    }

    /// Target conductances (g_plus, g_minus) for a single weight.
    pub fn weight_to_pair(&self, w: f64) -> (f64, f64) {
        let mag = w.abs().min(self.w_max) * self.slope;
        if w >= 0.0 {
            (self.g_base + mag, self.g_base)
        } else {
            (self.g_base, self.g_base + mag)
        }
    }

    /// Signed weight recovered from a conductance pair.
    pub fn pair_to_weight(&self, gp: f64, gn: f64) -> f64 {
        (gp - gn) / self.slope
    }

    /// Map a whole weight matrix to (G+, G-) target maps.
    pub fn map_matrix(&self, w: &Mat) -> (Mat, Mat) {
        let mut gp = Mat::zeros(w.rows, w.cols);
        let mut gn = Mat::zeros(w.rows, w.cols);
        for idx in 0..w.data.len() {
            let (p, n) = self.weight_to_pair(w.data[idx]);
            gp.data[idx] = p;
            gn.data[idx] = n;
        }
        (gp, gn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DeviceConfig {
        DeviceConfig::default()
    }

    #[test]
    fn roundtrip_weight_pair_weight() {
        let w = Mat::from_vec(1, 4, vec![0.5, -0.25, 1.0, 0.0]);
        let m = WeightMapping::for_weights(&w, &cfg());
        for &x in &w.data {
            let (gp, gn) = m.weight_to_pair(x);
            let back = m.pair_to_weight(gp, gn);
            assert!((back - x).abs() < 1e-12, "{x} -> {back}");
        }
    }

    #[test]
    fn max_weight_uses_full_window() {
        let c = cfg();
        let w = Mat::from_vec(1, 2, vec![2.0, -2.0]);
        let m = WeightMapping::for_weights(&w, &c);
        let (gp, _) = m.weight_to_pair(2.0);
        assert!((gp - c.g_max).abs() < 1e-12);
        let (_, gn) = m.weight_to_pair(-2.0);
        assert!((gn - c.g_max).abs() < 1e-12);
    }

    #[test]
    fn pairs_stay_inside_device_window() {
        let c = cfg();
        let w = Mat::from_vec(1, 3, vec![0.7, -0.1, 0.0]);
        let m = WeightMapping::for_weights(&w, &c);
        for &x in &w.data {
            let (gp, gn) = m.weight_to_pair(x);
            for g in [gp, gn] {
                assert!(g >= c.g_min - 1e-15 && g <= c.g_max + 1e-15);
            }
        }
    }

    #[test]
    fn oversized_weights_clip() {
        let c = cfg();
        let w = Mat::from_vec(1, 1, vec![1.0]);
        let m = WeightMapping::for_weights(&w, &c);
        let (gp, _) = m.weight_to_pair(5.0); // beyond w_max
        assert!(gp <= c.g_max + 1e-15);
    }

    #[test]
    fn zero_matrix_has_finite_slope() {
        let w = Mat::zeros(3, 3);
        let m = WeightMapping::for_weights(&w, &cfg());
        assert!(m.slope.is_finite() && m.slope > 0.0);
    }

    #[test]
    fn map_matrix_shapes_and_signs() {
        let w = Mat::from_vec(2, 2, vec![1.0, -1.0, 0.5, 0.0]);
        let m = WeightMapping::for_weights(&w, &cfg());
        let (gp, gn) = m.map_matrix(&w);
        assert_eq!(gp.rows, 2);
        assert!(gp.at(0, 0) > gn.at(0, 0)); // positive weight
        assert!(gp.at(0, 1) < gn.at(0, 1)); // negative weight
        assert_eq!(gp.at(1, 1), gn.at(1, 1)); // zero weight
    }
}
