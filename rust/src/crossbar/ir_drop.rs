//! First-order IR-drop (wire resistance) nonideality.
//!
//! Metal lines in a crossbar have finite resistance; cells far from the
//! drivers see a reduced effective bias and their currents are attenuated
//! on the way out. An exact solution requires a resistive-network solve;
//! for the paper's small (32x32) arrays a first-order model captures the
//! systematic part:
//!
//!   G_eff(r, c) = G(r, c) / (1 + G(r, c) * R_wire * (n_before_r + n_after_c))
//!
//! where `n_before_r` counts wire segments the input traverses along the
//! row and `n_after_c` segments the output current traverses along the
//! column. This is the standard series-resistance approximation used in
//! compact crossbar models; DESIGN.md records it as a deliberate
//! substitution for a SPICE-level solve.

use crate::util::tensor::Mat;

/// Per-segment wire resistance (Ohm). 180 nm M4/M5 lines at 32-cell pitch
/// are a few Ohms per cell; 2.5 Ohm is a representative value.
pub const DEFAULT_R_SEGMENT: f64 = 2.5;

/// Apply the first-order IR-drop correction to a conductance matrix.
///
/// Inputs enter at row 0 (bit-line drivers on the left), outputs are
/// collected at the bottom of each column (source-line TIAs).
pub fn apply_ir_drop(g: &Mat, r_segment: f64) -> Mat {
    let rows = g.rows;
    let cols = g.cols;
    Mat::from_fn(rows, cols, |r, c| {
        let segments = (c + 1) as f64 + (rows - r) as f64;
        let gv = g.at(r, c);
        gv / (1.0 + gv * r_segment * segments)
    })
}

/// Worst-case relative attenuation across the array (a scalar figure of
/// merit used in DESIGN.md's nonideality budget).
pub fn worst_case_attenuation(g: &Mat, r_segment: f64) -> f64 {
    let eff = apply_ir_drop(g, r_segment);
    let mut worst = 0.0f64;
    for i in 0..g.data.len() {
        if g.data[i] > 0.0 {
            worst = worst.max(1.0 - eff.data[i] / g.data[i]);
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attenuation_is_monotone_in_distance() {
        let g = Mat::full(8, 8, 100e-6);
        let eff = apply_ir_drop(&g, DEFAULT_R_SEGMENT);
        // Farther along the column (larger c) -> more segments -> smaller G.
        assert!(eff.at(0, 7) < eff.at(0, 0));
        // Larger r means *fewer* output segments (closer to the TIA).
        assert!(eff.at(7, 0) > eff.at(0, 0));
    }

    #[test]
    fn zero_wire_resistance_is_identity() {
        let g = Mat::from_fn(4, 4, |r, c| (1 + r + c) as f64 * 1e-5);
        let eff = apply_ir_drop(&g, 0.0);
        assert_eq!(eff, g);
    }

    #[test]
    fn attenuation_small_for_paper_arrays() {
        // 32x32 at 100 µS worst case with 2.5 Ohm segments: the correction
        // must stay in the few-percent band (otherwise the paper's direct
        // programming scheme would not work).
        let g = Mat::full(32, 32, 100e-6);
        let worst = worst_case_attenuation(&g, DEFAULT_R_SEGMENT);
        assert!(worst < 0.05, "worst-case IR drop {worst} too large");
        assert!(worst > 0.001, "model inert: {worst}");
    }

    #[test]
    fn high_conductance_attenuates_more() {
        let lo = Mat::full(8, 8, 10e-6);
        let hi = Mat::full(8, 8, 100e-6);
        assert!(
            worst_case_attenuation(&hi, DEFAULT_R_SEGMENT)
                > worst_case_attenuation(&lo, DEFAULT_R_SEGMENT)
        );
    }
}
