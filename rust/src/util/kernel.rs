//! Runtime-dispatched GEMM microkernels — the SIMD / scalar / threading
//! policy behind every `Mat::vecmat*` kernel, i.e. behind every crossbar
//! read, model forward and analogue IVP step in the system.
//!
//! ## Dispatch rules
//!
//! * [`active`] picks the process-wide kernel once: the `MEMODE_KERNEL`
//!   environment variable (`scalar` | `simd` | `auto`) overrides runtime
//!   CPU detection (`is_x86_feature_detected!("avx2")`); the choice is
//!   cached in a `OnceLock` so the warm request path never re-reads the
//!   environment (reading an env var allocates — see the zero-allocation
//!   contract in `lib.rs`). The scalar kernel is the portable fallback on
//!   every non-x86_64 target.
//! * Forcing `simd` on a machine without AVX2 falls back to scalar with a
//!   loud stderr notice — the override is a testing aid, never a way to
//!   execute unsupported instructions. Tests that must pin a kernel use
//!   the explicit `Mat::*_with` entry points instead of mutating the
//!   environment (per-test env writes race the parallel test harness).
//! * [`plan_threads`] keeps small / latency-sensitive batches
//!   single-threaded: the multicore path engages only when a batched GEMM
//!   carries at least [`THREAD_MIN_BATCH`] trajectories *and* performs at
//!   least [`THREAD_MIN_WORK`] multiply-adds, capped by
//!   `MEMODE_GEMM_THREADS` (0 / unset = all available cores).
//!
//! ## Bit-identity
//!
//! Every kernel — scalar, AVX2, threaded — produces **bit-identical**
//! output:
//!
//! * the AVX2 path vectorises across *output columns* (4 f64 per ymm
//!   register) with plain mul+add, never FMA — FMA's single rounding
//!   would change results relative to the scalar `*yc += xv * a` — so
//!   each output element's floating-point accumulation order over the
//!   shared dimension is exactly the serial order;
//! * the zero-input skip (`if x[r] == 0.0 { continue; }`) is kept in
//!   *both* kernels: it is part of the accumulation contract (skipping a
//!   zero input differs from adding `0.0 * a` whenever a weight is
//!   non-finite), and on dense inputs it costs one well-predicted branch
//!   per row (measured by `benches/gemm_kernels.rs`);
//! * the threaded path splits the batch into disjoint trajectory blocks
//!   and runs the identical single-trajectory kernel on each, so it
//!   cannot reorder any accumulation.
//!
//! Noise-lane draw indexing (`util::rng::NoiseLane`) addresses draws by
//! explicit index *after* the GEMM, so kernel choice can never affect
//! which noise a trajectory sees. See the perf-invariants section of the
//! crate docs (`lib.rs`) for the full contract.

use std::sync::OnceLock;

/// Output-tile width of the GEMM microkernels: 32 f64 = 4 cache lines =
/// 8 ymm registers, small enough that a full accumulator tile stays in
/// registers across the whole shared-dimension loop. Shared by the
/// full-width and the column-sharded kernels so both tile identically.
pub const VECMAT_TILE_COLS: usize = 32;

/// Trajectory-count floor below which batched GEMMs stay on the caller's
/// thread (small batches are latency-sensitive; spawn cost dominates).
pub const THREAD_MIN_BATCH: usize = 64;

/// Multiply-add floor (`batch * rows * cols`) below which batched GEMMs
/// stay single-threaded even at high trajectory counts.
pub const THREAD_MIN_WORK: usize = 1 << 21;

/// Which microkernel executes a `Mat::vecmat*` call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// Portable scalar loops (the reference implementation).
    Scalar,
    /// AVX2 column-vectorised microkernel (x86_64 only; bit-identical to
    /// `Scalar` by construction — see the module docs).
    Simd,
}

/// True when the running CPU supports the AVX2 microkernel.
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The kernel runtime detection would pick on this machine (ignoring the
/// `MEMODE_KERNEL` override).
pub fn detected() -> KernelKind {
    if simd_available() {
        KernelKind::Simd
    } else {
        KernelKind::Scalar
    }
}

/// The process-wide kernel choice: `MEMODE_KERNEL` override if set, else
/// runtime detection. Cached on first use (the hot path never re-reads
/// the environment).
pub fn active() -> KernelKind {
    static ACTIVE: OnceLock<KernelKind> = OnceLock::new();
    *ACTIVE.get_or_init(|| match std::env::var("MEMODE_KERNEL") {
        Ok(v) if v == "scalar" => KernelKind::Scalar,
        Ok(v) if v == "simd" => {
            if simd_available() {
                KernelKind::Simd
            } else {
                eprintln!(
                    "MEMODE_KERNEL=simd: AVX2 unavailable on this CPU; \
                     falling back to the scalar kernel"
                );
                KernelKind::Scalar
            }
        }
        Ok(v) if v == "auto" || v.is_empty() => detected(),
        Ok(v) => {
            eprintln!(
                "MEMODE_KERNEL={v}: unknown kernel (expected \
                 scalar|simd|auto); using auto detection"
            );
            detected()
        }
        Err(_) => detected(),
    })
}

/// Worker cap for the multicore batched GEMM: `MEMODE_GEMM_THREADS`
/// (0 / unset / unparseable = all available cores), cached once per
/// process.
pub fn max_threads() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        let auto = || {
            std::thread::available_parallelism()
                .map(std::num::NonZeroUsize::get)
                .unwrap_or(1)
        };
        match std::env::var("MEMODE_GEMM_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
        {
            Some(0) | None => auto(),
            Some(n) => n,
        }
    })
}

/// Workers for one batched GEMM on this machine (1 = stay on the
/// caller's thread). See [`plan_threads_with_cap`] for the policy.
pub fn plan_threads(batch: usize, rows: usize, cols: usize) -> usize {
    plan_threads_with_cap(max_threads(), batch, rows, cols)
}

/// The threading policy with an explicit worker cap (separated from
/// [`plan_threads`] so the thresholds are testable independently of the
/// machine): single-threaded below [`THREAD_MIN_BATCH`] trajectories or
/// [`THREAD_MIN_WORK`] multiply-adds, otherwise up to `cap` workers while
/// keeping at least `THREAD_MIN_BATCH / 2` trajectories per worker.
pub fn plan_threads_with_cap(
    cap: usize,
    batch: usize,
    rows: usize,
    cols: usize,
) -> usize {
    let work = batch.saturating_mul(rows).saturating_mul(cols);
    if cap <= 1 || batch < THREAD_MIN_BATCH || work < THREAD_MIN_WORK {
        return 1;
    }
    cap.min(batch / (THREAD_MIN_BATCH / 2)).max(1)
}

/// One trajectory's `y += x^T A[:, c0..c1]` (`y.len() == c1 - c0`, `y`
/// pre-zeroed by the caller), walked in [`VECMAT_TILE_COLS`]-wide output
/// tiles so the accumulator tile stays register/L1-resident across the
/// whole shared-dimension loop. Per output element the accumulation
/// order over `r` — including the zero-input skip — is exactly the
/// serial scalar order, whichever `kind` executes.
pub(crate) fn vecmat_range(
    kind: KernelKind,
    x: &[f64],
    a: &[f64],
    cols: usize,
    c0: usize,
    c1: usize,
    y: &mut [f64],
) {
    debug_assert_eq!(y.len(), c1 - c0);
    let mut t0 = c0;
    while t0 < c1 {
        let t1 = (t0 + VECMAT_TILE_COLS).min(c1);
        accumulate_tile(kind, x, a, cols, t0, &mut y[t0 - c0..t1 - c0]);
        t0 = t1;
    }
}

/// `yt[j] += Σ_r x[r] * a[r * cols + t0 + j]` for one output tile
/// (`yt.len() <= VECMAT_TILE_COLS`), zero-input rows skipped, accumulated
/// in exactly the serial scalar order per output element.
#[inline]
pub(crate) fn accumulate_tile(
    kind: KernelKind,
    x: &[f64],
    a: &[f64],
    cols: usize,
    t0: usize,
    yt: &mut [f64],
) {
    assert!(
        t0 + yt.len() <= cols && x.len() * cols <= a.len(),
        "accumulate_tile: tile {t0}+{} outside a {}x{cols} matrix",
        yt.len(),
        x.len()
    );
    match kind {
        KernelKind::Scalar => accumulate_tile_scalar(x, a, cols, t0, yt),
        KernelKind::Simd => {
            #[cfg(target_arch = "x86_64")]
            if simd_available() {
                // SAFETY: AVX2 is present (checked on the line above),
                // and the bounds assert above guarantees every row slice
                // `a[r * cols + t0 ..][..yt.len()]` read by the kernel is
                // in bounds.
                unsafe { accumulate_tile_avx2(x, a, cols, t0, yt) };
                return;
            }
            // Portable fallback: `Simd` requested but unavailable (other
            // arch, or a hand-constructed kind on an old x86_64).
            accumulate_tile_scalar(x, a, cols, t0, yt);
        }
    }
}

fn accumulate_tile_scalar(
    x: &[f64],
    a: &[f64],
    cols: usize,
    t0: usize,
    yt: &mut [f64],
) {
    let w = yt.len();
    for (r, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let at = &a[r * cols + t0..r * cols + t0 + w];
        for (yc, &av) in yt.iter_mut().zip(at) {
            *yc += xv * av;
        }
    }
}

/// AVX2 tile kernel: 4 f64 per ymm register across output columns, plain
/// mul+add (two roundings, exactly like the scalar kernel — never FMA),
/// zero-input skip kept. A full 32-wide tile holds its 8 accumulators in
/// registers for the whole shared-dimension loop (one load and one store
/// of `yt` total); narrower tail tiles take a generic quad + remainder
/// path.
///
/// # Safety
///
/// Caller must ensure AVX2 is available and
/// `x.len() * cols <= a.len() && t0 + yt.len() <= cols` (every row slice
/// read is then in bounds) — both are checked by [`accumulate_tile`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn accumulate_tile_avx2(
    x: &[f64],
    a: &[f64],
    cols: usize,
    t0: usize,
    yt: &mut [f64],
) {
    use std::arch::x86_64::{
        _mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd,
        _mm256_setzero_pd, _mm256_storeu_pd,
    };
    let w = yt.len();
    debug_assert!(w <= VECMAT_TILE_COLS);
    if w == VECMAT_TILE_COLS {
        // Full tile: fixed-size accumulator array, unrolled by the
        // compiler (the count is a compile-time constant).
        let mut acc = [_mm256_setzero_pd(); VECMAT_TILE_COLS / 4];
        for (k, a4) in acc.iter_mut().enumerate() {
            *a4 = _mm256_loadu_pd(yt.as_ptr().add(4 * k));
        }
        for (r, &xv) in x.iter().enumerate() {
            // Zero-input skip: part of the accumulation contract (and
            // ~free on dense inputs — one predictable branch per row).
            if xv == 0.0 {
                continue;
            }
            let row = a.as_ptr().add(r * cols + t0);
            let xb = _mm256_set1_pd(xv);
            for (k, a4) in acc.iter_mut().enumerate() {
                let prod = _mm256_mul_pd(xb, _mm256_loadu_pd(row.add(4 * k)));
                *a4 = _mm256_add_pd(*a4, prod);
            }
        }
        for (k, a4) in acc.iter().enumerate() {
            _mm256_storeu_pd(yt.as_mut_ptr().add(4 * k), *a4);
        }
        return;
    }
    // Tail tile (w < 32): quads in ymm registers plus a scalar remainder
    // of at most 3 columns, all held across the shared-dimension loop.
    let quads = w / 4;
    let rem = w % 4;
    let mut acc = [_mm256_setzero_pd(); VECMAT_TILE_COLS / 4 - 1];
    for (k, a4) in acc.iter_mut().enumerate().take(quads) {
        *a4 = _mm256_loadu_pd(yt.as_ptr().add(4 * k));
    }
    let mut tail = [0.0f64; 3];
    for (j, t) in tail.iter_mut().enumerate().take(rem) {
        *t = yt[quads * 4 + j];
    }
    for (r, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let row = a.as_ptr().add(r * cols + t0);
        let xb = _mm256_set1_pd(xv);
        for (k, a4) in acc.iter_mut().enumerate().take(quads) {
            let prod = _mm256_mul_pd(xb, _mm256_loadu_pd(row.add(4 * k)));
            *a4 = _mm256_add_pd(*a4, prod);
        }
        for (j, t) in tail.iter_mut().enumerate().take(rem) {
            *t += xv * *row.add(quads * 4 + j);
        }
    }
    for (k, a4) in acc.iter().enumerate().take(quads) {
        _mm256_storeu_pd(yt.as_mut_ptr().add(4 * k), *a4);
    }
    for (j, &t) in tail.iter().enumerate().take(rem) {
        yt[quads * 4 + j] = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reference(x: &[f64], a: &[f64], cols: usize, c0: usize, c1: usize) -> Vec<f64> {
        let mut y = vec![0.0; c1 - c0];
        for (r, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            for (j, yv) in y.iter_mut().enumerate() {
                *yv += xv * a[r * cols + c0 + j];
            }
        }
        y
    }

    #[test]
    fn simd_bit_identical_to_scalar_across_widths() {
        // Every tile width 1..=70 (tail quads, remainders, full tiles,
        // multi-tile ranges), with zeros sprinkled into the input. On
        // machines without AVX2 the Simd kind falls back to scalar and
        // the comparison is trivially true — the CI kernel-matrix legs
        // cover both worlds.
        let rows = 9;
        for cols in 1..=70usize {
            let a: Vec<f64> = (0..rows * cols)
                .map(|k| ((k * 37 % 23) as f64) / 7.0 - 1.4)
                .collect();
            let x: Vec<f64> = (0..rows)
                .map(|r| if r % 3 == 1 { 0.0 } else { (r as f64 * 0.61).sin() })
                .collect();
            let mut ys = vec![0.0; cols];
            let mut yv = vec![0.0; cols];
            vecmat_range(KernelKind::Scalar, &x, &a, cols, 0, cols, &mut ys);
            vecmat_range(KernelKind::Simd, &x, &a, cols, 0, cols, &mut yv);
            assert_eq!(ys, yv, "cols={cols}");
            assert_eq!(ys, reference(&x, &a, cols, 0, cols), "cols={cols}");
        }
    }

    #[test]
    fn zero_skip_shields_non_finite_weights_in_both_kernels() {
        // The zero-input skip is contractual: a skipped row must never
        // touch its weights, so an infinite weight behind a zero input
        // yields a finite output (0.0 * inf would be NaN). Both kernels
        // must honour it.
        let cols = 37;
        let mut a = vec![1.0; 2 * cols];
        for v in a.iter_mut().take(cols) {
            *v = f64::INFINITY;
        }
        let x = [0.0, 2.0];
        for kind in [KernelKind::Scalar, KernelKind::Simd] {
            let mut y = vec![0.0; cols];
            vecmat_range(kind, &x, &a, cols, 0, cols, &mut y);
            assert!(
                y.iter().all(|v| *v == 2.0),
                "{kind:?}: zero-skip violated: {y:?}"
            );
        }
    }

    #[test]
    fn column_ranges_match_full_width_slices() {
        let (rows, cols) = (7, 67);
        let a: Vec<f64> = (0..rows * cols)
            .map(|k| ((k * 29 % 19) as f64) / 6.0 - 1.1)
            .collect();
        let x: Vec<f64> =
            (0..rows).map(|r| (r as f64 * 0.43).cos()).collect();
        for kind in [KernelKind::Scalar, KernelKind::Simd] {
            let mut full = vec![0.0; cols];
            vecmat_range(kind, &x, &a, cols, 0, cols, &mut full);
            for &(c0, c1) in
                &[(0usize, 32usize), (32, 64), (64, 67), (3, 5), (0, 67)]
            {
                let mut y = vec![0.0; c1 - c0];
                vecmat_range(kind, &x, &a, cols, c0, c1, &mut y);
                assert_eq!(&y[..], &full[c0..c1], "{kind:?} {c0}..{c1}");
            }
        }
    }

    #[test]
    fn thread_plan_respects_thresholds() {
        // Below the trajectory floor: single-threaded however big the cap.
        assert_eq!(plan_threads_with_cap(16, 32, 512, 512), 1);
        // Below the work floor: single-threaded however many lanes.
        assert_eq!(plan_threads_with_cap(16, 1024, 8, 8), 1);
        // Cap 1 / no parallelism: never threads.
        assert_eq!(plan_threads_with_cap(1, 1024, 64, 64), 1);
        // Above both floors: threads, bounded by the cap and by
        // THREAD_MIN_BATCH / 2 trajectories per worker.
        assert_eq!(plan_threads_with_cap(4, 1024, 64, 64), 4);
        assert_eq!(plan_threads_with_cap(16, 64, 128, 512), 2);
        assert_eq!(plan_threads_with_cap(16, 128, 128, 512), 4);
    }

    #[test]
    fn active_kind_is_stable_and_consistent_with_detection() {
        // `active()` caches: two calls agree, and without an override the
        // choice matches detection. (The override itself is exercised by
        // the CI kernel-matrix leg running the suite under
        // MEMODE_KERNEL=scalar — mutating the environment here would race
        // the parallel test harness.)
        assert_eq!(active(), active());
        if std::env::var("MEMODE_KERNEL").is_err() {
            assert_eq!(active(), detected());
        }
        if std::env::var("MEMODE_KERNEL").as_deref() == Ok("scalar") {
            assert_eq!(active(), KernelKind::Scalar);
        }
    }
}
