//! Foundational substrates built from scratch (the offline image vendors
//! only `xla` + `anyhow`, so the usual ecosystem crates are replaced here).
//!
//! * [`tensor`]  — dense f64 matrix/vector math (gemv/gemm, the VMM hot path)
//! * [`rng`]     — deterministic PCG64 PRNG with normal/lognormal variates
//! * [`json`]    — JSON parser + writer (serde replacement for artifacts)
//! * [`cli`]     — declarative flag parser (clap replacement)
//! * [`stats`]   — summary statistics, percentiles, histograms
//! * [`bench`]   — warmup/iterate/median micro-benchmark harness (criterion
//!   replacement; all `cargo bench` targets use it with `harness = false`)
//! * [`proptest`] — randomized invariant-checking helpers (property tests)
//! * [`kernel`]  — runtime-dispatched GEMM microkernels (AVX2 / scalar /
//!   multicore) behind the `tensor` hot paths

pub mod bench;
pub mod cli;
pub mod json;
pub mod kernel;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod tensor;
