//! Deterministic PRNG for all stochastic device physics.
//!
//! PCG64 (O'Neill 2014, XSL-RR output on a 128-bit LCG) — fast, tiny state,
//! excellent statistical quality, and fully reproducible across platforms;
//! every noise source in the simulator (programming error, read noise,
//! retention drift, yield faults) derives from a seeded `Pcg64` so whole
//! experiments replay bit-exactly from a single seed.

/// PCG64 XSL-RR generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second normal variate from the Box-Muller pair.
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and stream id. Different streams with
    /// the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc, spare_normal: None };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed-only constructor on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive an independent child generator (used to give each device /
    /// array / worker its own stream without sharing mutable state).
    pub fn fork(&mut self, tag: u64) -> Self {
        let seed = self.next_u64();
        Self::new(seed ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag | 1)
    }

    /// Next raw 64-bit output (XSL-RR).
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire's unbiased method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n && lo < n.wrapping_neg() % n {
                continue;
            }
            return (m >> 64) as u64;
        }
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u = 0 (log singularity).
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)). Programming error in TaOx devices is
    /// well described by a lognormal conductance multiplier.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Pcg64::seeded(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn below_is_unbiased_and_in_range() {
        let mut r = Pcg64::seeded(5);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn lognormal_is_positive() {
        let mut r = Pcg64::seeded(9);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 0.5) > 0.0);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Pcg64::seeded(100);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Pcg64::seeded(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
