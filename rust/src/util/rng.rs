//! Deterministic PRNG for all stochastic device physics.
//!
//! Two generators with different jobs:
//!
//! * [`Pcg64`] (O'Neill 2014, XSL-RR output on a 128-bit LCG) — fast, tiny
//!   state, excellent statistical quality, and fully reproducible across
//!   platforms; the *sequential* generator behind everything that happens
//!   once per deployment (programming error, retention drift, yield
//!   faults, experiment scripts).
//! * [`NoiseLane`] — the *request-path* noise stream: one lane per
//!   trajectory, counter-based (every draw is addressed by an explicit
//!   index instead of consumed from a shared sequence), so batched GEMM
//!   kernels, shard fan-out workers and the serial monolithic solver all
//!   read **identical** values for the same logical draw. This is what
//!   makes noisy rollouts replayable independently of batch size, batch
//!   composition and shard layout (see the noise-determinism invariants in
//!   `lib.rs`).

/// PCG64 XSL-RR generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second normal variate from the Box-Muller pair.
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and stream id. Different streams with
    /// the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc, spare_normal: None };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed-only constructor on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive an independent child generator (used to give each device /
    /// array / worker its own stream without sharing mutable state).
    pub fn fork(&mut self, tag: u64) -> Self {
        let seed = self.next_u64();
        Self::new(seed ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag | 1)
    }

    /// Next raw 64-bit output (XSL-RR).
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire's unbiased method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n && lo < n.wrapping_neg() % n {
                continue;
            }
            return (m >> 64) as u64;
        }
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u = 0 (log singularity).
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)). Programming error in TaOx devices is
    /// well described by a lognormal conductance multiplier.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

// ---------------------------------------------------------------------------
// Per-trajectory noise lanes (counter-based, order-independent draws)
// ---------------------------------------------------------------------------

/// Golden-ratio increment of the splitmix64 PRF underlying [`NoiseLane`].
const LANE_GAMMA: u64 = 0x9e37_79b9_7f4a_7c15;

/// Finalising mixer of splitmix64 (Steele/Lea/Flood 2014) — a full-period
/// bijection with strong avalanche, used here as a keyed PRF over draw
/// indices.
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Derive the `k`-th child seed of `root` — the stateless analogue of
/// [`Pcg64::fork`], used wherever a deterministic family of independent
/// seeds is needed without shared mutable state (per-request auto seeds,
/// per-trajectory lane keys).
pub fn derive_stream_seed(root: u64, k: u64) -> u64 {
    mix64(mix64(root).wrapping_add(k.wrapping_mul(LANE_GAMMA)))
}

/// Deterministic per-request auto-seed source: twins use one to resolve
/// requests that arrive without an explicit noise seed, so every rollout
/// gets a distinct, replayable seed (echoed in the response) without any
/// shared mutable state or allocation.
#[derive(Debug, Clone)]
pub struct SeedSequencer {
    root: u64,
    seq: u64,
}

impl SeedSequencer {
    pub fn new(root: u64) -> Self {
        Self { root, seq: 0 }
    }

    /// Next auto-derived seed in this sequencer's family.
    pub fn next_seed(&mut self) -> u64 {
        self.seq = self.seq.wrapping_add(1);
        derive_stream_seed(self.root, self.seq)
    }

    /// An explicit request seed wins; otherwise auto-derive the next one.
    pub fn resolve(&mut self, explicit: Option<u64>) -> u64 {
        explicit.unwrap_or_else(|| self.next_seed())
    }
}

/// One trajectory's deterministic read-noise stream.
///
/// A lane is a splitmix64-keyed counter generator: draw `i` of the stream
/// is a pure function of `(key, i)`, never of how many draws other code
/// consumed before it. Kernels address draws *by index* —
/// [`NoiseLane::normal_at`] reads at `cursor + offset` without consuming —
/// and advance the cursor by the layer's full logical draw count once per
/// read ([`NoiseLane::advance`]). Consequences, all load-bearing for the
/// serving layer:
///
/// * a batched kernel looping trajectories in any order produces each
///   trajectory's exact serial draws (batch composition independence);
/// * a shard worker that draws only its column range and advances by the
///   *full* layer width stays in lockstep with the monolithic solver
///   (shard-layout independence);
/// * replaying a request with the same seed replays the rollout bit for
///   bit.
///
/// Plain `Copy` data (16 bytes), so lanes live in pooled scratch and never
/// touch the allocator on the warm path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NoiseLane {
    /// PRF key: identifies the stream.
    key: u64,
    /// Logical position: index of the next unconsumed draw.
    cursor: u64,
}

impl NoiseLane {
    /// Lane of trajectory `trajectory` under `root` — the deterministic
    /// stream derivation `lane = root.fork(trajectory_id)`.
    pub fn derive(root: u64, trajectory: u64) -> Self {
        Self { key: derive_stream_seed(root, trajectory), cursor: 0 }
    }

    /// Lane of a single-trajectory request: the request seed *is* the
    /// root, trajectory id 0.
    pub fn from_seed(seed: u64) -> Self {
        Self::derive(seed, 0)
    }

    /// Raw PRF word at an absolute draw index.
    fn word(&self, index: u64) -> u64 {
        mix64(self.key.wrapping_add(index.wrapping_mul(LANE_GAMMA)))
    }

    /// Current cursor (diagnostics and lockstep assertions).
    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    /// Consume `n` logical draws: callers advance by a read's *full* draw
    /// count regardless of which subset of draws they actually evaluated.
    pub fn advance(&mut self, n: u64) {
        self.cursor = self.cursor.wrapping_add(n);
    }

    /// Standard normal at `cursor + offset`, without consuming. Box-Muller
    /// over two indexed uniforms (no cached spare — statelessness is the
    /// point).
    pub fn normal_at(&self, offset: u64) -> f64 {
        let i = self.cursor.wrapping_add(offset);
        let a = self.word(i.wrapping_mul(2));
        let b = self.word(i.wrapping_mul(2).wrapping_add(1));
        // u in (0, 1]: the +0.5 half-step keeps the log argument strictly
        // positive; v in [0, 1).
        let scale = 1.0 / (1u64 << 53) as f64;
        let u = ((a >> 11) as f64 + 0.5) * scale;
        let v = (b >> 11) as f64 * scale;
        (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Pcg64::seeded(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn below_is_unbiased_and_in_range() {
        let mut r = Pcg64::seeded(5);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn lognormal_is_positive() {
        let mut r = Pcg64::seeded(9);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 0.5) > 0.0);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Pcg64::seeded(100);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Pcg64::seeded(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn lane_draws_are_order_independent() {
        // Reading the same indices in any order, with any interleaving,
        // yields the same values — the property the batched and sharded
        // kernels rest on.
        let lane = NoiseLane::from_seed(42);
        let forward: Vec<f64> = (0..16).map(|j| lane.normal_at(j)).collect();
        let backward: Vec<f64> =
            (0..16).rev().map(|j| lane.normal_at(j)).collect();
        for (j, b) in backward.iter().rev().enumerate() {
            assert_eq!(forward[j], *b, "draw {j}");
        }
    }

    #[test]
    fn lane_advance_shifts_the_window() {
        let mut a = NoiseLane::from_seed(7);
        let b = NoiseLane::from_seed(7);
        let want = b.normal_at(10);
        a.advance(10);
        assert_eq!(a.cursor(), 10);
        assert_eq!(a.normal_at(0), want);
    }

    #[test]
    fn lane_split_draw_matches_contiguous_draw() {
        // A "shard" evaluating only indices 3..6 sees exactly what the
        // monolithic reader sees at those indices.
        let lane = NoiseLane::from_seed(99);
        let full: Vec<f64> = (0..6).map(|j| lane.normal_at(j)).collect();
        let shard: Vec<f64> = (3..6).map(|j| lane.normal_at(j)).collect();
        assert_eq!(&full[3..6], &shard[..]);
    }

    #[test]
    fn distinct_lanes_are_decorrelated() {
        let a = NoiseLane::derive(1, 0);
        let b = NoiseLane::derive(1, 1);
        let c = NoiseLane::derive(2, 0);
        let same_ab =
            (0..64).filter(|&j| a.normal_at(j) == b.normal_at(j)).count();
        let same_ac =
            (0..64).filter(|&j| a.normal_at(j) == c.normal_at(j)).count();
        assert_eq!(same_ab, 0);
        assert_eq!(same_ac, 0);
    }

    #[test]
    fn lane_normal_moments() {
        let lane = NoiseLane::from_seed(11);
        let n = 200_000u64;
        let xs: Vec<f64> = (0..n).map(|j| lane.normal_at(j)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn derive_stream_seed_is_stable_and_spread() {
        assert_eq!(derive_stream_seed(5, 3), derive_stream_seed(5, 3));
        assert_ne!(derive_stream_seed(5, 3), derive_stream_seed(5, 4));
        assert_ne!(derive_stream_seed(5, 3), derive_stream_seed(6, 3));
    }
}
