//! Randomized invariant checking (proptest replacement for the offline
//! build).
//!
//! `check` runs an invariant over N randomly generated cases and, on
//! failure, greedily shrinks the failing input before panicking with a
//! reproducible seed. Generators are plain closures over [`Pcg64`], so any
//! domain type can be generated without macro machinery.

use crate::util::rng::Pcg64;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_iters: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 256, seed: 0x5eed, max_shrink_iters: 200 }
    }
}

/// Run `prop` over `cases` inputs drawn from `gen`; panic with the seed and
/// (shrunk) counterexample on failure.
///
/// `shrink` proposes smaller variants of a failing input (return an empty
/// vec when no simplification applies).
pub fn check_with<T: Clone + std::fmt::Debug>(
    cfg: &Config,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Pcg64::seeded(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if prop(&input) {
            continue;
        }
        // Shrink greedily: take the first simpler failing variant.
        let mut best = input.clone();
        let mut iters = 0;
        'outer: loop {
            for cand in shrink(&best) {
                iters += 1;
                if iters > cfg.max_shrink_iters {
                    break 'outer;
                }
                if !prop(&cand) {
                    best = cand;
                    continue 'outer;
                }
            }
            break;
        }
        panic!(
            "property failed (seed={:#x}, case={case}):\n  original: {:?}\n  shrunk:   {:?}",
            cfg.seed, input, best
        );
    }
}

/// `check_with` without shrinking.
pub fn check<T: Clone + std::fmt::Debug>(
    cfg: &Config,
    gen: impl FnMut(&mut Pcg64) -> T,
    prop: impl Fn(&T) -> bool,
) {
    check_with(cfg, gen, |_| Vec::new(), prop);
}

// ---------------------------------------------------------------------------
// Common generators
// ---------------------------------------------------------------------------

/// Vector of `n` uniforms in [lo, hi).
pub fn gen_vec(rng: &mut Pcg64, n: usize, lo: f64, hi: f64) -> Vec<f64> {
    (0..n).map(|_| rng.uniform_in(lo, hi)).collect()
}

/// Vector with random length in [1, max_len].
pub fn gen_vec_any_len(
    rng: &mut Pcg64,
    max_len: usize,
    lo: f64,
    hi: f64,
) -> Vec<f64> {
    let n = 1 + rng.below(max_len as u64) as usize;
    gen_vec(rng, n, lo, hi)
}

/// Random permutation of `0..n` (Fisher-Yates) — used by the noisy
/// determinism suite to shuffle batch compositions.
pub fn gen_permutation(rng: &mut Pcg64, n: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    idx
}

/// Shrinker for vectors: halve the length, then zero elements one by one.
/// Takes a slice; pass `|v| shrink_vec(v)` where a `Fn(&Vec<f64>)`
/// shrinker is expected.
pub fn shrink_vec(v: &[f64]) -> Vec<Vec<f64>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
    }
    for i in 0..v.len().min(8) {
        if v[i] != 0.0 {
            let mut w = v.to_vec();
            w[i] = 0.0;
            out.push(w);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(
            &Config::default(),
            |r| gen_vec(r, 8, -1.0, 1.0),
            |v| v.iter().all(|x| x.abs() <= 1.0),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            &Config { cases: 50, ..Config::default() },
            |r| r.uniform(),
            |&x| x < 0.5,
        );
    }

    #[test]
    fn shrinking_finds_smaller_counterexample() {
        // Property: no element > 0.9. The shrunk case should be shorter
        // than the original (halving applies while it still fails).
        let res = std::panic::catch_unwind(|| {
            check_with(
                &Config { cases: 100, ..Config::default() },
                |r| gen_vec(r, 64, 0.0, 1.0),
                |v| shrink_vec(v),
                |v| v.iter().all(|&x| x <= 0.9),
            );
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("shrunk"));
    }

    #[test]
    fn gen_vec_any_len_within_bounds() {
        let mut r = Pcg64::seeded(1);
        for _ in 0..100 {
            let v = gen_vec_any_len(&mut r, 17, 0.0, 1.0);
            assert!((1..=17).contains(&v.len()));
        }
    }

    #[test]
    fn gen_permutation_is_a_permutation() {
        let mut r = Pcg64::seeded(2);
        let p = gen_permutation(&mut r, 20);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }
}
