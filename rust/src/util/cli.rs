//! Declarative command-line parsing (clap replacement for the offline build).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, per-flag defaults and an auto-generated `--help`. Used by the
//! `memode` binary, the examples and the bench targets.

use std::collections::BTreeMap;

/// One declared option.
#[derive(Debug, Clone)]
struct Opt {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
}

/// Declarative argument parser.
#[derive(Debug, Clone)]
pub struct Args {
    program: String,
    about: String,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Self {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            values: BTreeMap::new(),
            positionals: Vec::new(),
        }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    /// Declare a required `--name <value>` (no default).
    pub fn required(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: false,
        });
        self
    }

    /// Declare a boolean `--name` switch (default false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some("false".to_string()),
            is_bool: true,
        });
        self
    }

    /// Parse an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(
        mut self,
        argv: I,
    ) -> Result<Args, String> {
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| {
                        format!("unknown option --{name}\n{}", self.usage())
                    })?
                    .clone();
                let value = if let Some(v) = inline {
                    v
                } else if opt.is_bool {
                    "true".to_string()
                } else {
                    it.next().ok_or_else(|| {
                        format!("--{name} expects a value")
                    })?
                };
                self.values.insert(name, value);
            } else {
                self.positionals.push(arg);
            }
        }
        // Check required options.
        for o in &self.opts {
            if o.default.is_none()
                && !self.values.contains_key(&o.name)
            {
                return Err(format!(
                    "missing required option --{}\n{}",
                    o.name,
                    self.usage()
                ));
            }
        }
        Ok(self)
    }

    /// Parse `std::env::args()` and exit with the message on error/help.
    pub fn parse_env(self) -> Args {
        match self.parse(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }

    /// Usage text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let def = match (&o.default, o.is_bool) {
                (_, true) => String::new(),
                (Some(d), _) => format!(" [default: {d}]"),
                (None, _) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, def));
        }
        s
    }

    // -- typed getters ------------------------------------------------------

    fn raw(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.opts
            .iter()
            .find(|o| o.name == name)
            .and_then(|o| o.default.clone())
            .unwrap_or_else(|| panic!("option --{name} was never declared"))
    }

    pub fn get(&self, name: &str) -> String {
        self.raw(name)
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.raw(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.raw(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.raw(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a number"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.raw(name) == "true"
    }

    /// Comma-separated list of usizes (e.g. `--hidden 64,128,256`).
    pub fn get_usize_list(&self, name: &str) -> Vec<usize> {
        self.raw(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("--{name}: bad integer '{s}'"))
            })
            .collect()
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = Args::new("t", "test")
            .opt("steps", "100", "steps")
            .opt("name", "x", "name")
            .parse(argv("--steps 7"))
            .unwrap();
        assert_eq!(a.get_usize("steps"), 7);
        assert_eq!(a.get("name"), "x");
    }

    #[test]
    fn equals_syntax() {
        let a = Args::new("t", "")
            .opt("lr", "0.1", "")
            .parse(argv("--lr=0.5"))
            .unwrap();
        assert_eq!(a.get_f64("lr"), 0.5);
    }

    #[test]
    fn bool_flags() {
        let a = Args::new("t", "")
            .flag("verbose", "")
            .parse(argv("--verbose"))
            .unwrap();
        assert!(a.get_bool("verbose"));
        let b = Args::new("t", "").flag("verbose", "").parse(argv("")).unwrap();
        assert!(!b.get_bool("verbose"));
    }

    #[test]
    fn required_missing_errors() {
        let r = Args::new("t", "").required("model", "").parse(argv(""));
        assert!(r.is_err());
        assert!(r.unwrap_err().contains("--model"));
    }

    #[test]
    fn unknown_flag_errors() {
        let r = Args::new("t", "").parse(argv("--nope 1"));
        assert!(r.is_err());
    }

    #[test]
    fn positionals_collected() {
        let a = Args::new("t", "")
            .opt("k", "1", "")
            .parse(argv("serve --k 2 extra"))
            .unwrap();
        assert_eq!(a.positionals(), &["serve", "extra"]);
        assert_eq!(a.get_usize("k"), 2);
    }

    #[test]
    fn usize_list() {
        let a = Args::new("t", "")
            .opt("sizes", "64,128", "")
            .parse(argv(""))
            .unwrap();
        assert_eq!(a.get_usize_list("sizes"), vec![64, 128]);
    }

    #[test]
    fn help_is_error_with_usage() {
        let r = Args::new("prog", "about").opt("x", "1", "the x").parse(argv("--help"));
        let msg = r.unwrap_err();
        assert!(msg.contains("prog"));
        assert!(msg.contains("--x"));
    }
}
