//! Minimal JSON parser + writer (the offline image has no serde).
//!
//! Handles the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) with precise error positions. Used for the
//! artifact manifest, trained-weight files and machine-readable experiment
//! reports. Numbers parse to f64 (all our payloads are numeric tensors).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name (manifest parsing ergonomics).
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            pos: 0,
            msg: format!("missing key '{key}'"),
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Flatten a numeric array (1-D).
    pub fn as_vec_f64(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    /// Nested numeric array (2-D, row-major rows).
    pub fn as_mat_f64(&self) -> Option<Vec<Vec<f64>>> {
        self.as_arr()?.iter().map(Json::as_vec_f64).collect()
    }

    // -- serialisation (via `Display`; `.to_string()` comes with it) -------

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no Inf/NaN; emit null like serde_json's
                    // lossy mode would reject — we choose null + caller
                    // beware (reports never contain non-finite values).
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32));
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse a JSON document (rejects trailing garbage).
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let ch = if (0xd800..0xdc00).contains(&cp) {
                            if self.bump() != Some(b'\\')
                                || self.bump() != Some(b'u')
                            {
                                return Err(
                                    self.err("missing low surrogate")
                                );
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000
                                + ((cp - 0xd800) << 10)
                                + (lo - 0xdc00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(
                            ch.ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xf0 {
                            4
                        } else if c >= 0xe0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(
                            &self.bytes[start..end],
                        )
                        .map_err(|_| self.err("bad utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = self
                .bump()
                .and_then(|c| (c as char).to_digit(16))
                .ok_or_else(|| self.err("bad \\u escape"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .expect("ascii");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Read + parse a JSON file.
pub fn from_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
}

/// Serialise + write a JSON file.
pub fn to_file(path: &std::path::Path, v: &Json) -> anyhow::Result<()> {
    std::fs::write(path, v.to_string())
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parses_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1f600}"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("'single'").is_err());
        assert!(parse("{\"a\":}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"w":[[1.5,-2],[0,3]],"name":"m1","ok":true,"n":null}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_preserves_precision() {
        let x = 0.1234567890123456;
        let v = Json::Num(x);
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(back.as_f64(), Some(x));
    }

    #[test]
    fn mat_accessor() {
        let v = parse("[[1,2],[3,4]]").unwrap();
        assert_eq!(
            v.as_mat_f64(),
            Some(vec![vec![1.0, 2.0], vec![3.0, 4.0]])
        );
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }
}
