//! Dense f64 vector/matrix math — the numerical substrate for the whole
//! simulator (crossbar VMM, circuit integration, baseline model inference).
//!
//! Deliberately small: row-major [`Mat`], `Vec<f64>` vectors, and the
//! operations the hot paths need (`gemv`, transposed `gemv`, `gemm`), plus
//! allocation-free `_into` forms used by the request path.
//!
//! The batched request path adds [`Mat::vecmat_batch_into`]: B stacked
//! input vectors against one matrix, executed as a column-blocked
//! microkernel that touches each trajectory's input and output in
//! contiguous tiles. Its per-trajectory accumulation order is *identical*
//! to [`Mat::vecmat_into`], so a batched rollout reproduces B serial
//! rollouts bit-for-bit when no stochastic term intervenes — that exactness
//! is what the batched-vs-serial equivalence tests pin down.
//!
//! Every `vecmat*` kernel executes through the runtime-dispatched
//! microkernels of [`super::kernel`]: AVX2 when the CPU has it, portable
//! scalar otherwise, scoped-thread fan-out over trajectory blocks for
//! large batches — all bit-identical to each other (see the dispatch and
//! bit-identity rules in that module's docs and in `lib.rs`). The
//! `*_with` variants pin an explicit [`KernelKind`] / worker count for
//! tests and benches; production callers use the auto entry points.
//!
//! [`Trajectory`] is the flat solver-output container (one row per sample)
//! shared by every layer from the ODE steppers to `TwinResponse`; together
//! with [`TrajectoryPool`] it is what keeps the warm batched request path
//! free of steady-state heap allocations.

use super::kernel::{self, KernelKind};

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with a constant.
    pub fn full(rows: usize, cols: usize, v: f64) -> Self {
        Self { rows, cols, data: vec![v; rows * cols] }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(
        rows: usize,
        cols: usize,
        mut f: impl FnMut(usize, usize) -> f64,
    ) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    /// Build from a row-major flat vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Build from nested rows (e.g. parsed JSON weights).
    pub fn from_rows(rows_data: &[Vec<f64>]) -> Self {
        let rows = rows_data.len();
        let cols = rows_data.first().map_or(0, Vec::len);
        let mut data = Vec::with_capacity(rows * cols);
        for r in rows_data {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |r, c| self.at(c, r))
    }

    /// y = x^T A  (vector times matrix; `x.len() == rows`, output `cols`).
    ///
    /// This orientation matches the crossbar: input voltages drive the rows
    /// (bit lines), column currents are the output — and it walks `data`
    /// contiguously, which is what makes it the preferred hot-path form.
    pub fn vecmat(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.vecmat_into(x, &mut y);
        y
    }

    /// Allocation-free form of [`Mat::vecmat`].
    pub fn vecmat_into(&self, x: &[f64], y: &mut [f64]) {
        self.vecmat_into_with(kernel::active(), x, y);
    }

    /// [`Mat::vecmat_into`] with an explicit kernel (testing/benching —
    /// the auto entry point dispatches once per process).
    pub fn vecmat_into_with(
        &self,
        kind: KernelKind,
        x: &[f64],
        y: &mut [f64],
    ) {
        assert_eq!(x.len(), self.rows, "vecmat: x length != rows");
        assert_eq!(y.len(), self.cols, "vecmat: y length != cols");
        y.fill(0.0);
        // Row-major accumulate: y[c] += x[r] * A[r, c], tiled and
        // dispatched by util::kernel (AVX2 or scalar, same accumulation
        // order per output element either way).
        kernel::vecmat_range(kind, x, &self.data, self.cols, 0, self.cols, y);
    }

    /// Column-sharded [`Mat::vecmat_into`]: `y = x^T A[:, c0..c1]`, the
    /// shard read of a tile column-group (`y.len() == c1 - c0`).
    ///
    /// For every output element the accumulation order over the shared
    /// dimension — including the zero-input skip — is exactly that of the
    /// full-width `vecmat_into`, so a state vector assembled from shard
    /// reads is bit-identical to one monolithic read. This is the
    /// accumulation-order contract the sharded analogue path relies on.
    pub fn vecmat_cols_into(
        &self,
        x: &[f64],
        c0: usize,
        c1: usize,
        y: &mut [f64],
    ) {
        self.vecmat_cols_into_with(kernel::active(), x, c0, c1, y);
    }

    /// [`Mat::vecmat_cols_into`] with an explicit kernel.
    pub fn vecmat_cols_into_with(
        &self,
        kind: KernelKind,
        x: &[f64],
        c0: usize,
        c1: usize,
        y: &mut [f64],
    ) {
        assert!(
            c0 <= c1 && c1 <= self.cols,
            "vecmat_cols: column range {c0}..{c1} outside 0..{}",
            self.cols
        );
        assert_eq!(x.len(), self.rows, "vecmat_cols: x length != rows");
        assert_eq!(
            y.len(),
            c1 - c0,
            "vecmat_cols: y length != column range width"
        );
        y.fill(0.0);
        kernel::vecmat_range(kind, x, &self.data, self.cols, c0, c1, y);
    }

    /// y = A x (matrix times vector; `x.len() == cols`, output `rows`).
    pub fn gemv(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.gemv_into(x, &mut y);
        y
    }

    /// Allocation-free form of [`Mat::gemv`].
    pub fn gemv_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "gemv: x length != cols");
        assert_eq!(y.len(), self.rows, "gemv: y length != rows");
        for (r, yv) in y.iter_mut().enumerate() {
            let row = self.row(r);
            let mut acc = 0.0;
            for (&a, &b) in row.iter().zip(x) {
                acc += a * b;
            }
            *yv = acc;
        }
    }

    /// Batched [`Mat::vecmat`]: `ys[b] = xs[b]^T A` for `batch` row-major
    /// stacked inputs (`xs: [batch * rows]`, `ys: [batch * cols]`).
    ///
    /// This is the row-major GEMM of the batched request path, tiled as a
    /// column-blocked microkernel: each trajectory's input vector is read
    /// contiguously (front to back, once per column block), its output is
    /// accumulated into one hot `VECMAT_TILE_COLS`-wide tile at a time, and
    /// the matrix is streamed in contiguous row chunks — no batch-major
    /// strides anywhere. The tiles execute on the runtime-dispatched
    /// microkernel (AVX2 where available, scalar elsewhere or under
    /// `MEMODE_KERNEL=scalar`), and batches past the
    /// [`kernel::plan_threads`] thresholds fan out over scoped threads in
    /// trajectory blocks. For each output element the accumulation order
    /// over `r` — including the zero-input skip — is the same as
    /// [`Mat::vecmat_into`] under *every* kernel/thread choice, so
    /// per-trajectory outputs are bit-identical to B independent serial
    /// calls (the contract `rust/tests/batched.rs` pins down).
    pub fn vecmat_batch_into(
        &self,
        xs: &[f64],
        batch: usize,
        ys: &mut [f64],
    ) {
        self.vecmat_batch_into_with(
            kernel::active(),
            kernel::plan_threads(batch, self.rows, self.cols),
            xs,
            batch,
            ys,
        );
    }

    /// [`Mat::vecmat_batch_into`] with an explicit kernel and worker
    /// count (testing/benching; `threads` is clamped to `1..=batch`).
    ///
    /// `threads > 1` fans the batch out over scoped threads in disjoint
    /// trajectory blocks — each block runs the identical
    /// single-trajectory kernel, so the output is bit-identical to the
    /// single-threaded call by construction. Spawning allocates: the
    /// threaded path is deliberately outside the zero-allocation contract
    /// (like the shard fan-out in `twin::shard`), and the auto entry
    /// point's [`kernel::plan_threads`] threshold keeps small /
    /// latency-sensitive batches (and therefore the warm zero-alloc hot
    /// path) single-threaded.
    pub fn vecmat_batch_into_with(
        &self,
        kind: KernelKind,
        threads: usize,
        xs: &[f64],
        batch: usize,
        ys: &mut [f64],
    ) {
        assert_eq!(
            xs.len(),
            batch * self.rows,
            "vecmat_batch: xs length != batch * rows"
        );
        assert_eq!(
            ys.len(),
            batch * self.cols,
            "vecmat_batch: ys length != batch * cols"
        );
        ys.fill(0.0);
        let (rows, cols) = (self.rows, self.cols);
        if cols == 0 || batch == 0 {
            return;
        }
        let data = self.data.as_slice();
        let threads = threads.clamp(1, batch);
        if threads <= 1 || rows == 0 {
            for b in 0..batch {
                kernel::vecmat_range(
                    kind,
                    &xs[b * rows..(b + 1) * rows],
                    data,
                    cols,
                    0,
                    cols,
                    &mut ys[b * cols..(b + 1) * cols],
                );
            }
            return;
        }
        // Multicore path: disjoint trajectory blocks on scoped threads
        // (the worker pattern of twin::shard). No synchronisation beyond
        // the scope join — blocks share only the read-only matrix.
        let per = batch.div_ceil(threads);
        std::thread::scope(|scope| {
            for (xb, yb) in
                xs.chunks(per * rows).zip(ys.chunks_mut(per * cols))
            {
                scope.spawn(move || {
                    let nb = yb.len() / cols;
                    for b in 0..nb {
                        kernel::vecmat_range(
                            kind,
                            &xb[b * rows..(b + 1) * rows],
                            data,
                            cols,
                            0,
                            cols,
                            &mut yb[b * cols..(b + 1) * cols],
                        );
                    }
                });
            }
        });
    }

    /// Column-sharded [`Mat::vecmat_batch_into`]: `ys[b] = xs[b]^T
    /// A[:, c0..c1]` for `batch` stacked inputs (`ys: [batch * (c1-c0)]`).
    ///
    /// Tiled exactly like the full-width batched kernel (the tile walk
    /// simply starts at `c0` and stops at `c1`), and per output element the
    /// accumulation order over the shared dimension — zero-skip included —
    /// matches `vecmat_into`, so a batched sharded read is bit-identical to
    /// the corresponding column slice of the monolithic batched read.
    pub fn vecmat_batch_cols_into(
        &self,
        xs: &[f64],
        batch: usize,
        c0: usize,
        c1: usize,
        ys: &mut [f64],
    ) {
        self.vecmat_batch_cols_into_with(
            kernel::active(),
            xs,
            batch,
            c0,
            c1,
            ys,
        );
    }

    /// [`Mat::vecmat_batch_cols_into`] with an explicit kernel. Shard
    /// reads stay single-threaded by design: the parallel shard fan-out
    /// (`twin::shard`) already owns one worker per shard, and the serial
    /// in-solver shard loop sits inside the zero-allocation contract.
    pub fn vecmat_batch_cols_into_with(
        &self,
        kind: KernelKind,
        xs: &[f64],
        batch: usize,
        c0: usize,
        c1: usize,
        ys: &mut [f64],
    ) {
        assert!(
            c0 <= c1 && c1 <= self.cols,
            "vecmat_batch_cols: column range {c0}..{c1} outside 0..{}",
            self.cols
        );
        let width = c1 - c0;
        assert_eq!(
            xs.len(),
            batch * self.rows,
            "vecmat_batch_cols: xs length != batch * rows"
        );
        assert_eq!(
            ys.len(),
            batch * width,
            "vecmat_batch_cols: ys length != batch * range width"
        );
        ys.fill(0.0);
        let (rows, cols) = (self.rows, self.cols);
        for b in 0..batch {
            let x = &xs[b * rows..(b + 1) * rows];
            let y = &mut ys[b * width..(b + 1) * width];
            kernel::vecmat_range(kind, x, &self.data, cols, c0, c1, y);
        }
    }

    /// Allocating form of [`Mat::vecmat_batch_into`].
    pub fn vecmat_batch(&self, xs: &[f64], batch: usize) -> Vec<f64> {
        let mut ys = vec![0.0; batch * self.cols];
        self.vecmat_batch_into(xs, batch, &mut ys);
        ys
    }

    /// C = A B.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                let crow =
                    &mut c.data[i * b.cols..(i + 1) * b.cols];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += a * bv;
                }
            }
        }
        c
    }

    /// Element-wise map into a new matrix.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Frobenius norm.
    pub fn frob(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

// ---------------------------------------------------------------------------
// Trajectory: flat row-major solver output
// ---------------------------------------------------------------------------

/// A sampled trajectory stored flat: `n_points` rows of `dim` values in one
/// contiguous row-major buffer (row = one sample).
///
/// This is the output container threaded through every layer that used to
/// produce `Vec<Vec<f64>>` — the ODE solvers, the analogue closed loop, the
/// twins and `TwinResponse`. One allocation per trajectory instead of one
/// per sample, rows are cache-contiguous, and a cleared `Trajectory` keeps
/// its buffer, so pooled instances make the warm batched request path
/// allocation-free. Batched solvers use the same type with
/// `dim = batch * d` (each row is one lockstep sample of the whole batch).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trajectory {
    dim: usize,
    n_points: usize,
    data: Vec<f64>,
}

impl Trajectory {
    /// Empty trajectory with row width `dim`.
    pub fn new(dim: usize) -> Self {
        Self { dim, n_points: 0, data: Vec::new() }
    }

    /// Empty trajectory with capacity for `n_points` rows.
    pub fn with_capacity(dim: usize, n_points: usize) -> Self {
        Self { dim, n_points: 0, data: Vec::with_capacity(dim * n_points) }
    }

    /// Zero-filled trajectory.
    pub fn zeros(dim: usize, n_points: usize) -> Self {
        Self { dim, n_points, data: vec![0.0; dim * n_points] }
    }

    /// Adopt a flat row-major buffer (`data.len()` must be a multiple of
    /// `dim`); the inverse of [`Trajectory::into_data`].
    pub fn from_data(dim: usize, data: Vec<f64>) -> Self {
        if dim == 0 {
            assert!(data.is_empty(), "dim-0 trajectory with data");
            return Self { dim, n_points: 0, data };
        }
        assert_eq!(
            data.len() % dim,
            0,
            "trajectory data length {} not a multiple of dim {}",
            data.len(),
            dim
        );
        let n_points = data.len() / dim;
        Self { dim, n_points, data }
    }

    /// Build from nested rows (the legacy `[n][dim]` layout).
    pub fn from_nested(rows: &[Vec<f64>]) -> Self {
        let dim = rows.first().map_or(0, Vec::len);
        let mut t = Self::with_capacity(dim, rows.len());
        for r in rows {
            t.push_row(r);
        }
        t
    }

    /// `n` copies of one row (dim = `row.len()`).
    pub fn repeat_row(row: &[f64], n: usize) -> Self {
        let mut t = Self::with_capacity(row.len(), n);
        for _ in 0..n {
            t.push_row(row);
        }
        t
    }

    /// Row width (state dimension; `batch * d` for batched solves).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of sampled rows.
    pub fn n_points(&self) -> usize {
        self.n_points
    }

    /// Alias for [`Trajectory::n_points`] (container idiom).
    pub fn len(&self) -> usize {
        self.n_points
    }

    pub fn is_empty(&self) -> bool {
        self.n_points == 0
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        assert!(i < self.n_points, "row {i} >= n_points {}", self.n_points);
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        assert!(i < self.n_points, "row {i} >= n_points {}", self.n_points);
        &mut self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// The final row, if any.
    pub fn last(&self) -> Option<&[f64]> {
        self.n_points.checked_sub(1).map(|i| self.row(i))
    }

    /// Append one row (`row.len()` must equal `dim`).
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(
            row.len(),
            self.dim,
            "push_row: row length {} != dim {}",
            row.len(),
            self.dim
        );
        self.data.extend_from_slice(row);
        self.n_points += 1;
    }

    /// Append one row from an iterator that must yield exactly `dim`
    /// values (lets callers sample non-contiguous state — e.g. integrator
    /// capacitor voltages — without a staging buffer).
    pub fn push_row_from_iter(&mut self, it: impl IntoIterator<Item = f64>) {
        let before = self.data.len();
        self.data.extend(it);
        assert_eq!(
            self.data.len() - before,
            self.dim,
            "push_row_from_iter: iterator yielded {} values, dim is {}",
            self.data.len() - before,
            self.dim
        );
        self.n_points += 1;
    }

    /// Append every row of `other` (row widths must match). Used by the
    /// ensemble response path to materialise a pooled copy of a stats
    /// trajectory — on a warm pooled buffer this performs no allocation.
    pub fn extend_rows(&mut self, other: &Trajectory) {
        assert_eq!(
            other.dim, self.dim,
            "extend_rows: dim {} != dim {}",
            other.dim, self.dim
        );
        self.data.extend_from_slice(&other.data);
        self.n_points += other.n_points;
    }

    /// Append a copy of the final row (the fixed-step solvers' "advance
    /// in place from the previous sample" idiom; no scratch state vector).
    pub fn push_copy_of_last(&mut self) {
        assert!(self.n_points > 0, "push_copy_of_last on empty trajectory");
        let start = (self.n_points - 1) * self.dim;
        self.data.extend_from_within(start..start + self.dim);
        self.n_points += 1;
    }

    /// Drop all rows, keeping the buffer (capacity) for reuse.
    pub fn clear(&mut self) {
        self.data.clear();
        self.n_points = 0;
    }

    /// Clear and retarget the row width — the pooled-reuse entry point:
    /// the heap buffer survives, so a warm pool never reallocates.
    pub fn reset(&mut self, dim: usize) {
        self.clear();
        self.dim = dim;
    }

    /// Reserve space for `additional` more rows.
    pub fn reserve_rows(&mut self, additional: usize) {
        self.data.reserve(additional * self.dim);
    }

    /// The flat row-major buffer.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Consume into the flat buffer (for `dim == 1` this *is* the scalar
    /// sample series).
    pub fn into_data(self) -> Vec<f64> {
        self.data
    }

    /// Copy out the legacy nested `[n][dim]` layout (report/metric code).
    pub fn to_nested(&self) -> Vec<Vec<f64>> {
        self.iter().map(|r| r.to_vec()).collect()
    }

    /// Iterate over rows.
    pub fn iter(&self) -> TrajectoryRows<'_> {
        TrajectoryRows { t: self, i: 0 }
    }
}

impl std::ops::Index<usize> for Trajectory {
    type Output = [f64];

    fn index(&self, i: usize) -> &[f64] {
        self.row(i)
    }
}

/// Row iterator over a [`Trajectory`].
pub struct TrajectoryRows<'a> {
    t: &'a Trajectory,
    i: usize,
}

impl<'a> Iterator for TrajectoryRows<'a> {
    type Item = &'a [f64];

    fn next(&mut self) -> Option<&'a [f64]> {
        if self.i < self.t.n_points {
            let r = self.t.row(self.i);
            self.i += 1;
            Some(r)
        } else {
            None
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = self.t.n_points - self.i;
        (rem, Some(rem))
    }
}

impl ExactSizeIterator for TrajectoryRows<'_> {}

impl<'a> IntoIterator for &'a Trajectory {
    type Item = &'a [f64];
    type IntoIter = TrajectoryRows<'a>;

    fn into_iter(self) -> TrajectoryRows<'a> {
        self.iter()
    }
}

/// Free-list of [`Trajectory`] buffers.
///
/// `get` pops a cleared trajectory (retargeted to `dim`, buffer intact);
/// `put` returns one. A warm pool therefore hands out row storage without
/// touching the allocator — the twins draw their per-request response
/// trajectories from a pool, and callers that hand responses back (e.g.
/// the steady-state allocation test) close the loop to zero allocations
/// per batch.
#[derive(Debug, Default)]
pub struct TrajectoryPool {
    free: Vec<Trajectory>,
}

impl TrajectoryPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pop a cleared trajectory with row width `dim` (allocates only when
    /// the pool is empty).
    pub fn get(&mut self, dim: usize) -> Trajectory {
        let mut t = self.free.pop().unwrap_or_default();
        t.reset(dim);
        t
    }

    /// Return a trajectory's buffer to the pool.
    pub fn put(&mut self, t: Trajectory) {
        self.free.push(t);
    }

    /// Buffers currently pooled.
    pub fn len(&self) -> usize {
        self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.free.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Vector helpers (free functions over &[f64])
// ---------------------------------------------------------------------------

/// z = a + s * b (fused axpy-like update), allocation-free.
pub fn axpy_into(z: &mut [f64], a: &[f64], s: f64, b: &[f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), z.len());
    for ((zv, &av), &bv) in z.iter_mut().zip(a).zip(b) {
        *zv = av + s * bv;
    }
}

/// Batched axpy over `batch` stacked `dim`-vectors: the same fused update
/// as [`axpy_into`] on a flat `[batch * dim]` state, with every operand's
/// shape checked. Because the update is element-wise, the result is
/// bit-identical to applying [`axpy_into`] to each trajectory separately.
/// The batched ODE solvers get that same guarantee implicitly by running
/// the serial stepper arithmetic over flat state (`ode::batch::Flattened`);
/// this explicit form is for callers composing their own batched updates.
pub fn axpy_batch_into(
    z: &mut [f64],
    a: &[f64],
    s: f64,
    b: &[f64],
    batch: usize,
    dim: usize,
) {
    assert_eq!(z.len(), batch * dim, "axpy_batch: z length != batch * dim");
    assert_eq!(a.len(), batch * dim, "axpy_batch: a length != batch * dim");
    assert_eq!(b.len(), batch * dim, "axpy_batch: b length != batch * dim");
    axpy_into(z, a, s, b);
}

/// Element-wise a + b.
pub fn add(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(&x, &y)| x + y).collect()
}

/// Element-wise a - b.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(&x, &y)| x - y).collect()
}

/// s * a.
pub fn scale(a: &[f64], s: f64) -> Vec<f64> {
    a.iter().map(|&x| s * x).collect()
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// L2 norm.
pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// L-infinity distance between two vectors.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_fn_and_at() {
        let m = Mat::from_fn(2, 3, |r, c| (r * 10 + c) as f64);
        assert_eq!(m.at(0, 0), 0.0);
        assert_eq!(m.at(1, 2), 12.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0]);
    }

    #[test]
    fn vecmat_matches_manual() {
        // A = [[1,2],[3,4],[5,6]], x = [1, 0.5, -1] -> x^T A = [-2.5, -2]
        let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let y = a.vecmat(&[1.0, 0.5, -1.0]);
        assert_eq!(y, vec![-2.5, -2.0]);
    }

    #[test]
    fn gemv_matches_manual() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let y = a.gemv(&[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![6.0, 15.0]);
    }

    #[test]
    fn gemv_is_transpose_of_vecmat() {
        let m = Mat::from_fn(5, 4, |r, c| ((r * 7 + c * 3) % 5) as f64 - 2.0);
        let x = [0.5, -1.0, 2.0, 0.25, 1.5];
        assert_eq!(m.vecmat(&x), m.transpose().gemv(&x));
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 3, |r, c| (r + c) as f64);
        let id = Mat::from_fn(3, 3, |r, c| (r == c) as u8 as f64);
        assert_eq!(a.matmul(&id), a);
        assert_eq!(id.matmul(&a), a);
    }

    #[test]
    fn matmul_known_product() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transpose_involution() {
        let m = Mat::from_fn(4, 7, |r, c| (r * 31 + c * 17) as f64);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn axpy_into_works() {
        let a = [1.0, 2.0];
        let b = [10.0, 20.0];
        let mut z = [0.0; 2];
        axpy_into(&mut z, &a, 0.5, &b);
        assert_eq!(z, [6.0, 12.0]);
    }

    #[test]
    fn vec_helpers() {
        assert_eq!(add(&[1., 2.], &[3., 4.]), vec![4., 6.]);
        assert_eq!(sub(&[1., 2.], &[3., 4.]), vec![-2., -2.]);
        assert_eq!(scale(&[1., 2.], 2.0), vec![2., 4.]);
        assert_eq!(dot(&[1., 2.], &[3., 4.]), 11.0);
        assert!((norm(&[3., 4.]) - 5.0).abs() < 1e-12);
        assert_eq!(max_abs_diff(&[1., 5.], &[2., 3.]), 2.0);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_checks_shape() {
        let _ = Mat::from_vec(2, 2, vec![1.0]);
    }

    #[test]
    fn vecmat_batch_bit_identical_to_serial() {
        // The contract the batched execution engine is built on: each
        // trajectory of the batched GEMM equals its serial vecmat exactly
        // (same FP accumulation order), including zero-input skips.
        let m = Mat::from_fn(7, 5, |r, c| {
            ((r * 13 + c * 7) % 11) as f64 / 3.0 - 1.5
        });
        let batch = 4;
        let mut xs = vec![0.0; batch * 7];
        for (k, x) in xs.iter_mut().enumerate() {
            *x = if k % 6 == 0 { 0.0 } else { (k as f64 * 0.37).sin() };
        }
        let ys = m.vecmat_batch(&xs, batch);
        for b in 0..batch {
            let want = m.vecmat(&xs[b * 7..(b + 1) * 7]);
            assert_eq!(&ys[b * 5..(b + 1) * 5], &want[..], "traj {b}");
        }
    }

    #[test]
    fn vecmat_batch_of_one_matches_vecmat() {
        let m = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let x = [1.0, 0.5, -1.0];
        assert_eq!(m.vecmat_batch(&x, 1), m.vecmat(&x));
    }

    #[test]
    #[should_panic(expected = "batch * rows")]
    fn vecmat_batch_checks_input_shape() {
        let m = Mat::zeros(3, 2);
        let mut ys = vec![0.0; 4];
        m.vecmat_batch_into(&[0.0; 5], 2, &mut ys);
    }

    #[test]
    fn axpy_batch_matches_per_trajectory_axpy() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0, 30.0, 40.0];
        let mut z = [0.0; 4];
        axpy_batch_into(&mut z, &a, 0.5, &b, 2, 2);
        let mut want = [0.0; 4];
        axpy_into(&mut want[..2], &a[..2], 0.5, &b[..2]);
        axpy_into(&mut want[2..], &a[2..], 0.5, &b[2..]);
        assert_eq!(z, want);
    }

    #[test]
    fn gemv_into_no_stale_state() {
        let a = Mat::from_vec(1, 2, vec![1., 1.]);
        let mut y = vec![123.0];
        a.gemv_into(&[1.0, 2.0], &mut y);
        assert_eq!(y, vec![3.0]);
    }

    #[test]
    fn vecmat_batch_tiling_spans_many_column_blocks() {
        // Wider than one 32-column tile: the blocked kernel must still be
        // bit-identical to the serial vecmat on every trajectory.
        let m = Mat::from_fn(9, 77, |r, c| {
            ((r * 31 + c * 17) % 13) as f64 / 7.0 - 0.9
        });
        let batch = 3;
        let mut xs = vec![0.0; batch * 9];
        for (k, x) in xs.iter_mut().enumerate() {
            *x = if k % 5 == 2 { 0.0 } else { (k as f64 * 0.73).cos() };
        }
        let ys = m.vecmat_batch(&xs, batch);
        for b in 0..batch {
            let want = m.vecmat(&xs[b * 9..(b + 1) * 9]);
            assert_eq!(&ys[b * 77..(b + 1) * 77], &want[..], "traj {b}");
        }
    }

    #[test]
    fn vecmat_cols_bit_identical_to_full_slice() {
        // The sharded-read contract: column-group reads reassemble the
        // monolithic read exactly, element for element.
        let m = Mat::from_fn(11, 70, |r, c| {
            ((r * 29 + c * 13) % 19) as f64 / 6.0 - 1.4
        });
        let mut x = vec![0.0; 11];
        for (k, v) in x.iter_mut().enumerate() {
            *v = if k % 4 == 1 { 0.0 } else { (k as f64 * 0.51).sin() };
        }
        let full = m.vecmat(&x);
        for &(c0, c1) in &[(0usize, 32usize), (32, 64), (64, 70), (0, 70), (5, 6)] {
            let mut y = vec![9.0; c1 - c0];
            m.vecmat_cols_into(&x, c0, c1, &mut y);
            assert_eq!(&y[..], &full[c0..c1], "range {c0}..{c1}");
        }
    }

    #[test]
    fn vecmat_batch_cols_bit_identical_to_full_slice() {
        let m = Mat::from_fn(9, 77, |r, c| {
            ((r * 31 + c * 17) % 13) as f64 / 7.0 - 0.9
        });
        let batch = 3;
        let mut xs = vec![0.0; batch * 9];
        for (k, x) in xs.iter_mut().enumerate() {
            *x = if k % 5 == 2 { 0.0 } else { (k as f64 * 0.73).cos() };
        }
        let full = m.vecmat_batch(&xs, batch);
        for &(c0, c1) in &[(0usize, 32usize), (32, 77), (40, 41), (0, 77)] {
            let w = c1 - c0;
            let mut ys = vec![7.0; batch * w];
            m.vecmat_batch_cols_into(&xs, batch, c0, c1, &mut ys);
            for b in 0..batch {
                assert_eq!(
                    &ys[b * w..(b + 1) * w],
                    &full[b * 77 + c0..b * 77 + c1],
                    "traj {b} range {c0}..{c1}"
                );
            }
        }
    }

    #[test]
    fn vecmat_batch_threaded_bit_identical_to_single_thread() {
        // The multicore fan-out must be invisible in the output: same
        // kernel per trajectory, disjoint blocks, bitwise-equal results —
        // including at thread counts that do not divide the batch.
        let m = Mat::from_fn(19, 45, |r, c| {
            ((r * 13 + c * 7) % 17) as f64 / 5.0 - 1.6
        });
        let batch = 13;
        let mut xs = vec![0.0; batch * 19];
        for (k, x) in xs.iter_mut().enumerate() {
            *x = if k % 7 == 3 { 0.0 } else { (k as f64 * 0.29).sin() };
        }
        let kind = kernel::active();
        let mut want = vec![0.0; batch * 45];
        m.vecmat_batch_into_with(kind, 1, &xs, batch, &mut want);
        for threads in [2usize, 3, 5, 13, 64] {
            let mut got = vec![1.0; batch * 45];
            m.vecmat_batch_into_with(kind, threads, &xs, batch, &mut got);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn vecmat_kernels_bit_identical_across_kinds() {
        // Forced scalar vs forced SIMD (falls back to scalar where AVX2
        // is absent — the CI kernel-matrix legs cover both worlds) across
        // all four kernel entry points.
        let m = Mat::from_fn(11, 77, |r, c| {
            ((r * 31 + c * 17) % 13) as f64 / 7.0 - 0.9
        });
        let batch = 5;
        let mut xs = vec![0.0; batch * 11];
        for (k, x) in xs.iter_mut().enumerate() {
            *x = if k % 6 == 0 { 0.0 } else { (k as f64 * 0.47).cos() };
        }
        let kinds = [KernelKind::Scalar, KernelKind::Simd];
        // vecmat_into / vecmat_cols_into.
        let x = &xs[..11];
        let mut y = [vec![0.0; 77], vec![0.0; 77]];
        for (k, kind) in kinds.iter().enumerate() {
            m.vecmat_into_with(*kind, x, &mut y[k]);
        }
        assert_eq!(y[0], y[1]);
        let mut yc = [vec![0.0; 31], vec![0.0; 31]];
        for (k, kind) in kinds.iter().enumerate() {
            m.vecmat_cols_into_with(*kind, x, 33, 64, &mut yc[k]);
        }
        assert_eq!(yc[0], yc[1]);
        assert_eq!(&yc[0][..], &y[0][33..64]);
        // vecmat_batch_into / vecmat_batch_cols_into.
        let mut ys = [vec![0.0; batch * 77], vec![0.0; batch * 77]];
        for (k, kind) in kinds.iter().enumerate() {
            m.vecmat_batch_into_with(*kind, 1, &xs, batch, &mut ys[k]);
        }
        assert_eq!(ys[0], ys[1]);
        let mut yb = [vec![0.0; batch * 44], vec![0.0; batch * 44]];
        for (k, kind) in kinds.iter().enumerate() {
            m.vecmat_batch_cols_into_with(*kind, &xs, batch, 33, 77, &mut yb[k]);
        }
        assert_eq!(yb[0], yb[1]);
    }

    #[test]
    #[should_panic(expected = "column range")]
    fn vecmat_cols_checks_range() {
        let m = Mat::zeros(2, 3);
        let mut y = vec![0.0; 2];
        m.vecmat_cols_into(&[0.0; 2], 2, 4, &mut y);
    }

    #[test]
    fn trajectory_roundtrip_and_accessors() {
        let mut t = Trajectory::with_capacity(2, 3);
        assert!(t.is_empty());
        assert_eq!(t.dim(), 2);
        t.push_row(&[1.0, 2.0]);
        t.push_row_from_iter([3.0, 4.0]);
        t.push_copy_of_last();
        assert_eq!(t.n_points(), 3);
        assert_eq!(t.len(), 3);
        assert_eq!(t.row(0), [1.0, 2.0]);
        assert_eq!(t[1], [3.0, 4.0]);
        assert_eq!(t.last().unwrap(), [3.0, 4.0]);
        assert_eq!(t.data(), &[1.0, 2.0, 3.0, 4.0, 3.0, 4.0]);
        // Nested round-trip.
        let nested = t.to_nested();
        assert_eq!(nested, vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![3.0, 4.0]]);
        assert_eq!(Trajectory::from_nested(&nested), t);
        // Flat round-trip.
        let dim = t.dim();
        let flat = t.clone().into_data();
        assert_eq!(Trajectory::from_data(dim, flat), t);
        // Row iteration matches indexing.
        for (i, row) in t.iter().enumerate() {
            assert_eq!(row, t.row(i));
        }
        assert_eq!(t.iter().len(), 3);
    }

    #[test]
    fn trajectory_extend_rows_copies_all_rows() {
        let src = Trajectory::from_nested(&[
            vec![1.0, 2.0],
            vec![3.0, 4.0],
        ]);
        let mut dst = Trajectory::new(2);
        dst.push_row(&[0.0, 0.0]);
        dst.extend_rows(&src);
        assert_eq!(dst.len(), 3);
        assert_eq!(dst.row(1), [1.0, 2.0]);
        assert_eq!(dst.row(2), [3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "extend_rows: dim")]
    fn trajectory_extend_rows_checks_dim() {
        let src = Trajectory::from_nested(&[vec![1.0]]);
        let mut dst = Trajectory::new(2);
        dst.extend_rows(&src);
    }

    #[test]
    fn trajectory_row_mut_and_repeat() {
        let mut t = Trajectory::repeat_row(&[7.0], 4);
        assert_eq!(t.n_points(), 4);
        t.row_mut(2)[0] = -1.0;
        assert_eq!(t.row(2), [-1.0]);
        assert_eq!(t.row(3), [7.0]);
    }

    #[test]
    fn trajectory_reset_keeps_capacity() {
        let mut t = Trajectory::with_capacity(4, 8);
        for _ in 0..8 {
            t.push_row(&[0.0; 4]);
        }
        let cap = t.data.capacity();
        t.reset(2);
        assert_eq!(t.dim(), 2);
        assert!(t.is_empty());
        assert_eq!(t.data.capacity(), cap, "reset must keep the buffer");
        for _ in 0..16 {
            t.push_row(&[1.0, 2.0]);
        }
        assert_eq!(t.data.capacity(), cap, "refill within capacity");
    }

    #[test]
    fn trajectory_pool_reuses_buffers() {
        let mut pool = TrajectoryPool::new();
        let mut t = pool.get(3);
        t.reserve_rows(10);
        for _ in 0..10 {
            t.push_row(&[1.0, 2.0, 3.0]);
        }
        let cap = t.data.capacity();
        pool.put(t);
        assert_eq!(pool.len(), 1);
        let t2 = pool.get(5);
        assert!(t2.is_empty());
        assert_eq!(t2.dim(), 5);
        assert_eq!(t2.data.capacity(), cap, "pooled buffer survives");
        assert!(pool.is_empty());
    }

    #[test]
    #[should_panic(expected = "push_row: row length")]
    fn trajectory_push_row_checks_dim() {
        let mut t = Trajectory::new(2);
        t.push_row(&[1.0]);
    }

    #[test]
    #[should_panic(expected = "not a multiple of dim")]
    fn trajectory_from_data_checks_shape() {
        let _ = Trajectory::from_data(2, vec![1.0, 2.0, 3.0]);
    }
}
