//! Dense f64 vector/matrix math — the numerical substrate for the whole
//! simulator (crossbar VMM, circuit integration, baseline model inference).
//!
//! Deliberately small: row-major [`Mat`], `Vec<f64>` vectors, and the
//! operations the hot paths need (`gemv`, transposed `gemv`, `gemm`), plus
//! allocation-free `_into` forms used by the request path.
//!
//! The batched request path adds [`Mat::vecmat_batch_into`]: B stacked
//! input vectors against one matrix in a single pass over the matrix (a
//! row-major GEMM). Its per-trajectory accumulation order is *identical*
//! to [`Mat::vecmat_into`], so a batched rollout reproduces B serial
//! rollouts bit-for-bit when no stochastic term intervenes — that exactness
//! is what the batched-vs-serial equivalence tests pin down.

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with a constant.
    pub fn full(rows: usize, cols: usize, v: f64) -> Self {
        Self { rows, cols, data: vec![v; rows * cols] }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(
        rows: usize,
        cols: usize,
        mut f: impl FnMut(usize, usize) -> f64,
    ) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    /// Build from a row-major flat vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Build from nested rows (e.g. parsed JSON weights).
    pub fn from_rows(rows_data: &[Vec<f64>]) -> Self {
        let rows = rows_data.len();
        let cols = rows_data.first().map_or(0, Vec::len);
        let mut data = Vec::with_capacity(rows * cols);
        for r in rows_data {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |r, c| self.at(c, r))
    }

    /// y = x^T A  (vector times matrix; `x.len() == rows`, output `cols`).
    ///
    /// This orientation matches the crossbar: input voltages drive the rows
    /// (bit lines), column currents are the output — and it walks `data`
    /// contiguously, which is what makes it the preferred hot-path form.
    pub fn vecmat(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.vecmat_into(x, &mut y);
        y
    }

    /// Allocation-free form of [`Mat::vecmat`].
    pub fn vecmat_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows, "vecmat: x length != rows");
        assert_eq!(y.len(), self.cols, "vecmat: y length != cols");
        y.fill(0.0);
        // Row-major accumulate: y[c] += x[r] * A[r, c]; the inner loop is a
        // contiguous axpy that autovectorises.
        for (r, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let row = self.row(r);
            for (yc, &a) in y.iter_mut().zip(row) {
                *yc += xv * a;
            }
        }
    }

    /// y = A x (matrix times vector; `x.len() == cols`, output `rows`).
    pub fn gemv(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.gemv_into(x, &mut y);
        y
    }

    /// Allocation-free form of [`Mat::gemv`].
    pub fn gemv_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "gemv: x length != cols");
        assert_eq!(y.len(), self.rows, "gemv: y length != rows");
        for (r, yv) in y.iter_mut().enumerate() {
            let row = self.row(r);
            let mut acc = 0.0;
            for (&a, &b) in row.iter().zip(x) {
                acc += a * b;
            }
            *yv = acc;
        }
    }

    /// Batched [`Mat::vecmat`]: `ys[b] = xs[b]^T A` for `batch` row-major
    /// stacked inputs (`xs: [batch * rows]`, `ys: [batch * cols]`).
    ///
    /// This is the row-major GEMM of the batched request path: the weight
    /// matrix is walked **once** per call (row `r` is loaded one time and
    /// applied to every trajectory) instead of once per trajectory, which
    /// is where batching amortises memory traffic. For each trajectory the
    /// accumulation order over `r` — including the zero-input skip — is the
    /// same as [`Mat::vecmat_into`], so per-trajectory outputs are
    /// bit-identical to B independent serial calls.
    pub fn vecmat_batch_into(
        &self,
        xs: &[f64],
        batch: usize,
        ys: &mut [f64],
    ) {
        assert_eq!(
            xs.len(),
            batch * self.rows,
            "vecmat_batch: xs length != batch * rows"
        );
        assert_eq!(
            ys.len(),
            batch * self.cols,
            "vecmat_batch: ys length != batch * cols"
        );
        ys.fill(0.0);
        for r in 0..self.rows {
            let row = self.row(r);
            for b in 0..batch {
                let xv = xs[b * self.rows + r];
                if xv == 0.0 {
                    continue;
                }
                let y = &mut ys[b * self.cols..(b + 1) * self.cols];
                for (yc, &a) in y.iter_mut().zip(row) {
                    *yc += xv * a;
                }
            }
        }
    }

    /// Allocating form of [`Mat::vecmat_batch_into`].
    pub fn vecmat_batch(&self, xs: &[f64], batch: usize) -> Vec<f64> {
        let mut ys = vec![0.0; batch * self.cols];
        self.vecmat_batch_into(xs, batch, &mut ys);
        ys
    }

    /// C = A B.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                let crow =
                    &mut c.data[i * b.cols..(i + 1) * b.cols];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += a * bv;
                }
            }
        }
        c
    }

    /// Element-wise map into a new matrix.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Frobenius norm.
    pub fn frob(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

// ---------------------------------------------------------------------------
// Vector helpers (free functions over &[f64])
// ---------------------------------------------------------------------------

/// z = a + s * b (fused axpy-like update), allocation-free.
pub fn axpy_into(z: &mut [f64], a: &[f64], s: f64, b: &[f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), z.len());
    for ((zv, &av), &bv) in z.iter_mut().zip(a).zip(b) {
        *zv = av + s * bv;
    }
}

/// Batched axpy over `batch` stacked `dim`-vectors: the same fused update
/// as [`axpy_into`] on a flat `[batch * dim]` state, with every operand's
/// shape checked. Because the update is element-wise, the result is
/// bit-identical to applying [`axpy_into`] to each trajectory separately.
/// The batched ODE solvers get that same guarantee implicitly by running
/// the serial stepper arithmetic over flat state (`ode::batch::Flattened`);
/// this explicit form is for callers composing their own batched updates.
pub fn axpy_batch_into(
    z: &mut [f64],
    a: &[f64],
    s: f64,
    b: &[f64],
    batch: usize,
    dim: usize,
) {
    assert_eq!(z.len(), batch * dim, "axpy_batch: z length != batch * dim");
    assert_eq!(a.len(), batch * dim, "axpy_batch: a length != batch * dim");
    assert_eq!(b.len(), batch * dim, "axpy_batch: b length != batch * dim");
    axpy_into(z, a, s, b);
}

/// Element-wise a + b.
pub fn add(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(&x, &y)| x + y).collect()
}

/// Element-wise a - b.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(&x, &y)| x - y).collect()
}

/// s * a.
pub fn scale(a: &[f64], s: f64) -> Vec<f64> {
    a.iter().map(|&x| s * x).collect()
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// L2 norm.
pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// L-infinity distance between two vectors.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_fn_and_at() {
        let m = Mat::from_fn(2, 3, |r, c| (r * 10 + c) as f64);
        assert_eq!(m.at(0, 0), 0.0);
        assert_eq!(m.at(1, 2), 12.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0]);
    }

    #[test]
    fn vecmat_matches_manual() {
        // A = [[1,2],[3,4],[5,6]], x = [1, 0.5, -1] -> x^T A = [-2.5, -2]
        let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let y = a.vecmat(&[1.0, 0.5, -1.0]);
        assert_eq!(y, vec![-2.5, -2.0]);
    }

    #[test]
    fn gemv_matches_manual() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let y = a.gemv(&[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![6.0, 15.0]);
    }

    #[test]
    fn gemv_is_transpose_of_vecmat() {
        let m = Mat::from_fn(5, 4, |r, c| ((r * 7 + c * 3) % 5) as f64 - 2.0);
        let x = [0.5, -1.0, 2.0, 0.25, 1.5];
        assert_eq!(m.vecmat(&x), m.transpose().gemv(&x));
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 3, |r, c| (r + c) as f64);
        let id = Mat::from_fn(3, 3, |r, c| (r == c) as u8 as f64);
        assert_eq!(a.matmul(&id), a);
        assert_eq!(id.matmul(&a), a);
    }

    #[test]
    fn matmul_known_product() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transpose_involution() {
        let m = Mat::from_fn(4, 7, |r, c| (r * 31 + c * 17) as f64);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn axpy_into_works() {
        let a = [1.0, 2.0];
        let b = [10.0, 20.0];
        let mut z = [0.0; 2];
        axpy_into(&mut z, &a, 0.5, &b);
        assert_eq!(z, [6.0, 12.0]);
    }

    #[test]
    fn vec_helpers() {
        assert_eq!(add(&[1., 2.], &[3., 4.]), vec![4., 6.]);
        assert_eq!(sub(&[1., 2.], &[3., 4.]), vec![-2., -2.]);
        assert_eq!(scale(&[1., 2.], 2.0), vec![2., 4.]);
        assert_eq!(dot(&[1., 2.], &[3., 4.]), 11.0);
        assert!((norm(&[3., 4.]) - 5.0).abs() < 1e-12);
        assert_eq!(max_abs_diff(&[1., 5.], &[2., 3.]), 2.0);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_checks_shape() {
        let _ = Mat::from_vec(2, 2, vec![1.0]);
    }

    #[test]
    fn vecmat_batch_bit_identical_to_serial() {
        // The contract the batched execution engine is built on: each
        // trajectory of the batched GEMM equals its serial vecmat exactly
        // (same FP accumulation order), including zero-input skips.
        let m = Mat::from_fn(7, 5, |r, c| {
            ((r * 13 + c * 7) % 11) as f64 / 3.0 - 1.5
        });
        let batch = 4;
        let mut xs = vec![0.0; batch * 7];
        for (k, x) in xs.iter_mut().enumerate() {
            *x = if k % 6 == 0 { 0.0 } else { (k as f64 * 0.37).sin() };
        }
        let ys = m.vecmat_batch(&xs, batch);
        for b in 0..batch {
            let want = m.vecmat(&xs[b * 7..(b + 1) * 7]);
            assert_eq!(&ys[b * 5..(b + 1) * 5], &want[..], "traj {b}");
        }
    }

    #[test]
    fn vecmat_batch_of_one_matches_vecmat() {
        let m = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let x = [1.0, 0.5, -1.0];
        assert_eq!(m.vecmat_batch(&x, 1), m.vecmat(&x));
    }

    #[test]
    #[should_panic(expected = "batch * rows")]
    fn vecmat_batch_checks_input_shape() {
        let m = Mat::zeros(3, 2);
        let mut ys = vec![0.0; 4];
        m.vecmat_batch_into(&[0.0; 5], 2, &mut ys);
    }

    #[test]
    fn axpy_batch_matches_per_trajectory_axpy() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0, 30.0, 40.0];
        let mut z = [0.0; 4];
        axpy_batch_into(&mut z, &a, 0.5, &b, 2, 2);
        let mut want = [0.0; 4];
        axpy_into(&mut want[..2], &a[..2], 0.5, &b[..2]);
        axpy_into(&mut want[2..], &a[2..], 0.5, &b[2..]);
        assert_eq!(z, want);
    }

    #[test]
    fn gemv_into_no_stale_state() {
        let a = Mat::from_vec(1, 2, vec![1., 1.]);
        let mut y = vec![123.0];
        a.gemv_into(&[1.0, 2.0], &mut y);
        assert_eq!(y, vec![3.0]);
    }
}
