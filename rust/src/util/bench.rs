//! Micro-benchmark harness (criterion replacement for the offline build).
//!
//! Warmup + timed iterations with median / p95 / mean reporting, a
//! `black_box` to defeat constant folding, and a tabular reporter used by
//! every `cargo bench` target (`harness = false`) to print the rows of the
//! paper's figures.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use crate::util::stats;

/// Re-export of `std::hint::black_box` under the criterion-style name.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn median_us(&self) -> f64 {
        self.median.as_secs_f64() * 1e6
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Minimum number of timed iterations.
    pub min_iters: usize,
    /// Target total measurement time; iterations stop after both bounds met.
    pub target_time: Duration,
    /// Warmup time before measuring.
    pub warmup: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            min_iters: 20,
            target_time: Duration::from_millis(400),
            warmup: Duration::from_millis(100),
        }
    }
}

impl Bencher {
    /// Fast settings for CI-ish runs.
    pub fn quick() -> Self {
        Self {
            min_iters: 10,
            target_time: Duration::from_millis(120),
            warmup: Duration::from_millis(30),
        }
    }

    /// Benchmark a closure; its return value is black-boxed.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std_black_box(f());
        }
        // Measure.
        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.min_iters * 2);
        let t0 = Instant::now();
        loop {
            let s = Instant::now();
            std_black_box(f());
            samples_ns.push(s.elapsed().as_nanos() as f64);
            if samples_ns.len() >= self.min_iters
                && t0.elapsed() >= self.target_time
            {
                break;
            }
        }
        let med = stats::median(&samples_ns);
        let p95 = stats::percentile(&samples_ns, 95.0);
        let mean = stats::summary(&samples_ns).mean;
        let min = samples_ns.iter().cloned().fold(f64::INFINITY, f64::min);
        BenchResult {
            name: name.to_string(),
            iters: samples_ns.len(),
            median: Duration::from_nanos(med as u64),
            mean: Duration::from_nanos(mean as u64),
            p95: Duration::from_nanos(p95 as u64),
            min: Duration::from_nanos(min as u64),
        }
    }
}

/// Pretty-print a table of results with an optional baseline row for
/// speedup ratios (the "ours vs digital" columns of Fig. 3k / 4h).
pub fn print_table(title: &str, results: &[BenchResult]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>10} {:>12} {:>12} {:>12}",
        "case", "iters", "median", "mean", "p95"
    );
    for r in results {
        println!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}",
            r.name,
            r.iters,
            fmt_dur(r.median),
            fmt_dur(r.mean),
            fmt_dur(r.p95)
        );
    }
}

/// Human-friendly duration.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_produces_sane_timings() {
        let b = Bencher::quick();
        let r = b.run("noop", || 1 + 1);
        assert!(r.iters >= 10);
        assert!(r.median <= r.p95);
        assert!(r.min <= r.median);
    }

    #[test]
    fn timed_work_is_ordered() {
        // Large gap so the assertion holds even on a loaded machine.
        let b = Bencher::quick();
        let fast = b.run("fast", || std_black_box(1u64) + 1);
        let slow = b.run("slow", || {
            (0..2_000_000u64).map(std_black_box).sum::<u64>()
        });
        assert!(slow.median > fast.median);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(50)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
