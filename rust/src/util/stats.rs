//! Summary statistics, percentiles, histograms and the streaming ensemble
//! accumulator.
//!
//! Backs the device-characterisation experiments (Fig. 2k programming-error
//! histogram), the benchmark harness (median/p95 latency), the
//! noise-robustness grids (Fig. 4j averages over repetitions) and the
//! Monte-Carlo ensemble responses ([`EnsembleAccumulator`]).
//!
//! NaN policy: percentiles *skip* NaN samples (and report how many were
//! skipped) instead of panicking — one diverged ensemble member or a
//! poisoned latency sample must never crash a telemetry snapshot or an
//! ensemble response. All-NaN inputs yield NaN.

use crate::util::tensor::{Trajectory, TrajectoryPool};

/// Basic summary of a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Population variance (the paper quotes variance of programming error).
    pub var: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

/// Compute a [`Summary`] over a sample (empty samples return NaNs).
pub fn summary(xs: &[f64]) -> Summary {
    let n = xs.len();
    if n == 0 {
        return Summary {
            n: 0,
            mean: f64::NAN,
            var: f64::NAN,
            std: f64::NAN,
            min: f64::NAN,
            max: f64::NAN,
        };
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / n as f64;
    let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
    for &x in xs {
        min = min.min(x);
        max = max.max(x);
    }
    Summary { n, mean, var, std: var.sqrt(), min, max }
}

/// p-th percentile (0..=100) by linear interpolation on the sorted sample.
/// NaN samples are skipped (see [`percentile_filtered`] to also get the
/// skip count); a sample with no non-NaN values yields NaN.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    percentile_filtered(xs, p).0
}

/// [`percentile`] that also reports how many NaN samples were skipped.
/// Total-order comparison (`f64::total_cmp`) — never panics on any input.
pub fn percentile_filtered(xs: &[f64], p: f64) -> (f64, usize) {
    assert!(!xs.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&p), "percentile out of range");
    let mut s: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    let skipped = xs.len() - s.len();
    if s.is_empty() {
        return (f64::NAN, skipped);
    }
    s.sort_unstable_by(f64::total_cmp);
    (percentile_of_sorted(&s, p), skipped)
}

/// p-th percentile of an already ascending-sorted, NaN-free sample — the
/// allocation-free core shared by [`percentile`], the telemetry snapshot's
/// sort-once latency scratch and the ensemble envelope computation.
pub fn percentile_of_sorted(s: &[f64], p: f64) -> f64 {
    assert!(!s.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&p), "percentile out of range");
    let idx = p / 100.0 * (s.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (idx - lo as f64) * (s[hi] - s[lo])
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets; values outside
/// the range clamp to the edge buckets (matches how the paper's Fig. 2k
/// histogram treats outliers).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self { lo, hi, counts: vec![0; bins] }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64).floor() as i64).clamp(0, bins as i64 - 1);
        self.counts[idx as usize] += 1;
    }

    pub fn add_all(&mut self, xs: &[f64]) {
        for &x in xs {
            self.add(x);
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Bucket centre for index `i`.
    pub fn center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.lo + (i as f64 + 0.5) * w
    }

    /// Render an ASCII bar chart (used by `memode characterize`).
    pub fn ascii(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = "#".repeat((c as usize * width) / max as usize);
            out.push_str(&format!(
                "{:>10.4} | {:<width$} {}\n",
                self.center(i),
                bar,
                c,
                width = width
            ));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Streaming per-timestep ensemble statistics (Welford)
// ---------------------------------------------------------------------------

/// Streaming per-timestep, per-dimension moment accumulator for
/// Monte-Carlo ensemble rollouts.
///
/// Members are fed one at a time ([`EnsembleAccumulator::add_member_rows`])
/// and mean/variance accumulate via Welford's update, so the whole-ensemble
/// member matrix never needs to be materialised beyond the batched rollout
/// the twins already hold. The mean and M2 buffers are [`Trajectory`]s
/// drawn from the caller's [`TrajectoryPool`] at [`EnsembleAccumulator::begin`]
/// and handed back (mean, std) by [`EnsembleAccumulator::finish`], so a
/// warm ensemble batch stays inside the zero-allocation contract (the
/// internal count and sort scratch are reused across batches too).
///
/// NaN policy: a NaN sample (diverged member) is skipped per element and
/// counted ([`EnsembleAccumulator::nan_skipped`]); an element with no
/// finite samples reports NaN mean/std. Variance is the population
/// variance, matching [`summary`].
#[derive(Debug, Default)]
pub struct EnsembleAccumulator {
    dim: usize,
    n_points: usize,
    members: usize,
    /// Per-element finite-sample counts (`[n_points * dim]`, reused).
    count: Vec<u64>,
    mean: Trajectory,
    m2: Trajectory,
    nan_skipped: u64,
    /// Per-element member-value sort scratch for percentile envelopes.
    psort: Vec<f64>,
}

impl EnsembleAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start accumulating an ensemble of `[n_points][dim]` trajectories.
    /// The mean/M2 buffers come from `pool`; call [`EnsembleAccumulator::finish`]
    /// to take them back out (an abandoned accumulation drops them).
    pub fn begin(
        &mut self,
        dim: usize,
        n_points: usize,
        pool: &mut TrajectoryPool,
    ) {
        self.dim = dim;
        self.n_points = n_points;
        self.members = 0;
        self.nan_skipped = 0;
        self.count.clear();
        self.count.resize(dim * n_points, 0);
        self.mean = pool.get(dim);
        self.m2 = pool.get(dim);
        self.mean.reserve_rows(n_points);
        self.m2.reserve_rows(n_points);
        for _ in 0..n_points {
            self.mean.push_row_from_iter((0..dim).map(|_| 0.0));
            self.m2.push_row_from_iter((0..dim).map(|_| 0.0));
        }
    }

    /// Fold one member in: `rows` must yield exactly `n_points` rows of
    /// width `dim` (e.g. per-member slices of the twins' flat batched
    /// rollout).
    pub fn add_member_rows<'a>(
        &mut self,
        rows: impl Iterator<Item = &'a [f64]>,
    ) {
        let dim = self.dim;
        let mut n_rows = 0;
        for (i, row) in rows.enumerate() {
            assert!(i < self.n_points, "ensemble member has too many rows");
            assert_eq!(row.len(), dim, "ensemble member row width");
            let mean_row = self.mean.row_mut(i);
            let m2_row = self.m2.row_mut(i);
            let count_row = &mut self.count[i * dim..(i + 1) * dim];
            for d in 0..dim {
                let x = row[d];
                if x.is_nan() {
                    self.nan_skipped += 1;
                    continue;
                }
                count_row[d] += 1;
                let c = count_row[d] as f64;
                let delta = x - mean_row[d];
                mean_row[d] += delta / c;
                m2_row[d] += delta * (x - mean_row[d]);
            }
            n_rows += 1;
        }
        assert_eq!(n_rows, self.n_points, "ensemble member row count");
        self.members += 1;
    }

    /// Members folded in so far.
    pub fn members(&self) -> usize {
        self.members
    }

    /// NaN samples skipped so far.
    pub fn nan_skipped(&self) -> u64 {
        self.nan_skipped
    }

    /// Finish: return `(mean, std, nan_skipped)`, consuming the pooled
    /// buffers (the M2 buffer is converted to std in place). Elements with
    /// zero finite samples are NaN.
    pub fn finish(&mut self) -> (Trajectory, Trajectory, u64) {
        let dim = self.dim;
        for i in 0..self.n_points {
            let row = self.m2.row_mut(i);
            let count_row = &self.count[i * dim..(i + 1) * dim];
            for d in 0..dim {
                row[d] = if count_row[d] == 0 {
                    f64::NAN
                } else {
                    (row[d] / count_row[d] as f64).sqrt()
                };
            }
            // NaN-out mean elements nothing contributed to.
            let mean_row = self.mean.row_mut(i);
            for d in 0..dim {
                if count_row[d] == 0 {
                    mean_row[d] = f64::NAN;
                }
            }
        }
        (
            std::mem::take(&mut self.mean),
            std::mem::take(&mut self.m2),
            self.nan_skipped,
        )
    }

    /// Fill every `(p, out)` pair with the per-timestep `p`-th percentile
    /// across the `members` trajectories stored in a flat batched
    /// rollout: `flat` rows are `batch * dim` wide and member `m`
    /// occupies columns `(lane0 + m) * dim ..`. Each element's member
    /// samples are gathered and sorted **once** for all requested
    /// percentiles (the envelope is the per-response hot path). NaN
    /// samples are skipped per element (all-NaN elements yield NaN); the
    /// internal sort scratch is reused, so a warm call allocates nothing
    /// beyond the outputs' pooled capacity. Each `out` must be a cleared
    /// trajectory with row width `dim`.
    pub fn percentile_pairs_flat_into(
        &mut self,
        flat: &Trajectory,
        lane0: usize,
        members: usize,
        outs: &mut [(f64, Trajectory)],
    ) {
        let dim = self.dim;
        assert_eq!(flat.len(), self.n_points, "flat rollout row count");
        if outs.is_empty() {
            return;
        }
        for (p, out) in outs.iter_mut() {
            assert!(
                (0.0..=100.0).contains(p),
                "percentile out of range"
            );
            assert_eq!(out.dim(), dim, "percentile output row width");
            out.reserve_rows(self.n_points);
            for _ in 0..self.n_points {
                out.push_row_from_iter((0..dim).map(|_| 0.0));
            }
        }
        for i in 0..self.n_points {
            let frow = flat.row(i);
            for d in 0..dim {
                self.psort.clear();
                for m in 0..members {
                    let x = frow[(lane0 + m) * dim + d];
                    if !x.is_nan() {
                        self.psort.push(x);
                    }
                }
                if self.psort.is_empty() {
                    for (_, out) in outs.iter_mut() {
                        out.row_mut(i)[d] = f64::NAN;
                    }
                } else {
                    self.psort.sort_unstable_by(f64::total_cmp);
                    for (p, out) in outs.iter_mut() {
                        out.row_mut(i)[d] =
                            percentile_of_sorted(&self.psort, *p);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = summary(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.mean, 2.5);
        assert!((s.var - 1.25).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_empty_is_nan() {
        assert!(summary(&[]).mean.is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(median(&xs), 25.0);
        assert!((percentile(&xs, 25.0) - 17.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [30.0, 10.0, 40.0, 20.0];
        assert_eq!(median(&xs), 25.0);
    }

    #[test]
    fn percentile_skips_nan_and_counts() {
        let xs = [10.0, f64::NAN, 30.0, 20.0, f64::NAN, 40.0];
        let (v, skipped) = percentile_filtered(&xs, 50.0);
        assert_eq!(v, 25.0);
        assert_eq!(skipped, 2);
        // The plain form no longer panics on NaN.
        assert_eq!(median(&xs), 25.0);
        // All-NaN: NaN result, full skip count.
        let (v, skipped) = percentile_filtered(&[f64::NAN, f64::NAN], 95.0);
        assert!(v.is_nan());
        assert_eq!(skipped, 2);
    }

    #[test]
    fn percentile_of_sorted_matches_percentile() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        for p in [0.0, 25.0, 50.0, 95.0, 100.0] {
            assert_eq!(percentile_of_sorted(&xs, p), percentile(&xs, p));
        }
    }

    #[test]
    fn ensemble_accumulator_matches_direct_moments() {
        // 3 members, 2 points, dim 2; compare against summary() per
        // element.
        let members = [
            [[1.0, 2.0], [3.0, 4.0]],
            [[2.0, 0.0], [5.0, 4.0]],
            [[6.0, 1.0], [1.0, 10.0]],
        ];
        let mut pool = TrajectoryPool::new();
        let mut acc = EnsembleAccumulator::new();
        acc.begin(2, 2, &mut pool);
        for m in &members {
            acc.add_member_rows(m.iter().map(|r| &r[..]));
        }
        assert_eq!(acc.members(), 3);
        let (mean, std, nan) = acc.finish();
        assert_eq!(nan, 0);
        for i in 0..2 {
            for d in 0..2 {
                let col: Vec<f64> =
                    members.iter().map(|m| m[i][d]).collect();
                let s = summary(&col);
                assert!((mean.row(i)[d] - s.mean).abs() < 1e-12);
                assert!((std.row(i)[d] - s.std).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn ensemble_accumulator_skips_nan_members() {
        let mut pool = TrajectoryPool::new();
        let mut acc = EnsembleAccumulator::new();
        acc.begin(1, 2, &mut pool);
        acc.add_member_rows([[2.0], [f64::NAN]].iter().map(|r| &r[..]));
        acc.add_member_rows([[4.0], [f64::NAN]].iter().map(|r| &r[..]));
        let (mean, std, nan) = acc.finish();
        assert_eq!(nan, 2);
        assert_eq!(mean.row(0), [3.0]);
        assert_eq!(std.row(0), [1.0]);
        // No finite samples at point 1: NaN, not a crash.
        assert!(mean.row(1)[0].is_nan());
        assert!(std.row(1)[0].is_nan());
    }

    #[test]
    fn ensemble_percentile_envelope_from_flat_rollout() {
        // Flat batched layout: 4 members, dim 1, 2 points; member m holds
        // value (m+1) * 10 at point 0 and -(m as f64) at point 1.
        let mut flat = Trajectory::new(4);
        flat.push_row(&[10.0, 20.0, 30.0, 40.0]);
        flat.push_row(&[0.0, -1.0, -2.0, -3.0]);
        let mut pool = TrajectoryPool::new();
        let mut acc = EnsembleAccumulator::new();
        acc.begin(1, 2, &mut pool);
        for m in 0..4 {
            acc.add_member_rows(flat.iter().map(|r| &r[m..m + 1]));
        }
        let _ = acc.finish();
        let mut outs =
            vec![(50.0, pool.get(1)), (100.0, pool.get(1))];
        acc.percentile_pairs_flat_into(&flat, 0, 4, &mut outs);
        assert_eq!(outs[0].1.row(0), [25.0]);
        assert_eq!(outs[0].1.row(1), [-1.5]);
        assert_eq!(outs[1].1.row(0), [40.0]);
        assert_eq!(outs[1].1.row(1), [0.0]);
    }

    #[test]
    fn histogram_buckets_and_clamping() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.add_all(&[0.1, 0.3, 0.6, 0.9, -5.0, 5.0]);
        assert_eq!(h.counts, vec![2, 1, 1, 2]);
        assert_eq!(h.total(), 6);
        assert!((h.center(0) - 0.125).abs() < 1e-12);
    }

    #[test]
    fn histogram_ascii_renders() {
        let mut h = Histogram::new(0.0, 1.0, 2);
        h.add_all(&[0.1, 0.2, 0.8]);
        let s = h.ascii(10);
        assert!(s.contains('#'));
        assert_eq!(s.lines().count(), 2);
    }
}
