//! Summary statistics, percentiles and histograms.
//!
//! Backs the device-characterisation experiments (Fig. 2k programming-error
//! histogram), the benchmark harness (median/p95 latency) and the
//! noise-robustness grids (Fig. 4j averages over repetitions).

/// Basic summary of a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Population variance (the paper quotes variance of programming error).
    pub var: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

/// Compute a [`Summary`] over a sample (empty samples return NaNs).
pub fn summary(xs: &[f64]) -> Summary {
    let n = xs.len();
    if n == 0 {
        return Summary {
            n: 0,
            mean: f64::NAN,
            var: f64::NAN,
            std: f64::NAN,
            min: f64::NAN,
            max: f64::NAN,
        };
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / n as f64;
    let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
    for &x in xs {
        min = min.min(x);
        max = max.max(x);
    }
    Summary { n, mean, var, std: var.sqrt(), min, max }
}

/// p-th percentile (0..=100) by linear interpolation on the sorted sample.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&p), "percentile out of range");
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile"));
    let idx = p / 100.0 * (s.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (idx - lo as f64) * (s[hi] - s[lo])
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets; values outside
/// the range clamp to the edge buckets (matches how the paper's Fig. 2k
/// histogram treats outliers).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self { lo, hi, counts: vec![0; bins] }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64).floor() as i64).clamp(0, bins as i64 - 1);
        self.counts[idx as usize] += 1;
    }

    pub fn add_all(&mut self, xs: &[f64]) {
        for &x in xs {
            self.add(x);
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Bucket centre for index `i`.
    pub fn center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.lo + (i as f64 + 0.5) * w
    }

    /// Render an ASCII bar chart (used by `memode characterize`).
    pub fn ascii(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = "#".repeat((c as usize * width) / max as usize);
            out.push_str(&format!(
                "{:>10.4} | {:<width$} {}\n",
                self.center(i),
                bar,
                c,
                width = width
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = summary(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.mean, 2.5);
        assert!((s.var - 1.25).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_empty_is_nan() {
        assert!(summary(&[]).mean.is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(median(&xs), 25.0);
        assert!((percentile(&xs, 25.0) - 17.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [30.0, 10.0, 40.0, 20.0];
        assert_eq!(median(&xs), 25.0);
    }

    #[test]
    fn histogram_buckets_and_clamping() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.add_all(&[0.1, 0.3, 0.6, 0.9, -5.0, 5.0]);
        assert_eq!(h.counts, vec![2, 1, 1, 2]);
        assert_eq!(h.total(), 6);
        assert!((h.center(0) - 0.125).abs() < 1e-12);
    }

    #[test]
    fn histogram_ascii_renders() {
        let mut h = Histogram::new(0.0, 1.0, 2);
        h.add_all(&[0.1, 0.2, 0.8]);
        let s = h.ascii(10);
        assert!(s.contains('#'));
        assert_eq!(s.lines().count(), 2);
    }
}
