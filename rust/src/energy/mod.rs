//! Latency / energy projection models (Fig. 3k-l, Fig. 4h-i, Supp. Table 1).
//!
//! The paper's speed/energy numbers are *projections*: GPU-side figures come
//! from an analytic latency/energy model of small-batch recurrent inference
//! on an A100-class device, and the memristive figures from the analogue
//! signal chain's settling times and static power. This module implements
//! the same methodology with every constant documented and unit-tested, so
//! the benches can regenerate the paper's ratio structure (who wins, by
//! roughly what factor, where the gap widens) — see DESIGN.md for the
//! substitution rationale.
//!
//! * [`digital`]  — GPU projection (kernel-launch-floor + roofline terms)
//! * [`analogue`] — memristive solver projection (settle times, crossbar
//!   static power, integrator energy), including a physically-derived
//!   estimate straight from a deployed simulated array
//! * [`report`]   — comparison-table assembly shared by the benches

pub mod analogue;
pub mod digital;
pub mod report;

pub use analogue::{recalibration_energy, AnalogCost, E_WRITE_PULSE_J};
pub use digital::{DigitalCost, ModelKind};
pub use report::{ComparisonRow, comparison_table};
