//! Comparison-table assembly shared by the Fig. 3k/3l/4h/4i benches and
//! Supplementary Table 1 regeneration.

use crate::energy::analogue::{self, AnalogParams};
use crate::energy::digital::{self, GpuParams, ModelKind};

/// One row of a speed/energy comparison table.
#[derive(Debug, Clone)]
pub struct ComparisonRow {
    pub model: String,
    pub hidden: usize,
    /// Projected latency (s) per inference step / forward pass.
    pub t_s: f64,
    /// Projected energy (J).
    pub e_j: f64,
    /// Ratio vs the memristive system (>1 means ours wins).
    pub speedup_vs_ours: f64,
    pub energy_ratio_vs_ours: f64,
}

/// Build the Fig. 4h/4i table: the four digital models + ours across the
/// paper's hidden sizes, per inference sample, d = 6 (Lorenz96).
pub fn comparison_table(
    hidden_sizes: &[usize],
    gpu: &GpuParams,
    ana: &AnalogParams,
) -> Vec<ComparisonRow> {
    let mut rows = Vec::new();
    for &h in hidden_sizes {
        let ours = analogue::project_step(3, h, ana);
        for kind in [
            ModelKind::NeuralOde,
            ModelKind::Lstm,
            ModelKind::Gru,
            ModelKind::Rnn,
        ] {
            let d = digital::project_step(kind, 6, h, 0, gpu);
            rows.push(ComparisonRow {
                model: kind.label().to_string(),
                hidden: h,
                t_s: d.t_step,
                e_j: d.e_step,
                speedup_vs_ours: d.t_step / ours.t_step,
                energy_ratio_vs_ours: d.e_step / ours.e_step,
            });
        }
        rows.push(ComparisonRow {
            model: "memristive-node (ours)".to_string(),
            hidden: h,
            t_s: ours.t_step,
            e_j: ours.e_step,
            speedup_vs_ours: 1.0,
            energy_ratio_vs_ours: 1.0,
        });
    }
    rows
}

/// Pretty-print rows the way the paper's figures read.
pub fn print_rows(title: &str, rows: &[ComparisonRow]) {
    println!("\n== {title} ==");
    println!(
        "{:<24} {:>7} {:>12} {:>12} {:>10} {:>10}",
        "model", "hidden", "latency", "energy", "speed x", "energy x"
    );
    for r in rows {
        println!(
            "{:<24} {:>7} {:>9.1} µs {:>9.2} µJ {:>9.1}x {:>9.1}x",
            r.model,
            r.hidden,
            r.t_s * 1e6,
            r.e_j * 1e6,
            r.speedup_vs_ours,
            r.energy_ratio_vs_ours
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_expected_rows() {
        let rows = comparison_table(
            &[64, 512],
            &GpuParams::default(),
            &AnalogParams::integrated(),
        );
        assert_eq!(rows.len(), 10); // (4 digital + ours) x 2 sizes
        assert!(rows.iter().any(|r| r.model.contains("ours")));
    }

    #[test]
    fn ours_rows_have_unit_ratio() {
        let rows = comparison_table(
            &[128],
            &GpuParams::default(),
            &AnalogParams::integrated(),
        );
        let ours = rows.iter().find(|r| r.model.contains("ours")).unwrap();
        assert_eq!(ours.speedup_vs_ours, 1.0);
        assert_eq!(ours.energy_ratio_vs_ours, 1.0);
    }

    #[test]
    fn gap_widens_with_scale() {
        // The paper's scalability claim: the ode-vs-ours speedup grows
        // with hidden size.
        let rows = comparison_table(
            &[64, 512],
            &GpuParams::default(),
            &AnalogParams::integrated(),
        );
        let at = |h: usize| {
            rows.iter()
                .find(|r| r.hidden == h && r.model == "neural-ode")
                .unwrap()
                .speedup_vs_ours
        };
        assert!(at(512) > at(64));
    }
}
