//! Memristive-solver latency/energy projection.
//!
//! The analogue system computes the vector field by letting the crossbar +
//! peripheral chain *settle*: one inference sample costs one settle chain
//! through the n layers — there is no 4x RK4 stage multiplier, because the
//! integrator is continuous (this is exactly the paper's continuous-time
//! speed argument, and why the gap vs the digital neural ODE (12.6x) is ~3x
//! the gap vs an RNN (2.5x), which also does one pass per sample).
//!
//! Latency model:  t_fwd = n_layers * (t_settle_base + t_settle_per_col*h)
//! Energy model:   E = P_system * t_fwd  (+ ADC-free by construction)
//!
//! Constants are calibrated to the paper's two anchors — Fig. 3k (4.2x at
//! hidden 64 vs one GPU field eval) and Fig. 4h (40.1 µs at hidden 512) —
//! and cross-checked against a physics-derived bound from the simulated
//! arrays (`power_from_arrays`). Two power presets reflect the paper's two
//! systems: the *experimental board* (discrete OPA4990 TIAs, TI muxes;
//! Fig. 3l's 17 µJ/pass) and the *projected integrated* system (Fig. 4i).

use crate::crossbar::differential::DifferentialArray;

/// Analogue system projection constants.
#[derive(Debug, Clone)]
pub struct AnalogParams {
    /// Per-layer settle floor (s): TIA + ReLU + clamp chain.
    pub t_settle_base: f64,
    /// Additional settle per logical column (s): wire/array capacitance.
    pub t_settle_per_col: f64,
    /// System power while settling (W).
    pub power_w: f64,
    /// Initial-conditioning time per trajectory (s): mux switch + capacitor
    /// pre-charge (Fig. 2c).
    pub t_condition: f64,
}

impl AnalogParams {
    /// The paper's experimental board (Fig. 3): discrete precision op-amps
    /// and analogue muxes burn ~0.58 W, and board-level wire/mux
    /// capacitance makes settling grow visibly with array width.
    pub fn board() -> Self {
        Self {
            t_settle_base: 9.0e-6,
            t_settle_per_col: 12.0e-9,
            power_w: 0.578,
            t_condition: 10e-6,
        }
    }

    /// The projected integrated system (Fig. 4): on-chip peripherals at
    /// ~93 mW (the paper's Supplementary Note 2 regime). On-chip wire
    /// capacitance is negligible, so settling is op-amp-GBW-bound and
    /// almost flat in array width — which is why the paper's speed gap
    /// *grows* with model size (Fig. 4h).
    pub fn integrated() -> Self {
        Self {
            t_settle_base: 13.2e-6,
            t_settle_per_col: 0.5e-9,
            power_w: 0.0929,
            t_condition: 10e-6,
        }
    }
}

/// Projected per-sample cost of the analogue solver.
#[derive(Debug, Clone, Copy)]
pub struct AnalogCost {
    /// Latency per inference sample (s) — one settle chain.
    pub t_step: f64,
    /// Energy per inference sample (J).
    pub e_step: f64,
}

/// Project one inference sample for an `n_layers`-deep field of hidden
/// width `h`.
pub fn project_step(n_layers: usize, h: usize, p: &AnalogParams) -> AnalogCost {
    let t_step =
        n_layers as f64 * (p.t_settle_base + p.t_settle_per_col * h as f64);
    AnalogCost { t_step, e_step: p.power_w * t_step }
}

/// Project a trajectory of `n_steps` samples (adds one conditioning phase).
pub fn project_trajectory(
    n_layers: usize,
    h: usize,
    n_steps: usize,
    p: &AnalogParams,
) -> AnalogCost {
    let s = project_step(n_layers, h, p);
    AnalogCost {
        t_step: p.t_condition + s.t_step * n_steps as f64,
        e_step: p.power_w * p.t_condition + s.e_step * n_steps as f64,
    }
}

/// Energy of one write-verify programming pulse (J).
///
/// A TaOx SET/RESET pulse is ~1 µs at ~1 V across a ~10 kΩ filament plus
/// the write driver's overhead — order 100 pJ per pulse, consistent with
/// the programming-energy regime the paper's Supplementary Note 2 assumes
/// for on-chip write circuitry. Recalibration energy is pulses x this.
pub const E_WRITE_PULSE_J: f64 = 1.0e-10;

/// Energy charged for a recalibration that issued `pulses` write-verify
/// pulses ([`crate::crossbar::tiling::TiledMatrix::reprogram`] returns the
/// count). Reported per-route in the coordinator's telemetry snapshot.
pub fn recalibration_energy(pulses: u64) -> f64 {
    pulses as f64 * E_WRITE_PULSE_J
}

/// Physics-derived static power of a deployed differential array under a
/// given RMS operating voltage: P = sum_cells G * V_rms^2 (both rails).
/// Used to sanity-check the `power_w` presets against the simulated
/// hardware (see EXPERIMENTS.md).
pub fn power_from_arrays(arrays: &[&DifferentialArray], v_rms: f64) -> f64 {
    let mut p = 0.0;
    for a in arrays {
        for m in [&a.pos, &a.neg] {
            let g = m.conductance_matrix();
            p += g.data.iter().sum::<f64>() * v_rms * v_rms;
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::digital::{self, GpuParams, ModelKind};

    #[test]
    fn fig4h_anchor_40us_at_512() {
        let c = project_step(3, 512, &AnalogParams::integrated());
        assert!(
            (c.t_step - 40.1e-6).abs() / 40.1e-6 < 0.05,
            "t = {:.2} µs",
            c.t_step * 1e6
        );
    }

    #[test]
    fn fig4h_speedups_match_paper_shape() {
        // @512: node 12.6x, LSTM 9.8x, GRU 7.4x, RNN 2.5x (paper). Accept
        // 20 % tolerance — this is the ratio structure, not the testbed.
        let gp = GpuParams::default();
        let ap = AnalogParams::integrated();
        let ours = project_step(3, 512, &ap).t_step;
        let anchors = [
            (ModelKind::NeuralOde, 12.6),
            (ModelKind::Lstm, 9.8),
            (ModelKind::Gru, 7.4),
            (ModelKind::Rnn, 2.5),
        ];
        for (kind, want) in anchors {
            let dig = digital::project_step(kind, 6, 512, 0, &gp).t_step;
            let ratio = dig / ours;
            assert!(
                (ratio - want).abs() / want < 0.2,
                "{}: {ratio:.2}x vs paper {want}x",
                kind.label()
            );
        }
    }

    #[test]
    fn fig4i_energy_ratios_match_paper_shape() {
        // @512: node 189.7x, LSTM 147.2x, GRU 100.6x, RNN 37.1x.
        let gp = GpuParams::default();
        let ap = AnalogParams::integrated();
        let ours = project_step(3, 512, &ap).e_step;
        let anchors = [
            (ModelKind::NeuralOde, 189.7),
            (ModelKind::Lstm, 147.2),
            (ModelKind::Gru, 100.6),
            (ModelKind::Rnn, 37.1),
        ];
        for (kind, want) in anchors {
            let dig = digital::project_step(kind, 6, 512, 0, &gp).e_step;
            let ratio = dig / ours;
            assert!(
                (ratio - want).abs() / want < 0.2,
                "{}: {ratio:.1}x vs paper {want}x",
                kind.label()
            );
        }
    }

    #[test]
    fn fig3_anchors_speed_and_energy() {
        // Fig. 3k: 4.2x vs one GPU field eval at hidden 64 (5 kernels).
        let gp = GpuParams::default();
        let ap = AnalogParams::board();
        let ours = project_step(3, 64, &ap);
        let dig_fwd = 5.0 * gp.t_kernel_floor
            + ModelKind::RecurrentResNet.macs_per_step(2, 64) / gp.macs_per_s;
        let speedup = dig_fwd / ours.t_step;
        assert!(
            (speedup - 4.2).abs() < 0.6,
            "fig3k speedup {speedup:.2} vs paper 4.2"
        );
        // Fig. 3l: ours ~17 µJ per forward pass.
        assert!(
            (ours.e_step - 17.0e-6).abs() / 17.0e-6 < 0.05,
            "E = {:.1} µJ",
            ours.e_step * 1e6
        );
    }

    #[test]
    fn trajectory_adds_conditioning_once() {
        let ap = AnalogParams::board();
        let one = project_step(3, 64, &ap);
        let traj = project_trajectory(3, 64, 100, &ap);
        assert!(
            (traj.t_step - (ap.t_condition + 100.0 * one.t_step)).abs()
                < 1e-12
        );
    }

    #[test]
    fn physics_power_within_order_of_magnitude_of_presets() {
        use crate::device::taox::DeviceConfig;
        use crate::util::rng::Pcg64;
        use crate::util::tensor::Mat;
        // Deploy the HP twin's three layers and compute static power at
        // 0.2 V RMS; it must be far below the board preset (the op-amps,
        // not the arrays, dominate) but nonzero.
        let cfg = DeviceConfig::default();
        let mut rng = Pcg64::seeded(1);
        let ws = [
            Mat::from_fn(3, 14, |r, c| ((r + c) as f64 / 17.0) - 0.4),
            Mat::from_fn(15, 14, |r, c| ((r * c) as f64 / 210.0) - 0.4),
            Mat::from_fn(15, 1, |r, _| (r as f64 / 15.0) - 0.4),
        ];
        let arrays: Vec<DifferentialArray> = ws
            .iter()
            .map(|w| DifferentialArray::deploy(w, &cfg, &mut rng))
            .collect();
        let refs: Vec<&DifferentialArray> = arrays.iter().collect();
        let p = power_from_arrays(&refs, 0.2);
        assert!(p > 1e-7 && p < 0.578, "array power {p} W");
    }
}
