//! GPU-side latency/energy projection.
//!
//! Small-batch recurrent inference on a GPU is *launch/latency dominated*:
//! each sequential kernel (a gemv, a gate nonlinearity, an elementwise
//! update) costs a fixed floor (launch + sync + L2 round trip) regardless of
//! how few FLOPs it contains, plus roofline terms for compute and memory.
//! The paper's Fig. 4h numbers decompose almost exactly this way:
//! RNN : GRU : LSTM : node ≈ 98.8 : 294.9 : 392.5 : 505.8 µs ≈ 4 : 12 : 16
//! : 20+ sequential kernels at a ~24.7 µs floor. We adopt that
//! decomposition explicitly.

/// Which model architecture is being projected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// Neural ODE stepped with RK4 (4 field evals x (3 gemv + 2 act)).
    NeuralOde,
    /// Recurrent ResNet (one field eval + state add per step).
    RecurrentResNet,
    Lstm,
    Gru,
    Rnn,
}

impl ModelKind {
    /// Sequential kernel count per inference step (the latency floor
    /// multiplier). Derived from the standard cuDNN-style decomposition of
    /// each cell; calibrated against the paper's Fig. 4h anchor ratios.
    pub fn kernels_per_step(self) -> usize {
        match self {
            // 4 RK4 stages x (3 gemv + 2 activations/concat) = 20.
            ModelKind::NeuralOde => 20,
            // 1 field eval (3 gemv + act/concat fused) + residual = 5.
            ModelKind::RecurrentResNet => 5,
            // 4 gate gemv-pairs fused to 4 + 8 pointwise + head ~ 16.
            ModelKind::Lstm => 16,
            // 3 gate blocks + candidate + head ~ 12.
            ModelKind::Gru => 12,
            // x/h gemv + tanh + head = 4.
            ModelKind::Rnn => 4,
        }
    }

    /// MACs per inference step for hidden width `h`, state dim `d`.
    pub fn macs_per_step(self, d: usize, h: usize) -> f64 {
        let (dh, hh, hd) = ((d * h) as f64, (h * h) as f64, (h * d) as f64);
        match self {
            // field = d->h, h->h, h->d; x4 RK4 stages.
            ModelKind::NeuralOde => 4.0 * (dh + hh + hd),
            ModelKind::RecurrentResNet => dh + hh + hd,
            // 4 gates: x->4h, h->4h, + head h->d.
            ModelKind::Lstm => 4.0 * (dh + hh) + hd,
            ModelKind::Gru => 3.0 * (dh + hh) + hd,
            ModelKind::Rnn => dh + hh + hd,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            ModelKind::NeuralOde => "neural-ode",
            ModelKind::RecurrentResNet => "recurrent-resnet",
            ModelKind::Lstm => "lstm",
            ModelKind::Gru => "gru",
            ModelKind::Rnn => "rnn",
        }
    }
}

/// A100-class projection constants (documented in DESIGN.md).
#[derive(Debug, Clone)]
pub struct GpuParams {
    /// Per-sequential-kernel latency floor (s). Paper anchor: 24.7 µs.
    pub t_kernel_floor: f64,
    /// Effective small-batch throughput (MAC/s). Far below peak: an
    /// unbatched gemv cannot saturate the SMs or HBM; ~2e10 MAC/s is the
    /// regime the paper's Fig. 4h growth-with-size implies.
    pub macs_per_s: f64,
    /// Marginal energy per sequential kernel (J): launch + operand
    /// streaming through the memory system. Paper anchor: Fig. 3l's
    /// node/ResNet = 4.0 at 20/5 kernels, 176.4 µJ per 5-kernel pass.
    pub e_kernel: f64,
    /// Marginal compute energy per MAC (J), on top of `e_kernel`.
    pub e_mac: f64,
    /// Energy per analogue-digital conversion of one sensor sample (J);
    /// digital twins must digitise the sensed signal (SAR ADC ~ nJ class).
    pub e_adc: f64,
}

impl Default for GpuParams {
    fn default() -> Self {
        Self {
            t_kernel_floor: 24.7e-6,
            macs_per_s: 2.0e10,
            e_kernel: 35.3e-6,
            e_mac: 0.5e-12,
            e_adc: 2.0e-9,
        }
    }
}

/// Projected per-step cost of a digital model.
#[derive(Debug, Clone, Copy)]
pub struct DigitalCost {
    /// Latency per inference step (s).
    pub t_step: f64,
    /// Energy per inference step (J).
    pub e_step: f64,
}

/// Project latency + energy for one inference step.
///
/// `d` = state dimension, `h` = hidden width, `n_adc` = sensor samples
/// digitised per step (0 for autonomous systems after initialisation).
pub fn project_step(
    kind: ModelKind,
    d: usize,
    h: usize,
    n_adc: usize,
    p: &GpuParams,
) -> DigitalCost {
    let kernels = kind.kernels_per_step() as f64;
    let macs = kind.macs_per_step(d, h);
    let t_compute = macs / p.macs_per_s;
    let t_step = kernels * p.t_kernel_floor + t_compute;
    // Energy: fixed per-kernel cost (launch + operand streaming) + compute
    // + ADC conversions of sensed inputs.
    let e_step =
        kernels * p.e_kernel + macs * p.e_mac + n_adc as f64 * p.e_adc;
    DigitalCost { t_step, e_step }
}

/// Project a full trajectory (n_steps sequential inference steps).
pub fn project_trajectory(
    kind: ModelKind,
    d: usize,
    h: usize,
    n_adc_per_step: usize,
    n_steps: usize,
    p: &GpuParams,
) -> DigitalCost {
    let s = project_step(kind, d, h, n_adc_per_step, p);
    DigitalCost {
        t_step: s.t_step * n_steps as f64,
        e_step: s.e_step * n_steps as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4h_anchor_ratios_reproduced() {
        // Paper Fig. 4h @ hidden 512: node 505.8, LSTM 392.5, GRU 294.9,
        // RNN 98.8 µs. The projection must land within 15 % of each.
        let p = GpuParams::default();
        let anchors = [
            (ModelKind::NeuralOde, 505.8e-6),
            (ModelKind::Lstm, 392.5e-6),
            (ModelKind::Gru, 294.9e-6),
            (ModelKind::Rnn, 98.8e-6),
        ];
        for (kind, want) in anchors {
            let got = project_step(kind, 6, 512, 0, &p).t_step;
            let rel = (got - want).abs() / want;
            assert!(
                rel < 0.15,
                "{}: projected {:.1} µs vs paper {:.1} µs",
                kind.label(),
                got * 1e6,
                want * 1e6
            );
        }
    }

    #[test]
    fn latency_increases_with_hidden_size() {
        let p = GpuParams::default();
        let t64 = project_step(ModelKind::NeuralOde, 6, 64, 0, &p).t_step;
        let t512 = project_step(ModelKind::NeuralOde, 6, 512, 0, &p).t_step;
        assert!(t512 > t64);
    }

    #[test]
    fn ode_slower_than_rnn_everywhere() {
        let p = GpuParams::default();
        for h in [64, 128, 256, 512] {
            let ode = project_step(ModelKind::NeuralOde, 6, h, 0, &p);
            let rnn = project_step(ModelKind::Rnn, 6, h, 0, &p);
            assert!(ode.t_step > rnn.t_step);
            assert!(ode.e_step > rnn.e_step);
        }
    }

    #[test]
    fn adc_energy_counts() {
        let p = GpuParams::default();
        let with = project_step(ModelKind::Rnn, 6, 64, 6, &p).e_step;
        let without = project_step(ModelKind::Rnn, 6, 64, 0, &p).e_step;
        assert!((with - without - 6.0 * p.e_adc).abs() < 1e-15);
    }

    #[test]
    fn trajectory_scales_linearly() {
        let p = GpuParams::default();
        let one = project_step(ModelKind::Gru, 6, 128, 1, &p);
        let many = project_trajectory(ModelKind::Gru, 6, 128, 1, 100, &p);
        assert!((many.t_step - 100.0 * one.t_step).abs() < 1e-12);
        assert!((many.e_step - 100.0 * one.e_step).abs() < 1e-12);
    }

    #[test]
    fn macs_formulas() {
        // Hand check: RNN d=2, h=3 -> 2*3 + 3*3 + 3*2 = 21.
        assert_eq!(ModelKind::Rnn.macs_per_step(2, 3), 21.0);
        // LSTM: 4*(6+9) + 6 = 66.
        assert_eq!(ModelKind::Lstm.macs_per_step(2, 3), 66.0);
    }
}
