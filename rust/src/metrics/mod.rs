//! Accuracy metrics exactly as the paper's Methods define them.
//!
//! * [`mre`]      — Mean Relative Error (Eq. 5)
//! * [`dtw`]      — Dynamic Time Warping distance (Eqs. 6-7)
//! * [`l1`]       — absolute-error metrics of Fig. 4d-g
//! * [`lyapunov`] — Lyapunov-time horizon bookkeeping (Methods Eq. 10)

pub mod dtw;
pub mod l1;
pub mod lyapunov;
pub mod mre;

pub use dtw::dtw_distance;
pub use l1::{l1_error, mean_l1_multi};
pub use mre::mre;
