//! Dynamic Time Warping (paper Eqs. 6-7).
//!
//! Classic O(n*m) dynamic program with the |x - y| local cost of Eq. 6 and
//! the three-neighbour recursion of Eq. 7. Two-row memory (O(min(n, m)))
//! so the 2400-point Lorenz96 sequences stay cache-friendly. The paper
//! reports a *normalised* DTW score; we expose both the raw cumulative
//! cost and the per-step normalisation used in Fig. 3j.

/// Raw DTW distance between two scalar series (Eq. 7 cumulative cost at
/// (n, m)).
pub fn dtw_distance(x: &[f64], y: &[f64]) -> f64 {
    assert!(!x.is_empty() && !y.is_empty(), "empty series");
    // Keep the shorter series in the inner dimension for memory.
    let (a, b) = if x.len() >= y.len() { (x, y) } else { (y, x) };
    let m = b.len();
    let mut prev = vec![f64::INFINITY; m + 1];
    let mut curr = vec![f64::INFINITY; m + 1];
    prev[0] = 0.0;
    for &ai in a {
        curr[0] = f64::INFINITY;
        for j in 1..=m {
            let d = (ai - b[j - 1]).abs();
            let best = prev[j].min(curr[j - 1]).min(prev[j - 1]);
            curr[j] = d + best;
        }
        std::mem::swap(&mut prev, &mut curr);
    }
    prev[m]
}

/// Normalised DTW: raw distance divided by the warping-path length bound
/// (n + m), the normalisation used for the paper's dimensionless scores.
pub fn dtw_normalized(x: &[f64], y: &[f64]) -> f64 {
    dtw_distance(x, y) / (x.len() + y.len()) as f64
}

/// Multivariate DTW averaged over dimensions (Fig. 4 uses d = 6 series).
/// `x`, `y`: [time][dim].
pub fn dtw_multi(x: &[Vec<f64>], y: &[Vec<f64>]) -> f64 {
    assert!(!x.is_empty() && !y.is_empty());
    let d = x[0].len();
    assert_eq!(d, y[0].len(), "dimension mismatch");
    (0..d)
        .map(|k| {
            let xs: Vec<f64> = x.iter().map(|r| r[k]).collect();
            let ys: Vec<f64> = y.iter().map(|r| r[k]).collect();
            dtw_normalized(&xs, &ys)
        })
        .sum::<f64>()
        / d as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_series_zero_distance() {
        let x = [1.0, 2.0, 3.0, 2.0, 1.0];
        assert_eq!(dtw_distance(&x, &x), 0.0);
    }

    #[test]
    fn known_small_case() {
        // x = [0, 1], y = [0, 1, 1]: perfect warp -> 0.
        assert_eq!(dtw_distance(&[0.0, 1.0], &[0.0, 1.0, 1.0]), 0.0);
        // x = [0, 0], y = [1, 1]: every match costs 1, path len 2 -> 2.
        assert_eq!(dtw_distance(&[0.0, 0.0], &[1.0, 1.0]), 2.0);
    }

    #[test]
    fn handles_time_shift_better_than_pointwise() {
        // A shifted sine matches well under DTW but poorly pointwise.
        let n = 200;
        let x: Vec<f64> =
            (0..n).map(|k| (k as f64 * 0.1).sin()).collect();
        let y: Vec<f64> =
            (0..n).map(|k| ((k as f64 + 5.0) * 0.1).sin()).collect();
        let pointwise: f64 =
            x.iter().zip(&y).map(|(a, b)| (a - b).abs()).sum();
        assert!(dtw_distance(&x, &y) < 0.3 * pointwise);
    }

    #[test]
    fn symmetry() {
        let x = [0.0, 0.5, 1.0, 0.5];
        let y = [0.1, 0.4, 0.9];
        assert!((dtw_distance(&x, &y) - dtw_distance(&y, &x)).abs() < 1e-12);
    }

    #[test]
    fn triangle_like_monotonicity() {
        // Distance to a more distorted copy must not decrease.
        let x: Vec<f64> = (0..50).map(|k| (k as f64 * 0.2).sin()).collect();
        let y1: Vec<f64> = x.iter().map(|v| v + 0.1).collect();
        let y2: Vec<f64> = x.iter().map(|v| v + 0.5).collect();
        assert!(dtw_distance(&x, &y1) < dtw_distance(&x, &y2));
    }

    #[test]
    fn normalized_in_sane_range() {
        let x = [1.0; 100];
        let y = [2.0; 100];
        let d = dtw_normalized(&x, &y);
        // Raw cost 100 (diagonal path), normalised by 200 -> 0.5.
        assert!((d - 0.5).abs() < 1e-12);
    }

    #[test]
    fn multivariate_averages_dimensions() {
        let x = vec![vec![0.0, 1.0]; 10];
        let y = vec![vec![0.0, 2.0]; 10];
        let d = dtw_multi(&x, &y);
        // dim 0 distance 0; dim 1 raw 10 / 20 = 0.5; mean 0.25.
        assert!((d - 0.25).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn empty_series_panics() {
        let _ = dtw_distance(&[], &[1.0]);
    }
}
