//! Lyapunov-time bookkeeping (Methods Eq. 10).
//!
//! The paper expresses extrapolation horizons in units of the Lyapunov
//! time T_lambda = 1 / MLE ("accurately predicts ... across the seven
//! largest Lyapunov times"). The MLE estimator itself lives in
//! [`crate::workload::lorenz96::max_lyapunov_exponent`]; this module turns
//! exponents into horizons and finds the valid-prediction horizon of a
//! trajectory pair.

/// Lyapunov time from a maximal Lyapunov exponent.
pub fn lyapunov_time(mle: f64) -> f64 {
    assert!(mle > 0.0, "Lyapunov time needs a positive MLE");
    1.0 / mle
}

/// Horizon (in seconds) until the normalised error between prediction and
/// truth first exceeds `threshold`. Error is normalised by the truth's RMS
/// so the threshold is scale-free (0.4 is a common "valid prediction time"
/// criterion in the chaos-forecasting literature the paper builds on).
pub fn valid_prediction_time(
    pred: &[Vec<f64>],
    truth: &[Vec<f64>],
    dt: f64,
    threshold: f64,
) -> f64 {
    assert_eq!(pred.len(), truth.len());
    // RMS of the truth over the whole window.
    let mut rms = 0.0;
    let mut count = 0usize;
    for row in truth {
        for &v in row {
            rms += v * v;
            count += 1;
        }
    }
    let rms = (rms / count.max(1) as f64).sqrt().max(1e-12);
    for (k, (p, t)) in pred.iter().zip(truth).enumerate() {
        let mut e = 0.0;
        for (&a, &b) in p.iter().zip(t) {
            e += (a - b) * (a - b);
        }
        let e = (e / p.len() as f64).sqrt() / rms;
        if e > threshold {
            return k as f64 * dt;
        }
    }
    pred.len() as f64 * dt
}

/// Horizon expressed in Lyapunov times.
pub fn horizon_in_lyapunov_times(horizon_s: f64, mle: f64) -> f64 {
    horizon_s * mle
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lyapunov_time_inverse() {
        assert_eq!(lyapunov_time(2.0), 0.5);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_mle_rejected() {
        let _ = lyapunov_time(0.0);
    }

    #[test]
    fn perfect_prediction_full_horizon() {
        let t: Vec<Vec<f64>> = (0..100).map(|k| vec![k as f64]).collect();
        let h = valid_prediction_time(&t, &t, 0.1, 0.4);
        assert_eq!(h, 10.0);
    }

    #[test]
    fn divergence_detected_at_right_step() {
        let truth: Vec<Vec<f64>> = (0..100).map(|_| vec![1.0]).collect();
        let mut pred = truth.clone();
        for row in pred.iter_mut().skip(50) {
            row[0] = 10.0; // error 9 / rms 1 >> threshold
        }
        let h = valid_prediction_time(&pred, &truth, 0.1, 0.4);
        assert!((h - 5.0).abs() < 1e-12);
    }

    #[test]
    fn horizon_conversion() {
        assert!((horizon_in_lyapunov_times(7.0, 1.5) - 10.5).abs() < 1e-12);
    }
}
