//! Absolute (L1) error metrics used throughout Fig. 4.

/// Mean absolute error between two scalar series.
pub fn l1_error(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len(), "series length mismatch");
    if pred.is_empty() {
        return f64::NAN;
    }
    pred.iter()
        .zip(truth)
        .map(|(&x, &y)| (x - y).abs())
        .sum::<f64>()
        / pred.len() as f64
}

/// Mean absolute error between multivariate series ([time][dim]), averaged
/// over time and dimensions (the Fig. 4g scalar).
pub fn mean_l1_multi(pred: &[Vec<f64>], truth: &[Vec<f64>]) -> f64 {
    assert_eq!(pred.len(), truth.len(), "series length mismatch");
    if pred.is_empty() {
        return f64::NAN;
    }
    let d = pred[0].len();
    let mut acc = 0.0;
    for (p, t) in pred.iter().zip(truth) {
        assert_eq!(p.len(), d);
        assert_eq!(t.len(), d);
        for (&x, &y) in p.iter().zip(t) {
            acc += (x - y).abs();
        }
    }
    acc / (pred.len() * d) as f64
}

/// Per-time-step absolute error of one dimension ([time][dim] inputs) —
/// the heat-map rows of Fig. 4d-f.
pub fn l1_per_step(
    pred: &[Vec<f64>],
    truth: &[Vec<f64>],
    dim: usize,
) -> Vec<f64> {
    pred.iter()
        .zip(truth)
        .map(|(p, t)| (p[dim] - t[dim]).abs())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_l1_known() {
        assert!((l1_error(&[1.0, 3.0], &[2.0, 1.0]) - 1.5).abs() < 1e-12);
        assert_eq!(l1_error(&[5.0], &[5.0]), 0.0);
    }

    #[test]
    fn multi_l1_known() {
        let p = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let t = vec![vec![1.0, 0.0], vec![1.0, 4.0]];
        // errors: 0, 2, 2, 0 -> mean 1.0
        assert!((mean_l1_multi(&p, &t) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn per_step_extracts_dimension() {
        let p = vec![vec![1.0, 9.0], vec![2.0, 9.0]];
        let t = vec![vec![0.0, 9.0], vec![4.0, 9.0]];
        assert_eq!(l1_per_step(&p, &t, 0), vec![1.0, 2.0]);
        assert_eq!(l1_per_step(&p, &t, 1), vec![0.0, 0.0]);
    }

    #[test]
    fn empty_is_nan() {
        assert!(l1_error(&[], &[]).is_nan());
    }
}
