//! Mean Relative Error (paper Eq. 5):
//! MRE(X, Y) = (1/n) * sum_i |(x_i - y_i) / y_i|.
//!
//! Ground-truth samples with |y_i| below `eps` are excluded (the relative
//! error is undefined at zero crossings — the paper's HP-memristor states
//! stay away from zero, but our test stimuli can graze it).

/// MRE with a guard band around y = 0.
pub fn mre_eps(pred: &[f64], truth: &[f64], eps: f64) -> f64 {
    assert_eq!(pred.len(), truth.len(), "series length mismatch");
    let mut acc = 0.0;
    let mut n = 0usize;
    for (&x, &y) in pred.iter().zip(truth) {
        if y.abs() > eps {
            acc += ((x - y) / y).abs();
            n += 1;
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        acc / n as f64
    }
}

/// MRE with the default guard (1e-9, effectively Eq. 5 verbatim).
pub fn mre(pred: &[f64], truth: &[f64]) -> f64 {
    mre_eps(pred, truth, 1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_match_is_zero() {
        assert_eq!(mre(&[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn known_value() {
        // errors: |0.1/1|, |0.2/2| -> mean 0.1
        assert!((mre(&[1.1, 2.2], &[1.0, 2.0]) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn zero_truth_excluded() {
        let v = mre(&[1.0, 5.0], &[0.0, 5.0]);
        assert_eq!(v, 0.0); // only the second point counts
    }

    #[test]
    fn all_zero_truth_is_nan() {
        assert!(mre(&[1.0], &[0.0]).is_nan());
    }

    #[test]
    fn scale_invariance() {
        let a = mre(&[1.1, 0.9], &[1.0, 1.0]);
        let b = mre(&[1100.0, 900.0], &[1000.0, 1000.0]);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn length_mismatch_panics() {
        let _ = mre(&[1.0], &[1.0, 2.0]);
    }
}
