//! Over-voltage protection clamp (Fig. 2d).
//!
//! Protects downstream stages (and, in the physical system, the memristor
//! bit lines) from excursions beyond a safe window. Ideal hard clamp plus a
//! soft (diode-string) variant; the system uses the hard clamp by default
//! and reports clamp activations so experiments can verify the signal chain
//! was gain-staged correctly (a clamp that engages during normal inference
//! distorts the ODE flow — worth telemetry).

/// Protection clamp with activation counting.
#[derive(Debug, Clone)]
pub struct Clamp {
    /// Clamp window: output in [-limit, limit].
    pub limit: f64,
    /// Number of samples clamped since construction/reset.
    pub activations: u64,
}

impl Clamp {
    pub fn new(limit: f64) -> Self {
        assert!(limit > 0.0, "clamp limit must be positive");
        Self { limit, activations: 0 }
    }

    /// Clamp one value (counts activations).
    #[inline]
    pub fn apply(&mut self, x: f64) -> f64 {
        if x > self.limit {
            self.activations += 1;
            self.limit
        } else if x < -self.limit {
            self.activations += 1;
            -self.limit
        } else {
            x
        }
    }

    /// Clamp a vector in place.
    pub fn apply_slice(&mut self, xs: &mut [f64]) {
        for x in xs {
            *x = self.apply(*x);
        }
    }

    /// Reset the activation counter.
    pub fn reset(&mut self) {
        self.activations = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_in_band() {
        let mut c = Clamp::new(5.0);
        assert_eq!(c.apply(3.0), 3.0);
        assert_eq!(c.apply(-4.9), -4.9);
        assert_eq!(c.activations, 0);
    }

    #[test]
    fn clamps_and_counts() {
        let mut c = Clamp::new(1.0);
        assert_eq!(c.apply(2.0), 1.0);
        assert_eq!(c.apply(-3.0), -1.0);
        assert_eq!(c.activations, 2);
        c.reset();
        assert_eq!(c.activations, 0);
    }

    #[test]
    fn slice_application() {
        let mut c = Clamp::new(1.0);
        let mut xs = vec![0.5, 1.5, -2.0];
        c.apply_slice(&mut xs);
        assert_eq!(xs, vec![0.5, 1.0, -1.0]);
        assert_eq!(c.activations, 2);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_limit_rejected() {
        let _ = Clamp::new(0.0);
    }
}
