//! Trans-impedance amplifier (TIA).
//!
//! Converts crossbar column currents to voltages for the next stage
//! (OPA4990 in the paper's board). Behavioural model: linear gain with
//! supply-rail saturation and an input-referred offset. In the logical
//! signal chain the TIA gain is chosen as 1/slope of the weight mapping, so
//! a column current slope*w*v reads back as w*v — this is where the
//! "digital rescale" of other mixed-signal systems happens *in the analogue
//! domain* here, as in the paper.

/// Behavioural TIA.
#[derive(Debug, Clone)]
pub struct Tia {
    /// Trans-impedance gain (V/A) — logical designs use 1/slope.
    pub gain: f64,
    /// Supply rails (V); output saturates at ±v_sat.
    pub v_sat: f64,
    /// Input-referred offset current (A).
    pub i_offset: f64,
}

impl Tia {
    pub fn new(gain: f64, v_sat: f64) -> Self {
        Self { gain, v_sat, i_offset: 0.0 }
    }

    /// Ideal logical TIA: unit gain, generous rails.
    pub fn logical(v_sat: f64) -> Self {
        Self { gain: 1.0, v_sat, i_offset: 0.0 }
    }

    /// v = clamp(gain * (i + i_offset), ±v_sat). The paper's inverting TIA
    /// sign is absorbed by the subsequent inverter stage, so the logical
    /// chain is non-inverting.
    #[inline]
    pub fn convert(&self, i: f64) -> f64 {
        (self.gain * (i + self.i_offset)).clamp(-self.v_sat, self.v_sat)
    }

    /// Convert a column-current vector in place.
    pub fn convert_slice(&self, xs: &mut [f64]) {
        for x in xs {
            *x = self.convert(*x);
        }
    }

    /// True if any value would saturate (diagnostic for gain staging).
    pub fn would_saturate(&self, xs: &[f64]) -> bool {
        xs.iter()
            .any(|&x| (self.gain * (x + self.i_offset)).abs() >= self.v_sat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_in_band() {
        let t = Tia::new(1e4, 5.0);
        assert!((t.convert(1e-4) - 1.0).abs() < 1e-12);
        assert!((t.convert(-2e-4) + 2.0).abs() < 1e-12);
    }

    #[test]
    fn saturates_at_rails() {
        let t = Tia::new(1e4, 5.0);
        assert_eq!(t.convert(1.0), 5.0);
        assert_eq!(t.convert(-1.0), -5.0);
    }

    #[test]
    fn offset_shifts_output() {
        let t = Tia { gain: 1e3, v_sat: 5.0, i_offset: 1e-3 };
        assert!((t.convert(0.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn slice_conversion_and_saturation_detect() {
        let t = Tia::new(10.0, 1.0);
        let mut xs = vec![0.05, 0.2, -0.3];
        assert!(t.would_saturate(&xs));
        t.convert_slice(&mut xs);
        assert_eq!(xs, vec![0.5, 1.0, -1.0]);
    }
}
