//! Peripheral analogue circuits and the closed-loop solver (Fig. 2a-e).
//!
//! * [`tia`]        — trans-impedance amplifier (current -> voltage, with
//!   rail saturation); its gain folds the weight-mapping slope so the loop
//!   operates in logical units end to end
//! * [`relu`]       — dual-diode analogue ReLU (ideal + behavioural knee)
//! * [`clamp`]      — over-voltage protection clamp
//! * [`mux`]        — analogue multiplexer with mode switching + settling
//! * [`integrator`] — the IVP integrator (initial-conditioning /
//!   current-integration modes, Fig. 2b-c)
//! * [`system`]     — the full memristive neural-ODE solver: crossbar MLP
//!   + peripherals + integrators in closed loop (Fig. 3b / 4b)

pub mod clamp;
pub mod integrator;
pub mod mux;
pub mod relu;
pub mod system;
pub mod tia;

pub use system::{AnalogMlp, AnalogNeuralOde, AnalogNoise};
