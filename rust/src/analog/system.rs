//! The closed-loop memristive neural-ODE solver (Fig. 2a, 3b, 4b).
//!
//! Wires the deployed crossbar layers, peripheral stages (TIA -> diode
//! ReLU -> clamp) and one IVP integrator per state dimension into the
//! continuous-time loop
//!
//!   dh/dt = f([x(t); h(t)]),
//!
//! where f is the analogue MLP. The circuit simulator advances at
//! `dt_circuit` (far below the signal bandwidth); each step performs fresh
//! noisy analogue reads — exactly how the physical system continuously
//! re-samples the crossbar — and feeds the integrators, whose capacitor
//! voltages *are* the twin state.
//!
//! Every crossbar read in the loop goes through
//! [`crate::crossbar::vmm::VmmEngine`] and therefore through the
//! runtime-dispatched GEMM microkernels (`util::kernel`): the analogue
//! IVP step is SIMD-accelerated (and, for large batches, multicore)
//! without any change here, and rollouts stay bit-identical across
//! kernel choices because the dispatch preserves the accumulation-order
//! contract of `lib.rs`.

use crate::analog::clamp::Clamp;
use crate::analog::integrator::IvpIntegrator;
use crate::analog::relu::DiodeRelu;
use crate::analog::tia::Tia;
use crate::crossbar::tiling::{uniform_layer_plans, ShardPlan, TiledMatrix};
use crate::crossbar::vmm::{NoiseMode, VmmEngine};
use crate::device::noise::NoiseSource;
use crate::device::taox::DeviceConfig;
use crate::util::rng::{derive_stream_seed, NoiseLane, Pcg64};
use crate::util::tensor::{Mat, Trajectory};

/// Stream tag for the aging RNG derived off a deployment seed, so an aging
/// deployment's *deploy-time* RNG consumption stays bit-identical to
/// [`AnalogMlp::deploy`] under the same seed (the aging walk draws from a
/// separate derived stream, never from the deploy stream).
const AGING_STREAM_TAG: u64 = 0xa9e5_11fe_0000_0001;

/// Retained mortal-hardware state behind an aging deployment: the tiled
/// arrays themselves (the engines cache only effective weights), the
/// logical targets recalibration reprograms toward, and the deterministic
/// virtual clock. Exists only for [`AnalogMlp::deploy_aging`] — the
/// immortal fast path carries no such state and is untouched.
#[derive(Debug, Clone)]
pub struct AgingState {
    /// Per-layer tiled deployments (same hardware the engines were built
    /// from; yield maps live here and survive recalibration).
    tiles: Vec<TiledMatrix>,
    /// Per-layer logical weight targets (post-programming-noise), the
    /// golden values recalibration reprograms toward.
    targets: Vec<Mat>,
    cfg: DeviceConfig,
    /// Drift / write-noise randomness of the lifetime walk — derived from
    /// the deploy seed via a separate stream, so the walk is replayable
    /// from the deployment seed alone.
    rng: Pcg64,
    /// Virtual device age (s). Advanced only by explicit
    /// [`AnalogMlp::advance_age`] calls, never by wall-clock reads.
    age_s: f64,
    /// Total write-verify pulses across all recalibrations.
    pulses: u64,
    /// Recalibrations performed.
    recals: u64,
}

/// Noise operating point (the Fig. 4j grid axes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AnalogNoise {
    /// Dynamic read noise, relative sigma per analogue read.
    pub read: f64,
    /// Static programming noise, relative sigma frozen at deployment.
    pub prog: f64,
}

impl AnalogNoise {
    pub fn off() -> Self {
        Self { read: 0.0, prog: 0.0 }
    }

    /// The paper's hardware operating point. Programming error is already
    /// produced physically by the write-verify deployment (Fig. 2k/3e
    /// statistics); `prog` here is the *additional* static perturbation of
    /// the Fig. 4j sweep, so it is zero at the hardware point.
    pub fn hardware() -> Self {
        Self { read: 0.01, prog: 0.0 }
    }
}

/// One trained layer: weights with the bias folded in as an extra input row
/// driven by a constant 1 (the standard crossbar bias-row trick).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    /// [fan_in + 1, fan_out]; last row is the bias.
    pub w_aug: Mat,
}

impl LayerWeights {
    pub fn new(w: &Mat, b: &[f64]) -> Self {
        assert_eq!(w.cols, b.len(), "bias length mismatch");
        let mut w_aug = Mat::zeros(w.rows + 1, w.cols);
        for r in 0..w.rows {
            for c in 0..w.cols {
                *w_aug.at_mut(r, c) = w.at(r, c);
            }
        }
        for c in 0..w.cols {
            *w_aug.at_mut(w.rows, c) = b[c];
        }
        Self { w_aug }
    }
}

/// The analogue MLP: per-layer crossbar VMM + TIA + (hidden) ReLU + clamp.
#[derive(Debug, Clone)]
pub struct AnalogMlp {
    engines: Vec<VmmEngine>,
    relu: DiodeRelu,
    tia: Tia,
    clamp: Clamp,
    /// Per-layer input scratch (with bias slot), preallocated.
    scratch_in: Vec<Vec<f64>>,
    /// Per-layer output scratch.
    scratch_out: Vec<Vec<f64>>,
    /// Per-layer batched input scratch (grown on first batched call).
    bscratch_in: Vec<Vec<f64>>,
    /// Per-layer batched output scratch.
    bscratch_out: Vec<Vec<f64>>,
    /// Staging for one shard's batched output (grown to the high-water
    /// `batch * widest shard`; reused across shards and layers).
    bshard: Vec<f64>,
    /// Root for the *default* noise lanes behind the seedless convenience
    /// wrappers (`eval`, `eval_batch`, the solver's `solve`/`solve_batch`).
    /// Request-path callers pass explicit per-trajectory lanes instead.
    lane_root: u64,
    /// Default lanes, one per trajectory slot, grown on demand (pooled —
    /// they persist across calls so repeated noisy reads keep sampling
    /// fresh draws).
    default_lanes: Vec<NoiseLane>,
    /// Mortal-hardware state ([`AnalogMlp::deploy_aging`] only); `None`
    /// on the immortal fast path, which stays byte-for-byte as before.
    aging: Option<Box<AgingState>>,
}

impl AnalogMlp {
    /// Deploy trained layers onto simulated hardware.
    ///
    /// * `prog` static noise perturbs the logical weights before the
    ///   write-verify deployment (Fig. 4j "programming noise" axis);
    /// * `read` dynamic noise is applied on every analogue read through the
    ///   moment-matched fast path;
    /// * `cfg` carries the device statistics (pulse sigma, yield, window).
    pub fn deploy(
        layers: &[LayerWeights],
        cfg: &DeviceConfig,
        noise: AnalogNoise,
        seed: u64,
    ) -> Self {
        let mut rng = Pcg64::seeded(seed);
        let mut engines = Vec::with_capacity(layers.len());
        for layer in layers {
            let mut w = layer.w_aug.clone();
            if noise.prog > 0.0 {
                for x in &mut w.data {
                    *x *= 1.0 + noise.prog * rng.normal();
                }
            }
            let tiled = TiledMatrix::deploy(&w, cfg, &mut rng);
            engines.push(VmmEngine::from_tiled(
                &tiled,
                NoiseSource::new(noise.read),
                if noise.read > 0.0 {
                    NoiseMode::Fast
                } else {
                    NoiseMode::Off
                },
            ));
        }
        Self::from_engines(engines, seed)
    }

    /// [`AnalogMlp::deploy`] variant that *retains* the tiled hardware so
    /// the deployment can age, be health-probed and be recalibrated.
    ///
    /// The deploy-time RNG consumption is identical to `deploy` (same
    /// seed ⇒ bit-identical engines at age 0); the lifetime walk's
    /// randomness comes from a separate stream derived off the seed, so
    /// the whole (deploy, age, recalibrate) history is replayable from
    /// `(layers, cfg, noise, seed)` plus the sequence of explicit
    /// [`AnalogMlp::advance_age`] / [`AnalogMlp::recalibrate`] calls.
    pub fn deploy_aging(
        layers: &[LayerWeights],
        cfg: &DeviceConfig,
        noise: AnalogNoise,
        seed: u64,
    ) -> Self {
        let mut rng = Pcg64::seeded(seed);
        let mut engines = Vec::with_capacity(layers.len());
        let mut tiles = Vec::with_capacity(layers.len());
        let mut targets = Vec::with_capacity(layers.len());
        for layer in layers {
            let mut w = layer.w_aug.clone();
            if noise.prog > 0.0 {
                for x in &mut w.data {
                    *x *= 1.0 + noise.prog * rng.normal();
                }
            }
            let tiled = TiledMatrix::deploy(&w, cfg, &mut rng);
            engines.push(VmmEngine::from_tiled(
                &tiled,
                NoiseSource::new(noise.read),
                if noise.read > 0.0 {
                    NoiseMode::Fast
                } else {
                    NoiseMode::Off
                },
            ));
            tiles.push(tiled);
            targets.push(w);
        }
        let mut this = Self::from_engines(engines, seed);
        this.aging = Some(Box::new(AgingState {
            tiles,
            targets,
            cfg: cfg.clone(),
            rng: Pcg64::seeded(derive_stream_seed(seed, AGING_STREAM_TAG)),
            age_s: 0.0,
            pulses: 0,
            recals: 0,
        }));
        this
    }

    /// Advance the deployment's virtual clock by `dt_s`: every cell of
    /// every tile drifts per `retention::drift_factor` (+ diffusive walk)
    /// and the engines' cached weights/variance kernels are refreshed.
    /// Negative or zero `dt_s` is a strict no-op. Panics on an immortal
    /// deployment — aging is opt-in via [`AnalogMlp::deploy_aging`].
    pub fn advance_age(&mut self, dt_s: f64) {
        let aging = self
            .aging
            .as_mut()
            .expect("advance_age on a non-aging deployment (use deploy_aging)");
        if !(dt_s > 0.0) {
            return;
        }
        for tiled in &mut aging.tiles {
            tiled.advance_age(dt_s, &mut aging.rng);
        }
        aging.age_s += dt_s;
        for (engine, tiled) in self.engines.iter_mut().zip(&aging.tiles) {
            engine.refresh_from_tiled(tiled);
        }
    }

    /// Recalibrate: reprogram every tile toward its deployment target
    /// (write-verify + stuck-at compensation on the *same* hardware — the
    /// yield map survives, accumulated drift on healthy cells is erased)
    /// and refresh the engines. Returns the write-verify pulse count,
    /// also accumulated in [`AnalogMlp::lifetime_pulses`]. Panics on an
    /// immortal deployment.
    pub fn recalibrate(&mut self) -> u64 {
        let aging = self
            .aging
            .as_mut()
            .expect("recalibrate on a non-aging deployment (use deploy_aging)");
        let mut pulses = 0;
        for (tiled, target) in aging.tiles.iter_mut().zip(&aging.targets) {
            pulses += tiled.reprogram(target, &aging.cfg, &mut aging.rng);
        }
        aging.pulses += pulses;
        aging.recals += 1;
        for (engine, tiled) in self.engines.iter_mut().zip(&aging.tiles) {
            engine.refresh_from_tiled(tiled);
        }
        pulses
    }

    /// Whether this deployment carries mortal-hardware state.
    pub fn is_aging(&self) -> bool {
        self.aging.is_some()
    }

    /// Virtual device age (s); 0 for immortal deployments.
    pub fn age_s(&self) -> f64 {
        self.aging.as_ref().map_or(0.0, |a| a.age_s)
    }

    /// Total write-verify pulses spent on recalibration so far.
    pub fn lifetime_pulses(&self) -> u64 {
        self.aging.as_ref().map_or(0, |a| a.pulses)
    }

    /// Recalibrations performed so far.
    pub fn recalibrations(&self) -> u64 {
        self.aging.as_ref().map_or(0, |a| a.recals)
    }

    /// Healthy-cell fraction across the retained arrays (1.0 when the
    /// deployment is immortal — nothing to be stuck).
    pub fn array_health(&self) -> f64 {
        match &self.aging {
            None => 1.0,
            Some(a) => {
                let n = a.tiles.len() as f64;
                a.tiles.iter().map(TiledMatrix::health).sum::<f64>() / n
            }
        }
    }

    /// Test/fault-campaign hook: mark a fraction of cells in every
    /// retained array as stuck (alternating OFF/ON), making the
    /// deployment progressively un-recalibratable. Deterministic in the
    /// aging RNG stream. Panics on an immortal deployment.
    pub fn inject_stuck_faults(&mut self, fraction: f64) {
        use crate::device::taox::StuckMode;
        let aging = self
            .aging
            .as_mut()
            .expect("inject_stuck_faults on a non-aging deployment");
        let mut flip = false;
        for tiled in &mut aging.tiles {
            for row_tiles in &mut tiled.tiles {
                for tile in row_tiles {
                    for rail in [&mut tile.pos, &mut tile.neg] {
                        for r in 0..rail.rows {
                            for c in 0..rail.cols {
                                if aging.rng.chance(fraction) {
                                    rail.cell_mut(r, c).stuck = Some(if flip {
                                        StuckMode::StuckOn
                                    } else {
                                        StuckMode::StuckOff
                                    });
                                    flip = !flip;
                                }
                            }
                        }
                    }
                }
            }
        }
        for (engine, tiled) in self.engines.iter_mut().zip(&aging.tiles) {
            engine.refresh_from_tiled(tiled);
        }
    }

    /// Ideal (no hardware sampling) MLP — the digital reference path and
    /// the fast ablation baseline.
    pub fn ideal(layers: &[LayerWeights], seed: u64) -> Self {
        let engines = layers
            .iter()
            .map(|l| VmmEngine::ideal(l.w_aug.clone()))
            .collect();
        Self::from_engines(engines, seed)
    }

    fn from_engines(engines: Vec<VmmEngine>, lane_root: u64) -> Self {
        let scratch_in: Vec<Vec<f64>> =
            engines.iter().map(|e| vec![0.0; e.rows()]).collect();
        let scratch_out: Vec<Vec<f64>> =
            engines.iter().map(|e| vec![0.0; e.cols()]).collect();
        let bscratch_in = vec![Vec::new(); engines.len()];
        let bscratch_out = vec![Vec::new(); engines.len()];
        Self {
            engines,
            relu: DiodeRelu::ideal(),
            tia: Tia::logical(1e3),
            clamp: Clamp::new(1e3),
            scratch_in,
            scratch_out,
            bscratch_in,
            bscratch_out,
            bshard: Vec::new(),
            lane_root,
            default_lanes: Vec::new(),
            aging: None,
        }
    }

    /// Derive the noise lane of trajectory `trajectory` under this
    /// deployment's lane root (the deploy seed).
    pub fn lane(&self, trajectory: u64) -> NoiseLane {
        NoiseLane::derive(self.lane_root, trajectory)
    }

    /// Take the pooled default lanes (grown to at least `n` trajectory
    /// slots) out of the struct so the caller can pass them back into a
    /// `&mut self` method; hand back via [`AnalogMlp::put_default_lanes`].
    /// A panic between take and put leaves the pool empty, which only
    /// resets the *default* lane cursors — explicit request lanes are
    /// unaffected.
    fn take_default_lanes(&mut self, n: usize) -> Vec<NoiseLane> {
        while self.default_lanes.len() < n {
            let t = self.default_lanes.len() as u64;
            let lane = NoiseLane::derive(self.lane_root, t);
            self.default_lanes.push(lane);
        }
        std::mem::take(&mut self.default_lanes)
    }

    /// Restore lanes taken by [`AnalogMlp::take_default_lanes`].
    fn put_default_lanes(&mut self, lanes: Vec<NoiseLane>) {
        self.default_lanes = lanes;
    }

    /// Use behavioural (soft-knee, leaky) peripherals instead of ideal ones.
    pub fn with_behavioural_peripherals(mut self, v_sat: f64) -> Self {
        self.relu = DiodeRelu::behavioural();
        self.tia = Tia::logical(v_sat);
        self.clamp = Clamp::new(v_sat);
        self
    }

    /// Input dimension (excluding the bias slot).
    pub fn d_in(&self) -> usize {
        self.engines[0].rows() - 1
    }

    /// Output dimension.
    pub fn d_out(&self) -> usize {
        self.engines.last().expect("empty mlp").cols()
    }

    /// Number of crossbar layers.
    pub fn n_layers(&self) -> usize {
        self.engines.len()
    }

    /// Output width of layer `l`.
    pub fn layer_cols(&self, l: usize) -> usize {
        self.engines[l].cols()
    }

    /// The deployed VMM engine of layer `l` (shard construction and
    /// diagnostics).
    pub fn engine(&self, l: usize) -> &VmmEngine {
        &self.engines[l]
    }

    /// Clones of the peripheral stages (TIA, diode ReLU, clamp) — shard
    /// workers replicate the signal chain per tile column-group.
    pub fn peripherals(&self) -> (Tia, DiodeRelu, Clamp) {
        (self.tia.clone(), self.relu.clone(), self.clamp.clone())
    }

    /// Forward pass `y = f(u)` with fresh analogue reads drawn from the
    /// trajectory's noise lane; writes into `out`.
    pub fn eval_into(
        &mut self,
        u: &[f64],
        out: &mut [f64],
        lane: &mut NoiseLane,
    ) {
        let n_layers = self.engines.len();
        debug_assert_eq!(u.len(), self.d_in());
        for l in 0..n_layers {
            // Fill the input scratch: previous activation + bias 1.
            {
                let src: &[f64] = if l == 0 { u } else { &self.scratch_out[l - 1] };
                let (head, tail) =
                    self.scratch_in[l].split_at_mut(src.len());
                head.copy_from_slice(src);
                tail[0] = 1.0;
            }
            // Split borrows: engine + in/out scratch.
            let (inp, outp) = {
                // Safety-free split via index juggling: clone input slice
                // is avoided by using raw indices into self fields.
                let inp = std::mem::take(&mut self.scratch_in[l]);
                let mut outp = std::mem::take(&mut self.scratch_out[l]);
                self.engines[l].vmm_into(&inp, &mut outp, lane);
                (inp, outp)
            };
            self.scratch_in[l] = inp;
            self.scratch_out[l] = outp;
            let is_last = l + 1 == n_layers;
            let buf = &mut self.scratch_out[l];
            self.tia.convert_slice(buf);
            if !is_last {
                self.relu.activate_slice(buf);
            }
            self.clamp.apply_slice(buf);
        }
        out.copy_from_slice(&self.scratch_out[n_layers - 1]);
    }

    /// Allocating convenience wrapper on the pooled default lane.
    pub fn eval(&mut self, u: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.d_out()];
        let mut lanes = self.take_default_lanes(1);
        self.eval_into(u, &mut y, &mut lanes[0]);
        self.put_default_lanes(lanes);
        y
    }

    /// Batched forward pass: `batch` stacked inputs (`us: [batch * d_in]`)
    /// through the analogue chain with **one multi-vector crossbar read per
    /// layer** ([`VmmEngine::vmm_batch_into`]) instead of one read per
    /// trajectory — the GEMM-instead-of-repeated-GEMV amortisation of the
    /// batched execution engine. The peripheral stages (TIA, diode ReLU,
    /// clamp) are element-wise and act on the whole batch buffer at once.
    /// With per-trajectory noise lanes the result is bit-identical, per
    /// trajectory, to [`AnalogMlp::eval_into`] with the same lane — noise
    /// on or off, regardless of batch composition.
    pub fn eval_batch_into(
        &mut self,
        us: &[f64],
        batch: usize,
        out: &mut [f64],
        lanes: &mut [NoiseLane],
    ) {
        let n_layers = self.engines.len();
        let d_in = self.d_in();
        assert_eq!(
            us.len(),
            batch * d_in,
            "eval_batch: us length != batch * d_in"
        );
        assert_eq!(
            out.len(),
            batch * self.d_out(),
            "eval_batch: out length != batch * d_out"
        );
        assert_eq!(
            lanes.len(),
            batch,
            "eval_batch: one noise lane per trajectory"
        );
        for l in 0..n_layers {
            let rows = self.engines[l].rows();
            let cols = self.engines[l].cols();
            let src_dim = rows - 1;
            let mut bin = std::mem::take(&mut self.bscratch_in[l]);
            let mut bout = std::mem::take(&mut self.bscratch_out[l]);
            bin.resize(batch * rows, 0.0);
            bout.resize(batch * cols, 0.0);
            // Fill the stacked inputs: previous activation + bias 1 per
            // trajectory row.
            for b in 0..batch {
                let dst = &mut bin[b * rows..(b + 1) * rows];
                let src: &[f64] = if l == 0 {
                    &us[b * d_in..(b + 1) * d_in]
                } else {
                    &self.bscratch_out[l - 1]
                        [b * src_dim..(b + 1) * src_dim]
                };
                dst[..src_dim].copy_from_slice(src);
                dst[src_dim] = 1.0;
            }
            // One multi-vector analogue read for the whole batch.
            self.engines[l].vmm_batch_into(&bin, batch, &mut bout, lanes);
            let is_last = l + 1 == n_layers;
            self.tia.convert_slice(&mut bout);
            if !is_last {
                self.relu.activate_slice(&mut bout);
            }
            self.clamp.apply_slice(&mut bout);
            self.bscratch_in[l] = bin;
            self.bscratch_out[l] = bout;
        }
        out.copy_from_slice(&self.bscratch_out[n_layers - 1]);
    }

    /// Allocating batched forward pass on the pooled default lanes.
    pub fn eval_batch(&mut self, us: &[f64], batch: usize) -> Vec<f64> {
        let mut y = vec![0.0; batch * self.d_out()];
        let mut lanes = self.take_default_lanes(batch);
        self.eval_batch_into(us, batch, &mut y, &mut lanes[..batch]);
        self.put_default_lanes(lanes);
        y
    }

    /// Sharded forward pass: every layer's output columns are produced by
    /// per-shard tile column-group reads ([`VmmEngine::vmm_shard_into`])
    /// executed in ascending shard order, with the peripheral stages
    /// applied per shard slice. `plans` carries one [`ShardPlan`] per
    /// layer. Because the per-element accumulation order matches the
    /// monolithic read and noise draws are lane-indexed by full-layer
    /// column, the result is bit-identical to [`AnalogMlp::eval_into`]
    /// with the same lane — in *every* noise mode — while exercising the
    /// same column grouping a physically tiled deployment executes. The
    /// lane advances once per layer by the full-read draw count
    /// ([`VmmEngine::draws_per_read`]), keeping it in lockstep with the
    /// monolithic path.
    pub fn eval_sharded_into(
        &mut self,
        u: &[f64],
        plans: &[ShardPlan],
        out: &mut [f64],
        lane: &mut NoiseLane,
    ) {
        let n_layers = self.engines.len();
        assert_eq!(
            plans.len(),
            n_layers,
            "sharded eval: {} shard plans for {} layers",
            plans.len(),
            n_layers
        );
        debug_assert_eq!(u.len(), self.d_in());
        for l in 0..n_layers {
            {
                let src: &[f64] =
                    if l == 0 { u } else { &self.scratch_out[l - 1] };
                let (head, tail) = self.scratch_in[l].split_at_mut(src.len());
                head.copy_from_slice(src);
                tail[0] = 1.0;
            }
            let inp = std::mem::take(&mut self.scratch_in[l]);
            let mut outp = std::mem::take(&mut self.scratch_out[l]);
            let plan = &plans[l];
            assert_eq!(
                plan.dim(),
                self.engines[l].cols(),
                "layer {l}: shard plan dim != layer width"
            );
            let is_last = l + 1 == n_layers;
            for s in 0..plan.n_shards() {
                let r = plan.range(s);
                let seg = &mut outp[r.clone()];
                self.engines[l].vmm_shard_into(
                    &inp, r.start, r.end, seg, lane,
                );
                self.tia.convert_slice(seg);
                if !is_last {
                    self.relu.activate_slice(seg);
                }
                self.clamp.apply_slice(seg);
            }
            lane.advance(self.engines[l].draws_per_read());
            self.scratch_in[l] = inp;
            self.scratch_out[l] = outp;
        }
        out.copy_from_slice(&self.scratch_out[n_layers - 1]);
    }

    /// Batched sharded forward pass: `batch` stacked inputs through
    /// per-shard tile column-group reads
    /// ([`VmmEngine::vmm_shard_batch_into`]), each shard's stacked output
    /// staged contiguously and scattered into the full layer buffer. With
    /// per-trajectory noise lanes the result is bit-identical, per
    /// trajectory, to [`AnalogMlp::eval_batch_into`] — in every noise
    /// mode.
    pub fn eval_sharded_batch_into(
        &mut self,
        us: &[f64],
        batch: usize,
        plans: &[ShardPlan],
        out: &mut [f64],
        lanes: &mut [NoiseLane],
    ) {
        let n_layers = self.engines.len();
        let d_in = self.d_in();
        assert_eq!(
            plans.len(),
            n_layers,
            "sharded eval_batch: {} shard plans for {} layers",
            plans.len(),
            n_layers
        );
        assert_eq!(
            us.len(),
            batch * d_in,
            "sharded eval_batch: us length != batch * d_in"
        );
        assert_eq!(
            out.len(),
            batch * self.d_out(),
            "sharded eval_batch: out length != batch * d_out"
        );
        assert_eq!(
            lanes.len(),
            batch,
            "sharded eval_batch: one noise lane per trajectory"
        );
        for l in 0..n_layers {
            let rows = self.engines[l].rows();
            let cols = self.engines[l].cols();
            let src_dim = rows - 1;
            let mut bin = std::mem::take(&mut self.bscratch_in[l]);
            let mut bout = std::mem::take(&mut self.bscratch_out[l]);
            bin.resize(batch * rows, 0.0);
            bout.resize(batch * cols, 0.0);
            for b in 0..batch {
                let dst = &mut bin[b * rows..(b + 1) * rows];
                let src: &[f64] = if l == 0 {
                    &us[b * d_in..(b + 1) * d_in]
                } else {
                    &self.bscratch_out[l - 1][b * src_dim..(b + 1) * src_dim]
                };
                dst[..src_dim].copy_from_slice(src);
                dst[src_dim] = 1.0;
            }
            let plan = &plans[l];
            assert_eq!(
                plan.dim(),
                cols,
                "layer {l}: shard plan dim != layer width"
            );
            let is_last = l + 1 == n_layers;
            for s in 0..plan.n_shards() {
                let r = plan.range(s);
                let w = r.len();
                self.bshard.resize(batch * w, 0.0);
                self.engines[l].vmm_shard_batch_into(
                    &bin,
                    batch,
                    r.start,
                    r.end,
                    &mut self.bshard,
                    lanes,
                );
                self.tia.convert_slice(&mut self.bshard);
                if !is_last {
                    self.relu.activate_slice(&mut self.bshard);
                }
                self.clamp.apply_slice(&mut self.bshard);
                for b in 0..batch {
                    bout[b * cols + r.start..b * cols + r.end]
                        .copy_from_slice(&self.bshard[b * w..(b + 1) * w]);
                }
            }
            let n_draws = self.engines[l].draws_per_read();
            for lane in lanes.iter_mut() {
                lane.advance(n_draws);
            }
            self.bscratch_in[l] = bin;
            self.bscratch_out[l] = bout;
        }
        out.copy_from_slice(&self.bscratch_out[n_layers - 1]);
    }

    /// Effective logical weights of layer `l` (diagnostics).
    pub fn layer_weights(&self, l: usize) -> &Mat {
        self.engines[l].weights()
    }
}

/// Tile-shard layout of a closed-loop solver: one column partition per
/// MLP layer (uniform shard count) plus the state partition, which is the
/// last layer's plan — shard `s` owns the state slice its tile
/// column-group produces, and therefore the integrators behind it.
#[derive(Debug, Clone)]
pub struct ShardSpec {
    /// Per-layer output-column partitions.
    pub layers: Vec<ShardPlan>,
    /// State partition (equals `layers.last()`).
    pub state: ShardPlan,
}

impl ShardSpec {
    /// Build the uniform layout for an MLP's layer widths.
    pub fn for_mlp(mlp: &AnalogMlp, n_shards: usize) -> Self {
        let widths: Vec<usize> =
            (0..mlp.n_layers()).map(|l| mlp.layer_cols(l)).collect();
        let layers = uniform_layer_plans(&widths, n_shards);
        let state = layers.last().expect("mlp has layers").clone();
        Self { layers, state }
    }

    /// Shard count (uniform across layers).
    pub fn n_shards(&self) -> usize {
        self.state.n_shards()
    }
}

/// The closed-loop solver: analogue MLP + one IVP integrator per state dim.
#[derive(Debug, Clone)]
pub struct AnalogNeuralOde {
    pub mlp: AnalogMlp,
    pub integrators: Vec<IvpIntegrator>,
    /// External input dimension (0 for autonomous twins).
    pub d_drive: usize,
    /// Circuit-time step (s) — the continuous-solver resolution.
    pub dt_circuit: f64,
    /// Tile-shard layout; `None` runs the monolithic kernel.
    shards: Option<ShardSpec>,
    /// Scratch: [x(t); h(t)] input vector.
    u: Vec<f64>,
    /// Scratch: MLP output (dh/dt).
    dh: Vec<f64>,
    /// Scratch: the drive closure's per-trajectory stimulus buffer.
    xbuf: Vec<f64>,
    /// Scratch: batched integrator banks (batch * d_state, reused).
    bank: Vec<IvpIntegrator>,
    /// Scratch: batched [x_b; h_b] input rows.
    us: Vec<f64>,
    /// Scratch: batched MLP output.
    dhs: Vec<f64>,
}

impl AnalogNeuralOde {
    /// Build a solver around a deployed MLP.
    ///
    /// `d_state` integrators are created; `d_drive = mlp.d_in() - d_state`
    /// input lines remain externally driven. `dt_circuit` is the circuit
    /// integration step — callers pick `dt_out / substeps`.
    pub fn new(mlp: AnalogMlp, d_state: usize, dt_circuit: f64) -> Self {
        assert_eq!(
            mlp.d_out(),
            d_state,
            "MLP output dim must equal state dim"
        );
        assert!(mlp.d_in() >= d_state, "MLP input must include the state");
        let d_drive = mlp.d_in() - d_state;
        let integrators = (0..d_state)
            .map(|_| IvpIntegrator::logical(1e3))
            .collect();
        let u = vec![0.0; mlp.d_in()];
        let dh = vec![0.0; d_state];
        let xbuf = vec![0.0; d_drive];
        Self {
            mlp,
            integrators,
            d_drive,
            dt_circuit,
            shards: None,
            u,
            dh,
            xbuf,
            bank: Vec::new(),
            us: Vec::new(),
            dhs: Vec::new(),
        }
    }

    /// Install a tile-shard layout: every circuit step's device reads run
    /// as per-shard tile column-group reads sharing the step's assembled
    /// input, and the integrators partition into per-shard banks along the
    /// state plan. The shard count is clamped to the narrowest layer.
    /// Output stays bit-identical to the monolithic solver in every noise
    /// mode (lane-indexed draws, see [`AnalogMlp::eval_sharded_into`]),
    /// serial and batched.
    pub fn with_shards(mut self, n_shards: usize) -> Self {
        let spec = ShardSpec::for_mlp(&self.mlp, n_shards);
        assert_eq!(
            spec.state.dim(),
            self.integrators.len(),
            "shard state plan dim != state dim"
        );
        self.shards = Some(spec);
        self
    }

    /// The installed shard layout, if any.
    pub fn shard_spec(&self) -> Option<&ShardSpec> {
        self.shards.as_ref()
    }

    /// Current state (integrator capacitor voltages).
    pub fn state(&self) -> Vec<f64> {
        self.integrators.iter().map(|i| i.v).collect()
    }

    /// Initial-conditioning phase: pre-charge all integrators.
    pub fn set_initial(&mut self, h0: &[f64]) {
        assert_eq!(h0.len(), self.integrators.len());
        for (i, &v0) in self.integrators.iter_mut().zip(h0) {
            i.stop();
            i.set_initial(v0);
        }
    }

    /// Solve the IVP, sampling the state every `dt_out` for `n_points`
    /// samples (the first sample is h0 itself), appended to `out` (reset
    /// to row width `d_state`). `drive(t, x)` writes the external stimulus
    /// into the `d_drive`-long slice `x` (a no-op closure for autonomous
    /// systems). `lane` is the trajectory's noise stream: the same lane
    /// state replays the rollout bit for bit, and the batched/sharded
    /// paths consume identical draws. Allocation-free with a warm `out`:
    /// the stimulus and input-vector buffers are owned scratch.
    pub fn solve_into(
        &mut self,
        h0: &[f64],
        drive: &mut dyn FnMut(f64, &mut [f64]),
        dt_out: f64,
        n_points: usize,
        lane: &mut NoiseLane,
        out: &mut Trajectory,
    ) {
        self.set_initial(h0);
        for i in &mut self.integrators {
            i.start_integration();
        }
        let substeps =
            ((dt_out / self.dt_circuit).round() as usize).max(1);
        let dt = dt_out / substeps as f64;
        out.reset(self.integrators.len());
        out.reserve_rows(n_points.max(1));
        out.push_row_from_iter(self.integrators.iter().map(|i| i.v));
        let mut t = 0.0;
        for _ in 1..n_points {
            for _ in 0..substeps {
                // Assemble u = [x(t); h(t)].
                drive(t, &mut self.xbuf);
                self.u[..self.d_drive].copy_from_slice(&self.xbuf);
                for (slot, integ) in self.u[self.d_drive..]
                    .iter_mut()
                    .zip(&self.integrators)
                {
                    *slot = integ.v;
                }
                // Analogue forward pass (fresh reads): per-shard tile
                // column-group reads when a shard layout is installed —
                // bit-identical to the monolithic read, so the integrator
                // feed is shared (the state plan partitions 0..d_state in
                // ascending order; truly private per-shard banks live in
                // the parallel fan-out, `twin::shard`).
                match self.shards.as_ref() {
                    Some(spec) => self.mlp.eval_sharded_into(
                        &self.u,
                        &spec.layers,
                        &mut self.dh,
                        lane,
                    ),
                    None => {
                        self.mlp.eval_into(&self.u, &mut self.dh, lane)
                    }
                }
                for (integ, &d) in
                    self.integrators.iter_mut().zip(self.dh.iter())
                {
                    integ.step(d, dt);
                }
                t += dt;
            }
            out.push_row_from_iter(self.integrators.iter().map(|i| i.v));
        }
        for i in &mut self.integrators {
            i.stop();
        }
    }

    /// Allocating convenience wrapper around
    /// [`AnalogNeuralOde::solve_into`] on the MLP's pooled default lane
    /// (trajectory slot 0; request-path callers pass explicit lanes).
    pub fn solve(
        &mut self,
        h0: &[f64],
        drive: &mut dyn FnMut(f64, &mut [f64]),
        dt_out: f64,
        n_points: usize,
    ) -> Trajectory {
        let mut out = Trajectory::new(self.integrators.len());
        let mut lanes = self.mlp.take_default_lanes(1);
        self.solve_into(h0, drive, dt_out, n_points, &mut lanes[0], &mut out);
        self.mlp.put_default_lanes(lanes);
        out
    }

    /// Batched IVP solve: `batch` trajectories integrated in lockstep from
    /// the flat `[batch * d_state]` initial states `h0s`, sampling each
    /// every `dt_out` for `n_points` samples into `out` (reset to row
    /// width `batch * d_state`; split per trajectory with
    /// [`crate::ode::batch::unbatch_into`]).
    ///
    /// Every circuit step performs **one shared multi-vector device read**
    /// ([`AnalogMlp::eval_batch_into`]) feeding `batch` private integrator
    /// banks — the physical picture of a crossbar serving B concurrent
    /// twins, and the core amortisation of the batched execution engine.
    /// `drive(b, t, x)` writes trajectory `b`'s stimulus (`d_drive`
    /// values; `x` is empty for autonomous systems). `lanes` carries one
    /// noise lane per trajectory: each trajectory's draws are indexed, so
    /// with the same lane state trajectory `b` reproduces
    /// [`AnalogNeuralOde::solve_into`] bit-for-bit — noise on or off,
    /// whatever the batch composition. The integrator banks are clones of
    /// this solver's integrators held in owned scratch, so circuit
    /// parameters (tau, leak, rails) match the serial path exactly and a
    /// warm solver performs zero heap allocations. The serial integrator
    /// state is left untouched.
    #[allow(clippy::too_many_arguments)]
    pub fn solve_batch_into(
        &mut self,
        h0s: &[f64],
        batch: usize,
        drive: &mut dyn FnMut(usize, f64, &mut [f64]),
        dt_out: f64,
        n_points: usize,
        lanes: &mut [NoiseLane],
        out: &mut Trajectory,
    ) {
        let d_state = self.integrators.len();
        let d_in = self.mlp.d_in();
        assert_eq!(
            h0s.len(),
            batch * d_state,
            "solve_batch: h0s length {} != batch {} * state dim {}",
            h0s.len(),
            batch,
            d_state
        );
        assert_eq!(
            lanes.len(),
            batch,
            "solve_batch: one noise lane per trajectory"
        );
        // Per-trajectory integrator banks, cloned (into reused scratch) so
        // circuit parameters — and therefore the update rule — match the
        // serial solver.
        self.bank.clear();
        self.bank.reserve(batch * d_state);
        for _ in 0..batch {
            for src in &self.integrators {
                self.bank.push(src.clone());
            }
        }
        for (integ, &v0) in self.bank.iter_mut().zip(h0s) {
            integ.stop();
            integ.set_initial(v0);
            integ.start_integration();
        }
        let substeps =
            ((dt_out / self.dt_circuit).round() as usize).max(1);
        let dt = dt_out / substeps as f64;
        self.us.resize(batch * d_in, 0.0);
        self.dhs.resize(batch * d_state, 0.0);
        out.reset(batch * d_state);
        out.reserve_rows(n_points.max(1));
        out.push_row_from_iter(self.bank.iter().map(|i| i.v));
        let mut t = 0.0;
        for _ in 1..n_points {
            for _ in 0..substeps {
                // Assemble every trajectory's u = [x_b(t); h_b(t)].
                for b in 0..batch {
                    drive(b, t, &mut self.xbuf);
                    let u = &mut self.us[b * d_in..(b + 1) * d_in];
                    u[..self.d_drive].copy_from_slice(&self.xbuf);
                    for (slot, integ) in u[self.d_drive..]
                        .iter_mut()
                        .zip(&self.bank[b * d_state..(b + 1) * d_state])
                    {
                        *slot = integ.v;
                    }
                }
                // One shared analogue read for the whole batch — split
                // into per-shard tile column-group reads when sharded;
                // the bank feed is shared (see the serial loop above).
                match self.shards.as_ref() {
                    Some(spec) => self.mlp.eval_sharded_batch_into(
                        &self.us,
                        batch,
                        &spec.layers,
                        &mut self.dhs,
                        lanes,
                    ),
                    None => self.mlp.eval_batch_into(
                        &self.us,
                        batch,
                        &mut self.dhs,
                        lanes,
                    ),
                }
                for (integ, &d) in self.bank.iter_mut().zip(self.dhs.iter())
                {
                    integ.step(d, dt);
                }
                t += dt;
            }
            out.push_row_from_iter(self.bank.iter().map(|i| i.v));
        }
        for i in &mut self.bank {
            i.stop();
        }
    }

    /// Allocating convenience wrapper around
    /// [`AnalogNeuralOde::solve_batch_into`] on the MLP's pooled default
    /// lanes (trajectory slot `b` for batch row `b`).
    pub fn solve_batch(
        &mut self,
        h0s: &[f64],
        batch: usize,
        drive: &mut dyn FnMut(usize, f64, &mut [f64]),
        dt_out: f64,
        n_points: usize,
    ) -> Trajectory {
        let mut out = Trajectory::new(batch * self.integrators.len());
        let mut lanes = self.mlp.take_default_lanes(batch);
        self.solve_batch_into(
            h0s,
            batch,
            drive,
            dt_out,
            n_points,
            &mut lanes[..batch],
            &mut out,
        );
        self.mlp.put_default_lanes(lanes);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Layers implementing f(h) = -h exactly with ReLU hidden layer:
    /// hidden = relu([h, -h]) (2 units), out = -hidden[0] + hidden[1] = -h.
    fn linear_decay_layers() -> Vec<LayerWeights> {
        let w1 = Mat::from_vec(1, 2, vec![1.0, -1.0]);
        let b1 = vec![0.0, 0.0];
        let w2 = Mat::from_vec(2, 1, vec![-1.0, 1.0]);
        let b2 = vec![0.0];
        vec![LayerWeights::new(&w1, &b1), LayerWeights::new(&w2, &b2)]
    }

    #[test]
    fn ideal_mlp_computes_expected_field() {
        let mut mlp = AnalogMlp::ideal(&linear_decay_layers(), 1);
        assert_eq!(mlp.d_in(), 1);
        assert_eq!(mlp.d_out(), 1);
        for h in [-2.0, -0.5, 0.0, 0.7, 3.0] {
            let y = mlp.eval(&[h]);
            assert!((y[0] + h).abs() < 1e-12, "f({h}) = {}", y[0]);
        }
    }

    #[test]
    fn closed_loop_solves_exponential_decay() {
        // dh/dt = -h from h0 = 1 -> h(t) = e^{-t}.
        let mlp = AnalogMlp::ideal(&linear_decay_layers(), 2);
        let mut ode = AnalogNeuralOde::new(mlp, 1, 1e-4);
        let traj =
            ode.solve(&[1.0], &mut |_t, _x: &mut [f64]| {}, 0.1, 11);
        assert_eq!(traj.len(), 11);
        for (k, row) in traj.iter().enumerate() {
            let want = (-(k as f64) * 0.1).exp();
            assert!(
                (row[0] - want).abs() < 2e-3,
                "t={k}: {} vs {want}",
                row[0]
            );
        }
    }

    #[test]
    fn driven_loop_tracks_input() {
        // f([x; h]) = x - h  ->  h follows a step input with tau = 1.
        let w1 = Mat::from_vec(2, 2, vec![1.0, -1.0, -1.0, 1.0]);
        let b1 = vec![0.0, 0.0];
        let w2 = Mat::from_vec(2, 1, vec![1.0, -1.0]);
        let b2 = vec![0.0];
        let layers =
            vec![LayerWeights::new(&w1, &b1), LayerWeights::new(&w2, &b2)];
        let mlp = AnalogMlp::ideal(&layers, 3);
        let mut ode = AnalogNeuralOde::new(mlp, 1, 1e-4);
        let traj = ode.solve(
            &[0.0],
            &mut |_t, x: &mut [f64]| x[0] = 1.0,
            0.5,
            11,
        );
        // After 5 time constants h ~ 1.
        let h_end = traj.last().unwrap()[0];
        assert!((h_end - 1.0).abs() < 0.01, "h_end={h_end}");
    }

    #[test]
    fn deployed_mlp_close_to_ideal() {
        let cfg = DeviceConfig { fault_rate: 0.0, ..Default::default() };
        let layers = linear_decay_layers();
        let mut ideal = AnalogMlp::ideal(&layers, 1);
        let mut real =
            AnalogMlp::deploy(&layers, &cfg, AnalogNoise::off(), 7);
        for h in [-1.0, 0.3, 0.9] {
            let yi = ideal.eval(&[h]);
            let yr = real.eval(&[h]);
            assert!(
                (yi[0] - yr[0]).abs() < 0.1,
                "ideal {} vs deployed {}",
                yi[0],
                yr[0]
            );
        }
    }

    #[test]
    fn aging_deployment_matches_deploy_at_age_zero() {
        // deploy_aging's deploy-time RNG consumption is identical to
        // deploy: same seed ⇒ bit-identical effective weights at age 0.
        let cfg = DeviceConfig { fault_rate: 0.0, ..Default::default() };
        let layers = linear_decay_layers();
        let plain = AnalogMlp::deploy(&layers, &cfg, AnalogNoise::off(), 7);
        let aging =
            AnalogMlp::deploy_aging(&layers, &cfg, AnalogNoise::off(), 7);
        for l in 0..plain.n_layers() {
            assert_eq!(
                plain.engine(l).weights().data,
                aging.engine(l).weights().data,
                "layer {l} diverged at age 0"
            );
        }
        assert!(aging.is_aging() && !plain.is_aging());
        assert_eq!(aging.age_s(), 0.0);
    }

    #[test]
    fn advance_age_drifts_and_recalibrate_restores() {
        let cfg = DeviceConfig { fault_rate: 0.0, ..Default::default() };
        let layers = linear_decay_layers();
        let mut mlp =
            AnalogMlp::deploy_aging(&layers, &cfg, AnalogNoise::off(), 7);
        let fresh = mlp.engine(0).weights().clone();
        mlp.advance_age(1e7);
        assert_eq!(mlp.age_s(), 1e7);
        let aged = mlp.engine(0).weights().clone();
        let dev = |a: &Mat, b: &Mat| {
            a.data
                .iter()
                .zip(&b.data)
                .map(|(&x, &y)| (x - y).abs())
                .sum::<f64>()
        };
        assert!(dev(&aged, &fresh) > 0.0, "aging did not move the engine");
        let pulses = mlp.recalibrate();
        assert!(pulses > 0);
        assert_eq!(mlp.recalibrations(), 1);
        assert_eq!(mlp.lifetime_pulses(), pulses);
        let recal = mlp.engine(0).weights().clone();
        assert!(
            dev(&recal, &fresh) < dev(&aged, &fresh),
            "recalibration did not restore the weights"
        );
        // Negative dt is a strict no-op on the virtual clock.
        mlp.advance_age(-1e6);
        assert_eq!(mlp.age_s(), 1e7);
    }

    #[test]
    fn injected_faults_lower_health_and_survive_recal() {
        let cfg = DeviceConfig { fault_rate: 0.0, ..Default::default() };
        let layers = linear_decay_layers();
        let mut mlp =
            AnalogMlp::deploy_aging(&layers, &cfg, AnalogNoise::off(), 3);
        assert_eq!(mlp.array_health(), 1.0);
        mlp.inject_stuck_faults(0.5);
        let h = mlp.array_health();
        assert!(h < 0.9, "fault injection inert (health {h})");
        mlp.recalibrate();
        assert!(
            (mlp.array_health() - h).abs() < 1e-12,
            "recalibration altered the yield map"
        );
    }

    #[test]
    fn read_noise_perturbs_but_preserves_mean() {
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let layers = linear_decay_layers();
        let mut mlp = AnalogMlp::deploy(
            &layers,
            &cfg,
            AnalogNoise { read: 0.05, prog: 0.0 },
            11,
        );
        let samples: Vec<f64> =
            (0..2000).map(|_| mlp.eval(&[1.0])[0]).collect();
        let s = crate::util::stats::summary(&samples);
        assert!((s.mean + 1.0).abs() < 0.02, "mean {}", s.mean);
        assert!(s.std > 1e-4, "noise inert");
    }

    #[test]
    fn eval_batch_bit_identical_to_serial_noise_free() {
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let mut mlp =
            AnalogMlp::deploy(&linear_decay_layers(), &cfg, AnalogNoise::off(), 5);
        let hs = [-2.0, -0.5, 0.0, 0.7, 3.0];
        let ys = mlp.eval_batch(&hs, hs.len());
        for (b, &h) in hs.iter().enumerate() {
            let want = mlp.eval(&[h]);
            assert_eq!(ys[b], want[0], "traj {b}");
        }
    }

    #[test]
    fn eval_batch_noisy_mean_matches_serial() {
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let mut mlp = AnalogMlp::deploy(
            &linear_decay_layers(),
            &cfg,
            AnalogNoise { read: 0.05, prog: 0.0 },
            11,
        );
        let batch = 4;
        let us = vec![1.0; batch];
        let samples: Vec<f64> = (0..1500)
            .flat_map(|_| mlp.eval_batch(&us, batch))
            .collect();
        let s = crate::util::stats::summary(&samples);
        assert!((s.mean + 1.0).abs() < 0.02, "mean {}", s.mean);
        assert!(s.std > 1e-4, "batched noise inert");
    }

    #[test]
    fn solve_batch_bit_identical_to_serial_solves() {
        // dh/dt = -h from three different initial conditions: the batched
        // closed loop must reproduce three serial closed loops exactly.
        let mlp = AnalogMlp::ideal(&linear_decay_layers(), 2);
        let mut ode = AnalogNeuralOde::new(mlp, 1, 1e-3);
        let h0s = [1.0, -0.5, 0.25];
        let batched = ode.solve_batch(
            &h0s,
            3,
            &mut |_b, _t, _x| {},
            0.1,
            11,
        );
        assert_eq!(batched.dim(), 3);
        for (b, &h0) in h0s.iter().enumerate() {
            let serial =
                ode.solve(&[h0], &mut |_t, _x: &mut [f64]| {}, 0.1, 11);
            for (row, srow) in batched.iter().zip(&serial) {
                assert_eq!(row[b], srow[0], "traj {b}");
            }
        }
    }

    #[test]
    fn solve_batch_driven_matches_serial_driven() {
        // f([x; h]) = x - h with per-trajectory step inputs.
        let w1 = Mat::from_vec(2, 2, vec![1.0, -1.0, -1.0, 1.0]);
        let b1 = vec![0.0, 0.0];
        let w2 = Mat::from_vec(2, 1, vec![1.0, -1.0]);
        let b2 = vec![0.0];
        let layers =
            vec![LayerWeights::new(&w1, &b1), LayerWeights::new(&w2, &b2)];
        let mlp = AnalogMlp::ideal(&layers, 3);
        let mut ode = AnalogNeuralOde::new(mlp, 1, 1e-3);
        let drives = [0.5, 1.0];
        let batched = ode.solve_batch(
            &[0.0, 0.0],
            2,
            &mut |b, _t, x| x[0] = drives[b],
            0.2,
            6,
        );
        for (b, &amp) in drives.iter().enumerate() {
            let serial = ode.solve(
                &[0.0],
                &mut |_t, x: &mut [f64]| x[0] = amp,
                0.2,
                6,
            );
            for (row, srow) in batched.iter().zip(&serial) {
                assert_eq!(row[b], srow[0], "traj {b}");
            }
        }
    }

    #[test]
    fn warm_solver_scratch_is_bit_stable_across_batch_sizes() {
        // Alternating batch sizes through the same solver instance must
        // reproduce a fresh solver's output exactly (the pooled bank /
        // us / dhs scratch never leaks state between calls).
        let mlp = AnalogMlp::ideal(&linear_decay_layers(), 2);
        let mut warm = AnalogNeuralOde::new(mlp.clone(), 1, 1e-3);
        let _ = warm.solve_batch(
            &[0.3, -0.7, 0.9, 0.1],
            4,
            &mut |_b, _t, _x| {},
            0.1,
            7,
        );
        let got = warm.solve_batch(
            &[1.0, -0.5],
            2,
            &mut |_b, _t, _x| {},
            0.1,
            5,
        );
        let mut fresh = AnalogNeuralOde::new(mlp, 1, 1e-3);
        let want = fresh.solve_batch(
            &[1.0, -0.5],
            2,
            &mut |_b, _t, _x| {},
            0.1,
            5,
        );
        assert_eq!(got, want);
    }

    /// f(h) = -h element-wise for dimension d (the shared exact-ReLU
    /// decay fixture) — with d > 32 deployment spans several physical
    /// tiles.
    fn wide_decay_layers(d: usize) -> Vec<LayerWeights> {
        crate::models::loader::decay_mlp_weights(d)
            .layers
            .iter()
            .map(|(w, b)| LayerWeights::new(w, b))
            .collect()
    }

    fn wide_h0(d: usize) -> Vec<f64> {
        (0..d).map(|i| ((i as f64) * 0.37).sin() * 0.8).collect()
    }

    #[test]
    fn sharded_solve_bit_identical_to_monolithic() {
        let d = 34;
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            read_noise: 0.0,
            ..Default::default()
        };
        let layers = wide_decay_layers(d);
        let mlp = AnalogMlp::deploy(&layers, &cfg, AnalogNoise::off(), 9);
        let mut mono = AnalogNeuralOde::new(mlp.clone(), d, 0.01);
        let mut sharded =
            AnalogNeuralOde::new(mlp, d, 0.01).with_shards(2);
        let spec = sharded.shard_spec().expect("sharded");
        assert_eq!(spec.n_shards(), 2);
        assert!(spec.state.is_sharded());
        let h0 = wide_h0(d);
        let a = mono.solve(&h0, &mut |_t, _x: &mut [f64]| {}, 0.1, 6);
        let b = sharded.solve(&h0, &mut |_t, _x: &mut [f64]| {}, 0.1, 6);
        assert_eq!(a, b, "sharded rollout diverged from monolithic");
    }

    #[test]
    fn sharded_solve_batch_bit_identical_to_monolithic() {
        let d = 34;
        let layers = wide_decay_layers(d);
        let mlp = AnalogMlp::ideal(&layers, 4);
        let mut mono = AnalogNeuralOde::new(mlp.clone(), d, 0.01);
        let mut sharded =
            AnalogNeuralOde::new(mlp, d, 0.01).with_shards(2);
        let batch = 3;
        let h0s: Vec<f64> = (0..batch * d)
            .map(|k| ((k as f64) * 0.23).cos() * 0.6)
            .collect();
        let a = mono.solve_batch(&h0s, batch, &mut |_b, _t, _x| {}, 0.1, 5);
        let b =
            sharded.solve_batch(&h0s, batch, &mut |_b, _t, _x| {}, 0.1, 5);
        assert_eq!(a, b, "sharded batched rollout diverged");
    }

    #[test]
    fn sharded_fast_noise_stream_matches_monolithic_serial() {
        // Ascending shards share the MLP's RNG, so even the *noisy* serial
        // sharded rollout reproduces the monolithic one bit for bit.
        let d = 34;
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        let layers = wide_decay_layers(d);
        let noise = AnalogNoise { read: 0.05, prog: 0.0 };
        let mono_mlp = AnalogMlp::deploy(&layers, &cfg, noise, 21);
        let shard_mlp = AnalogMlp::deploy(&layers, &cfg, noise, 21);
        let mut mono = AnalogNeuralOde::new(mono_mlp, d, 0.01);
        let mut sharded =
            AnalogNeuralOde::new(shard_mlp, d, 0.01).with_shards(2);
        let h0 = wide_h0(d);
        let a = mono.solve(&h0, &mut |_t, _x: &mut [f64]| {}, 0.1, 4);
        let b = sharded.solve(&h0, &mut |_t, _x: &mut [f64]| {}, 0.1, 4);
        assert_eq!(a, b, "fast-noise shard stream diverged");
    }

    fn noisy_deploy(d: usize, seed: u64) -> AnalogMlp {
        let cfg = DeviceConfig {
            fault_rate: 0.0,
            pulse_sigma: 0.0,
            ..Default::default()
        };
        AnalogMlp::deploy(
            &wide_decay_layers(d),
            &cfg,
            AnalogNoise { read: 0.05, prog: 0.0 },
            seed,
        )
    }

    #[test]
    fn noisy_solve_replays_bit_identical_with_same_lane() {
        let d = 34;
        let mut ode = AnalogNeuralOde::new(noisy_deploy(d, 17), d, 0.01);
        let h0 = wide_h0(d);
        let mut a = Trajectory::new(d);
        let mut b = Trajectory::new(d);
        let mut lane = NoiseLane::from_seed(123);
        ode.solve_into(&h0, &mut |_t, _x: &mut [f64]| {}, 0.1, 5, &mut lane, &mut a);
        let mut lane2 = NoiseLane::from_seed(123);
        ode.solve_into(&h0, &mut |_t, _x: &mut [f64]| {}, 0.1, 5, &mut lane2, &mut b);
        assert_eq!(a, b, "same seed must replay the noisy rollout exactly");
        assert_eq!(lane, lane2, "replay left a different lane cursor");
        assert!(lane.cursor() > 0, "noisy rollout consumed no draws");
    }

    #[test]
    fn noisy_solve_batch_bit_identical_to_serial_lanes() {
        // The tentpole guarantee at the solver level: with one lane per
        // trajectory, the batched noisy rollout reproduces each serial
        // noisy rollout exactly, whatever the batch composition.
        let d = 34;
        let mut ode = AnalogNeuralOde::new(noisy_deploy(d, 19), d, 0.01);
        let batch = 3;
        let seeds = [7u64, 8, 9];
        let h0s: Vec<f64> = (0..batch * d)
            .map(|k| ((k as f64) * 0.23).cos() * 0.6)
            .collect();
        let mut lanes: Vec<NoiseLane> =
            seeds.iter().map(|&s| NoiseLane::from_seed(s)).collect();
        let mut batched = Trajectory::new(batch * d);
        ode.solve_batch_into(
            &h0s,
            batch,
            &mut |_b, _t, _x| {},
            0.1,
            4,
            &mut lanes,
            &mut batched,
        );
        for (b, &s) in seeds.iter().enumerate() {
            let mut lane = NoiseLane::from_seed(s);
            let mut serial = Trajectory::new(d);
            ode.solve_into(
                &h0s[b * d..(b + 1) * d],
                &mut |_t, _x: &mut [f64]| {},
                0.1,
                4,
                &mut lane,
                &mut serial,
            );
            for (row, srow) in batched.iter().zip(&serial) {
                assert_eq!(
                    &row[b * d..(b + 1) * d],
                    srow,
                    "noisy trajectory {b} diverged in the batch"
                );
            }
            assert_eq!(lane, lanes[b], "trajectory {b} lane cursor");
        }
    }

    #[test]
    fn noisy_sharded_solve_bit_identical_to_monolithic() {
        // Same deployment, same lane: the serial sharded kernel consumes
        // identical indexed draws — noisy output matches bit for bit,
        // serial and batched.
        let d = 34;
        let mut mono = AnalogNeuralOde::new(noisy_deploy(d, 23), d, 0.01);
        let mut sharded =
            AnalogNeuralOde::new(noisy_deploy(d, 23), d, 0.01).with_shards(2);
        let h0 = wide_h0(d);
        let mut a = Trajectory::new(d);
        let mut b = Trajectory::new(d);
        let mut la = NoiseLane::from_seed(31);
        let mut lb = NoiseLane::from_seed(31);
        mono.solve_into(&h0, &mut |_t, _x: &mut [f64]| {}, 0.1, 4, &mut la, &mut a);
        sharded.solve_into(&h0, &mut |_t, _x: &mut [f64]| {}, 0.1, 4, &mut lb, &mut b);
        assert_eq!(a, b, "noisy sharded rollout diverged from monolithic");
        assert_eq!(la, lb, "sharded lane fell out of lockstep");

        let batch = 2;
        let h0s: Vec<f64> =
            (0..batch * d).map(|k| ((k as f64) * 0.11).sin() * 0.4).collect();
        let mut lanes_a =
            vec![NoiseLane::from_seed(41), NoiseLane::from_seed(42)];
        let mut lanes_b = lanes_a.clone();
        let mut ba = Trajectory::new(batch * d);
        let mut bb = Trajectory::new(batch * d);
        mono.solve_batch_into(
            &h0s, batch, &mut |_b, _t, _x| {}, 0.1, 3, &mut lanes_a, &mut ba,
        );
        sharded.solve_batch_into(
            &h0s, batch, &mut |_b, _t, _x| {}, 0.1, 3, &mut lanes_b, &mut bb,
        );
        assert_eq!(ba, bb, "noisy sharded batch diverged from monolithic");
        assert_eq!(lanes_a, lanes_b);
    }

    #[test]
    fn shard_count_clamped_to_narrowest_layer() {
        // The 1-wide output layer caps the stack at one shard.
        let mlp = AnalogMlp::ideal(&linear_decay_layers(), 1);
        let ode = AnalogNeuralOde::new(mlp, 1, 1e-3).with_shards(8);
        assert_eq!(ode.shard_spec().unwrap().n_shards(), 1);
    }

    #[test]
    fn autonomous_solver_rejects_drive_mismatch() {
        let mlp = AnalogMlp::ideal(&linear_decay_layers(), 1);
        let ode = AnalogNeuralOde::new(mlp, 1, 1e-3);
        assert_eq!(ode.d_drive, 0);
    }

    #[test]
    #[should_panic(expected = "state dim")]
    fn wrong_state_dim_panics() {
        let mlp = AnalogMlp::ideal(&linear_decay_layers(), 1);
        let _ = AnalogNeuralOde::new(mlp, 2, 1e-3);
    }
}
