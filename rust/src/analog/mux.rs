//! Analogue multiplexer (TMUX1134-class) used to switch the IVP integrator
//! between its two modes (Fig. 2c) and to route programming vs.
//! multiplication paths (Methods).
//!
//! Behavioural model: finite on-resistance, a settling time constant after
//! each mode switch, and an off-isolation leak. The settling model matters
//! for the timing budget: the paper's initial-conditioning phase must wait
//! for the mux + capacitor network to settle before integration starts.

/// Routing state of a 2:1 analogue mux.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MuxState {
    /// Path A selected (e.g. initial-conditioning supply).
    A,
    /// Path B selected (e.g. crossbar output into the integrator).
    B,
}

/// Behavioural 2:1 analogue multiplexer.
#[derive(Debug, Clone)]
pub struct AnalogMux {
    pub state: MuxState,
    /// On-resistance of the selected channel (Ohm).
    pub r_on: f64,
    /// Settling time constant after a switch (s).
    pub tau_settle: f64,
    /// Time since the last switch (s).
    since_switch: f64,
    /// Off-channel isolation leak fraction (0 = perfect isolation).
    pub leak: f64,
}

impl Default for AnalogMux {
    fn default() -> Self {
        // TMUX1134: ~5 Ohm on-resistance, sub-µs settling.
        Self {
            state: MuxState::A,
            r_on: 5.0,
            tau_settle: 2e-7,
            since_switch: 1.0,
            leak: 1e-5,
        }
    }
}

impl AnalogMux {
    /// Switch to a state; resets the settling clock if the state changed.
    pub fn switch_to(&mut self, s: MuxState) {
        if self.state != s {
            self.state = s;
            self.since_switch = 0.0;
        }
    }

    /// Advance time.
    pub fn advance(&mut self, dt: f64) {
        self.since_switch += dt.max(0.0);
    }

    /// Whether the channel has settled to within `eps` of its final value.
    pub fn settled(&self, eps: f64) -> bool {
        (-self.since_switch / self.tau_settle).exp() < eps
    }

    /// Route the two inputs: output follows the selected channel through a
    /// first-order settling transient, plus off-channel leak.
    pub fn route(&self, a: f64, b: f64) -> f64 {
        let alpha = 1.0 - (-self.since_switch / self.tau_settle).exp();
        let (sel, other) = match self.state {
            MuxState::A => (a, b),
            MuxState::B => (b, a),
        };
        // During settling the output blends from the *previous* channel.
        let blended = other + alpha * (sel - other);
        blended + self.leak * other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn settled_mux_routes_selected_channel() {
        let mut m = AnalogMux::default();
        m.switch_to(MuxState::B);
        m.advance(1e-3); // >> tau
        let out = m.route(1.0, 2.0);
        assert!((out - 2.0).abs() < 1e-3, "out={out}");
    }

    #[test]
    fn switching_resets_settling() {
        let mut m = AnalogMux::default();
        m.advance(1.0);
        assert!(m.settled(1e-6));
        m.switch_to(MuxState::B);
        assert!(!m.settled(1e-6));
        m.advance(10.0 * m.tau_settle);
        assert!(m.settled(1e-4));
    }

    #[test]
    fn mid_settling_output_is_blend() {
        let mut m = AnalogMux::default();
        m.advance(1.0);
        m.switch_to(MuxState::B);
        m.advance(m.tau_settle); // one time constant: ~63 %
        let out = m.route(0.0, 1.0);
        assert!(out > 0.5 && out < 0.75, "out={out}");
    }

    #[test]
    fn redundant_switch_does_not_reset() {
        let mut m = AnalogMux::default();
        m.advance(1.0);
        m.switch_to(MuxState::A); // already A
        assert!(m.settled(1e-6));
    }
}
