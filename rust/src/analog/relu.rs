//! Analogue ReLU (dual-diode rectifier, Fig. 2d-e).
//!
//! The paper realises activation with a 1N4148 dual-diode stage inside the
//! TIA loop. A real diode has a soft exponential knee and a small reverse
//! leakage; the behavioural model exposes both, plus the ideal limit used
//! for fast logical simulation.

/// Behavioural diode-ReLU.
#[derive(Debug, Clone)]
pub struct DiodeRelu {
    /// Knee sharpness (V): 0 gives the ideal max(0, x).
    /// Physical 1N4148-in-feedback stages have effective knees of a few mV.
    pub knee: f64,
    /// Reverse-leakage slope for x < 0 (ideal: 0).
    pub leakage: f64,
}

impl DiodeRelu {
    /// Ideal rectifier.
    pub fn ideal() -> Self {
        Self { knee: 0.0, leakage: 0.0 }
    }

    /// Representative behavioural values for the paper's board.
    pub fn behavioural() -> Self {
        Self { knee: 5e-3, leakage: 1e-4 }
    }

    /// Activation: softplus-shaped knee blending into linear, with leakage.
    #[inline]
    pub fn activate(&self, x: f64) -> f64 {
        let pos = if self.knee == 0.0 {
            x.max(0.0)
        } else {
            // Numerically-stable softplus scaled by the knee width.
            let t = x / self.knee;
            if t > 30.0 {
                x
            } else if t < -30.0 {
                0.0
            } else {
                self.knee * (1.0 + t.exp()).ln()
            }
        };
        pos + self.leakage * x.min(0.0)
    }

    /// Activate a vector in place.
    pub fn activate_slice(&self, xs: &mut [f64]) {
        for x in xs {
            *x = self.activate(*x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_max_zero() {
        let r = DiodeRelu::ideal();
        assert_eq!(r.activate(2.0), 2.0);
        assert_eq!(r.activate(-2.0), 0.0);
        assert_eq!(r.activate(0.0), 0.0);
    }

    #[test]
    fn behavioural_close_to_ideal_away_from_knee() {
        let r = DiodeRelu::behavioural();
        assert!((r.activate(1.0) - 1.0).abs() < 1e-3);
        assert!(r.activate(-1.0).abs() < 2e-4); // only leakage
    }

    #[test]
    fn knee_is_smooth_and_monotone() {
        let r = DiodeRelu::behavioural();
        let mut prev = r.activate(-0.05);
        let mut x = -0.05;
        while x < 0.05 {
            x += 1e-3;
            let y = r.activate(x);
            assert!(y >= prev - 1e-12, "non-monotone at {x}");
            prev = y;
        }
    }

    #[test]
    fn extreme_inputs_do_not_overflow() {
        let r = DiodeRelu::behavioural();
        assert!(r.activate(1e6).is_finite());
        assert!(r.activate(-1e6).is_finite());
    }

    #[test]
    fn slice_matches_scalar() {
        let r = DiodeRelu::ideal();
        let mut xs = vec![-1.0, 0.5, 2.0];
        r.activate_slice(&mut xs);
        assert_eq!(xs, vec![0.0, 0.5, 2.0]);
    }
}
