//! The IVP integrator (Fig. 2b-c): the circuit block that turns the
//! crossbar MLP into an ODE *solver*.
//!
//! An op-amp integrating capacitor accumulates the (inverted) network
//! output; analogue muxes switch between two modes:
//!
//! * **initial conditioning** — S1/S2 open, S3/S4 closed: the capacitor is
//!   pre-charged to the initial state h(t0);
//! * **current integration** — all muxes toggled: the capacitor integrates
//!   the network output, closing the loop dh/dt = f(h, x, t).
//!
//! Behavioural model: ideal integration dv/dt = u / tau with rail
//! saturation and a finite leak (op-amp bias current + capacitor
//! dielectric absorption), integrated with RK4 *inside the circuit
//! simulator* at a time step far below the signal bandwidth.

use crate::analog::mux::{AnalogMux, MuxState};

/// Operating mode (mirrors the oscilloscope phases of Fig. 2c).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntegratorMode {
    InitialConditioning,
    Integrating,
}

/// Behavioural IVP integrator.
#[derive(Debug, Clone)]
pub struct IvpIntegrator {
    /// Integration time constant tau = R*C (s of circuit time per unit of
    /// input); logical designs use tau = 1 so circuit time equals ODE time.
    pub tau: f64,
    /// Output saturation (op-amp rails).
    pub v_sat: f64,
    /// Leak rate (1/s): dv/dt includes -leak * v.
    pub leak: f64,
    /// Capacitor voltage = the ODE state component.
    pub v: f64,
    pub mode: IntegratorMode,
    /// Mode-switch mux (its settling gates integration start).
    pub mux: AnalogMux,
}

impl IvpIntegrator {
    /// A logical integrator: tau = 1, generous rails, tiny leak.
    pub fn logical(v_sat: f64) -> Self {
        Self {
            tau: 1.0,
            v_sat,
            leak: 1e-6,
            v: 0.0,
            mode: IntegratorMode::InitialConditioning,
            mux: AnalogMux::default(),
        }
    }

    /// Pre-charge the capacitor (initial-conditioning phase).
    pub fn set_initial(&mut self, v0: f64) {
        assert!(
            self.mode == IntegratorMode::InitialConditioning,
            "must be in initial-conditioning mode to pre-charge"
        );
        self.v = v0.clamp(-self.v_sat, self.v_sat);
    }

    /// Toggle into integration mode (flips the analogue muxes).
    pub fn start_integration(&mut self) {
        self.mode = IntegratorMode::Integrating;
        self.mux.switch_to(MuxState::B);
    }

    /// Back to conditioning (stops integrating, holds the state).
    pub fn stop(&mut self) {
        self.mode = IntegratorMode::InitialConditioning;
        self.mux.switch_to(MuxState::A);
    }

    /// Advance circuit time by `dt` with constant input `u` over the step
    /// (the system simulator calls this at sub-signal-bandwidth steps, so
    /// zero-order hold on u is accurate).
    pub fn step(&mut self, u: f64, dt: f64) {
        self.mux.advance(dt);
        if self.mode != IntegratorMode::Integrating {
            return;
        }
        // dv/dt = u/tau - leak*v  (linear ODE; exact solution per step).
        let a = -self.leak;
        let b = u / self.tau;
        if self.leak.abs() < 1e-12 {
            self.v += b * dt;
        } else {
            // v(t+dt) = (v + b/a)(e^{a dt}) - b/a
            let e = (a * dt).exp();
            self.v = (self.v + b / a) * e - b / a;
        }
        self.v = self.v.clamp(-self.v_sat, self.v_sat);
    }

    /// Whether the output has railed (diagnostic).
    pub fn saturated(&self) -> bool {
        self.v.abs() >= self.v_sat
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integrates_constant_input_linearly() {
        let mut i = IvpIntegrator::logical(100.0);
        i.set_initial(0.0);
        i.start_integration();
        for _ in 0..1000 {
            i.step(2.0, 1e-3);
        }
        assert!((i.v - 2.0).abs() < 1e-3, "v={}", i.v);
    }

    #[test]
    fn conditioning_mode_holds_state() {
        let mut i = IvpIntegrator::logical(10.0);
        i.set_initial(1.5);
        for _ in 0..100 {
            i.step(5.0, 1e-3); // input ignored while conditioning
        }
        assert_eq!(i.v, 1.5);
    }

    #[test]
    fn initial_condition_respects_rails() {
        let mut i = IvpIntegrator::logical(2.0);
        i.set_initial(5.0);
        assert_eq!(i.v, 2.0);
    }

    #[test]
    #[should_panic(expected = "initial-conditioning")]
    fn precharge_while_integrating_panics() {
        let mut i = IvpIntegrator::logical(2.0);
        i.start_integration();
        i.set_initial(1.0);
    }

    #[test]
    fn saturation_bounds_output() {
        let mut i = IvpIntegrator::logical(1.0);
        i.set_initial(0.0);
        i.start_integration();
        for _ in 0..10_000 {
            i.step(10.0, 1e-3);
        }
        assert_eq!(i.v, 1.0);
        assert!(i.saturated());
    }

    #[test]
    fn leak_decays_state() {
        let mut i = IvpIntegrator::logical(10.0);
        i.leak = 0.5;
        i.set_initial(1.0);
        i.start_integration();
        for _ in 0..1000 {
            i.step(0.0, 1e-3); // 1 s total
        }
        // v = e^{-0.5} ≈ 0.6065
        assert!((i.v - (-0.5f64).exp()).abs() < 1e-3, "v={}", i.v);
    }

    #[test]
    fn stop_freezes_integration() {
        let mut i = IvpIntegrator::logical(10.0);
        i.set_initial(0.0);
        i.start_integration();
        i.step(1.0, 0.5);
        i.stop();
        let v = i.v;
        i.step(1.0, 0.5);
        assert_eq!(i.v, v);
    }
}
