//! Behavioural models of the paper's analogue devices.
//!
//! The paper's artefact is a fabricated 180 nm TiN/TaOx/Ta2O5/TiN 1T1R
//! memristor chip; this module replaces it with a statistics-calibrated
//! simulator (see DESIGN.md "Reproduction bands & substitutions"):
//!
//! * [`taox`]        — the analogue memristor cell: bounded conductance,
//!   6-bit programmable levels (Fig. 2h), noisy reads
//! * [`programming`] — write-verify programming loop and its error
//!   distribution (Fig. 2k: 4.36 % variance)
//! * [`noise`]       — read / programming noise sources
//! * [`retention`]   — conductance drift over time (Fig. 2i)
//! * [`yield_model`] — stuck-device faults (Fig. 2j: 97.3 % yield)
//! * [`hp`]          — the HP memristor *ground truth* ODE (Strukov 2008,
//!   Eqs. 2-3) — the physical asset the Fig. 3 digital twin mirrors

pub mod hp;
pub mod noise;
pub mod programming;
pub mod retention;
pub mod taox;
pub mod yield_model;

pub use programming::{program_cell, ProgrammingResult};
pub use taox::{DeviceConfig, Memristor, StuckMode};
