//! Conductance retention over time (Fig. 2i).
//!
//! The paper demonstrates stable analogue states under a 0.2 V read for
//! > 1e4 s and quotes retention exceeding 1e5 s. TaOx filaments exhibit a
//! slow log-time relaxation toward the window centre plus a diffusive
//! component; we model
//!
//!   g(t) = g0 * (1 - nu * log10(1 + t/t0))  + diffusive walk,
//!
//! with `nu` small enough that drift over 1e5 s stays within the read-noise
//! band — reproducing the "flat lines" of Fig. 2i while still giving a
//! physically shaped decay for long-horizon studies.

use crate::device::taox::{DeviceConfig, Memristor};
use crate::util::rng::Pcg64;

/// Reference time constant of the log-relaxation (s).
const T0: f64 = 10.0;

/// Deterministic drift factor after `age_s` seconds.
pub fn drift_factor(cfg: &DeviceConfig, age_s: f64) -> f64 {
    1.0 - cfg.drift_nu * (1.0 + age_s / T0).log10()
}

/// Advance a cell's age by `dt_s`, applying drift + a small diffusive step.
///
/// `dt_s` is clamped to `>= 0` *before any branch touches the cell*: a
/// negative dt is a strict no-op on both `g` and `age_s` (time never runs
/// backwards on hardware), so callers integrating a virtual clock can pass
/// raw deltas without pre-validating them.
pub fn age_cell(
    cell: &mut Memristor,
    cfg: &DeviceConfig,
    dt_s: f64,
    rng: &mut Pcg64,
) {
    if !(dt_s > 0.0) {
        return;
    }
    if !cell.is_healthy() {
        cell.age_s += dt_s;
        return;
    }
    let before = drift_factor(cfg, cell.age_s);
    cell.age_s += dt_s;
    let after = drift_factor(cfg, cell.age_s);
    // Apply the incremental deterministic relaxation...
    cell.g = cfg.clamp_g(cell.g * after / before);
    // ...plus a diffusive component ~ sqrt(dt) scaled far below read noise.
    let diff_sigma = 0.1 * cfg.drift_nu * (dt_s / 1e5).sqrt();
    if diff_sigma > 0.0 {
        cell.g = cfg.clamp_g(cell.g * (1.0 + diff_sigma * rng.normal()));
    }
}

/// Simulate a retention trace: read the cell every `interval_s` for
/// `duration_s` under the characterisation read voltage. Returns (t, g).
pub fn retention_trace(
    cell: &mut Memristor,
    cfg: &DeviceConfig,
    duration_s: f64,
    interval_s: f64,
    rng: &mut Pcg64,
) -> Vec<(f64, f64)> {
    let n = (duration_s / interval_s).ceil() as usize;
    let mut out = Vec::with_capacity(n + 1);
    out.push((0.0, cell.read(cfg, rng)));
    for k in 1..=n {
        age_cell(cell, cfg, interval_s, rng);
        out.push((k as f64 * interval_s, cell.read(cfg, rng)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::programming::program_cell;

    #[test]
    fn drift_factor_is_monotone_decreasing() {
        let cfg = DeviceConfig::default();
        let mut prev = drift_factor(&cfg, 0.0);
        assert_eq!(prev, 1.0);
        for k in 1..=10 {
            let f = drift_factor(&cfg, 10f64.powi(k));
            assert!(f < prev);
            prev = f;
        }
    }

    #[test]
    fn retention_within_read_noise_band_at_1e5_s() {
        // Fig. 2i claim: analogue states remain distinguishable beyond
        // 1e5 s. Drift at 1e5 s must stay below ~3x read noise.
        let cfg = DeviceConfig::default();
        let f = drift_factor(&cfg, 1e5);
        assert!(
            (1.0 - f) < 3.0 * cfg.read_noise,
            "drift {} too large",
            1.0 - f
        );
    }

    #[test]
    fn distinct_levels_remain_ordered_after_aging() {
        let cfg = DeviceConfig::default();
        let mut rng = Pcg64::seeded(1);
        let targets = [10e-6, 30e-6, 50e-6, 70e-6, 90e-6];
        let mut cells: Vec<Memristor> = targets
            .iter()
            .map(|&g| {
                let mut c = Memristor::new(&cfg);
                program_cell(&mut c, &cfg, g, &mut rng);
                c
            })
            .collect();
        for c in &mut cells {
            age_cell(c, &cfg, 1e5, &mut rng);
        }
        for w in cells.windows(2) {
            assert!(w[0].g < w[1].g, "levels crossed after retention");
        }
    }

    #[test]
    fn trace_has_expected_length_and_times() {
        let cfg = DeviceConfig::default();
        let mut rng = Pcg64::seeded(2);
        let mut cell = Memristor::new(&cfg);
        program_cell(&mut cell, &cfg, 40e-6, &mut rng);
        let trace = retention_trace(&mut cell, &cfg, 100.0, 10.0, &mut rng);
        assert_eq!(trace.len(), 11);
        assert_eq!(trace[0].0, 0.0);
        assert_eq!(trace[10].0, 100.0);
    }

    #[test]
    fn negative_dt_is_a_strict_noop() {
        let cfg = DeviceConfig::default();
        let mut rng = Pcg64::seeded(4);
        let mut cell = Memristor::new(&cfg);
        program_cell(&mut cell, &cfg, 40e-6, &mut rng);
        age_cell(&mut cell, &cfg, 100.0, &mut rng);
        let (g0, age0) = (cell.g, cell.age_s);
        for bad in [-1.0, -1e9, f64::NEG_INFINITY, f64::NAN, 0.0, -0.0] {
            age_cell(&mut cell, &cfg, bad, &mut rng);
            assert_eq!(cell.g, g0, "g mutated by dt={bad}");
            assert_eq!(cell.age_s, age0, "age mutated by dt={bad}");
        }
        // Unhealthy branch: same contract.
        cell.stuck = Some(crate::device::taox::StuckMode::StuckOff);
        age_cell(&mut cell, &cfg, -5.0, &mut rng);
        assert_eq!(cell.age_s, age0, "stuck-cell age mutated by dt<0");
    }

    #[test]
    fn stuck_cells_do_not_drift() {
        let cfg = DeviceConfig::default();
        let mut rng = Pcg64::seeded(3);
        let mut cell = Memristor::new(&cfg);
        cell.stuck = Some(crate::device::taox::StuckMode::StuckOn);
        let g0 = cell.conductance(&cfg);
        age_cell(&mut cell, &cfg, 1e6, &mut rng);
        assert_eq!(cell.conductance(&cfg), g0);
    }
}
