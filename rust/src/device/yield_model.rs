//! Array-level yield characterisation (Fig. 2j).
//!
//! The paper demonstrates array health by programming the letters
//! 'H', 'K', 'U' onto three 32x32 arrays and reports a 97.3 % device yield.
//! This module reproduces that experiment: render the letter bitmaps into
//! conductance targets, program a sampled (faulty-cell-containing) array,
//! and report yield + error statistics.

use crate::device::programming::{program_map, summarize, ArrayProgrammingStats};
use crate::device::taox::{DeviceConfig, Memristor};
use crate::util::rng::Pcg64;

/// Array side used throughout the paper (32x32 1T1R crossbars).
pub const ARRAY_SIDE: usize = 32;

/// 8x8 letter bitmaps, scaled up to 32x32 by 4x nearest-neighbour.
/// 1 bits program to the top of the window, 0 bits to the bottom.
const LETTERS: [(&str, [u8; 8]); 3] = [
    ("H", [0b10000001, 0b10000001, 0b10000001, 0b11111111, 0b11111111, 0b10000001, 0b10000001, 0b10000001]),
    ("K", [0b10000110, 0b10001100, 0b10011000, 0b11110000, 0b11110000, 0b10011000, 0b10001100, 0b10000110]),
    ("U", [0b10000001, 0b10000001, 0b10000001, 0b10000001, 0b10000001, 0b10000001, 0b11000011, 0b01111110]),
];

/// Render a letter into a 32x32 conductance-target map.
pub fn letter_targets(letter: &str, cfg: &DeviceConfig) -> Vec<f64> {
    let bits = LETTERS
        .iter()
        .find(|(n, _)| *n == letter)
        .unwrap_or_else(|| panic!("unknown letter {letter} (H, K or U)"))
        .1;
    let hi = 0.9 * cfg.g_max;
    let lo = 1.1 * cfg.g_min;
    let mut out = vec![lo; ARRAY_SIDE * ARRAY_SIDE];
    for r in 0..ARRAY_SIDE {
        for c in 0..ARRAY_SIDE {
            let bit = (bits[r / 4] >> (7 - c / 4)) & 1;
            if bit == 1 {
                out[r * ARRAY_SIDE + c] = hi;
            }
        }
    }
    out
}

/// Result of programming one letter onto a fresh sampled array.
#[derive(Debug, Clone)]
pub struct LetterExperiment {
    pub letter: String,
    pub stats: ArrayProgrammingStats,
    /// Post-programming conductance map (row-major 32x32), for rendering.
    pub g_map: Vec<f64>,
}

/// Run the Fig. 2j experiment for one letter.
pub fn program_letter(
    letter: &str,
    cfg: &DeviceConfig,
    rng: &mut Pcg64,
) -> LetterExperiment {
    let targets = letter_targets(letter, cfg);
    let mut cells: Vec<Memristor> = (0..targets.len())
        .map(|_| Memristor::sample(cfg, rng))
        .collect();
    let results = program_map(&mut cells, cfg, &targets, rng);
    let stats = summarize(&results);
    let g_map = cells.iter().map(|c| c.conductance(cfg)).collect();
    LetterExperiment { letter: letter.to_string(), stats, g_map }
}

/// Run all three letters (the full Fig. 2j/2k experiment); returns the
/// per-letter experiments and the pooled yield fraction.
pub fn run_letters_experiment(
    cfg: &DeviceConfig,
    seed: u64,
) -> (Vec<LetterExperiment>, f64) {
    let mut rng = Pcg64::seeded(seed);
    let exps: Vec<LetterExperiment> = ["H", "K", "U"]
        .iter()
        .map(|l| program_letter(l, cfg, &mut rng))
        .collect();
    let pooled =
        exps.iter().map(|e| e.stats.yield_frac).sum::<f64>() / exps.len() as f64;
    (exps, pooled)
}

/// ASCII rendering of a conductance map (for the CLI characterize command).
pub fn render_map(g_map: &[f64], cfg: &DeviceConfig) -> String {
    let mid = 0.5 * (cfg.g_min + cfg.g_max);
    let mut s = String::with_capacity(ARRAY_SIDE * (ARRAY_SIDE + 1));
    for r in 0..ARRAY_SIDE {
        for c in 0..ARRAY_SIDE {
            s.push(if g_map[r * ARRAY_SIDE + c] > mid { '#' } else { '.' });
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn letter_targets_are_binary_maps() {
        let cfg = DeviceConfig::default();
        for l in ["H", "K", "U"] {
            let t = letter_targets(l, &cfg);
            assert_eq!(t.len(), 1024);
            let hi = t.iter().filter(|&&g| g > 50e-6).count();
            assert!(hi > 100 && hi < 900, "letter {l} density {hi}");
        }
    }

    #[test]
    #[should_panic(expected = "unknown letter")]
    fn unknown_letter_panics() {
        let _ = letter_targets("Z", &DeviceConfig::default());
    }

    #[test]
    fn yield_close_to_paper_value() {
        let cfg = DeviceConfig::default();
        let (_, pooled) = run_letters_experiment(&cfg, 42);
        // 3 x 1024 devices at 97.3 % expected yield; allow sampling slack.
        assert!(
            (pooled - 0.973).abs() < 0.02,
            "pooled yield {pooled} far from 97.3 %"
        );
    }

    #[test]
    fn error_variance_order_of_magnitude() {
        // Fig. 2k: variance of the percentage programming error ~ 4.36.
        let cfg = DeviceConfig::default();
        let (exps, _) = run_letters_experiment(&cfg, 7);
        for e in &exps {
            assert!(
                e.stats.var_rel_error_pct > 0.1
                    && e.stats.var_rel_error_pct < 20.0,
                "letter {} var {}",
                e.letter,
                e.stats.var_rel_error_pct
            );
        }
    }

    #[test]
    fn render_shows_letter_shape() {
        let cfg = DeviceConfig { fault_rate: 0.0, ..Default::default() };
        let mut rng = Pcg64::seeded(1);
        let exp = program_letter("H", &cfg, &mut rng);
        let art = render_map(&exp.g_map, &cfg);
        // The H crossbar row (rows 12-19) must be mostly filled.
        let line: &str = art.lines().nth(14).unwrap();
        let filled = line.chars().filter(|&c| c == '#').count();
        assert!(filled >= 28, "crossbar row only {filled} filled:\n{art}");
    }
}
