//! The analogue TaOx memristor cell.
//!
//! Calibrated to the statistics the paper reports for its 180 nm
//! TiN/TaOx/Ta2O5/TiN devices:
//!
//! * usable conductance window ~2-100 µS with **> 64 distinct states**
//!   (6-bit analogue programming, Fig. 2h);
//! * relative programming error with **variance ≈ 4.36 %** after
//!   write-verify (Fig. 2k), modelled lognormal (multiplicative);
//! * stable retention over > 1e5 s with a slow diffusive drift (Fig. 2i);
//! * array yield ≈ **97.3 %** with stuck-at faults (Fig. 2j).

use crate::util::rng::Pcg64;

/// Physical/operating parameters of one device family.
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    /// Minimum programmable conductance (S).
    pub g_min: f64,
    /// Maximum programmable conductance (S).
    pub g_max: f64,
    /// Number of programmable levels (paper: 6-bit => 64).
    pub levels: u32,
    /// Std-dev of the *relative* error of a single programming pulse
    /// (before write-verify; the verify loop tightens the final error).
    pub pulse_sigma: f64,
    /// Write-verify acceptance band, relative (|g - target| / target).
    pub verify_tol: f64,
    /// Maximum write-verify iterations before giving up.
    pub max_verify_iters: u32,
    /// Std-dev of the relative read noise (thermal + 1/f lumped), per read.
    pub read_noise: f64,
    /// Retention drift coefficient: per-decade relative drift scale.
    pub drift_nu: f64,
    /// Probability a device is faulty (1 - yield).
    pub fault_rate: f64,
    /// Read voltage used for characterisation (V). Paper: 0.2 V.
    pub v_read: f64,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        Self {
            g_min: 2e-6,
            g_max: 100e-6,
            levels: 64,
            pulse_sigma: 0.08,
            verify_tol: 0.033,
            max_verify_iters: 32,
            read_noise: 0.01,
            drift_nu: 0.004,
            fault_rate: 0.027, // 97.3 % yield
            v_read: 0.2,
        }
    }
}

impl DeviceConfig {
    /// Conductance of level `k` (0-based, linearly spaced levels).
    pub fn level_conductance(&self, k: u32) -> f64 {
        assert!(k < self.levels);
        let t = k as f64 / (self.levels - 1) as f64;
        self.g_min + t * (self.g_max - self.g_min)
    }

    /// Nearest programmable level for a target conductance.
    pub fn nearest_level(&self, g: f64) -> u32 {
        let t = (g - self.g_min) / (self.g_max - self.g_min);
        (t * (self.levels - 1) as f64)
            .round()
            .clamp(0.0, (self.levels - 1) as f64) as u32
    }

    /// Clamp a conductance into the programmable window.
    pub fn clamp_g(&self, g: f64) -> f64 {
        g.clamp(self.g_min, self.g_max)
    }
}

/// Fault modes observed at array level (Fig. 2j analysis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StuckMode {
    /// Forming failure: permanently high-resistance (reads ~g_min).
    StuckOff,
    /// Breakdown: permanently low-resistance (reads ~g_max).
    StuckOn,
}

/// One 1T1R analogue memristor cell.
#[derive(Debug, Clone)]
pub struct Memristor {
    /// Programmed (post-verify) conductance, S.
    pub g: f64,
    /// Conductance the programming loop aimed for, S.
    pub g_target: f64,
    /// Fault state, if any.
    pub stuck: Option<StuckMode>,
    /// Accumulated retention time since programming, s.
    pub age_s: f64,
}

impl Memristor {
    /// A fresh healthy cell at the bottom of the window.
    pub fn new(cfg: &DeviceConfig) -> Self {
        Self { g: cfg.g_min, g_target: cfg.g_min, stuck: None, age_s: 0.0 }
    }

    /// Sample a possibly-faulty cell per the yield model.
    pub fn sample(cfg: &DeviceConfig, rng: &mut Pcg64) -> Self {
        let mut m = Self::new(cfg);
        if rng.chance(cfg.fault_rate) {
            // Forming failures dominate breakdowns ~3:1 in TaOx arrays.
            m.stuck = Some(if rng.chance(0.75) {
                StuckMode::StuckOff
            } else {
                StuckMode::StuckOn
            });
        }
        m
    }

    /// Effective conductance including fault state (no read noise).
    pub fn conductance(&self, cfg: &DeviceConfig) -> f64 {
        match self.stuck {
            Some(StuckMode::StuckOff) => cfg.g_min,
            Some(StuckMode::StuckOn) => cfg.g_max,
            None => self.g,
        }
    }

    /// One noisy analogue read: returns the conductance seen by the
    /// peripheral circuit. Multiplicative Gaussian read noise models the
    /// lumped thermal/1-f noise of cell + mux + TIA input.
    pub fn read(&self, cfg: &DeviceConfig, rng: &mut Pcg64) -> f64 {
        let g = self.conductance(cfg);
        cfg.clamp_g(g * (1.0 + cfg.read_noise * rng.normal()))
    }

    /// Current drawn at bias `v`: Ohm's law (the multiply of the analogue
    /// MAC).
    pub fn current(&self, cfg: &DeviceConfig, v: f64, rng: &mut Pcg64) -> f64 {
        v * self.read(cfg, rng)
    }

    /// Whether the cell responds to programming.
    pub fn is_healthy(&self) -> bool {
        self.stuck.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_span_window_monotonically() {
        let cfg = DeviceConfig::default();
        assert_eq!(cfg.level_conductance(0), cfg.g_min);
        assert_eq!(cfg.level_conductance(cfg.levels - 1), cfg.g_max);
        let mut prev = -1.0;
        for k in 0..cfg.levels {
            let g = cfg.level_conductance(k);
            assert!(g > prev);
            prev = g;
        }
    }

    #[test]
    fn paper_claims_at_least_64_states() {
        // Fig. 2h: "more than 64 states" — the default config must provide
        // 64 *distinct* programmable conductances.
        let cfg = DeviceConfig::default();
        assert!(cfg.levels >= 64);
        let distinct: std::collections::BTreeSet<u64> = (0..cfg.levels)
            .map(|k| cfg.level_conductance(k).to_bits())
            .collect();
        assert_eq!(distinct.len() as u32, cfg.levels);
    }

    #[test]
    fn nearest_level_roundtrip() {
        let cfg = DeviceConfig::default();
        for k in 0..cfg.levels {
            let g = cfg.level_conductance(k);
            assert_eq!(cfg.nearest_level(g), k);
        }
        assert_eq!(cfg.nearest_level(0.0), 0);
        assert_eq!(cfg.nearest_level(1.0), cfg.levels - 1);
    }

    #[test]
    fn stuck_modes_pin_conductance() {
        let cfg = DeviceConfig::default();
        let mut m = Memristor::new(&cfg);
        m.g = 50e-6;
        m.stuck = Some(StuckMode::StuckOff);
        assert_eq!(m.conductance(&cfg), cfg.g_min);
        m.stuck = Some(StuckMode::StuckOn);
        assert_eq!(m.conductance(&cfg), cfg.g_max);
    }

    #[test]
    fn read_noise_statistics() {
        let cfg = DeviceConfig::default();
        let mut m = Memristor::new(&cfg);
        m.g = 50e-6;
        let mut rng = Pcg64::seeded(1);
        let reads: Vec<f64> =
            (0..20_000).map(|_| m.read(&cfg, &mut rng)).collect();
        let s = crate::util::stats::summary(&reads);
        assert!((s.mean / 50e-6 - 1.0).abs() < 0.005, "mean={}", s.mean);
        let rel_std = s.std / s.mean;
        assert!((rel_std - cfg.read_noise).abs() < 0.002, "std={rel_std}");
    }

    #[test]
    fn read_stays_in_window() {
        let cfg = DeviceConfig { read_noise: 0.5, ..Default::default() };
        let mut m = Memristor::new(&cfg);
        m.g = cfg.g_max;
        let mut rng = Pcg64::seeded(2);
        for _ in 0..1000 {
            let g = m.read(&cfg, &mut rng);
            assert!(g >= cfg.g_min && g <= cfg.g_max);
        }
    }

    #[test]
    fn yield_sampling_close_to_configured_rate() {
        let cfg = DeviceConfig::default();
        let mut rng = Pcg64::seeded(3);
        let n = 100_000;
        let faulty = (0..n)
            .filter(|_| !Memristor::sample(&cfg, &mut rng).is_healthy())
            .count();
        let rate = faulty as f64 / n as f64;
        assert!((rate - cfg.fault_rate).abs() < 0.002, "rate={rate}");
    }

    #[test]
    fn ohms_law_current() {
        let cfg = DeviceConfig { read_noise: 0.0, ..Default::default() };
        let mut m = Memristor::new(&cfg);
        m.g = 10e-6;
        let mut rng = Pcg64::seeded(4);
        let i = m.current(&cfg, 0.2, &mut rng);
        assert!((i - 2e-6).abs() < 1e-12);
    }
}
