//! Write-verify programming of analogue conductances.
//!
//! Models the paper's B1500A-driven programming scheme (Methods,
//! Supplementary Fig. 3): iterative SET/RESET pulses nudge the cell toward
//! the target conductance; each pulse lands with lognormal multiplicative
//! error; the loop stops when the read-back value is within the verify
//! tolerance or the iteration budget is exhausted.
//!
//! The resulting *relative programming error* distribution is what Fig. 2k
//! reports (variance 4.36 % across responsive devices, < 2.2 % mean error in
//! the 20-100 µS band of Fig. 3e).

use crate::device::taox::{DeviceConfig, Memristor};
use crate::util::rng::Pcg64;

/// Outcome of programming one cell.
#[derive(Debug, Clone, Copy)]
pub struct ProgrammingResult {
    /// Verify-loop iterations used.
    pub iters: u32,
    /// Relative error |g - target| / target after programming.
    pub rel_error: f64,
    /// Whether the verify tolerance was met (false for stuck cells or
    /// budget exhaustion).
    pub converged: bool,
}

/// Program `cell` toward `g_target` (S) with write-verify.
///
/// Stuck cells do not respond; the result reports `converged = false` and
/// the error against whatever the fault pins them to.
pub fn program_cell(
    cell: &mut Memristor,
    cfg: &DeviceConfig,
    g_target: f64,
    rng: &mut Pcg64,
) -> ProgrammingResult {
    let g_target = cfg.clamp_g(g_target);
    cell.g_target = g_target;
    cell.age_s = 0.0;

    if !cell.is_healthy() {
        let g = cell.conductance(cfg);
        return ProgrammingResult {
            iters: 0,
            rel_error: (g - g_target).abs() / g_target,
            converged: false,
        };
    }

    let mut iters = 0;
    loop {
        iters += 1;
        // One programming pulse: move to the target with lognormal
        // multiplicative error (pulse-to-pulse variation of the filament).
        let sigma = cfg.pulse_sigma;
        // exp(N(-sigma^2/2, sigma)) has mean 1 — unbiased pulses.
        let mult = rng.lognormal(-0.5 * sigma * sigma, sigma);
        cell.g = cfg.clamp_g(g_target * mult);

        // Verify read (the read itself is noisy).
        let seen = cell.read(cfg, rng);
        let err = (seen - g_target).abs() / g_target;
        if err <= cfg.verify_tol || iters >= cfg.max_verify_iters {
            let true_err = (cell.g - g_target).abs() / g_target;
            return ProgrammingResult {
                iters,
                rel_error: true_err,
                converged: err <= cfg.verify_tol,
            };
        }
    }
}

/// Program every cell of a target conductance map; returns per-cell results.
pub fn program_map(
    cells: &mut [Memristor],
    cfg: &DeviceConfig,
    targets: &[f64],
    rng: &mut Pcg64,
) -> Vec<ProgrammingResult> {
    assert_eq!(cells.len(), targets.len(), "map shape mismatch");
    cells
        .iter_mut()
        .zip(targets)
        .map(|(c, &g)| program_cell(c, cfg, g, rng))
        .collect()
}

/// Array-level programming statistics (the Fig. 2j/2k summary).
#[derive(Debug, Clone)]
pub struct ArrayProgrammingStats {
    /// Fraction of cells that converged (responsive yield).
    pub yield_frac: f64,
    /// Mean relative error over responsive cells.
    pub mean_rel_error: f64,
    /// Variance of the relative error over responsive cells (the paper's
    /// "4.36 % variance" metric, i.e. variance of the percentage error).
    pub var_rel_error_pct: f64,
}

/// Summarise programming results the way the paper reports them.
pub fn summarize(results: &[ProgrammingResult]) -> ArrayProgrammingStats {
    let responsive: Vec<f64> = results
        .iter()
        .filter(|r| r.converged)
        .map(|r| r.rel_error)
        .collect();
    let yield_frac = responsive.len() as f64 / results.len().max(1) as f64;
    let s = crate::util::stats::summary(&responsive);
    // The paper quotes the variance of the *percentage* programming error
    // across responsive devices (Fig. 2k: 4.36 %).
    let pct: Vec<f64> = responsive.iter().map(|e| e * 100.0).collect();
    ArrayProgrammingStats {
        yield_frac,
        mean_rel_error: s.mean,
        var_rel_error_pct: crate::util::stats::summary(&pct).var,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::taox::StuckMode;

    fn cfg() -> DeviceConfig {
        DeviceConfig::default()
    }

    #[test]
    fn programming_converges_within_tolerance() {
        let cfg = cfg();
        let mut rng = Pcg64::seeded(1);
        let mut cell = Memristor::new(&cfg);
        let r = program_cell(&mut cell, &cfg, 50e-6, &mut rng);
        assert!(r.converged);
        // The true post-programming error can exceed the verify tol only by
        // the read-noise margin.
        assert!(r.rel_error < cfg.verify_tol + 4.0 * cfg.read_noise);
    }

    #[test]
    fn stuck_cells_do_not_converge() {
        let cfg = cfg();
        let mut rng = Pcg64::seeded(2);
        let mut cell = Memristor::new(&cfg);
        cell.stuck = Some(StuckMode::StuckOn);
        let r = program_cell(&mut cell, &cfg, 10e-6, &mut rng);
        assert!(!r.converged);
        assert!(r.rel_error > 1.0); // pinned at g_max, far from 10 µS
    }

    #[test]
    fn target_is_clamped_to_window() {
        let cfg = cfg();
        let mut rng = Pcg64::seeded(3);
        let mut cell = Memristor::new(&cfg);
        program_cell(&mut cell, &cfg, 1.0, &mut rng); // 1 S, absurd
        assert!(cell.g_target <= cfg.g_max);
    }

    #[test]
    fn mean_error_matches_fig3e_band() {
        // Fig. 3e: < 2.2 % average relative error in the 20-100 µS band.
        let cfg = cfg();
        let mut rng = Pcg64::seeded(4);
        let mut errors = Vec::new();
        for k in 0..2000 {
            let g = 20e-6 + (k as f64 / 1999.0) * 80e-6;
            let mut cell = Memristor::new(&cfg);
            let r = program_cell(&mut cell, &cfg, g, &mut rng);
            if r.converged {
                errors.push(r.rel_error);
            }
        }
        let mean = crate::util::stats::summary(&errors).mean;
        assert!(mean < 0.022, "mean rel error {mean} exceeds paper's 2.2 %");
    }

    #[test]
    fn array_summary_counts_yield() {
        let cfg = cfg();
        let mut rng = Pcg64::seeded(5);
        let mut cells: Vec<Memristor> =
            (0..500).map(|_| Memristor::sample(&cfg, &mut rng)).collect();
        let targets = vec![40e-6; 500];
        let results = program_map(&mut cells, &cfg, &targets, &mut rng);
        let stats = summarize(&results);
        // Yield should be close to 1 - fault_rate (97.3 %).
        assert!((stats.yield_frac - (1.0 - cfg.fault_rate)).abs() < 0.03);
        assert!(stats.mean_rel_error < 0.03);
        assert!(stats.var_rel_error_pct > 0.0);
    }

    #[test]
    fn programming_resets_age() {
        let cfg = cfg();
        let mut rng = Pcg64::seeded(6);
        let mut cell = Memristor::new(&cfg);
        cell.age_s = 1e4;
        program_cell(&mut cell, &cfg, 30e-6, &mut rng);
        assert_eq!(cell.age_s, 0.0);
    }
}
