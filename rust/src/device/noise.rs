//! Noise sources of the analogue signal chain.
//!
//! Two families matter for the paper's robustness analysis (Fig. 4j):
//!
//! * **programming noise** — a *static* multiplicative error frozen into the
//!   conductances at deployment time (weight perturbation);
//! * **read noise** — a *dynamic* multiplicative error re-sampled on every
//!   analogue read (activation perturbation). The paper's key observation is
//!   that moderate read noise can *lower* extrapolation error, acting like
//!   stochastic regularisation of the ODE flow.

use crate::util::rng::Pcg64;

/// A configurable multiplicative-Gaussian noise source.
#[derive(Debug, Clone)]
pub struct NoiseSource {
    /// Relative standard deviation (0.02 == "2 % noise" in Fig. 4j).
    pub sigma: f64,
}

impl NoiseSource {
    pub fn new(sigma: f64) -> Self {
        assert!(sigma >= 0.0, "noise sigma must be non-negative");
        Self { sigma }
    }

    /// The zero-noise source.
    pub fn off() -> Self {
        Self { sigma: 0.0 }
    }

    pub fn is_off(&self) -> bool {
        self.sigma == 0.0
    }

    /// Apply to a scalar: x * (1 + sigma * N(0,1)).
    #[inline]
    pub fn apply(&self, x: f64, rng: &mut Pcg64) -> f64 {
        if self.sigma == 0.0 {
            x
        } else {
            x * (1.0 + self.sigma * rng.normal())
        }
    }

    /// Apply element-wise in place.
    pub fn apply_slice(&self, xs: &mut [f64], rng: &mut Pcg64) {
        if self.sigma == 0.0 {
            return;
        }
        for x in xs {
            *x *= 1.0 + self.sigma * rng.normal();
        }
    }
}

/// The paper's Fig. 4j grid axes: read-noise and programming-noise levels
/// swept jointly (values are relative sigmas).
pub const FIG4J_READ_LEVELS: [f64; 4] = [0.0, 0.01, 0.02, 0.05];
pub const FIG4J_PROG_LEVELS: [f64; 4] = [0.0, 0.01, 0.02, 0.05];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn off_is_identity() {
        let mut rng = Pcg64::seeded(1);
        let n = NoiseSource::off();
        assert_eq!(n.apply(3.5, &mut rng), 3.5);
        let mut xs = vec![1.0, -2.0];
        n.apply_slice(&mut xs, &mut rng);
        assert_eq!(xs, vec![1.0, -2.0]);
    }

    #[test]
    fn sigma_controls_spread() {
        let mut rng = Pcg64::seeded(2);
        let n = NoiseSource::new(0.05);
        let samples: Vec<f64> =
            (0..50_000).map(|_| n.apply(1.0, &mut rng)).collect();
        let s = stats::summary(&samples);
        assert!((s.mean - 1.0).abs() < 0.002);
        assert!((s.std - 0.05).abs() < 0.003);
    }

    #[test]
    fn slice_application_matches_scalar_distribution() {
        let mut rng = Pcg64::seeded(3);
        let n = NoiseSource::new(0.1);
        let mut xs = vec![2.0; 50_000];
        n.apply_slice(&mut xs, &mut rng);
        let s = stats::summary(&xs);
        assert!((s.mean - 2.0).abs() < 0.01);
        assert!((s.std - 0.2).abs() < 0.01);
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn negative_sigma_rejected() {
        let _ = NoiseSource::new(-0.1);
    }
}
