//! Workload generators for the paper's two evaluation tasks.
//!
//! * [`stimuli`]  — the four Fig. 3 stimulation waveforms (sine, triangular,
//!   rectangular, modulated sine)
//! * [`lorenz96`] — the Lorenz96 atmospheric dynamics of Fig. 4 (ground
//!   truth generator + maximal-Lyapunov-exponent estimator)

pub mod lorenz96;
pub mod stimuli;
