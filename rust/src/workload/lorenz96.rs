//! Lorenz96 atmospheric dynamics (Eq. 4) — the Fig. 4 evaluation workload.
//!
//! Ground-truth generator (RK4 at sub-sample resolution), the paper's exact
//! initial condition and split (1800 interpolation / 600 extrapolation
//! samples at dt = 0.02 s), and a Benettin estimator for the maximal
//! Lyapunov exponent used to express extrapolation horizons in Lyapunov
//! times. Constants mirror `python/compile/datasets.py`.

use crate::util::rng::Pcg64;

/// State dimension of the paper's twin.
pub const DIM: usize = 6;
/// Canonical forcing (chaotic regime for n >= 5).
pub const FORCING: f64 = 8.0;
/// Sample interval (s): 2400 samples span the 48 s window of Fig. 4.
pub const DT: f64 = 0.02;
/// Total sequence length.
pub const N_POINTS: usize = 2400;
/// Interpolation (training) split.
pub const TRAIN_POINTS: usize = 1800;
/// State normalisation scale. The paper's quoted initial condition spans
/// ~[-1.6, 1.2] while the F = 8 attractor spans ~[-8, 13]: the paper's
/// twin (and its L1 error figures) live in *normalized* units, physical
/// state / SCALE. All twins and metrics here follow that convention; the
/// physical trajectory is SCALE * normalized.
pub const SCALE: f64 = 8.0;
/// The paper's quoted initial condition (normalized units).
pub const Y0: [f64; DIM] =
    [-1.2061, 0.0617, 1.1632, -1.5008, -1.5944, -0.0187];

/// Canonical initial condition for a `dim`-dimensional system (normalized
/// units). The paper's quoted [`Y0`] is kept verbatim for the 6-dim twin;
/// wider twins (tile-sharded states, d = 64/128) get a deterministic
/// bounded perturbation of the rest state — the classic "x_i = F with one
/// site nudged" recipe, expressed in normalized units.
pub fn default_y0(dim: usize) -> Vec<f64> {
    if dim == DIM {
        return Y0.to_vec();
    }
    (0..dim)
        .map(|i| 1.0 + 0.25 * ((i as f64) * 0.73).sin())
        .collect()
}

/// Eq. (4) vector field with periodic boundary: out[i] =
/// (x[i+1] - x[i-2]) * x[i-1] - x[i] + F.
pub fn field_into(x: &[f64], forcing: f64, out: &mut [f64]) {
    let n = x.len();
    debug_assert!(n > 3, "Lorenz96 needs n > 3");
    debug_assert_eq!(out.len(), n);
    for i in 0..n {
        let ip1 = x[(i + 1) % n];
        let im1 = x[(i + n - 1) % n];
        let im2 = x[(i + n - 2) % n];
        out[i] = (ip1 - im2) * im1 - x[i] + forcing;
    }
}

/// Allocating wrapper for [`field_into`].
pub fn field(x: &[f64], forcing: f64) -> Vec<f64> {
    let mut out = vec![0.0; x.len()];
    field_into(x, forcing, &mut out);
    out
}

/// One RK4 step of the ground truth (allocation-light; scratch reused).
fn rk4_step(x: &mut [f64], forcing: f64, dt: f64, scratch: &mut Scratch) {
    let n = x.len();
    let Scratch { k1, k2, k3, k4, tmp } = scratch;
    field_into(x, forcing, k1);
    for i in 0..n {
        tmp[i] = x[i] + 0.5 * dt * k1[i];
    }
    field_into(tmp, forcing, k2);
    for i in 0..n {
        tmp[i] = x[i] + 0.5 * dt * k2[i];
    }
    field_into(tmp, forcing, k3);
    for i in 0..n {
        tmp[i] = x[i] + dt * k3[i];
    }
    field_into(tmp, forcing, k4);
    for i in 0..n {
        x[i] += dt / 6.0 * (k1[i] + 2.0 * k2[i] + 2.0 * k3[i] + k4[i]);
    }
}

struct Scratch {
    k1: Vec<f64>,
    k2: Vec<f64>,
    k3: Vec<f64>,
    k4: Vec<f64>,
    tmp: Vec<f64>,
}

impl Scratch {
    fn new(n: usize) -> Self {
        Self {
            k1: vec![0.0; n],
            k2: vec![0.0; n],
            k3: vec![0.0; n],
            k4: vec![0.0; n],
            tmp: vec![0.0; n],
        }
    }
}

/// Integrate from `x0`, emitting `n_points` samples spaced `dt`, with
/// `substeps` RK4 sub-intervals per sample. Returns row-major
/// `[n_points][dim]`.
pub fn simulate(
    x0: &[f64],
    n_points: usize,
    dt: f64,
    forcing: f64,
    substeps: usize,
) -> Vec<Vec<f64>> {
    let mut x = x0.to_vec();
    let mut scratch = Scratch::new(x.len());
    let hd = dt / substeps as f64;
    let mut out = Vec::with_capacity(n_points);
    out.push(x.clone());
    for _ in 1..n_points {
        for _ in 0..substeps {
            rk4_step(&mut x, forcing, hd, &mut scratch);
        }
        out.push(x.clone());
    }
    out
}

/// Paper-default trajectory in *physical* units: starts from SCALE * Y0.
pub fn simulate_default() -> Vec<Vec<f64>> {
    let y0: Vec<f64> = Y0.iter().map(|&v| v * SCALE).collect();
    simulate(&y0, N_POINTS, DT, FORCING, 4)
}

/// Paper-convention trajectory in *normalized* units (the space the twins,
/// the training data and every Fig. 4 error metric live in).
pub fn simulate_normalized(n_points: usize) -> Vec<Vec<f64>> {
    let y0: Vec<f64> = Y0.iter().map(|&v| v * SCALE).collect();
    simulate(&y0, n_points, DT, FORCING, 4)
        .into_iter()
        .map(|row| row.into_iter().map(|v| v / SCALE).collect())
        .collect()
}

/// Normalized-space vector field: d(x/S)/dt = f(S x_n) / S.
pub fn field_normalized(xn: &[f64], forcing: f64) -> Vec<f64> {
    let phys: Vec<f64> = xn.iter().map(|&v| v * SCALE).collect();
    field(&phys, forcing).into_iter().map(|v| v / SCALE).collect()
}

/// Benettin estimate of the maximal Lyapunov exponent (Methods Eq. 10).
pub fn max_lyapunov_exponent(forcing: f64, dim: usize, seed: u64) -> f64 {
    let mut rng = Pcg64::seeded(seed);
    let mut x = Y0[..dim.min(DIM)].to_vec();
    x.resize(dim, 0.1);
    let d0 = 1e-8;
    let mut y: Vec<f64> = x
        .iter()
        .map(|&v| v + d0 * rng.normal() / (dim as f64).sqrt())
        .collect();
    let dt = 0.01;
    let (n_steps, warmup) = (20_000, 2_000);
    let mut scratch = Scratch::new(dim);
    let mut acc = 0.0;
    for k in 0..n_steps {
        rk4_step(&mut x, forcing, dt, &mut scratch);
        rk4_step(&mut y, forcing, dt, &mut scratch);
        let d = x
            .iter()
            .zip(&y)
            .map(|(&a, &b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        if k >= warmup {
            acc += (d / d0).ln();
        }
        // Renormalise the perturbation back to d0.
        for (yv, &xv) in y.iter_mut().zip(&x) {
            *yv = xv + (*yv - xv) * (d0 / d);
        }
    }
    acc / ((n_steps - warmup) as f64 * dt)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_matches_hand_computation() {
        // n = 4, x = [1, 2, 3, 4], F = 0 (indices mod 4):
        // i=0: (x1 - x2)*x3 - x0 = (2-3)*4 - 1 = -5
        // i=1: (x2 - x3)*x0 - x1 = (3-4)*1 - 2 = -3
        // i=2: (x3 - x0)*x1 - x2 = (4-1)*2 - 3 =  3
        // i=3: (x0 - x1)*x2 - x3 = (1-2)*3 - 4 = -7
        let out = field(&[1.0, 2.0, 3.0, 4.0], 0.0);
        assert_eq!(out, vec![-5.0, -3.0, 3.0, -7.0]);
    }

    #[test]
    fn fixed_point_all_equal_f() {
        // x_i = F for all i is an equilibrium of Eq. (4).
        let x = vec![FORCING; DIM];
        let out = field(&x, FORCING);
        assert!(out.iter().all(|&v| v.abs() < 1e-12));
    }

    #[test]
    fn trajectory_shapes_and_start() {
        let traj = simulate_default();
        assert_eq!(traj.len(), N_POINTS);
        let y0: Vec<f64> = Y0.iter().map(|&v| v * SCALE).collect();
        assert_eq!(traj[0], y0);
        assert_eq!(traj[0].len(), DIM);
    }

    #[test]
    fn normalized_trajectory_starts_at_paper_y0() {
        let traj = simulate_normalized(50);
        for (a, b) in traj[0].iter().zip(Y0.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
        // Normalized attractor stays O(1.6).
        for row in &traj {
            for &v in row {
                assert!(v.abs() < 3.0, "normalized state {v}");
            }
        }
    }

    #[test]
    fn normalized_field_consistent_with_physical() {
        let xn = [0.5, -0.25, 1.0, 0.1, -0.9, 0.3];
        let fn_ = field_normalized(&xn, FORCING);
        let phys: Vec<f64> = xn.iter().map(|&v| v * SCALE).collect();
        let fp = field(&phys, FORCING);
        for (a, b) in fn_.iter().zip(&fp) {
            assert!((a * SCALE - b).abs() < 1e-12);
        }
    }

    #[test]
    fn trajectory_stays_bounded() {
        // Lorenz96 at F = 8 lives on a bounded attractor (|x| < ~20).
        let traj = simulate_default();
        for row in &traj {
            for &v in row {
                assert!(v.abs() < 25.0, "unbounded state {v}");
            }
        }
    }

    #[test]
    fn substeps_converge() {
        // Doubling substeps should change the result only slightly over a
        // short horizon (RK4 is 4th order).
        let a = simulate(&Y0, 50, DT, FORCING, 2);
        let b = simulate(&Y0, 50, DT, FORCING, 8);
        let d: f64 = a[49]
            .iter()
            .zip(&b[49])
            .map(|(&x, &y)| (x - y).abs())
            .fold(0.0, f64::max);
        assert!(d < 1e-4, "integrator not converged: {d}");
    }

    #[test]
    fn sensitive_dependence_on_initial_conditions() {
        let mut y0b = Y0.to_vec();
        y0b[0] += 1e-6;
        let a = simulate(&Y0, N_POINTS, DT, FORCING, 4);
        let b = simulate(&y0b, N_POINTS, DT, FORCING, 4);
        let d_end: f64 = a[N_POINTS - 1]
            .iter()
            .zip(&b[N_POINTS - 1])
            .map(|(&x, &y)| (x - y).abs())
            .sum();
        assert!(d_end > 0.1, "chaos missing: divergence {d_end}");
    }

    #[test]
    fn mle_positive_and_sane() {
        let mle = max_lyapunov_exponent(FORCING, DIM, 0);
        // d=6, F=8 Lorenz96 has a positive MLE of order 1 per time unit.
        assert!(mle > 0.2 && mle < 3.0, "MLE {mle} implausible");
    }

    #[test]
    fn splits_cover_whole_sequence() {
        assert_eq!(TRAIN_POINTS + 600, N_POINTS);
        // 36 s interpolation + 12 s extrapolation at 0.02 s.
        assert!((TRAIN_POINTS as f64 * DT - 36.0).abs() < 1e-9);
        assert!(((N_POINTS - TRAIN_POINTS) as f64 * DT - 12.0).abs() < 1e-9);
    }
}
