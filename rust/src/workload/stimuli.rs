//! Stimulation waveforms (Fig. 3f/j: sine, triangular, rectangular and
//! amplitude-modulated sine).
//!
//! Definitions match `python/compile/datasets.py` bit-for-bit so that the
//! Rust evaluation harness drives the twin with exactly the signals the
//! Python pipeline trained against.

/// A periodic stimulation waveform.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Waveform {
    /// amp * sin(2π f t + phase)
    Sine { amp: f64, freq: f64, phase: f64 },
    /// Symmetric triangle between ±amp.
    Triangular { amp: f64, freq: f64 },
    /// ±amp square wave with duty cycle.
    Rectangular { amp: f64, freq: f64, duty: f64 },
    /// Sine with raised-sine amplitude envelope.
    ModulatedSine { amp: f64, freq: f64, mod_freq: f64 },
}

impl Waveform {
    pub fn sine(amp: f64, freq: f64) -> Self {
        Waveform::Sine { amp, freq, phase: 0.0 }
    }

    pub fn triangular(amp: f64, freq: f64) -> Self {
        Waveform::Triangular { amp, freq }
    }

    pub fn rectangular(amp: f64, freq: f64) -> Self {
        Waveform::Rectangular { amp, freq, duty: 0.5 }
    }

    pub fn modulated(amp: f64, freq: f64, mod_freq: f64) -> Self {
        Waveform::ModulatedSine { amp, freq, mod_freq }
    }

    /// The paper's four test stimuli at the default amplitude/frequency.
    pub fn paper_set() -> Vec<(&'static str, Waveform)> {
        vec![
            ("sine", Waveform::sine(1.0, 4.0)),
            ("triangular", Waveform::triangular(1.0, 4.0)),
            ("rectangular", Waveform::rectangular(1.0, 4.0)),
            ("modulated", Waveform::modulated(1.0, 4.0, 1.0)),
        ]
    }

    /// Evaluate the waveform at time `t` (seconds).
    pub fn eval(&self, t: f64) -> f64 {
        match *self {
            Waveform::Sine { amp, freq, phase } => {
                amp * (2.0 * std::f64::consts::PI * freq * t + phase).sin()
            }
            Waveform::Triangular { amp, freq } => {
                let ph = (t * freq).rem_euclid(1.0);
                amp * (4.0 * (ph - 0.5).abs() - 1.0)
            }
            Waveform::Rectangular { amp, freq, duty } => {
                let ph = (t * freq).rem_euclid(1.0);
                if ph < duty {
                    amp
                } else {
                    -amp
                }
            }
            Waveform::ModulatedSine { amp, freq, mod_freq } => {
                let envelope = 0.5
                    * (1.0
                        + (2.0 * std::f64::consts::PI * mod_freq * t).sin());
                amp * envelope
                    * (2.0 * std::f64::consts::PI * freq * t).sin()
            }
        }
    }

    /// Sample at `n` points spaced `dt` starting from t = 0.
    pub fn sample(&self, n: usize, dt: f64) -> Vec<f64> {
        (0..n).map(|k| self.eval(k as f64 * dt)).collect()
    }

    /// Sample at half-step resolution: `2*(n-1)+1` points spaced `dt/2`.
    /// This is the resolution the RK4 rollout artifacts consume (value at
    /// t, t+dt/2, t+dt for every step).
    pub fn sample_half_steps(&self, n: usize, dt: f64) -> Vec<f64> {
        (0..2 * (n - 1) + 1).map(|k| self.eval(k as f64 * dt / 2.0)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sine_basic_values() {
        let w = Waveform::sine(2.0, 1.0);
        assert!((w.eval(0.0)).abs() < 1e-12);
        assert!((w.eval(0.25) - 2.0).abs() < 1e-12);
        assert!((w.eval(0.5)).abs() < 1e-10);
    }

    #[test]
    fn triangle_peaks_and_zeros() {
        let w = Waveform::triangular(1.0, 1.0);
        assert!((w.eval(0.0) - 1.0).abs() < 1e-12); // phase 0 is a peak
        assert!((w.eval(0.5) + 1.0).abs() < 1e-12); // mid-period trough
        assert!((w.eval(0.25)).abs() < 1e-12);
    }

    #[test]
    fn rectangle_levels_and_duty() {
        let w = Waveform::Rectangular { amp: 1.0, freq: 1.0, duty: 0.25 };
        assert_eq!(w.eval(0.1), 1.0);
        assert_eq!(w.eval(0.3), -1.0);
        assert_eq!(w.eval(1.1), 1.0); // periodic
    }

    #[test]
    fn modulated_envelope_bounds() {
        let w = Waveform::modulated(1.0, 4.0, 1.0);
        for k in 0..1000 {
            let v = w.eval(k as f64 * 1e-3);
            assert!(v.abs() <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn all_waveforms_bounded_by_amp() {
        for (_, w) in Waveform::paper_set() {
            for k in 0..5000 {
                assert!(w.eval(k as f64 * 1e-4).abs() <= 1.0 + 1e-12);
            }
        }
    }

    #[test]
    fn negative_time_is_periodic_not_nan() {
        let w = Waveform::triangular(1.0, 4.0);
        assert!((w.eval(-0.25) - w.eval(0.0)).abs() < 1e-9);
    }

    #[test]
    fn half_step_sampling_interleaves() {
        let w = Waveform::sine(1.0, 4.0);
        let full = w.sample(10, 1e-3);
        let half = w.sample_half_steps(10, 1e-3);
        assert_eq!(half.len(), 19);
        for k in 0..10 {
            assert_eq!(half[2 * k], full[k]);
        }
    }
}
