//! Bench-regression gate: compare the fresh smoke
//! `BENCH_batch_throughput.json` against the committed
//! `BENCH_baseline.json` and fail (exit 1) if any tracked route's
//! ns/trajectory-step regressed by more than the allowance (default 25%)
//! after normalising out uniform machine-speed differences — see
//! [`memode::twin::throughput::gate_against_baseline`] for the exact rule.
//!
//! Usage:
//!   bench_gate [--serve] [--baseline PATH] [--fresh PATH]
//!              [--max-regress FRAC] [--update] [--ratchet]
//!              [--allow-unseeded] [--assert-speedup ROUTE:FACTOR]
//!
//! `--serve` switches to the serving-latency gate: compare the fresh
//! `BENCH_serve.json` (a flat loadgen report) against the committed
//! `BENCH_serve_baseline.json` under
//! [`memode::coordinator::loadgen::gate_serve_against_baseline`] —
//! p99 latency and throughput may not regress past the allowance and
//! the rejected fraction may not grow past it. `--ratchet` /
//! `--update` / `--allow-unseeded` behave exactly as in the
//! batch-throughput mode. No machine-speed normalisation is applied,
//! so CI passes a wider `--max-regress` here.
//!
//! An unseeded (missing/empty) baseline is a **hard failure**: a gate
//! that protects nothing must never look green. `--allow-unseeded`
//! restores the old vacuous pass for the bootstrap window only (CI's
//! seed job on the main branch closes it by committing a seeded
//! baseline).
//!
//! `--update` copies the fresh document over the baseline
//! unconditionally (manual seed/refresh on a quiet machine).
//!
//! `--ratchet` is the CI self-maintenance mode: seed the baseline when
//! unseeded; rewrite it when the fresh run *improved* beyond the
//! allowance (so future regressions are measured from the new, faster
//! level); fail — without touching the baseline — on a regression.
//!
//! `--assert-speedup ROUTE:FACTOR` (repeatable) switches to the in-job
//! comparison mode: assert the fresh document's batched
//! ns/trajectory-step on ROUTE improved by at least FACTOR over the
//! baseline document at the largest common batch size. No machine-speed
//! normalisation is applied — this mode expects baseline and fresh to
//! come from the *same machine* (e.g. a forced-scalar
//! `MEMODE_KERNEL=scalar` run vs an auto run), where normalisation
//! would cancel exactly the kernel-level speedup being asserted. The
//! regression gate does not run in this mode.

use std::path::PathBuf;
use std::process::ExitCode;

use memode::coordinator::loadgen;
use memode::twin::throughput::{
    default_baseline_path, default_json_path, gate_against_baseline,
    route_speedup,
};
use memode::util::json::{self, Json};

struct Args {
    /// `None` = mode default (throughput vs serve paths).
    baseline_override: Option<PathBuf>,
    fresh_override: Option<PathBuf>,
    baseline: PathBuf,
    fresh: PathBuf,
    max_regress: f64,
    update: bool,
    ratchet: bool,
    allow_unseeded: bool,
    serve: bool,
    /// (route, min factor) assertions from --assert-speedup.
    speedups: Vec<(String, f64)>,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        baseline_override: None,
        fresh_override: None,
        baseline: default_baseline_path(),
        fresh: default_json_path(),
        max_regress: 0.25,
        update: false,
        ratchet: false,
        allow_unseeded: false,
        serve: false,
        speedups: Vec::new(),
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--baseline" => {
                args.baseline_override = Some(
                    it.next().ok_or("--baseline needs a path")?.into(),
                );
            }
            "--fresh" => {
                args.fresh_override =
                    Some(it.next().ok_or("--fresh needs a path")?.into());
            }
            "--max-regress" => {
                let v = it.next().ok_or("--max-regress needs a fraction")?;
                args.max_regress = v
                    .parse::<f64>()
                    .map_err(|e| format!("--max-regress {v}: {e}"))?;
            }
            "--update" => args.update = true,
            "--ratchet" => args.ratchet = true,
            "--allow-unseeded" => args.allow_unseeded = true,
            "--serve" => args.serve = true,
            "--assert-speedup" => {
                let v = it
                    .next()
                    .ok_or("--assert-speedup needs ROUTE:FACTOR")?;
                let (route, factor) = v
                    .rsplit_once(':')
                    .ok_or_else(|| {
                        format!("--assert-speedup {v}: expected ROUTE:FACTOR")
                    })?;
                let factor = factor.parse::<f64>().map_err(|e| {
                    format!("--assert-speedup {v}: bad factor: {e}")
                })?;
                args.speedups.push((route.to_string(), factor));
            }
            "--help" | "-h" => {
                return Err(
                    "usage: bench_gate [--serve] [--baseline PATH] \
                     [--fresh PATH] [--max-regress FRAC] [--update] \
                     [--ratchet] [--allow-unseeded] \
                     [--assert-speedup ROUTE:FACTOR]"
                        .into(),
                );
            }
            other => return Err(format!("unknown argument '{other}'")),
        }
    }
    if args.serve {
        args.baseline = loadgen::default_baseline_path();
        args.fresh = loadgen::default_json_path();
    }
    if let Some(p) = args.baseline_override.take() {
        args.baseline = p;
    }
    if let Some(p) = args.fresh_override.take() {
        args.fresh = p;
    }
    Ok(args)
}

/// Unseeded-baseline notice. With `allow` (bootstrap window) the gate
/// exits 0 but emits a CI annotation (GitHub renders `::warning` lines on
/// the workflow summary) plus an unmissable stderr banner; without it,
/// unseeded is a hard failure — a regression gate that compares nothing
/// must never look green.
fn report_unseeded(reason: &str, allow: bool) -> ExitCode {
    let level = if allow { "warning" } else { "error" };
    println!(
        "::{level} title=bench_gate unseeded::BENCH_baseline.json is \
         unseeded ({reason}) — the bench-regression gate is NOT \
         protecting any route. Seed it on a quiet runner with `cargo \
         bench --bench batch_throughput -- --smoke && cargo run \
         --release --bin bench_gate -- --ratchet`, inspect, commit (the \
         main-branch CI job does this automatically)."
    );
    if allow {
        eprintln!(
            "bench gate: VACUOUS PASS (--allow-unseeded) — unseeded \
             baseline ({reason}); no route is protected against perf \
             regressions until a seeded BENCH_baseline.json is committed"
        );
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "bench gate: FAIL — unseeded baseline ({reason}). Seed it \
             (see above) or pass --allow-unseeded during bootstrap."
        );
        ExitCode::FAILURE
    }
}

fn load(path: &std::path::Path, what: &str) -> Result<Json, ExitCode> {
    match json::from_file(path) {
        Ok(doc) => Ok(doc),
        Err(e) => {
            eprintln!("reading {what} {}: {e:#}", path.display());
            Err(ExitCode::FAILURE)
        }
    }
}

/// `--assert-speedup` mode: same-machine baseline-vs-fresh route
/// speedups, no normalisation, no regression gate.
fn run_speedup_asserts(args: &Args) -> ExitCode {
    let baseline = match load(&args.baseline, "speedup baseline") {
        Ok(d) => d,
        Err(c) => return c,
    };
    let fresh = match load(&args.fresh, "fresh benchmark") {
        Ok(d) => d,
        Err(c) => return c,
    };
    let mut failed = false;
    for (route, factor) in &args.speedups {
        match route_speedup(&baseline, &fresh, route) {
            Ok(Some((batch, batched, serial))) => {
                let ok = batched >= *factor;
                println!(
                    "speedup {route} B={batch}: batched x{batched:.2} \
                     (serial x{serial:.2}) vs required x{factor:.2} — {}",
                    if ok { "PASS" } else { "FAIL" }
                );
                failed |= !ok;
            }
            Ok(None) => {
                eprintln!(
                    "speedup {route}: route missing from baseline or \
                     fresh document — FAIL"
                );
                failed = true;
            }
            Err(e) => {
                eprintln!("speedup {route}: {e:#}");
                failed = true;
            }
        }
    }
    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::FAILURE;
        }
    };
    if !args.speedups.is_empty() {
        return run_speedup_asserts(&args);
    }
    if args.update {
        match std::fs::copy(&args.fresh, &args.baseline) {
            Ok(_) => {
                println!(
                    "seeded baseline {} from {}",
                    args.baseline.display(),
                    args.fresh.display()
                );
                return ExitCode::SUCCESS;
            }
            Err(e) => {
                eprintln!(
                    "seeding {} failed: {e}",
                    args.baseline.display()
                );
                return ExitCode::FAILURE;
            }
        }
    }
    if args.serve {
        return run_serve_gate(&args);
    }
    let fresh = match json::from_file(&args.fresh) {
        Ok(doc) => doc,
        Err(e) => {
            eprintln!(
                "reading fresh benchmark {}: {e:#} (run `cargo bench \
                 --bench batch_throughput -- --smoke` first)",
                args.fresh.display()
            );
            return ExitCode::FAILURE;
        }
    };
    let baseline = if args.baseline.exists() {
        match load(&args.baseline, "baseline") {
            Ok(d) => d,
            Err(c) => return c,
        }
    } else if args.ratchet {
        return seed_baseline(&args, "baseline file missing");
    } else {
        return report_unseeded("baseline file missing", args.allow_unseeded);
    };
    let report =
        match gate_against_baseline(&baseline, &fresh, args.max_regress) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("gate error: {e:#}");
                return ExitCode::FAILURE;
            }
        };
    if report.unseeded() {
        if args.ratchet {
            return seed_baseline(&args, "baseline has no entries");
        }
        return report_unseeded("no comparable entries", args.allow_unseeded);
    }
    println!(
        "bench gate: {} metrics compared, machine scale x{:.2}, allowance \
         {:.0}%",
        report.compared,
        report.scale,
        args.max_regress * 100.0
    );
    if !report.passed() {
        eprintln!("bench gate: FAIL — regressed routes:");
        for f in &report.failures {
            eprintln!("  {f}");
        }
        if args.ratchet {
            eprintln!(
                "bench gate: baseline left untouched (never ratchet over \
                 a regression)"
            );
        }
        return ExitCode::FAILURE;
    }
    if args.ratchet {
        if report.improved() {
            println!("bench gate: improvements beyond the allowance:");
            for s in &report.improvements {
                println!("  {s}");
            }
            return seed_baseline(&args, "ratcheting improved baseline");
        }
        println!("bench gate: PASS (no improvements to ratchet)");
        return ExitCode::SUCCESS;
    }
    println!("bench gate: PASS");
    ExitCode::SUCCESS
}

/// `--serve` mode: gate the flat loadgen report against the committed
/// serving baseline (p99 / throughput / rejected fraction).
fn run_serve_gate(args: &Args) -> ExitCode {
    let fresh = match json::from_file(&args.fresh) {
        Ok(doc) => doc,
        Err(e) => {
            eprintln!(
                "reading fresh serve report {}: {e:#} (run `memode \
                 loadgen` against a live server first)",
                args.fresh.display()
            );
            return ExitCode::FAILURE;
        }
    };
    let baseline = if args.baseline.exists() {
        match load(&args.baseline, "serve baseline") {
            Ok(d) => d,
            Err(c) => return c,
        }
    } else if args.ratchet {
        return seed_baseline(args, "serve baseline file missing");
    } else {
        return report_unseeded(
            "serve baseline file missing",
            args.allow_unseeded,
        );
    };
    let report = match loadgen::gate_serve_against_baseline(
        &baseline,
        &fresh,
        args.max_regress,
    ) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("serve gate error: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    println!(
        "serve gate: {} metrics compared, allowance {:.0}%",
        report.compared,
        args.max_regress * 100.0
    );
    if !report.passed() {
        eprintln!("serve gate: FAIL — regressed metrics:");
        for f in &report.failures {
            eprintln!("  {f}");
        }
        if args.ratchet {
            eprintln!(
                "serve gate: baseline left untouched (never ratchet \
                 over a regression)"
            );
        }
        return ExitCode::FAILURE;
    }
    if args.ratchet {
        if report.improved() {
            println!("serve gate: improvements beyond the allowance:");
            for s in &report.improvements {
                println!("  {s}");
            }
            return seed_baseline(args, "ratcheting improved baseline");
        }
        println!("serve gate: PASS (no improvements to ratchet)");
        return ExitCode::SUCCESS;
    }
    println!("serve gate: PASS");
    ExitCode::SUCCESS
}

/// Copy the fresh document over the baseline (seed or ratchet).
fn seed_baseline(args: &Args, why: &str) -> ExitCode {
    match std::fs::copy(&args.fresh, &args.baseline) {
        Ok(_) => {
            println!(
                "bench gate: wrote baseline {} from {} ({why})",
                args.baseline.display(),
                args.fresh.display()
            );
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!(
                "bench gate: writing baseline {} failed: {e}",
                args.baseline.display()
            );
            ExitCode::FAILURE
        }
    }
}
