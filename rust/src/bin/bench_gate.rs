//! Bench-regression gate: compare the fresh smoke
//! `BENCH_batch_throughput.json` against the committed
//! `BENCH_baseline.json` and fail (exit 1) if any tracked route's
//! ns/trajectory-step regressed by more than the allowance (default 25%)
//! after normalising out uniform machine-speed differences — see
//! [`memode::twin::throughput::gate_against_baseline`] for the exact rule.
//!
//! Usage:
//!   bench_gate [--baseline PATH] [--fresh PATH] [--max-regress FRAC]
//!              [--update]
//!
//! `--update` copies the fresh document over the baseline (seed or refresh
//! it after an intentional perf change, on a quiet machine). Paths default
//! to `$BENCH_BASELINE` / `BENCH_baseline.json` and `$BENCH_OUT` /
//! `BENCH_batch_throughput.json` at the repository root. A missing or
//! empty baseline passes vacuously so the gate can land before the first
//! seeding.

use std::path::PathBuf;
use std::process::ExitCode;

use memode::twin::throughput::{
    default_baseline_path, default_json_path, gate_against_baseline,
};
use memode::util::json;

struct Args {
    baseline: PathBuf,
    fresh: PathBuf,
    max_regress: f64,
    update: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        baseline: default_baseline_path(),
        fresh: default_json_path(),
        max_regress: 0.25,
        update: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--baseline" => {
                args.baseline = it
                    .next()
                    .ok_or("--baseline needs a path")?
                    .into();
            }
            "--fresh" => {
                args.fresh =
                    it.next().ok_or("--fresh needs a path")?.into();
            }
            "--max-regress" => {
                let v = it.next().ok_or("--max-regress needs a fraction")?;
                args.max_regress = v
                    .parse::<f64>()
                    .map_err(|e| format!("--max-regress {v}: {e}"))?;
            }
            "--update" => args.update = true,
            "--help" | "-h" => {
                return Err(
                    "usage: bench_gate [--baseline PATH] [--fresh PATH] \
                     [--max-regress FRAC] [--update]"
                        .into(),
                );
            }
            other => return Err(format!("unknown argument '{other}'")),
        }
    }
    Ok(args)
}

/// Loud vacuous-pass notice: the gate exits 0 (there is nothing to
/// compare), but an unseeded baseline must never look like a green
/// regression check — emit a CI annotation (GitHub renders `::warning`
/// lines on the workflow summary) plus an unmissable stderr banner.
fn warn_unseeded(reason: &str) {
    println!(
        "::warning title=bench_gate vacuous::BENCH_baseline.json is \
         unseeded ({reason}) — the bench-regression gate is NOT \
         protecting any route. Seed it on a quiet runner with `cargo \
         bench --bench batch_throughput -- --smoke && cargo run \
         --release --bin bench_gate -- --update`, inspect, commit."
    );
    eprintln!(
        "bench gate: VACUOUS PASS — unseeded baseline ({reason}); no \
         route is protected against perf regressions until a seeded \
         BENCH_baseline.json is committed"
    );
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::FAILURE;
        }
    };
    if args.update {
        match std::fs::copy(&args.fresh, &args.baseline) {
            Ok(_) => {
                println!(
                    "seeded baseline {} from {}",
                    args.baseline.display(),
                    args.fresh.display()
                );
                return ExitCode::SUCCESS;
            }
            Err(e) => {
                eprintln!(
                    "seeding {} failed: {e}",
                    args.baseline.display()
                );
                return ExitCode::FAILURE;
            }
        }
    }
    let fresh = match json::from_file(&args.fresh) {
        Ok(doc) => doc,
        Err(e) => {
            eprintln!(
                "reading fresh benchmark {}: {e:#} (run `cargo bench \
                 --bench batch_throughput -- --smoke` first)",
                args.fresh.display()
            );
            return ExitCode::FAILURE;
        }
    };
    let baseline = if args.baseline.exists() {
        match json::from_file(&args.baseline) {
            Ok(doc) => doc,
            Err(e) => {
                eprintln!(
                    "reading baseline {}: {e:#}",
                    args.baseline.display()
                );
                return ExitCode::FAILURE;
            }
        }
    } else {
        warn_unseeded("baseline file missing");
        return ExitCode::SUCCESS;
    };
    let report =
        match gate_against_baseline(&baseline, &fresh, args.max_regress) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("gate error: {e:#}");
                return ExitCode::FAILURE;
            }
        };
    if report.unseeded() {
        warn_unseeded("no comparable entries");
        return ExitCode::SUCCESS;
    }
    println!(
        "bench gate: {} metrics compared, machine scale x{:.2}, allowance \
         {:.0}%",
        report.compared,
        report.scale,
        args.max_regress * 100.0
    );
    if report.passed() {
        println!("bench gate: PASS");
        ExitCode::SUCCESS
    } else {
        eprintln!("bench gate: FAIL — regressed routes:");
        for f in &report.failures {
            eprintln!("  {f}");
        }
        ExitCode::FAILURE
    }
}
