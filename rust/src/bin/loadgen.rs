//! Standalone load generator for the memode network front door — the
//! same driver as `memode loadgen`, packaged as its own binary so a
//! bench box can hammer a remote server without the leader binary's
//! artifact expectations.
//!
//! Usage:
//!   loadgen [--addr HOST:PORT] [--conns N] [--duration S] [--rate HZ]
//!           [--steps N] [--seed N] [--routes a,b,...]
//!           [--scenarios a.twin,b.twin,...]
//!           [--ensemble-fraction F] [--ensemble-members N]
//!           [--max-rejected F] [--out PATH] [--smoke]
//!
//! Reports p50/p99/p99.9 latency, throughput and the rejected fraction
//! into `BENCH_serve.json` (see `docs/SERVING.md`); exits non-zero on
//! wire-level protocol errors or a rejected fraction past
//! `--max-rejected`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = memode::coordinator::loadgen::cli("loadgen", argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
