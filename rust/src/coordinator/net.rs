//! The network front door: a non-blocking TCP server speaking the
//! length-prefixed JSON protocol of [`crate::coordinator::wire`].
//!
//! Deliberately hand-rolled on `std::net` (the offline image has no
//! async runtime or poll crate): one server thread owns a non-blocking
//! listener and every connection, and turns over a poll loop —
//!
//! 1. **accept** new sockets (a connection cap sheds excess ones with a
//!    typed `rejected_overload` frame before closing);
//! 2. **read** whatever bytes each socket has, extracting complete
//!    frames;
//! 3. **process** frames *fairly*: one frame per connection per sweep,
//!    round-robin across connections until nobody makes progress, so a
//!    pipelining client cannot starve its neighbours. Each frame is
//!    decoded, stamped with a replay seed, and submitted to the
//!    coordinator ([`Coordinator::try_submit`]), mapping typed
//!    rejections onto protocol error codes. A connection already at
//!    its in-flight cap (`conn_inflight`) keeps further request bytes
//!    buffered — they are decoded only as its pending jobs complete,
//!    instead of being shed;
//! 4. **poll** in-flight jobs (`try_recv` on each pending reply
//!    channel) and queue finished responses;
//! 5. **write** queued bytes back without blocking.
//!
//! Admission control composes two [`Backpressure`] gates: the server's
//! own connection cap, and the coordinator's global + per-route
//! in-flight budget (requests shed there are answered with
//! `rejected_overload` and recorded in the per-route shed counters).
//!
//! **Seed stamping happens before admission.** A seedless request gets
//! `derive_stream_seed(NET_SEED_ROOT, id)` the moment it decodes, so
//! even a request the admission gate rejects echoes the seed it *would
//! have* used — an operator can replay any request in a serving log,
//! shed or served (`ok:false` frames carry `"seed"` too).
//!
//! **Graceful drain**: [`NetHandle::shutdown`] stops accepting, answers
//! new frames with `shutting_down`, waits for in-flight jobs to finish
//! and flushes their responses (bounded by `drain_timeout_s`), then
//! closes everything and returns the final [`NetStats`].

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::backpressure::{Backpressure, Permit};
use crate::coordinator::router::{SubmitError, Submitted};
use crate::coordinator::service::Coordinator;
use crate::coordinator::telemetry::Telemetry;
use crate::coordinator::wire::{self, ErrorCode};
use crate::util::rng::derive_stream_seed;

/// Root of the network layer's pre-admission seed family (fixed
/// constant: seeds exist for replay, not secrecy — see the router's
/// seed root).
const NET_SEED_ROOT: u64 = 0x6e65_745f_5eed_0008;

/// Network front-door configuration.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Listen address, e.g. `"127.0.0.1:7171"` (`:0` picks a free
    /// port; [`NetHandle::addr`] reports the bound one).
    pub addr: String,
    /// Connection cap: sockets past it get `rejected_overload` and are
    /// closed immediately.
    pub max_conns: usize,
    /// Per-frame payload cap (larger frames get `bad_frame` + close).
    pub max_frame_bytes: usize,
    /// Per-connection in-flight cap: a connection with this many jobs
    /// pending has further request bytes left in its read buffer until
    /// results come back, keeping one greedy pipeliner from monopolising
    /// the coordinator's admission budget.
    pub conn_inflight: usize,
    /// Sleep between poll turns when nothing happened (µs).
    pub idle_sleep_us: u64,
    /// Drain budget on shutdown: in-flight responses not flushed within
    /// this window are abandoned (s).
    pub drain_timeout_s: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7171".into(),
            max_conns: 64,
            max_frame_bytes: wire::MAX_FRAME_BYTES,
            conn_inflight: 32,
            idle_sleep_us: 500,
            drain_timeout_s: 10.0,
        }
    }
}

impl NetConfig {
    /// Apply `MEMODE_*` environment overrides (`docs/SERVING.md`):
    /// `MEMODE_NET_MAX_CONNS`, `MEMODE_NET_MAX_FRAME_MB`,
    /// `MEMODE_CONN_INFLIGHT`. Unset or unparsable variables keep the
    /// current value.
    pub fn apply_env(&mut self) {
        let read = |name: &str| -> Option<usize> {
            std::env::var(name).ok()?.trim().parse().ok()
        };
        if let Some(v) = read("MEMODE_NET_MAX_CONNS") {
            self.max_conns = v;
        }
        if let Some(v) = read("MEMODE_NET_MAX_FRAME_MB") {
            self.max_frame_bytes = v * 1024 * 1024;
        }
        if let Some(v) = read("MEMODE_CONN_INFLIGHT") {
            self.conn_inflight = v;
        }
    }
}

/// Final counters a server reports when it shuts down (the same values
/// stream into [`Telemetry`] while it runs).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Connections accepted.
    pub connections: u64,
    /// Connections refused at the cap.
    pub conns_rejected: u64,
    /// Request frames decoded.
    pub frames_in: u64,
    /// Response frames queued.
    pub frames_out: u64,
    /// Protocol violations (bad frames / bad JSON / oversized).
    pub protocol_errors: u64,
}

/// Handle to a running server; dropping it shuts the server down.
pub struct NetHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<NetStats>>,
}

impl NetHandle {
    /// The actually-bound listen address (resolves `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful drain: stop accepting, flush in-flight work, close, and
    /// return the final counters.
    pub fn shutdown(mut self) -> NetStats {
        self.stop.store(true, Ordering::Relaxed);
        self.thread
            .take()
            .map(|t| t.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

impl Drop for NetHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// The server. [`NetServer::start`] binds and spawns the poll thread.
pub struct NetServer;

impl NetServer {
    /// Bind `cfg.addr` and serve `coord` until the handle shuts down.
    pub fn start(
        coord: Arc<Coordinator>,
        cfg: NetConfig,
    ) -> Result<NetHandle> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        listener.set_nonblocking(true).context("non-blocking listener")?;
        let addr = listener.local_addr().context("listener address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("net".into())
            .spawn(move || serve_loop(listener, coord, cfg, stop2))
            .context("spawning the net thread")?;
        Ok(NetHandle { addr, stop, thread: Some(thread) })
    }
}

/// One job awaiting its result: the correlation id and pre-admission
/// seed ride along so the response (or failure) can echo both.
struct Pending {
    id: u64,
    seed: u64,
    sub: Submitted,
}

/// One live connection's state.
struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    pending: Vec<Pending>,
    /// Read side alive; `false` = close once `wbuf`/`pending` empty.
    open: bool,
    /// Socket failed; drop regardless of queued data.
    dead: bool,
    /// Connection-cap slot, released on drop.
    _permit: Permit,
}

impl Conn {
    fn done(&self, draining: bool) -> bool {
        if self.dead {
            return true;
        }
        // `rbuf` may still hold complete frames the in-flight cap has
        // deferred; the connection is only finished once those are
        // answered too (a trailing partial frame is cleared at EOF).
        let flushed = self.wbuf.is_empty()
            && self.pending.is_empty()
            && self.rbuf.is_empty();
        flushed && (!self.open || draining)
    }
}

/// Queue one response frame on a connection.
fn queue(
    conn: &mut Conn,
    payload: &str,
    telemetry: &Telemetry,
    stats: &mut NetStats,
) {
    conn.wbuf.extend_from_slice(&wire::encode_frame(payload));
    telemetry.net_frames_out.fetch_add(1, Ordering::Relaxed);
    stats.frames_out += 1;
}

fn submit_error_code(e: &SubmitError) -> ErrorCode {
    match e {
        SubmitError::UnknownRoute { .. } => ErrorCode::UnknownRoute,
        SubmitError::InvalidRequest(_) => ErrorCode::BadRequest,
        SubmitError::BadDimension { .. } => ErrorCode::BadRequest,
        SubmitError::Overloaded { .. } => ErrorCode::RejectedOverload,
        SubmitError::Stopped => ErrorCode::ShuttingDown,
    }
}

/// Decode + admit one request frame, queueing either a pending job or
/// an immediate error response.
fn handle_frame(
    conn: &mut Conn,
    payload: &[u8],
    draining: bool,
    coord: &Coordinator,
    telemetry: &Telemetry,
    stats: &mut NetStats,
) {
    let mut w = match wire::decode_request(payload) {
        Ok(w) => w,
        Err(e) => {
            telemetry.net_protocol_errors.fetch_add(1, Ordering::Relaxed);
            stats.protocol_errors += 1;
            let msg = wire::encode_error(e.id, e.code, &e.msg, None);
            queue(conn, &msg, telemetry, stats);
            if e.code == ErrorCode::BadFrame {
                // The stream cannot be re-synchronised; stop reading
                // and close once the error frame is flushed.
                conn.open = false;
                conn.rbuf.clear();
            }
            return;
        }
    };
    // Stamp the replay seed *before* admission: even a shed request's
    // error frame echoes the seed it would have used.
    let seed = *w
        .req
        .seed
        .get_or_insert_with(|| derive_stream_seed(NET_SEED_ROOT, w.id));
    if draining {
        let msg = wire::encode_error(
            Some(w.id),
            ErrorCode::ShuttingDown,
            "server is draining",
            Some(seed),
        );
        queue(conn, &msg, telemetry, stats);
        return;
    }
    match coord.try_submit(&w.route, w.req) {
        Ok(sub) => conn.pending.push(Pending { id: w.id, seed, sub }),
        Err(e) => {
            let msg = wire::encode_error(
                Some(w.id),
                submit_error_code(&e),
                &e.to_string(),
                Some(seed),
            );
            queue(conn, &msg, telemetry, stats);
        }
    }
}

/// Poll every pending job on a connection; queue finished responses.
/// Returns `true` if any job completed this turn.
fn poll_pending(
    conn: &mut Conn,
    telemetry: &Telemetry,
    stats: &mut NetStats,
) -> bool {
    let mut progressed = false;
    let mut i = 0;
    while i < conn.pending.len() {
        match conn.pending[i].sub.rx.try_recv() {
            Ok(jr) => {
                let p = conn.pending.remove(i);
                progressed = true;
                let wait_us = (jr.wait_s.max(0.0) * 1e6).round() as u64;
                let exec_us = (jr.exec_s.max(0.0) * 1e6).round() as u64;
                let msg = match jr.result {
                    Ok(resp) => {
                        wire::encode_response(p.id, &resp, wait_us, exec_us)
                    }
                    Err(e) => wire::encode_error(
                        Some(p.id),
                        ErrorCode::Internal,
                        &format!("{e:#}"),
                        Some(p.seed),
                    ),
                };
                queue(conn, &msg, telemetry, stats);
            }
            Err(std::sync::mpsc::TryRecvError::Empty) => i += 1,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                let p = conn.pending.remove(i);
                progressed = true;
                let msg = wire::encode_error(
                    Some(p.id),
                    ErrorCode::Internal,
                    "coordinator dropped the job",
                    Some(p.seed),
                );
                queue(conn, &msg, telemetry, stats);
            }
        }
    }
    progressed
}

/// Best-effort typed rejection for a connection past the cap.
fn reject_connection(stream: TcpStream) {
    let msg = wire::encode_error(
        None,
        ErrorCode::RejectedOverload,
        "connection limit reached",
        None,
    );
    // The socket may have inherited non-blocking mode from the
    // listener on some platforms; a tiny blocking write is fine here.
    let _ = stream.set_nonblocking(false);
    let mut stream = stream;
    let _ = stream.write_all(&wire::encode_frame(&msg));
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// The server thread: turn the poll loop until shutdown + drain.
fn serve_loop(
    listener: TcpListener,
    coord: Arc<Coordinator>,
    cfg: NetConfig,
    stop: Arc<AtomicBool>,
) -> NetStats {
    let telemetry = coord.telemetry();
    let conn_gate = Backpressure::new(cfg.max_conns.max(1));
    let mut listener = Some(listener);
    let mut conns: Vec<Conn> = Vec::new();
    let mut stats = NetStats::default();
    let mut drain_deadline: Option<Instant> = None;
    let mut chunk = [0u8; 4096];

    loop {
        let mut active = false;
        let draining = stop.load(Ordering::Relaxed);
        if draining {
            if listener.take().is_some() {
                drain_deadline = Some(
                    Instant::now()
                        + Duration::from_secs_f64(
                            cfg.drain_timeout_s.max(0.0),
                        ),
                );
            }
        } else if let Some(l) = &listener {
            loop {
                match l.accept() {
                    Ok((stream, _)) => {
                        active = true;
                        match conn_gate.try_acquire() {
                            Some(permit) => {
                                if stream.set_nonblocking(true).is_err() {
                                    continue;
                                }
                                let _ = stream.set_nodelay(true);
                                telemetry
                                    .net_connections
                                    .fetch_add(1, Ordering::Relaxed);
                                stats.connections += 1;
                                conns.push(Conn {
                                    stream,
                                    rbuf: Vec::new(),
                                    wbuf: Vec::new(),
                                    pending: Vec::new(),
                                    open: true,
                                    dead: false,
                                    _permit: permit,
                                });
                            }
                            None => {
                                telemetry
                                    .net_conns_rejected
                                    .fetch_add(1, Ordering::Relaxed);
                                stats.conns_rejected += 1;
                                reject_connection(stream);
                            }
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        break
                    }
                    Err(_) => break,
                }
            }
        }

        // Read phase: drain every socket into its frame buffer.
        for conn in conns.iter_mut() {
            while conn.open && !conn.dead {
                match conn.stream.read(&mut chunk) {
                    Ok(0) => conn.open = false,
                    Ok(n) => {
                        active = true;
                        conn.rbuf.extend_from_slice(&chunk[..n]);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        break
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(_) => conn.dead = true,
                }
            }
        }

        // Frame phase, round-robin for fairness: one frame per
        // connection per sweep, repeated until nobody progresses, so a
        // pipelining client interleaves with its neighbours instead of
        // draining first. A connection at the in-flight cap keeps its
        // bytes buffered until pending jobs complete.
        let inflight_cap = cfg.conn_inflight.max(1);
        loop {
            let mut progressed = false;
            for conn in conns.iter_mut() {
                if conn.dead || conn.pending.len() >= inflight_cap {
                    continue;
                }
                match wire::extract_frame(
                    &mut conn.rbuf,
                    cfg.max_frame_bytes,
                ) {
                    Ok(Some(payload)) => {
                        active = true;
                        progressed = true;
                        telemetry
                            .net_frames_in
                            .fetch_add(1, Ordering::Relaxed);
                        stats.frames_in += 1;
                        handle_frame(
                            conn, &payload, draining, &coord, &telemetry,
                            &mut stats,
                        );
                    }
                    Ok(None) => {
                        if !conn.open && !conn.rbuf.is_empty() {
                            // EOF with a trailing partial frame: it can
                            // never complete, drop the bytes so the
                            // connection can finish.
                            conn.rbuf.clear();
                        }
                    }
                    Err(e) => {
                        active = true;
                        progressed = true;
                        telemetry
                            .net_protocol_errors
                            .fetch_add(1, Ordering::Relaxed);
                        stats.protocol_errors += 1;
                        let msg = wire::encode_error(
                            None,
                            ErrorCode::BadFrame,
                            &e.to_string(),
                            None,
                        );
                        queue(conn, &msg, &telemetry, &mut stats);
                        conn.open = false;
                        conn.rbuf.clear();
                    }
                }
            }
            if !progressed {
                break;
            }
        }

        // Completion + write phases.
        for conn in conns.iter_mut() {
            if poll_pending(conn, &telemetry, &mut stats) {
                active = true;
            }
            if !conn.wbuf.is_empty() && !conn.dead {
                match conn.stream.write(&conn.wbuf) {
                    Ok(0) => conn.dead = true,
                    Ok(n) => {
                        active = true;
                        conn.wbuf.drain(..n);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {}
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(_) => conn.dead = true,
                }
            }
        }

        conns.retain(|c| !c.done(draining));

        if let Some(deadline) = drain_deadline {
            let flushed = conns
                .iter()
                .all(|c| c.pending.is_empty() && c.wbuf.is_empty());
            if flushed || Instant::now() >= deadline {
                break;
            }
        }
        if !active {
            std::thread::sleep(Duration::from_micros(
                cfg.idle_sleep_us.max(1),
            ));
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::coordinator::client::WireClient;
    use crate::coordinator::wire::{WireRequest, WireResponse};
    use crate::twin::registry::TwinRegistry;
    use crate::twin::{Twin, TwinRequest, TwinResponse};
    use crate::util::tensor::Trajectory;

    struct EchoTwin;
    impl Twin for EchoTwin {
        fn name(&self) -> &str {
            "echo"
        }
        fn state_dim(&self) -> usize {
            1
        }
        fn dt(&self) -> f64 {
            1.0
        }
        fn default_h0(&self) -> Vec<f64> {
            vec![0.0]
        }
        fn run(&mut self, req: &TwinRequest) -> anyhow::Result<TwinResponse> {
            Ok(TwinResponse {
                trajectory: Trajectory::repeat_row(&[1.0], req.n_points),
                backend: "echo",
                seed: req.seed.unwrap_or(0),
                ensemble: None,
                degraded: false,
            })
        }
    }

    fn start_server(max_conns: usize) -> (Arc<Coordinator>, NetHandle) {
        let mut reg = TwinRegistry::new();
        reg.register_info(
            "echo",
            crate::twin::registry::RouteInfo {
                dim: 1,
                dt: 1.0,
                backend: "echo",
                aged: false,
                synthetic: true,
            },
            || Box::new(EchoTwin),
        );
        let coord = Arc::new(Coordinator::start(
            reg,
            &ServeConfig {
                workers: 1,
                max_batch: 4,
                batch_window_s: 1e-4,
                batch_window_min_s: 1e-4,
                batch_window_max_s: 1e-4,
                queue_depth: 16,
                route_queue_depth: 16,
                ..Default::default()
            },
        ));
        let handle = NetServer::start(
            Arc::clone(&coord),
            NetConfig {
                addr: "127.0.0.1:0".into(),
                max_conns,
                idle_sleep_us: 100,
                ..NetConfig::default()
            },
        )
        .expect("server starts");
        (coord, handle)
    }

    #[test]
    fn serves_a_request_end_to_end() {
        let (_coord, handle) = start_server(4);
        let mut client =
            WireClient::connect(&handle.addr().to_string()).unwrap();
        let resp = client
            .call(&WireRequest {
                id: 7,
                route: "echo".into(),
                req: TwinRequest::autonomous(vec![], 3).with_seed(99),
            })
            .unwrap();
        match resp {
            WireResponse::Ok(ok) => {
                assert_eq!(ok.id, 7);
                assert_eq!(ok.seed, 99);
                assert_eq!(ok.backend, "echo");
                assert_eq!(ok.trajectory.len(), 3);
            }
            other => panic!("expected ok, got {other:?}"),
        }
        let stats = handle.shutdown();
        assert_eq!(stats.connections, 1);
        assert_eq!(stats.frames_in, 1);
        assert_eq!(stats.frames_out, 1);
        assert_eq!(stats.protocol_errors, 0);
    }

    #[test]
    fn seedless_requests_get_a_replay_seed_echo() {
        let (_coord, handle) = start_server(4);
        let mut client =
            WireClient::connect(&handle.addr().to_string()).unwrap();
        let req = WireRequest {
            id: 3,
            route: "echo".into(),
            req: TwinRequest::autonomous(vec![], 2),
        };
        let seed = match client.call(&req).unwrap() {
            WireResponse::Ok(ok) => {
                assert_eq!(
                    ok.seed,
                    derive_stream_seed(NET_SEED_ROOT, 3),
                    "net layer stamps id-derived seeds"
                );
                ok.seed
            }
            other => panic!("expected ok, got {other:?}"),
        };
        // Replaying under the echoed seed is accepted verbatim.
        let replay = WireRequest {
            id: 4,
            route: "echo".into(),
            req: TwinRequest::autonomous(vec![], 2).with_seed(seed),
        };
        match client.call(&replay).unwrap() {
            WireResponse::Ok(ok) => assert_eq!(ok.seed, seed),
            other => panic!("expected ok, got {other:?}"),
        }
        handle.shutdown();
    }

    #[test]
    fn unknown_route_is_a_typed_error_with_seed() {
        let (_coord, handle) = start_server(4);
        let mut client =
            WireClient::connect(&handle.addr().to_string()).unwrap();
        let resp = client
            .call(&WireRequest {
                id: 11,
                route: "ghost".into(),
                req: TwinRequest::autonomous(vec![], 1),
            })
            .unwrap();
        match resp {
            WireResponse::Err(e) => {
                assert_eq!(e.code, ErrorCode::UnknownRoute);
                assert_eq!(e.id, Some(11));
                assert!(
                    e.seed.is_some(),
                    "rejections echo the pre-admission seed"
                );
            }
            other => panic!("expected error, got {other:?}"),
        }
        // The connection survives a per-request error.
        let resp = client
            .call(&WireRequest {
                id: 12,
                route: "echo".into(),
                req: TwinRequest::autonomous(vec![], 1),
            })
            .unwrap();
        assert!(matches!(resp, WireResponse::Ok(_)));
        handle.shutdown();
    }

    #[test]
    fn wrong_y0_dimension_is_a_typed_bad_request() {
        let (_coord, handle) = start_server(4);
        let mut client =
            WireClient::connect(&handle.addr().to_string()).unwrap();
        let resp = client
            .call(&WireRequest {
                id: 21,
                route: "echo".into(),
                req: TwinRequest::autonomous(vec![0.0, 1.0], 2),
            })
            .unwrap();
        match resp {
            WireResponse::Err(e) => {
                assert_eq!(e.code, ErrorCode::BadRequest);
                assert_eq!(e.id, Some(21));
                assert!(e.message.contains("dim"), "{}", e.message);
            }
            other => panic!("expected bad_request, got {other:?}"),
        }
        // The connection survives and a well-shaped request succeeds.
        let resp = client
            .call(&WireRequest {
                id: 22,
                route: "echo".into(),
                req: TwinRequest::autonomous(vec![0.5], 2),
            })
            .unwrap();
        assert!(matches!(resp, WireResponse::Ok(_)));
        handle.shutdown();
    }

    #[test]
    fn malformed_json_gets_bad_frame_and_close() {
        let (_coord, handle) = start_server(4);
        let mut client =
            WireClient::connect(&handle.addr().to_string()).unwrap();
        client.send_raw("this is not json").unwrap();
        match client.recv().unwrap() {
            WireResponse::Err(e) => {
                assert_eq!(e.code, ErrorCode::BadFrame)
            }
            other => panic!("expected error, got {other:?}"),
        }
        // Server closes the stream after a bad frame.
        assert!(client.recv().is_err());
        let stats = handle.shutdown();
        assert_eq!(stats.protocol_errors, 1);
    }

    #[test]
    fn connection_cap_rejects_with_typed_frame() {
        let (_coord, handle) = start_server(1);
        let mut first =
            WireClient::connect(&handle.addr().to_string()).unwrap();
        // Ensure the first connection is registered server-side.
        first
            .call(&WireRequest {
                id: 1,
                route: "echo".into(),
                req: TwinRequest::autonomous(vec![], 1),
            })
            .unwrap();
        let mut second =
            WireClient::connect(&handle.addr().to_string()).unwrap();
        match second.recv().unwrap() {
            WireResponse::Err(e) => {
                assert_eq!(e.code, ErrorCode::RejectedOverload)
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        drop(first);
        let stats = handle.shutdown();
        assert_eq!(stats.connections, 1);
        assert_eq!(stats.conns_rejected, 1);
    }

    #[test]
    fn shutdown_reports_telemetry_counters() {
        let (coord, handle) = start_server(4);
        let mut client =
            WireClient::connect(&handle.addr().to_string()).unwrap();
        client
            .call(&WireRequest {
                id: 1,
                route: "echo".into(),
                req: TwinRequest::autonomous(vec![], 1),
            })
            .unwrap();
        let snap = coord.stats();
        assert_eq!(snap.net_connections, 1);
        assert_eq!(snap.net_frames_in, 1);
        assert_eq!(snap.net_frames_out, 1);
        handle.shutdown();
    }
}
