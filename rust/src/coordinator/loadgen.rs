//! Closed-loop load generator for the network front door.
//!
//! `memode loadgen` (and the standalone `loadgen` binary) drives a
//! running server over real TCP: N concurrent connections, each issuing
//! a **seeded, deterministic request mix** (plain rollouts, Monte-Carlo
//! ensembles, and the aged route when present) and measuring
//! request→response latency. An optional open-loop arrival rate paces
//! each connection's next send instead of going back-to-back.
//! `--scenarios a.twin,b.twin` swaps the synthetic mix for committed
//! scenario files (`docs/SCENARIOS.md`), so a load test can replay the
//! exact rollouts CI accepts as fixtures.
//!
//! The report lands in `BENCH_serve.json` (machine-local, gitignored —
//! CI uploads it as an artifact like the other `BENCH_*` documents):
//! p50/p99/p999 latency, throughput, and the **rejected fraction** —
//! the share of requests the server shed with `rejected_overload`,
//! which is the admission-control signal an operator tunes
//! `MEMODE_QUEUE_DEPTH` / `MEMODE_ROUTE_QUEUE_DEPTH` against.
//!
//! Request ids encode `(connection, sequence)` so every id in a serving
//! log maps back to one loadgen decision; the mix itself derives from
//! `--seed`, making a run reproducible end to end.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::client::WireClient;
use crate::coordinator::wire::{ErrorCode, WireRequest, WireResponse};
use crate::twin::{EnsembleSpec, TwinRequest};
use crate::util::json::Json;
use crate::util::rng::{derive_stream_seed, Pcg64};
use crate::util::stats;
use crate::workload::stimuli::Waveform;

/// Request-mix preset shaping the tail of the offered load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mix {
    /// Every request the same shape; ensembles at the configured width.
    Uniform,
    /// Heavy-tailed: most requests stay light, but a deterministic
    /// minority are an order of magnitude heavier — 1-in-10 requests
    /// carry 4x the trajectory points, and ensembles widen to 2x or 8x
    /// the configured member count. This is the p99-dominating shape
    /// the adaptive batch windows and work stealing are tuned against
    /// (`docs/SERVING.md`).
    HeavyTail,
}

/// Load-generator parameters.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Server address, e.g. `"127.0.0.1:7171"`.
    pub addr: String,
    /// Concurrent connections (one thread each).
    pub conns: usize,
    /// Run length (s).
    pub duration_s: f64,
    /// Open-loop arrival rate per connection (requests/s); 0 = closed
    /// loop (send the next request as soon as the response arrives).
    pub rate_hz: f64,
    /// Trajectory points per request.
    pub steps: usize,
    /// Root seed of the request mix (route choice, ensemble cadence,
    /// request seeds all derive from it).
    pub seed: u64,
    /// Route mix to sample from (weighted uniformly).
    pub routes: Vec<String>,
    /// Fraction of requests carrying an ensemble spec (0.0..=1.0).
    pub ensemble_fraction: f64,
    /// Ensemble width for those requests.
    pub ensemble_members: usize,
    /// Request-mix preset (see [`Mix`]).
    pub mix: Mix,
    /// Parsed `*.twin` scenario files. When non-empty they replace the
    /// synthetic route mix entirely: each request is one scenario's
    /// rollout (route, horizon, stimulus, ensemble from the file),
    /// sampled uniformly, with a per-`(connection, sequence)` stream
    /// seed stamped unless the file pins one.
    pub scenarios: Vec<crate::twin::scenario::Scenario>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7171".into(),
            conns: 4,
            duration_s: 10.0,
            rate_hz: 0.0,
            steps: 32,
            seed: 42,
            routes: vec![
                "lorenz96/digital".into(),
                "lorenz96/analog".into(),
                "lorenz96/analog-sharded".into(),
                "lorenz96/analog-aged".into(),
                "hp/digital".into(),
            ],
            ensemble_fraction: 0.2,
            ensemble_members: 8,
            mix: Mix::Uniform,
            scenarios: Vec::new(),
        }
    }
}

/// What one run measured.
#[derive(Debug, Clone, Default)]
pub struct LoadgenReport {
    /// Requests sent.
    pub sent: u64,
    /// `ok:true` responses.
    pub ok: u64,
    /// `rejected_overload` responses (admission-control sheds).
    pub rejected: u64,
    /// Other typed error responses (`internal`, `unknown_route`, ...).
    pub errors: u64,
    /// Wire-level failures: undecodable frames, dropped connections,
    /// timeouts. A healthy server keeps this at zero.
    pub protocol_errors: u64,
    /// Latency percentiles over completed request→response pairs (µs).
    pub p50_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    pub mean_us: f64,
    /// Completed responses per second of wall time.
    pub throughput_rps: f64,
    /// Measured wall time (s).
    pub duration_s: f64,
}

impl LoadgenReport {
    /// Share of sent requests the server shed at an admission gate.
    pub fn rejected_fraction(&self) -> f64 {
        if self.sent == 0 {
            return 0.0;
        }
        self.rejected as f64 / self.sent as f64
    }

    /// Serialise to the tracked-benchmark JSON document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::Str("serve".into())),
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("protocol_errors", Json::Num(self.protocol_errors as f64)),
            ("p50_us", Json::Num(self.p50_us)),
            ("p99_us", Json::Num(self.p99_us)),
            ("p999_us", Json::Num(self.p999_us)),
            ("mean_us", Json::Num(self.mean_us)),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("duration_s", Json::Num(self.duration_s)),
            ("rejected_fraction", Json::Num(self.rejected_fraction())),
        ])
    }
}

/// Where the report lands: `$BENCH_SERVE_OUT` if set, else
/// `BENCH_serve.json` at the repository root.
pub fn default_json_path() -> PathBuf {
    if let Ok(p) = std::env::var("BENCH_SERVE_OUT") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_serve.json")
}

/// The committed serving baseline `bench_gate --serve` compares
/// against: `$BENCH_SERVE_BASELINE` if set, else
/// `BENCH_serve_baseline.json` at the repository root.
pub fn default_baseline_path() -> PathBuf {
    if let Ok(p) = std::env::var("BENCH_SERVE_BASELINE") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_serve_baseline.json")
}

/// Outcome of comparing a fresh serve report against the baseline.
#[derive(Debug, Clone, Default)]
pub struct ServeGateReport {
    /// Human-readable regressions (non-empty => gate fails).
    pub failures: Vec<String>,
    /// Improvements beyond the allowance (ratchet candidates).
    pub improvements: Vec<String>,
    /// Metrics compared.
    pub compared: usize,
}

impl ServeGateReport {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
    pub fn improved(&self) -> bool {
        !self.improvements.is_empty()
    }
}

/// Gate rule for `BENCH_serve.json` documents — flat loadgen reports,
/// not the per-route entry arrays the batch-throughput gate walks:
///
/// * `p99_us` (lower is better) may not regress past the allowance;
/// * `throughput_rps` (higher is better) may not drop past it;
/// * `rejected_fraction` may not grow past the allowance in absolute
///   terms (a scheduler change that sheds more under the same offered
///   load is a regression even when the survivors got faster).
///
/// No machine-speed normalisation is applied: serve latency mixes
/// compute with socket and scheduling waits, so the allowance itself
/// must absorb runner variance (CI passes a wider `--max-regress` here
/// than the batch gate's default).
pub fn gate_serve_against_baseline(
    baseline: &Json,
    fresh: &Json,
    max_regress: f64,
) -> Result<ServeGateReport> {
    let field = |doc: &Json, name: &str, which: &str| -> Result<f64> {
        doc.get(name).and_then(Json::as_f64).with_context(|| {
            format!("{which} serve document has no numeric {name:?}")
        })
    };
    let mut report = ServeGateReport::default();
    // (name, higher_is_better)
    for (name, higher_better) in
        [("p99_us", false), ("throughput_rps", true)]
    {
        let base = field(baseline, name, "baseline")?;
        let new = field(fresh, name, "fresh")?;
        anyhow::ensure!(
            base > 0.0 && new.is_finite(),
            "{name}: baseline {base}, fresh {new} not comparable"
        );
        report.compared += 1;
        let ratio = if higher_better { base / new } else { new / base };
        if ratio > 1.0 + max_regress {
            report.failures.push(format!(
                "{name}: baseline {base:.1}, fresh {new:.1} \
                 (x{ratio:.2} worse, allowance x{:.2})",
                1.0 + max_regress
            ));
        } else if ratio < 1.0 / (1.0 + max_regress) {
            report.improvements.push(format!(
                "{name}: baseline {base:.1}, fresh {new:.1} \
                 (x{:.2} better)",
                1.0 / ratio
            ));
        }
    }
    let base_rej = field(baseline, "rejected_fraction", "baseline")?;
    let new_rej = field(fresh, "rejected_fraction", "fresh")?;
    report.compared += 1;
    if new_rej > base_rej + max_regress {
        report.failures.push(format!(
            "rejected_fraction: baseline {base_rej:.3}, fresh \
             {new_rej:.3} (grew past the +{max_regress:.2} allowance)"
        ));
    }
    Ok(report)
}

/// Write the report JSON.
pub fn write_json(
    path: &std::path::Path,
    report: &LoadgenReport,
) -> Result<()> {
    crate::util::json::to_file(path, &report.to_json())
}

/// Shared CLI driver behind `memode loadgen` and the standalone
/// `loadgen` binary (one flag surface, two entry points).
///
/// Exit contract (what CI gates on): non-zero when the server produced
/// wire-level protocol errors, when `--max-rejected` is exceeded, or
/// when a `--smoke` run completes zero requests.
pub fn cli(prog: &str, argv: Vec<String>) -> Result<()> {
    let defaults = LoadgenConfig::default();
    let args = crate::util::cli::Args::new(
        prog,
        "drive a running memode server over TCP and report latency",
    )
    .opt("addr", &defaults.addr, "server address")
    .opt("conns", "4", "concurrent connections (one thread each)")
    .opt("duration", "10", "run length (s)")
    .opt(
        "rate",
        "0",
        "open-loop arrival rate per connection (req/s; 0 = closed loop)",
    )
    .opt("steps", "32", "trajectory points per request")
    .opt("seed", "42", "root seed of the request mix")
    .opt(
        "routes",
        "lorenz96/digital,lorenz96/analog,lorenz96/analog-sharded,\
         lorenz96/analog-aged,hp/digital",
        "comma-separated route mix",
    )
    .opt(
        "mix",
        "uniform",
        "request-mix preset: uniform | heavy-tail (long rollouts and \
         wide ensembles in the tail)",
    )
    .opt(
        "ensemble-fraction",
        "0.2",
        "fraction of requests carrying a Monte-Carlo ensemble",
    )
    .opt("ensemble-members", "8", "ensemble width for those requests")
    .opt(
        "scenarios",
        "",
        "comma-separated *.twin scenario files replacing the synthetic \
         request mix (docs/SCENARIOS.md)",
    )
    .opt(
        "max-rejected",
        "",
        "fail when the rejected fraction exceeds this (e.g. 0.05)",
    )
    .opt(
        "out",
        "",
        "report path (default $BENCH_SERVE_OUT, else BENCH_serve.json)",
    )
    .flag("smoke", "CI preset: 2 connections, 3 s, 8 steps, must serve")
    .parse(argv)
    .map_err(|m| anyhow::anyhow!("{m}"))?;

    let smoke = args.get_bool("smoke");
    let mix = match args.get("mix").as_str() {
        "" | "uniform" => Mix::Uniform,
        "heavy-tail" | "heavytail" => Mix::HeavyTail,
        other => anyhow::bail!(
            "unknown --mix {other:?} (expected uniform | heavy-tail)"
        ),
    };
    let scenarios = {
        let list = args.get("scenarios");
        let mut out = Vec::new();
        for path in
            list.split(',').map(str::trim).filter(|s| !s.is_empty())
        {
            let src = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
            let sc = crate::twin::scenario::Scenario::parse(&src)
                .map_err(|e| anyhow::anyhow!("{}", e.render(&src, path)))?;
            out.push(sc);
        }
        out
    };
    let cfg = LoadgenConfig {
        addr: args.get("addr"),
        conns: if smoke { 2 } else { args.get_usize("conns") },
        duration_s: if smoke { 3.0 } else { args.get_f64("duration") },
        rate_hz: args.get_f64("rate"),
        steps: if smoke { 8 } else { args.get_usize("steps") },
        seed: args.get_u64("seed"),
        routes: args
            .get("routes")
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        ensemble_fraction: args.get_f64("ensemble-fraction"),
        ensemble_members: args.get_usize("ensemble-members"),
        mix,
        scenarios,
    };
    let report = run(&cfg)?;
    println!(
        "loadgen: {} sent, {} ok, {} rejected (fraction {:.3}), {} \
         errors, {} wire errors in {:.2}s",
        report.sent,
        report.ok,
        report.rejected,
        report.rejected_fraction(),
        report.errors,
        report.protocol_errors,
        report.duration_s
    );
    println!(
        "latency µs: p50 {:.0} | p99 {:.0} | p99.9 {:.0} | mean {:.0} \
         ({:.1} req/s)",
        report.p50_us,
        report.p99_us,
        report.p999_us,
        report.mean_us,
        report.throughput_rps
    );
    let out = match args.get("out").as_str() {
        "" => default_json_path(),
        p => PathBuf::from(p),
    };
    write_json(&out, &report)?;
    println!("report -> {}", out.display());

    anyhow::ensure!(
        report.protocol_errors == 0,
        "{} wire-level protocol errors (healthy servers report zero)",
        report.protocol_errors
    );
    let max_rejected = args.get("max-rejected");
    if !max_rejected.is_empty() {
        let cap: f64 = max_rejected
            .parse()
            .map_err(|e| anyhow::anyhow!("--max-rejected: {e}"))?;
        anyhow::ensure!(
            report.rejected_fraction() <= cap,
            "rejected fraction {:.3} exceeds --max-rejected {cap}",
            report.rejected_fraction()
        );
    }
    if smoke {
        anyhow::ensure!(
            report.ok > 0,
            "smoke run completed zero requests against {}",
            cfg.addr
        );
    }
    Ok(())
}

/// One worker thread's tally, merged into the final report.
#[derive(Default)]
struct WorkerTally {
    sent: u64,
    ok: u64,
    rejected: u64,
    errors: u64,
    protocol_errors: u64,
    latencies_us: Vec<f64>,
}

/// Build request `seq` of connection `conn` — pure function of the
/// config seed, so a run's mix is reproducible.
fn build_request(
    cfg: &LoadgenConfig,
    rng: &mut Pcg64,
    conn: usize,
    seq: u64,
) -> WireRequest {
    // Scenario-driven mixes replace the synthetic route mix: each
    // request replays one scenario file's rollout. The early return
    // keeps the flag-driven path below byte-identical to earlier
    // releases' mixes (no extra RNG draws) when no scenarios are given.
    if !cfg.scenarios.is_empty() {
        let sc = &cfg.scenarios
            [rng.below(cfg.scenarios.len() as u64) as usize];
        let mut req = sc.to_request();
        if req.seed.is_none() {
            req = req.with_seed(derive_stream_seed(
                cfg.seed,
                ((conn as u64) << 32) | seq,
            ));
        }
        return WireRequest {
            id: ((conn as u64) << 32) | seq,
            route: sc.twin.clone(),
            req,
        };
    }
    let route = cfg.routes[rng.below(cfg.routes.len() as u64) as usize]
        .clone();
    // The mix preset shapes the tail. Uniform draws nothing extra, so
    // uniform runs stay byte-identical to earlier releases' mixes.
    let (steps, widen) = match cfg.mix {
        Mix::Uniform => (cfg.steps.max(2), 1),
        Mix::HeavyTail => {
            let steps = if rng.below(10) == 0 {
                cfg.steps.max(2) * 4
            } else {
                cfg.steps.max(2)
            };
            let widen = match rng.below(20) {
                0 => 8,
                1..=3 => 2,
                _ => 1,
            };
            (steps, widen)
        }
    };
    // Driven twins (hp/*) need a stimulus; autonomous ones ignore it.
    let mut req = if route.starts_with("hp/") {
        TwinRequest::driven(Vec::new(), steps, Waveform::sine(1.0, 4.0))
    } else {
        TwinRequest::autonomous(Vec::new(), steps)
    }
    .with_seed(derive_stream_seed(cfg.seed, ((conn as u64) << 32) | seq));
    if cfg.ensemble_members > 0 && rng.uniform() < cfg.ensemble_fraction {
        req = req.with_ensemble(EnsembleSpec::new(
            cfg.ensemble_members.max(1) * widen,
        ));
    }
    // Ids encode (connection, sequence): unique across the whole run.
    WireRequest { id: ((conn as u64) << 32) | seq, route, req }
}

/// Classify one response into the tally.
fn record(tally: &mut WorkerTally, resp: Result<WireResponse>, t0: Instant) {
    match resp {
        Ok(WireResponse::Ok(_)) => {
            tally.ok += 1;
            tally
                .latencies_us
                .push(t0.elapsed().as_secs_f64() * 1e6);
        }
        Ok(WireResponse::Err(e)) => {
            if e.code == ErrorCode::RejectedOverload {
                tally.rejected += 1;
            } else {
                tally.errors += 1;
            }
            tally
                .latencies_us
                .push(t0.elapsed().as_secs_f64() * 1e6);
        }
        Err(_) => tally.protocol_errors += 1,
    }
}

/// Drive the server at `cfg.addr` and return the merged report.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    anyhow::ensure!(cfg.conns >= 1, "loadgen needs >= 1 connection");
    anyhow::ensure!(
        !cfg.routes.is_empty() || !cfg.scenarios.is_empty(),
        "loadgen needs >= 1 route or scenario"
    );
    let started = Instant::now();
    let deadline = started + Duration::from_secs_f64(cfg.duration_s.max(0.0));
    let mut handles = Vec::new();
    for conn in 0..cfg.conns {
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || -> Result<WorkerTally> {
            let mut client = WireClient::connect(&cfg.addr)?;
            let mut rng =
                Pcg64::new(derive_stream_seed(cfg.seed, conn as u64), 1);
            let mut tally = WorkerTally::default();
            let pace = if cfg.rate_hz > 0.0 {
                Some(Duration::from_secs_f64(1.0 / cfg.rate_hz))
            } else {
                None
            };
            let mut next_send = Instant::now();
            let mut seq = 0u64;
            while Instant::now() < deadline {
                if let Some(gap) = pace {
                    let now = Instant::now();
                    if now < next_send {
                        std::thread::sleep(next_send - now);
                    }
                    next_send += gap;
                }
                seq += 1;
                let w = build_request(&cfg, &mut rng, conn, seq);
                let t0 = Instant::now();
                tally.sent += 1;
                record(&mut tally, client.call(&w), t0);
            }
            Ok(tally)
        }));
    }
    let mut report = LoadgenReport::default();
    let mut latencies = Vec::new();
    for h in handles {
        let tally = h
            .join()
            .map_err(|_| anyhow::anyhow!("loadgen worker panicked"))?
            .context("loadgen worker failed")?;
        report.sent += tally.sent;
        report.ok += tally.ok;
        report.rejected += tally.rejected;
        report.errors += tally.errors;
        report.protocol_errors += tally.protocol_errors;
        latencies.extend(tally.latencies_us);
    }
    report.duration_s = started.elapsed().as_secs_f64();
    if !latencies.is_empty() {
        report.p50_us = stats::percentile(&latencies, 50.0);
        report.p99_us = stats::percentile(&latencies, 99.0);
        report.p999_us = stats::percentile(&latencies, 99.9);
        report.mean_us =
            latencies.iter().sum::<f64>() / latencies.len() as f64;
        report.throughput_rps =
            latencies.len() as f64 / report.duration_s.max(1e-9);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_mix_is_deterministic_per_seed() {
        let cfg = LoadgenConfig {
            ensemble_fraction: 0.5,
            ..LoadgenConfig::default()
        };
        let build = |seed: u64| -> Vec<(u64, String, Option<usize>)> {
            let mut rng = Pcg64::new(derive_stream_seed(seed, 0), 1);
            (1..=16)
                .map(|seq| {
                    let w = build_request(&cfg, &mut rng, 0, seq);
                    (
                        w.id,
                        w.route,
                        w.req.ensemble.map(|e| e.members),
                    )
                })
                .collect()
        };
        assert_eq!(build(42), build(42), "same seed, same mix");
        // The mix actually exercises ensembles at this fraction.
        let mix = build(42);
        assert!(mix.iter().any(|(_, _, e)| e.is_some()));
        assert!(mix.iter().any(|(_, _, e)| e.is_none()));
    }

    #[test]
    fn heavy_tail_mix_is_deterministic_and_actually_heavy() {
        let cfg = LoadgenConfig {
            steps: 8,
            ensemble_fraction: 0.5,
            ensemble_members: 4,
            mix: Mix::HeavyTail,
            ..LoadgenConfig::default()
        };
        let build = |seed: u64| -> Vec<(usize, Option<usize>)> {
            let mut rng = Pcg64::new(derive_stream_seed(seed, 0), 1);
            (1..=128)
                .map(|seq| {
                    let w = build_request(&cfg, &mut rng, 0, seq);
                    (w.req.n_points, w.req.ensemble.map(|e| e.members))
                })
                .collect()
        };
        assert_eq!(build(42), build(42), "same seed, same heavy tail");
        let mix = build(42);
        // The body of the distribution stays light...
        assert!(mix.iter().any(|(n, e)| *n == 8 && e.is_none()));
        // ...but the tail carries 4x rollouts and widened ensembles.
        assert!(mix.iter().any(|(n, _)| *n == 32), "no long rollouts");
        assert!(
            mix.iter().any(|(_, e)| matches!(e, Some(m) if *m > 4)),
            "no widened ensembles"
        );
    }

    #[test]
    fn scenario_mix_replays_scenario_requests() {
        use crate::twin::scenario::Scenario;
        let pinned = Scenario::parse(
            "twin kuramoto/digital\nsteps 12\nseed 5\n",
        )
        .unwrap();
        let unpinned = Scenario::parse(
            "twin hp/digital\nsteps 6\nstimulus sine 1.0 50.0\n\
             ensemble 4\n",
        )
        .unwrap();
        let cfg = LoadgenConfig {
            scenarios: vec![pinned, unpinned],
            ..LoadgenConfig::default()
        };
        let build = |seed: u64| -> Vec<(String, usize, Option<u64>)> {
            let mut rng = Pcg64::new(derive_stream_seed(seed, 0), 1);
            (1..=16)
                .map(|seq| {
                    let w = build_request(&cfg, &mut rng, 0, seq);
                    (w.route, w.req.n_points, w.req.seed)
                })
                .collect()
        };
        assert_eq!(build(42), build(42), "same seed, same scenario mix");
        let mix = build(42);
        for (route, steps, seed) in &mix {
            match route.as_str() {
                "kuramoto/digital" => {
                    assert_eq!(*steps, 12);
                    assert_eq!(*seed, Some(5), "file-pinned seed kept");
                }
                "hp/digital" => {
                    assert_eq!(*steps, 6);
                    assert!(seed.is_some(), "stream seed stamped");
                    assert_ne!(*seed, Some(5));
                }
                other => panic!("unexpected route {other}"),
            }
        }
        assert!(mix.iter().any(|(r, _, _)| r == "kuramoto/digital"));
        assert!(mix.iter().any(|(r, _, _)| r == "hp/digital"));
    }

    #[test]
    fn ids_encode_connection_and_sequence() {
        let cfg = LoadgenConfig::default();
        let mut rng = Pcg64::new(1, 1);
        let w = build_request(&cfg, &mut rng, 3, 17);
        assert_eq!(w.id, (3u64 << 32) | 17);
        // Request seeds are pinned (stamped client-side, replayable).
        assert!(w.req.seed.is_some());
    }

    #[test]
    fn report_arithmetic_and_json_shape() {
        let report = LoadgenReport {
            sent: 10,
            ok: 7,
            rejected: 2,
            errors: 1,
            p50_us: 100.0,
            p99_us: 400.0,
            p999_us: 900.0,
            ..LoadgenReport::default()
        };
        assert!((report.rejected_fraction() - 0.2).abs() < 1e-12);
        let j = report.to_json();
        assert_eq!(j.get("sent").and_then(Json::as_f64), Some(10.0));
        assert_eq!(
            j.get("rejected_fraction").and_then(Json::as_f64),
            Some(0.2)
        );
        assert_eq!(j.get("p999_us").and_then(Json::as_f64), Some(900.0));
        // Empty runs divide to zero, not NaN.
        assert_eq!(LoadgenReport::default().rejected_fraction(), 0.0);
    }

    #[test]
    fn serve_gate_flags_p99_and_throughput_and_shed_regressions() {
        let doc = |p99: f64, rps: f64, rej: f64| {
            Json::obj(vec![
                ("p99_us", Json::Num(p99)),
                ("throughput_rps", Json::Num(rps)),
                ("rejected_fraction", Json::Num(rej)),
            ])
        };
        let base = doc(1000.0, 500.0, 0.01);
        // Within the allowance: pass, nothing to ratchet.
        let r = gate_serve_against_baseline(
            &base,
            &doc(1100.0, 480.0, 0.02),
            0.25,
        )
        .unwrap();
        assert!(r.passed() && !r.improved(), "{:?}", r);
        assert_eq!(r.compared, 3);
        // p99 blew the allowance.
        let r = gate_serve_against_baseline(
            &base,
            &doc(1500.0, 500.0, 0.01),
            0.25,
        )
        .unwrap();
        assert!(!r.passed());
        assert!(r.failures[0].contains("p99_us"), "{:?}", r.failures);
        // Throughput collapsed.
        let r = gate_serve_against_baseline(
            &base,
            &doc(1000.0, 300.0, 0.01),
            0.25,
        )
        .unwrap();
        assert!(!r.passed());
        // Sheds grew past the absolute allowance.
        let r = gate_serve_against_baseline(
            &base,
            &doc(1000.0, 500.0, 0.5),
            0.25,
        )
        .unwrap();
        assert!(!r.passed());
        // A real improvement is a ratchet candidate.
        let r = gate_serve_against_baseline(
            &base,
            &doc(600.0, 900.0, 0.0),
            0.25,
        )
        .unwrap();
        assert!(r.passed() && r.improved());
        // Malformed documents are errors, not silent passes.
        assert!(gate_serve_against_baseline(
            &Json::obj(vec![]),
            &base,
            0.25
        )
        .is_err());
    }

    #[test]
    fn zero_duration_run_reports_cleanly_against_nothing() {
        // duration 0 => the workers exit before sending; no server
        // needed beyond the TCP connect, so point at a bound listener.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let cfg = LoadgenConfig {
            addr: listener.local_addr().unwrap().to_string(),
            conns: 2,
            duration_s: 0.0,
            ..LoadgenConfig::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.sent, 0);
        assert_eq!(report.protocol_errors, 0);
        assert_eq!(report.rejected_fraction(), 0.0);
    }
}
