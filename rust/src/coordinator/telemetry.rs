//! Serving telemetry: lock-free counters plus a bounded latency reservoir,
//! per-route admission/shed counters, and per-route device-lifetime
//! status published by health-monitored twins.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::twin::health::LifetimeSnapshot;
use crate::util::stats;

/// Maximum retained latency samples (reservoir, newest-wins ring).
const RESERVOIR: usize = 4096;

/// Maximum retained (job id, noise seed) replay pairs.
const SEED_RING: usize = 64;

/// Smoothing factor of the per-route execution-time EWMA: each observed
/// batch execution moves the estimate 20% of the way to the new sample,
/// so the estimate settles within ~10 batches yet rides out one-off
/// stragglers. The adaptive batcher reads this estimate to size each
/// route's maturity window.
const EXEC_EWMA_ALPHA: f64 = 0.2;

/// Shared serving metrics.
#[derive(Debug, Default)]
pub struct Telemetry {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of batch sizes (mean batch size = / batches).
    pub batched_jobs: AtomicU64,
    /// Tile-sharded rollouts executed (one per sharded solve fan-out).
    pub shard_rollouts: AtomicU64,
    /// Total shard-worker circuit steps across all sharded rollouts.
    pub shard_steps: AtomicU64,
    /// Monte-Carlo ensemble rollouts served (one per ensemble request).
    pub ensemble_rollouts: AtomicU64,
    /// Total ensemble members across those rollouts.
    pub ensemble_members: AtomicU64,
    /// TCP connections accepted by the network front door.
    pub net_connections: AtomicU64,
    /// Connections refused at the accept gate (connection cap reached).
    pub net_conns_rejected: AtomicU64,
    /// Request frames decoded off sockets.
    pub net_frames_in: AtomicU64,
    /// Response frames written back to sockets.
    pub net_frames_out: AtomicU64,
    /// Wire-protocol violations observed (unparsable frames, oversized
    /// lengths, malformed requests).
    pub net_protocol_errors: AtomicU64,
    latencies_us: Mutex<Ring<f64, RESERVOIR>>,
    /// Recent (job id, noise seed) pairs of completed jobs — enough for
    /// the serve CLI to print replay commands (`run-twin --seed <s>`).
    seeds: Mutex<Ring<(u64, u64), SEED_RING>>,
    /// Per-route admission counters recorded at the router's backpressure
    /// gate (admitted vs shed). Sorted map so snapshots print stably.
    route_load: Mutex<BTreeMap<String, RouteLoad>>,
    /// Latest per-route device-lifetime status, published by
    /// health-monitored twins ([`crate::twin::health::MonitoredTwin`]).
    lifetime: Mutex<BTreeMap<String, LifetimeSnapshot>>,
    /// Per-route EWMA of observed batch execution time (s), recorded by
    /// scheduler workers after every executed batch and read by the
    /// adaptive batcher to size that route's maturity window.
    route_exec_s: Mutex<BTreeMap<String, f64>>,
    /// Reusable latency-stats scratch for [`Telemetry::snapshot`]: the
    /// ring is *copied* out under its lock, then sorted and reduced here
    /// with the ring lock released — the hot `record_latency` path never
    /// waits behind a snapshot's sort. Guarded by its own (snapshot-only,
    /// uncontended) mutex so `snapshot(&self)` stays shareable.
    snapshot_scratch: Mutex<Vec<f64>>,
}

/// Per-route admission counters at the backpressure gate.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RouteLoad {
    /// Requests admitted past the gate.
    pub admitted: u64,
    /// Requests shed (rejected for overload).
    pub shed: u64,
}

impl RouteLoad {
    /// Fraction of this route's submissions that were shed (NaN with no
    /// traffic).
    pub fn shed_fraction(&self) -> f64 {
        let total = self.admitted + self.shed;
        if total == 0 {
            f64::NAN
        } else {
            self.shed as f64 / total as f64
        }
    }
}

/// Bounded newest-wins ring: fills to `N`, then overwrites oldest-first.
/// Backs both the latency reservoir (order-insensitive stats over `buf`)
/// and the seed replay ring (chronological snapshots).
#[derive(Debug)]
struct Ring<T, const N: usize> {
    buf: Vec<T>,
    next: usize,
}

impl<T, const N: usize> Default for Ring<T, N> {
    fn default() -> Self {
        Self { buf: Vec::new(), next: 0 }
    }
}

impl<T: Copy, const N: usize> Ring<T, N> {
    fn push(&mut self, x: T) {
        if self.buf.len() < N {
            self.buf.push(x);
        } else {
            self.buf[self.next] = x;
        }
        self.next = (self.next + 1) % N;
    }

    /// Contents oldest-first (rotates a wrapped ring).
    fn chronological(&self) -> Vec<T> {
        let mut v = Vec::with_capacity(self.buf.len());
        if self.buf.len() == N {
            v.extend_from_slice(&self.buf[self.next..]);
            v.extend_from_slice(&self.buf[..self.next]);
        } else {
            v.extend_from_slice(&self.buf);
        }
        v
    }
}

impl Telemetry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, wait_s: f64, exec_s: f64) {
        let us = (wait_s + exec_s) * 1e6;
        self.latencies_us.lock().expect("telemetry lock").push(us);
    }

    /// Record a completed job's noise seed (newest-wins ring) so replay
    /// commands can be surfaced without holding every response.
    pub fn record_seed(&self, job_id: u64, seed: u64) {
        self.seeds.lock().expect("telemetry lock").push((job_id, seed));
    }

    /// Record a request admitted past the backpressure gate on `route`.
    /// Allocation-free after the route's first record.
    pub fn record_admitted(&self, route: &str) {
        let mut map = self.route_load.lock().expect("telemetry lock");
        if let Some(r) = map.get_mut(route) {
            r.admitted += 1;
        } else {
            map.insert(
                route.to_owned(),
                RouteLoad { admitted: 1, shed: 0 },
            );
        }
    }

    /// Record a request shed at the backpressure gate on `route`.
    pub fn record_shed(&self, route: &str) {
        let mut map = self.route_load.lock().expect("telemetry lock");
        if let Some(r) = map.get_mut(route) {
            r.shed += 1;
        } else {
            map.insert(route.to_owned(), RouteLoad { admitted: 0, shed: 1 });
        }
    }

    /// Fold one observed batch execution time (s) into `route`'s EWMA.
    /// Non-finite or negative samples are dropped — a poisoned timing
    /// must never wedge a route's batch window. Allocation-free after
    /// the route's first record.
    pub fn record_route_exec(&self, route: &str, exec_s: f64) {
        if !exec_s.is_finite() || exec_s < 0.0 {
            return;
        }
        let mut map = self.route_exec_s.lock().expect("telemetry lock");
        if let Some(e) = map.get_mut(route) {
            *e += EXEC_EWMA_ALPHA * (exec_s - *e);
        } else {
            map.insert(route.to_owned(), exec_s);
        }
    }

    /// Current execution-time EWMA (s) for `route`, if any batch has
    /// completed on it yet.
    pub fn route_exec_ewma(&self, route: &str) -> Option<f64> {
        self.route_exec_s
            .lock()
            .expect("telemetry lock")
            .get(route)
            .copied()
    }

    /// Publish a route's latest device-lifetime status (newest wins).
    pub fn record_lifetime(&self, route: &str, snap: LifetimeSnapshot) {
        let mut map = self.lifetime.lock().expect("telemetry lock");
        if let Some(s) = map.get_mut(route) {
            *s = snap;
        } else {
            map.insert(route.to_owned(), snap);
        }
    }

    /// Point-in-time snapshot.
    ///
    /// Latency stats are computed from a single sort: the ring is copied
    /// into the reusable scratch under the ring lock (cheap memcpy), the
    /// lock is dropped, and p50/p95/mean come off the sorted scratch —
    /// no per-percentile clone-and-sort, and no sorting under the lock
    /// the request path records into. Non-finite samples are skipped so
    /// one poisoned latency can never corrupt (or panic) a snapshot.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let mut scratch =
            self.snapshot_scratch.lock().expect("telemetry lock");
        {
            let ring = self.latencies_us.lock().expect("telemetry lock");
            scratch.clear();
            scratch
                .extend(ring.buf.iter().copied().filter(|x| x.is_finite()));
        }
        let (p50, p95, p99, mean) = if scratch.is_empty() {
            (f64::NAN, f64::NAN, f64::NAN, f64::NAN)
        } else {
            scratch.sort_unstable_by(f64::total_cmp);
            let mean =
                scratch.iter().sum::<f64>() / scratch.len() as f64;
            (
                stats::percentile_of_sorted(&scratch[..], 50.0),
                stats::percentile_of_sorted(&scratch[..], 95.0),
                stats::percentile_of_sorted(&scratch[..], 99.0),
                mean,
            )
        };
        drop(scratch);
        let batches = self.batches.load(Ordering::Relaxed);
        TelemetrySnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches == 0 {
                f64::NAN
            } else {
                self.batched_jobs.load(Ordering::Relaxed) as f64
                    / batches as f64
            },
            latency_p50_us: p50,
            latency_p95_us: p95,
            latency_p99_us: p99,
            latency_mean_us: mean,
            shard_rollouts: self.shard_rollouts.load(Ordering::Relaxed),
            shard_steps: self.shard_steps.load(Ordering::Relaxed),
            ensemble_rollouts: self
                .ensemble_rollouts
                .load(Ordering::Relaxed),
            ensemble_members: self
                .ensemble_members
                .load(Ordering::Relaxed),
            net_connections: self.net_connections.load(Ordering::Relaxed),
            net_conns_rejected: self
                .net_conns_rejected
                .load(Ordering::Relaxed),
            net_frames_in: self.net_frames_in.load(Ordering::Relaxed),
            net_frames_out: self.net_frames_out.load(Ordering::Relaxed),
            net_protocol_errors: self
                .net_protocol_errors
                .load(Ordering::Relaxed),
            recent_seeds: self
                .seeds
                .lock()
                .expect("telemetry lock")
                .chronological(),
            route_load: self
                .route_load
                .lock()
                .expect("telemetry lock")
                .iter()
                .map(|(k, v)| (k.clone(), *v))
                .collect(),
            lifetime: self
                .lifetime
                .lock()
                .expect("telemetry lock")
                .iter()
                .map(|(k, v)| (k.clone(), *v))
                .collect(),
            route_exec_s: self
                .route_exec_s
                .lock()
                .expect("telemetry lock")
                .iter()
                .map(|(k, v)| (k.clone(), *v))
                .collect(),
        }
    }
}

/// Immutable metrics snapshot.
#[derive(Debug, Clone)]
pub struct TelemetrySnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub latency_p50_us: f64,
    pub latency_p95_us: f64,
    pub latency_p99_us: f64,
    pub latency_mean_us: f64,
    /// Tile-sharded rollouts served.
    pub shard_rollouts: u64,
    /// Shard-worker circuit steps across those rollouts.
    pub shard_steps: u64,
    /// Monte-Carlo ensemble rollouts served.
    pub ensemble_rollouts: u64,
    /// Total ensemble members across those rollouts (mean ensemble width
    /// = / ensemble_rollouts).
    pub ensemble_members: u64,
    /// TCP connections accepted by the network front door.
    pub net_connections: u64,
    /// Connections refused at the accept gate.
    pub net_conns_rejected: u64,
    /// Request frames decoded off sockets.
    pub net_frames_in: u64,
    /// Response frames written back to sockets.
    pub net_frames_out: u64,
    /// Wire-protocol violations observed.
    pub net_protocol_errors: u64,
    /// Recent (job id, noise seed) pairs — replay handles for the last
    /// completed jobs (bounded ring, oldest first; the tail is the most
    /// recent).
    pub recent_seeds: Vec<(u64, u64)>,
    /// Per-route (admitted, shed) counters, route-name sorted.
    pub route_load: Vec<(String, RouteLoad)>,
    /// Latest per-route device-lifetime status, route-name sorted.
    pub lifetime: Vec<(String, LifetimeSnapshot)>,
    /// Per-route batch execution-time EWMA (s), route-name sorted — the
    /// signal the adaptive batcher sizes maturity windows from.
    pub route_exec_s: Vec<(String, f64)>,
}

impl TelemetrySnapshot {
    /// Overall shed fraction at the admission gate: rejected over
    /// everything that reached the router (NaN with no traffic).
    pub fn rejected_fraction(&self) -> f64 {
        let total = self.submitted + self.rejected;
        if total == 0 {
            f64::NAN
        } else {
            self.rejected as f64 / total as f64
        }
    }
}

impl std::fmt::Display for TelemetrySnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "submitted={} completed={} failed={} rejected={} batches={} \
             mean_batch={:.1} p50={:.0}µs p95={:.0}µs",
            self.submitted,
            self.completed,
            self.failed,
            self.rejected,
            self.batches,
            self.mean_batch,
            self.latency_p50_us,
            self.latency_p95_us
        )?;
        let frac = self.rejected_fraction();
        if frac.is_finite() {
            write!(f, " shed_frac={frac:.3}")?;
        }
        if self.net_connections + self.net_conns_rejected > 0 {
            write!(
                f,
                " net[conns={} refused={} in={} out={} proto_err={}]",
                self.net_connections,
                self.net_conns_rejected,
                self.net_frames_in,
                self.net_frames_out,
                self.net_protocol_errors
            )?;
        }
        for (route, s) in &self.lifetime {
            if s.degraded {
                write!(f, " DEGRADED[{route}]")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latency() {
        let t = Telemetry::new();
        t.submitted.fetch_add(3, Ordering::Relaxed);
        t.completed.fetch_add(2, Ordering::Relaxed);
        t.record_latency(1e-3, 2e-3);
        t.record_latency(2e-3, 2e-3);
        let s = t.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 2);
        assert!((s.latency_p50_us - 3500.0).abs() < 1.0);
    }

    #[test]
    fn empty_snapshot_is_nan_not_panic() {
        let s = Telemetry::new().snapshot();
        assert!(s.latency_p50_us.is_nan());
    }

    #[test]
    fn reservoir_bounded() {
        let t = Telemetry::new();
        for k in 0..(RESERVOIR * 2) {
            t.record_latency(k as f64 * 1e-6, 0.0);
        }
        let ring = t.latencies_us.lock().unwrap();
        assert_eq!(ring.buf.len(), RESERVOIR);
    }

    #[test]
    fn nan_latency_sample_cannot_poison_snapshot() {
        let t = Telemetry::new();
        t.record_latency(1e-3, 1e-3);
        t.record_latency(f64::NAN, 0.0);
        t.record_latency(3e-3, 1e-3);
        let s = t.snapshot();
        assert!(s.latency_p50_us.is_finite());
        assert!((s.latency_p50_us - 3000.0).abs() < 1.0);
        assert!((s.latency_p95_us - 3900.0).abs() < 1.0);
        assert!(s.latency_mean_us.is_finite());
    }

    #[test]
    fn ensemble_counters_surface_in_snapshot() {
        let t = Telemetry::new();
        t.ensemble_rollouts.fetch_add(2, Ordering::Relaxed);
        t.ensemble_members.fetch_add(64, Ordering::Relaxed);
        let s = t.snapshot();
        assert_eq!(s.ensemble_rollouts, 2);
        assert_eq!(s.ensemble_members, 64);
    }

    #[test]
    fn mean_batch_computed() {
        let t = Telemetry::new();
        t.batches.fetch_add(2, Ordering::Relaxed);
        t.batched_jobs.fetch_add(10, Ordering::Relaxed);
        assert!((t.snapshot().mean_batch - 5.0).abs() < 1e-12);
    }

    #[test]
    fn route_load_counters_and_shed_fraction() {
        let t = Telemetry::new();
        t.record_admitted("lorenz96/analog");
        t.record_admitted("lorenz96/analog");
        t.record_shed("lorenz96/analog");
        t.record_admitted("hp/digital");
        let s = t.snapshot();
        assert_eq!(s.route_load.len(), 2);
        // BTreeMap ordering: "hp/digital" < "lorenz96/analog".
        assert_eq!(s.route_load[0].0, "hp/digital");
        assert_eq!(
            s.route_load[0].1,
            RouteLoad { admitted: 1, shed: 0 }
        );
        let l96 = &s.route_load[1].1;
        assert_eq!(*l96, RouteLoad { admitted: 2, shed: 1 });
        assert!((l96.shed_fraction() - 1.0 / 3.0).abs() < 1e-12);
        assert!(RouteLoad::default().shed_fraction().is_nan());
    }

    #[test]
    fn rejected_fraction_tracks_gate_counters() {
        let t = Telemetry::new();
        assert!(t.snapshot().rejected_fraction().is_nan());
        t.submitted.fetch_add(6, Ordering::Relaxed);
        t.rejected.fetch_add(2, Ordering::Relaxed);
        let s = t.snapshot();
        assert!((s.rejected_fraction() - 0.25).abs() < 1e-12);
        assert!(format!("{s}").contains("shed_frac=0.250"));
    }

    #[test]
    fn lifetime_status_latest_wins_and_flags_degraded() {
        use crate::twin::health::LifetimeSnapshot;
        let t = Telemetry::new();
        t.record_lifetime(
            "lorenz96/analog-aged",
            LifetimeSnapshot { age_s: 1.0, ..Default::default() },
        );
        t.record_lifetime(
            "lorenz96/analog-aged",
            LifetimeSnapshot {
                age_s: 2.0,
                degraded: true,
                ..Default::default()
            },
        );
        let s = t.snapshot();
        assert_eq!(s.lifetime.len(), 1);
        assert_eq!(s.lifetime[0].1.age_s, 2.0);
        assert!(s.lifetime[0].1.degraded);
        assert!(
            format!("{s}").contains("DEGRADED[lorenz96/analog-aged]")
        );
    }

    #[test]
    fn net_counters_surface_in_snapshot_and_display() {
        let t = Telemetry::new();
        assert!(!format!("{}", t.snapshot()).contains("net["));
        t.net_connections.fetch_add(3, Ordering::Relaxed);
        t.net_conns_rejected.fetch_add(1, Ordering::Relaxed);
        t.net_frames_in.fetch_add(10, Ordering::Relaxed);
        t.net_frames_out.fetch_add(9, Ordering::Relaxed);
        t.net_protocol_errors.fetch_add(2, Ordering::Relaxed);
        let s = t.snapshot();
        assert_eq!(s.net_connections, 3);
        assert_eq!(s.net_conns_rejected, 1);
        assert_eq!(s.net_frames_in, 10);
        assert_eq!(s.net_frames_out, 9);
        assert_eq!(s.net_protocol_errors, 2);
        let line = format!("{s}");
        assert!(line.contains("net[conns=3 refused=1"), "{line}");
    }

    #[test]
    fn route_exec_ewma_converges_and_rejects_poison() {
        let t = Telemetry::new();
        assert!(t.route_exec_ewma("lorenz96/analog").is_none());
        // First sample seeds the estimate exactly.
        t.record_route_exec("lorenz96/analog", 10e-3);
        assert_eq!(t.route_exec_ewma("lorenz96/analog"), Some(10e-3));
        // Subsequent samples blend at alpha = 0.2.
        t.record_route_exec("lorenz96/analog", 20e-3);
        let e = t.route_exec_ewma("lorenz96/analog").unwrap();
        assert!((e - 12e-3).abs() < 1e-12, "{e}");
        // NaN / negative samples are dropped, not folded in.
        t.record_route_exec("lorenz96/analog", f64::NAN);
        t.record_route_exec("lorenz96/analog", -1.0);
        assert_eq!(t.route_exec_ewma("lorenz96/analog"), Some(e));
        // Routes are independent; snapshot carries the sorted map.
        t.record_route_exec("hp/digital", 1e-3);
        let s = t.snapshot();
        assert_eq!(s.route_exec_s.len(), 2);
        assert_eq!(s.route_exec_s[0].0, "hp/digital");
        assert_eq!(s.route_exec_s[0].1, 1e-3);
    }

    #[test]
    fn p99_comes_from_the_same_sorted_reservoir() {
        let t = Telemetry::new();
        for k in 1..=100 {
            t.record_latency(k as f64 * 1e-6, 0.0);
        }
        let s = t.snapshot();
        assert!(s.latency_p50_us <= s.latency_p95_us);
        assert!(s.latency_p95_us <= s.latency_p99_us);
        assert!((s.latency_p99_us - 99.0).abs() < 1.5);
    }

    #[test]
    fn seed_ring_records_and_stays_bounded() {
        let t = Telemetry::new();
        t.record_seed(1, 111);
        t.record_seed(2, 222);
        let s = t.snapshot();
        assert!(s.recent_seeds.contains(&(1, 111)));
        assert!(s.recent_seeds.contains(&(2, 222)));
        for k in 0..(SEED_RING as u64 * 2) {
            t.record_seed(k, k);
        }
        let seeds = t.snapshot().recent_seeds;
        assert_eq!(seeds.len(), SEED_RING);
        // Chronological after wraparound: the tail is the newest entry.
        assert_eq!(seeds.last(), Some(&(SEED_RING as u64 * 2 - 1, SEED_RING as u64 * 2 - 1)));
        assert!(seeds.windows(2).all(|w| w[0].0 < w[1].0));
    }
}
