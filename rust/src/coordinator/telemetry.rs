//! Serving telemetry: lock-free counters plus a bounded latency reservoir.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats;

/// Maximum retained latency samples (reservoir, newest-wins ring).
const RESERVOIR: usize = 4096;

/// Shared serving metrics.
#[derive(Debug, Default)]
pub struct Telemetry {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of batch sizes (mean batch size = / batches).
    pub batched_jobs: AtomicU64,
    /// Tile-sharded rollouts executed (one per sharded solve fan-out).
    pub shard_rollouts: AtomicU64,
    /// Total shard-worker circuit steps across all sharded rollouts.
    pub shard_steps: AtomicU64,
    latencies_us: Mutex<LatencyRing>,
}

#[derive(Debug, Default)]
struct LatencyRing {
    buf: Vec<f64>,
    next: usize,
}

impl Telemetry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, wait_s: f64, exec_s: f64) {
        let us = (wait_s + exec_s) * 1e6;
        let mut ring = self.latencies_us.lock().expect("telemetry lock");
        if ring.buf.len() < RESERVOIR {
            ring.buf.push(us);
        } else {
            let slot = ring.next;
            ring.buf[slot] = us;
        }
        ring.next = (ring.next + 1) % RESERVOIR;
    }

    /// Point-in-time snapshot.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let ring = self.latencies_us.lock().expect("telemetry lock");
        let (p50, p95, mean) = if ring.buf.is_empty() {
            (f64::NAN, f64::NAN, f64::NAN)
        } else {
            (
                stats::median(&ring.buf),
                stats::percentile(&ring.buf, 95.0),
                stats::summary(&ring.buf).mean,
            )
        };
        let batches = self.batches.load(Ordering::Relaxed);
        TelemetrySnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches == 0 {
                f64::NAN
            } else {
                self.batched_jobs.load(Ordering::Relaxed) as f64
                    / batches as f64
            },
            latency_p50_us: p50,
            latency_p95_us: p95,
            latency_mean_us: mean,
            shard_rollouts: self.shard_rollouts.load(Ordering::Relaxed),
            shard_steps: self.shard_steps.load(Ordering::Relaxed),
        }
    }
}

/// Immutable metrics snapshot.
#[derive(Debug, Clone)]
pub struct TelemetrySnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub latency_p50_us: f64,
    pub latency_p95_us: f64,
    pub latency_mean_us: f64,
    /// Tile-sharded rollouts served.
    pub shard_rollouts: u64,
    /// Shard-worker circuit steps across those rollouts.
    pub shard_steps: u64,
}

impl std::fmt::Display for TelemetrySnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "submitted={} completed={} failed={} rejected={} batches={} \
             mean_batch={:.1} p50={:.0}µs p95={:.0}µs",
            self.submitted,
            self.completed,
            self.failed,
            self.rejected,
            self.batches,
            self.mean_batch,
            self.latency_p50_us,
            self.latency_p95_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latency() {
        let t = Telemetry::new();
        t.submitted.fetch_add(3, Ordering::Relaxed);
        t.completed.fetch_add(2, Ordering::Relaxed);
        t.record_latency(1e-3, 2e-3);
        t.record_latency(2e-3, 2e-3);
        let s = t.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 2);
        assert!((s.latency_p50_us - 3500.0).abs() < 1.0);
    }

    #[test]
    fn empty_snapshot_is_nan_not_panic() {
        let s = Telemetry::new().snapshot();
        assert!(s.latency_p50_us.is_nan());
    }

    #[test]
    fn reservoir_bounded() {
        let t = Telemetry::new();
        for k in 0..(RESERVOIR * 2) {
            t.record_latency(k as f64 * 1e-6, 0.0);
        }
        let ring = t.latencies_us.lock().unwrap();
        assert_eq!(ring.buf.len(), RESERVOIR);
    }

    #[test]
    fn mean_batch_computed() {
        let t = Telemetry::new();
        t.batches.fetch_add(2, Ordering::Relaxed);
        t.batched_jobs.fetch_add(10, Ordering::Relaxed);
        assert!((t.snapshot().mean_batch - 5.0).abs() < 1e-12);
    }
}
