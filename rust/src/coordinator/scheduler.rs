//! Worker pool + least-loaded batch dispatch, with optional work stealing.
//!
//! Each worker owns private twin instances (created lazily from the
//! registry the first time a route lands on it) so no twin state is ever
//! shared across threads. The scheduler keeps one deque of batches per
//! worker and sends each batch to the least-loaded worker (fewest
//! outstanding jobs).
//!
//! A worker executes the **whole batch as one [`Twin::run_batch`] call**
//! — the batched execution engine's dispatch point. Twins with batched
//! backends roll every trajectory of the batch out together (one
//! multi-vector crossbar read / GEMM per step); the trait's default keeps
//! plain twins on the serial per-job path. Failures stay per-job, and the
//! recorded execution time is the batch execution time — which is exactly
//! the latency each coalesced client observed.
//!
//! **Work stealing.** With stealing enabled
//! ([`Scheduler::start_with_stealing`]), a worker whose own deque is
//! empty takes a whole batch from the back of the longest peer deque
//! instead of going idle. Stealing moves *entire batches*, never splits
//! one: the batch still executes as a single `run_batch_into` call on
//! exactly one worker, and because every response is a pure function of
//! the seeded request (noise comes from counter-addressed streams, not
//! thread state), which worker runs it cannot change a single output
//! byte. Outstanding-job counts transfer with the stolen batch so
//! least-loaded dispatch keeps seeing true load.
//!
//! **Tile-aware dispatch.** Routes whose state exceeds one physical
//! crossbar array register tile-sharded twins
//! ([`crate::twin::shard::ShardedAnalogOde`]): when a worker executes such
//! a batch, the rollout itself fans out across parallel shard workers —
//! one per tile column-group, barrier-synchronised at every exchange point
//! of every circuit step — and the shard results are stitched back into
//! the pooled response trajectories before the worker replies. The
//! dispatch contract is unchanged (one batch, one `run_batch_into` call,
//! per-job failure isolation); what changes is the execution shape under
//! it, and the shard workers report per-shard counters into the shared
//! [`Telemetry`] (`shard_rollouts` / `shard_steps`) so sharded load is
//! visible next to batching metrics.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::coordinator::telemetry::Telemetry;
use crate::coordinator::{Batch, JobResult};
use crate::twin::registry::TwinRegistry;
use crate::twin::{Twin, TwinRequest, TwinResponse};

/// Handle to the worker pool.
pub struct Scheduler {
    shared: Arc<Shared>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

/// State shared between the dispatcher and every worker.
struct Shared {
    inner: Mutex<Inner>,
    cv: Condvar,
    steal: bool,
    /// Per-worker outstanding-job counts (queued + executing). Kept
    /// outside the mutex so `dispatch` picks a target without blocking
    /// on a worker that holds the queue lock.
    outstanding: Vec<AtomicUsize>,
}

struct Inner {
    /// One FIFO of whole batches per worker. The owner pops the front;
    /// thieves pop the back, so the oldest work keeps its worker
    /// affinity (warm twin instances) and the youngest migrates.
    queues: Vec<VecDeque<Batch>>,
    stop: bool,
}

impl Scheduler {
    /// Spawn `n_workers` workers over a shared registry (no stealing).
    pub fn start(
        n_workers: usize,
        registry: TwinRegistry,
        telemetry: Arc<Telemetry>,
    ) -> Self {
        Self::start_with_stealing(n_workers, registry, telemetry, false)
    }

    /// Spawn `n_workers` workers; when `steal` is set, idle workers take
    /// whole batches from the longest peer deque instead of sleeping.
    pub fn start_with_stealing(
        n_workers: usize,
        registry: TwinRegistry,
        telemetry: Arc<Telemetry>,
        steal: bool,
    ) -> Self {
        assert!(n_workers > 0);
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner {
                queues: (0..n_workers).map(|_| VecDeque::new()).collect(),
                stop: false,
            }),
            cv: Condvar::new(),
            steal,
            outstanding: (0..n_workers)
                .map(|_| AtomicUsize::new(0))
                .collect(),
        });
        let threads = (0..n_workers)
            .map(|i| {
                spawn_worker(
                    i,
                    Arc::clone(&shared),
                    registry.clone(),
                    Arc::clone(&telemetry),
                )
            })
            .collect();
        Self { shared, threads }
    }

    /// Dispatch a batch to the least-loaded worker.
    pub fn dispatch(&self, batch: Batch) -> anyhow::Result<()> {
        let target = (0..self.shared.outstanding.len())
            .min_by_key(|&i| {
                self.shared.outstanding[i].load(Ordering::Relaxed)
            })
            .expect("at least one worker");
        let mut g = self.shared.inner.lock().expect("scheduler lock");
        if g.stop {
            anyhow::bail!("scheduler stopped");
        }
        self.shared.outstanding[target]
            .fetch_add(batch.jobs.len(), Ordering::AcqRel);
        g.queues[target].push_back(batch);
        drop(g);
        // Batch granularity makes notify_all cheap, and it guarantees an
        // idle thief wakes even when the target worker is mid-batch.
        self.shared.cv.notify_all();
        Ok(())
    }

    /// Total outstanding jobs across workers.
    pub fn outstanding(&self) -> usize {
        self.shared
            .outstanding
            .iter()
            .map(|o| o.load(Ordering::Relaxed))
            .sum()
    }

    pub fn n_workers(&self) -> usize {
        self.shared.outstanding.len()
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        {
            let mut g = self.shared.inner.lock().expect("scheduler lock");
            g.stop = true;
        }
        self.shared.cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Blocking fetch of the next batch for worker `index`; `None` = shut
/// down. Own queue first (front), then — with stealing on — the back of
/// the longest peer queue, transferring the outstanding count with the
/// batch. After `stop`, workers keep draining until no fetchable batch
/// remains so every accepted job still gets a reply.
fn next_batch(index: usize, shared: &Shared) -> Option<Batch> {
    let mut g = shared.inner.lock().expect("scheduler lock");
    loop {
        if let Some(b) = g.queues[index].pop_front() {
            return Some(b);
        }
        if shared.steal {
            let victim = (0..g.queues.len())
                .filter(|&j| j != index && !g.queues[j].is_empty())
                .max_by_key(|&j| g.queues[j].len());
            if let Some(v) = victim {
                let b = g.queues[v].pop_back().expect("non-empty victim");
                let n = b.jobs.len();
                shared.outstanding[v].fetch_sub(n, Ordering::AcqRel);
                shared.outstanding[index].fetch_add(n, Ordering::AcqRel);
                return Some(b);
            }
        }
        if g.stop {
            return None;
        }
        g = shared.cv.wait(g).expect("scheduler lock");
    }
}

/// Best-effort panic payload rendering (panics carry a `String` or a
/// `&'static str`; anything else prints a placeholder).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<String>()
        .map(String::as_str)
        .or_else(|| payload.downcast_ref::<&'static str>().copied())
        .unwrap_or("opaque panic payload")
}

fn spawn_worker(
    index: usize,
    shared: Arc<Shared>,
    registry: TwinRegistry,
    telemetry: Arc<Telemetry>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("twin-worker-{index}"))
        .spawn(move || {
            // Worker-private warm twin instances, plus reusable request /
            // result staging vectors. The vectors themselves never re-grow
            // once warm; the per-job `req.clone()` payloads (h0 vectors)
            // still allocate per batch — the zero-allocation contract
            // covers the twins' `run_batch_into`, not this dispatch shim.
            let mut twins: BTreeMap<String, Box<dyn Twin>> = BTreeMap::new();
            let mut reqs: Vec<TwinRequest> = Vec::new();
            let mut results: Vec<anyhow::Result<TwinResponse>> = Vec::new();
            while let Some(batch) = next_batch(index, &shared) {
                let n = batch.jobs.len();
                telemetry.batches.fetch_add(1, Ordering::Relaxed);
                telemetry.batched_jobs.fetch_add(n as u64, Ordering::Relaxed);
                let route = batch.route.clone();
                // Per-job queue wait ends when execution starts.
                let waits: Vec<f64> = batch
                    .jobs
                    .iter()
                    .map(|j| j.enqueued.elapsed().as_secs_f64())
                    .collect();
                let twin = match twins.entry(route.clone()) {
                    std::collections::btree_map::Entry::Occupied(e) => {
                        Ok(e.into_mut())
                    }
                    std::collections::btree_map::Entry::Vacant(e) => {
                        registry.create(&route).map(|t| e.insert(t))
                    }
                };
                let t0 = Instant::now();
                results.clear();
                let mut poisoned = false;
                match twin {
                    Ok(t) => {
                        reqs.clear();
                        reqs.extend(
                            batch.jobs.iter().map(|j| j.req.clone()),
                        );
                        // A panicking twin must fail its batch, not kill
                        // the worker thread (a dead worker would strand
                        // every future batch routed to it).
                        // AssertUnwindSafe is sound here because the twin
                        // instance is discarded below on panic — nobody
                        // observes its possibly-inconsistent state.
                        let unwound = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| {
                                t.run_batch_into(&reqs, &mut results);
                            }),
                        );
                        if let Err(payload) = unwound {
                            poisoned = true;
                            let msg = format!(
                                "twin '{route}' panicked: {} (instance \
                                 discarded; the route rebuilds on next \
                                 dispatch)",
                                panic_message(payload.as_ref())
                            );
                            results.clear();
                            results.extend((0..n).map(|_| {
                                Err(anyhow::anyhow!(msg.clone()))
                            }));
                        }
                    }
                    Err(e) => {
                        let msg = format!("{e:#}");
                        results.extend(
                            (0..n)
                                .map(|_| Err(anyhow::anyhow!(msg.clone()))),
                        );
                    }
                }
                if poisoned {
                    twins.remove(&route);
                }
                // Defensive: a twin returning the wrong arity must not
                // leave submitters hanging.
                if results.len() != n {
                    let msg = format!(
                        "twin '{route}' returned {} results for {n} jobs",
                        results.len()
                    );
                    results.clear();
                    results.extend(
                        (0..n).map(|_| Err(anyhow::anyhow!(msg.clone()))),
                    );
                }
                let exec_s = t0.elapsed().as_secs_f64();
                // Feeds the batcher's adaptive per-route window.
                telemetry.record_route_exec(&route, exec_s);
                for ((job, result), wait_s) in
                    batch.jobs.into_iter().zip(results.drain(..)).zip(waits)
                {
                    match &result {
                        Ok(resp) => {
                            telemetry
                                .completed
                                .fetch_add(1, Ordering::Relaxed);
                            // Replay handle: the noise seed this rollout
                            // actually used (run-twin --seed <s>).
                            telemetry.record_seed(job.id, resp.seed);
                            if let Some(ens) = &resp.ensemble {
                                telemetry
                                    .ensemble_rollouts
                                    .fetch_add(1, Ordering::Relaxed);
                                telemetry.ensemble_members.fetch_add(
                                    ens.members as u64,
                                    Ordering::Relaxed,
                                );
                            }
                        }
                        Err(_) => {
                            telemetry.failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    telemetry.record_latency(wait_s, exec_s);
                    shared.outstanding[index].fetch_sub(1, Ordering::AcqRel);
                    let _ = job.reply.send(JobResult {
                        id: job.id,
                        result,
                        wait_s,
                        exec_s,
                    });
                }
            }
        })
        .expect("spawn worker")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::twin::{TwinRequest, TwinResponse};
    use crate::util::tensor::Trajectory;
    use std::sync::mpsc;
    use std::time::Duration;

    struct EchoTwin;

    impl Twin for EchoTwin {
        fn name(&self) -> &str {
            "echo"
        }
        fn state_dim(&self) -> usize {
            1
        }
        fn dt(&self) -> f64 {
            1.0
        }
        fn default_h0(&self) -> Vec<f64> {
            vec![0.0]
        }
        fn run(
            &mut self,
            req: &TwinRequest,
        ) -> anyhow::Result<TwinResponse> {
            Ok(TwinResponse {
                trajectory: Trajectory::repeat_row(&req.h0, req.n_points),
                backend: "echo",
                seed: req.seed.unwrap_or(0),
                ensemble: None,
                degraded: false,
            })
        }
    }

    fn registry() -> TwinRegistry {
        let mut r = TwinRegistry::new();
        r.register("echo", || Box::new(EchoTwin));
        r
    }

    fn batch_of(n: usize, route: &str) -> (Batch, Vec<mpsc::Receiver<JobResult>>) {
        let mut jobs = Vec::new();
        let mut rxs = Vec::new();
        for id in 0..n as u64 {
            let (tx, rx) = mpsc::channel();
            jobs.push(crate::coordinator::Job {
                id,
                route: route.into(),
                req: TwinRequest::autonomous(vec![id as f64], 3),
                enqueued: Instant::now(),
                reply: tx,
            });
            rxs.push(rx);
        }
        (Batch { route: route.into(), jobs }, rxs)
    }

    #[test]
    fn batch_executes_and_replies() {
        let tel = Arc::new(Telemetry::new());
        let sched = Scheduler::start(2, registry(), Arc::clone(&tel));
        let (batch, rxs) = batch_of(4, "echo");
        sched.dispatch(batch).unwrap();
        for (id, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            assert_eq!(r.id, id as u64);
            let resp = r.result.unwrap();
            assert_eq!(resp.trajectory.row(0), [id as f64]);
        }
        let s = tel.snapshot();
        assert_eq!(s.completed, 4);
        assert_eq!(s.batches, 1);
    }

    #[test]
    fn unknown_route_fails_jobs_not_worker() {
        let tel = Arc::new(Telemetry::new());
        let sched = Scheduler::start(1, registry(), Arc::clone(&tel));
        let (batch, rxs) = batch_of(1, "missing");
        sched.dispatch(batch).unwrap();
        let r = rxs[0].recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(r.result.is_err());
        // Worker still alive: dispatch a good batch.
        let (batch, rxs) = batch_of(1, "echo");
        sched.dispatch(batch).unwrap();
        assert!(rxs[0]
            .recv_timeout(Duration::from_secs(2))
            .unwrap()
            .result
            .is_ok());
        assert_eq!(tel.snapshot().failed, 1);
    }

    #[test]
    fn whole_batch_executes_as_one_run_batch_call() {
        struct Probe {
            sizes: Arc<Mutex<Vec<usize>>>,
        }
        impl Twin for Probe {
            fn name(&self) -> &str {
                "probe"
            }
            fn state_dim(&self) -> usize {
                1
            }
            fn dt(&self) -> f64 {
                1.0
            }
            fn default_h0(&self) -> Vec<f64> {
                vec![0.0]
            }
            fn run(
                &mut self,
                req: &TwinRequest,
            ) -> anyhow::Result<TwinResponse> {
                Ok(TwinResponse {
                    trajectory: Trajectory::repeat_row(
                        &req.h0,
                        req.n_points,
                    ),
                    backend: "probe",
                    seed: req.seed.unwrap_or(0),
                    ensemble: None,
                    degraded: false,
                })
            }
            fn run_batch(
                &mut self,
                reqs: &[TwinRequest],
            ) -> Vec<anyhow::Result<TwinResponse>> {
                self.sizes.lock().unwrap().push(reqs.len());
                reqs.iter().map(|r| self.run(r)).collect()
            }
        }

        let sizes: Arc<Mutex<Vec<usize>>> = Arc::default();
        let mut reg = TwinRegistry::new();
        let s2 = Arc::clone(&sizes);
        reg.register("probe", move || {
            Box::new(Probe { sizes: Arc::clone(&s2) })
        });
        let tel = Arc::new(Telemetry::new());
        let sched = Scheduler::start(1, reg, tel);
        let (batch, rxs) = batch_of(5, "probe");
        sched.dispatch(batch).unwrap();
        for (id, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            assert_eq!(r.id, id as u64);
            assert_eq!(
                r.result.unwrap().trajectory.row(0),
                [id as f64]
            );
        }
        // One dispatch = one run_batch call covering all five jobs.
        assert_eq!(*sizes.lock().unwrap(), vec![5]);
    }

    #[test]
    fn panicking_twin_fails_its_batch_without_killing_the_worker() {
        // Panics on its first batch only; a rebuilt instance behaves.
        struct Grenade {
            armed: bool,
        }
        impl Twin for Grenade {
            fn name(&self) -> &str {
                "grenade"
            }
            fn state_dim(&self) -> usize {
                1
            }
            fn dt(&self) -> f64 {
                1.0
            }
            fn default_h0(&self) -> Vec<f64> {
                vec![0.0]
            }
            fn run(
                &mut self,
                req: &TwinRequest,
            ) -> anyhow::Result<TwinResponse> {
                assert!(!self.armed, "boom: simulated twin defect");
                Ok(TwinResponse {
                    trajectory: Trajectory::repeat_row(
                        &req.h0,
                        req.n_points,
                    ),
                    backend: "grenade",
                    seed: req.seed.unwrap_or(0),
                    ensemble: None,
                    degraded: false,
                })
            }
        }

        let builds: Arc<AtomicUsize> = Arc::default();
        let b2 = Arc::clone(&builds);
        let mut reg = TwinRegistry::new();
        reg.register("grenade", move || {
            let n = b2.fetch_add(1, Ordering::Relaxed);
            Box::new(Grenade { armed: n == 0 })
        });
        let tel = Arc::new(Telemetry::new());
        let sched = Scheduler::start(1, reg, Arc::clone(&tel));
        // First batch: every job gets a typed panic error, nobody hangs.
        let (batch, rxs) = batch_of(3, "grenade");
        sched.dispatch(batch).unwrap();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            let err = r.result.expect_err("panic must surface as error");
            let msg = format!("{err:#}");
            assert!(msg.contains("panicked"), "{msg}");
            assert!(msg.contains("grenade"), "{msg}");
        }
        assert_eq!(tel.snapshot().failed, 3);
        // Same worker thread, same route: the poisoned instance was
        // discarded and the rebuilt one serves.
        let (batch, rxs) = batch_of(2, "grenade");
        sched.dispatch(batch).unwrap();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            assert!(r.result.is_ok(), "worker did not recover");
        }
        assert_eq!(builds.load(Ordering::Relaxed), 2, "no rebuild");
        assert_eq!(sched.outstanding(), 0);
    }

    #[test]
    fn tile_sharded_route_fans_out_under_batch_dispatch() {
        // A sharded Lorenz96 twin behind a route: one dispatched batch ->
        // one run_batch call -> shard workers fan the rollout out and
        // report into the shared telemetry.
        use crate::analog::system::AnalogNoise;
        use crate::device::taox::DeviceConfig;
        use crate::models::loader::decay_mlp_weights;
        use crate::twin::lorenz96::{L96AnalogOpts, Lorenz96Twin};

        let tel = Arc::new(Telemetry::new());
        let mut reg = TwinRegistry::new();
        let t2 = Arc::clone(&tel);
        reg.register("l96/analog-sharded", move || {
            let quiet = DeviceConfig {
                fault_rate: 0.0,
                pulse_sigma: 0.0,
                read_noise: 0.0,
                ..Default::default()
            };
            let mut twin = Lorenz96Twin::analog_opts(
                &decay_mlp_weights(34),
                &quiet,
                AnalogNoise::off(),
                3,
                L96AnalogOpts { substeps: 2, shards: 2, parallel: true },
            );
            twin.attach_coordinator_telemetry(Arc::clone(&t2));
            Box::new(twin)
        });
        let sched = Scheduler::start(1, reg, Arc::clone(&tel));
        let mut jobs = Vec::new();
        let mut rxs = Vec::new();
        for id in 0..3u64 {
            let (tx, rx) = mpsc::channel();
            jobs.push(crate::coordinator::Job {
                id,
                route: "l96/analog-sharded".into(),
                req: TwinRequest::autonomous(
                    (0..34).map(|k| 0.02 * k as f64).collect(),
                    4,
                ),
                enqueued: Instant::now(),
                reply: tx,
            });
            rxs.push(rx);
        }
        sched
            .dispatch(Batch { route: "l96/analog-sharded".into(), jobs })
            .unwrap();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            let resp = r.result.unwrap();
            assert_eq!(resp.backend, "analog-sharded");
            assert_eq!(resp.trajectory.len(), 4);
            assert_eq!(resp.trajectory.dim(), 34);
        }
        let s = tel.snapshot();
        assert!(s.shard_rollouts >= 1, "no sharded rollout recorded");
        assert!(s.shard_steps > 0, "no shard steps recorded");
    }

    #[test]
    fn outstanding_drains_to_zero() {
        let tel = Arc::new(Telemetry::new());
        let sched = Scheduler::start(3, registry(), tel);
        for _ in 0..5 {
            let (batch, rxs) = batch_of(2, "echo");
            sched.dispatch(batch).unwrap();
            for rx in rxs {
                rx.recv_timeout(Duration::from_secs(2)).unwrap();
            }
        }
        // All replies received => outstanding must be 0.
        assert_eq!(sched.outstanding(), 0);
    }

    /// Counting semaphore for gate twins: `run` blocks until a permit
    /// is released, letting tests pin a worker mid-batch.
    #[derive(Clone)]
    struct Sem(Arc<(Mutex<u32>, Condvar)>);

    impl Sem {
        fn new() -> Self {
            Sem(Arc::new((Mutex::new(0), Condvar::new())))
        }
        fn release(&self, n: u32) {
            *self.0 .0.lock().unwrap() += n;
            self.0 .1.notify_all();
        }
        fn acquire(&self) {
            let mut g = self.0 .0.lock().unwrap();
            while *g == 0 {
                g = self.0 .1.wait(g).unwrap();
            }
            *g -= 1;
        }
    }

    struct GateTwin {
        sem: Sem,
    }

    impl Twin for GateTwin {
        fn name(&self) -> &str {
            "gate"
        }
        fn state_dim(&self) -> usize {
            1
        }
        fn dt(&self) -> f64 {
            1.0
        }
        fn default_h0(&self) -> Vec<f64> {
            vec![0.0]
        }
        fn run(
            &mut self,
            req: &TwinRequest,
        ) -> anyhow::Result<TwinResponse> {
            self.sem.acquire();
            Ok(TwinResponse {
                trajectory: Trajectory::repeat_row(&req.h0, req.n_points),
                backend: "gate",
                seed: req.seed.unwrap_or(0),
                ensemble: None,
                degraded: false,
            })
        }
    }

    /// Registry with two independently gated routes plus `echo`.
    fn gated_registry() -> (TwinRegistry, Sem, Sem) {
        let sem_a = Sem::new();
        let sem_b = Sem::new();
        let mut reg = TwinRegistry::new();
        let sa = sem_a.clone();
        reg.register("gate-a", move || {
            Box::new(GateTwin { sem: sa.clone() })
        });
        let sb = sem_b.clone();
        reg.register("gate-b", move || {
            Box::new(GateTwin { sem: sb.clone() })
        });
        reg.register("echo", || Box::new(EchoTwin));
        (reg, sem_a, sem_b)
    }

    /// Pin both workers on gated batches and queue an echo batch behind
    /// the lighter one; the worker freed first must steal and run it
    /// while the other worker is still blocked.
    #[test]
    fn idle_worker_steals_stranded_batch_from_busy_peer() {
        let (reg, sem_a, sem_b) = gated_registry();
        let tel = Arc::new(Telemetry::new());
        let sched =
            Scheduler::start_with_stealing(2, reg, tel, true);
        // Two gate-a jobs pin one worker; outstanding=2 routes the next
        // dispatches away from it regardless of pickup timing.
        let (b1, rx1) = batch_of(2, "gate-a");
        sched.dispatch(b1).unwrap();
        let (b2, rx2) = batch_of(1, "gate-b");
        sched.dispatch(b2).unwrap();
        // Lands in the gate-b worker's deque (1 outstanding vs 2) and
        // strands there: that worker is blocked inside gate-b.
        let (b3, rx3) = batch_of(1, "echo");
        sched.dispatch(b3).unwrap();
        // Free only the gate-a worker; it must steal the echo batch.
        sem_a.release(2);
        for rx in rx1 {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        let r = rx3[0]
            .recv_timeout(Duration::from_secs(5))
            .expect("echo batch was not stolen by the idle worker");
        assert!(r.result.is_ok());
        // Clean shutdown: unblock the gate-b worker too.
        sem_b.release(1);
        rx2[0].recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(sched.outstanding(), 0);
    }

    /// Same shape with stealing off: the stranded batch must wait for
    /// its own worker (documents the pre-stealing behaviour the default
    /// config keeps).
    #[test]
    fn without_stealing_stranded_batch_waits_for_its_worker() {
        let (reg, sem_a, sem_b) = gated_registry();
        let tel = Arc::new(Telemetry::new());
        let sched = Scheduler::start(2, reg, tel);
        let (b1, rx1) = batch_of(2, "gate-a");
        sched.dispatch(b1).unwrap();
        let (b2, rx2) = batch_of(1, "gate-b");
        sched.dispatch(b2).unwrap();
        let (b3, rx3) = batch_of(1, "echo");
        sched.dispatch(b3).unwrap();
        sem_a.release(2);
        for rx in rx1 {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        // The gate-a worker is idle but must NOT take the echo batch.
        assert!(
            rx3[0].recv_timeout(Duration::from_millis(300)).is_err(),
            "batch ran on a foreign worker with stealing disabled"
        );
        sem_b.release(1);
        rx2[0].recv_timeout(Duration::from_secs(5)).unwrap();
        let r = rx3[0].recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.result.is_ok());
        assert_eq!(sched.outstanding(), 0);
    }
}
