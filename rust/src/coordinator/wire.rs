//! The coordinator's wire protocol: length-prefixed JSON frames.
//!
//! Every message on a serving socket is one **frame**: a 4-byte
//! big-endian payload length followed by that many bytes of UTF-8 JSON.
//! Frames longer than the negotiated cap ([`MAX_FRAME_BYTES`] by
//! default) are a protocol violation — the server answers `bad_frame`
//! and closes the connection, because the stream can no longer be
//! re-synchronised.
//!
//! The byte-level layout, every field and a set of canonical examples
//! are documented in `docs/PROTOCOL.md`; `rust/tests/wire.rs` encodes
//! the documented examples with this module and asserts the bytes match
//! **verbatim**, so the document cannot drift from the code.
//!
//! Two properties make the canonical examples possible:
//!
//! * [`crate::util::json::Json`] objects are `BTreeMap`s, so encoding
//!   always emits keys in sorted order;
//! * integral numbers below 1e15 print without a decimal point.
//!
//! Together encoding is deterministic: the same message always produces
//! the same bytes.
//!
//! **Seeds travel as decimal strings.** `Json::Num` is an `f64`, and
//! the replay contract hands out full-range `u64` seeds (from
//! `derive_stream_seed`) that do not fit in the 53-bit mantissa; a
//! numeric seed field would silently corrupt them. Decoding also
//! accepts plain numbers below 2^53 for hand-written requests.
//!
//! JSON has no NaN/Inf: non-finite trajectory samples encode as `null`
//! and decode back to NaN (diverged ensemble members stay visible).

use std::fmt;

use crate::twin::{
    EnsembleSpec, EnsembleStats, FaultCampaign, TwinRequest, TwinResponse,
};
use crate::util::json::{self, Json};
use crate::util::tensor::Trajectory;
use crate::workload::stimuli::Waveform;

/// Default cap on one frame's payload (16 MiB) — bounds per-connection
/// memory; a 4096-member ensemble response with members returned stays
/// under it for the workloads in `docs/PROTOCOL.md`.
pub const MAX_FRAME_BYTES: usize = 16 * 1024 * 1024;

/// Largest integer JSON's f64 numbers carry exactly (2^53); ids and
/// numeric seed fields beyond it are rejected rather than rounded.
const MAX_EXACT_INT: f64 = 9_007_199_254_740_992.0;

// ---------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------

/// A frame declared a payload longer than the cap. Unrecoverable for
/// the stream: the bytes after the header cannot be trusted as a
/// boundary, so the connection must close.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FrameTooBig {
    pub declared: usize,
    pub limit: usize,
}

impl fmt::Display for FrameTooBig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "frame of {} bytes exceeds the {}-byte limit",
            self.declared, self.limit
        )
    }
}

impl std::error::Error for FrameTooBig {}

/// Wrap a JSON payload in a frame: 4-byte big-endian length + bytes.
pub fn encode_frame(payload: &str) -> Vec<u8> {
    let bytes = payload.as_bytes();
    assert!(
        bytes.len() <= u32::MAX as usize,
        "payload exceeds the u32 length prefix"
    );
    let mut out = Vec::with_capacity(4 + bytes.len());
    out.extend_from_slice(&(bytes.len() as u32).to_be_bytes());
    out.extend_from_slice(bytes);
    out
}

/// Incremental frame extraction from a connection's read buffer.
///
/// Returns `Ok(Some(payload))` when a whole frame is buffered (and
/// drains it), `Ok(None)` when more bytes are needed, and
/// `Err(FrameTooBig)` when the declared length exceeds `limit` (close
/// the connection).
pub fn extract_frame(
    buf: &mut Vec<u8>,
    limit: usize,
) -> Result<Option<Vec<u8>>, FrameTooBig> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let declared =
        u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if declared > limit {
        return Err(FrameTooBig { declared, limit });
    }
    if buf.len() < 4 + declared {
        return Ok(None);
    }
    let payload = buf[4..4 + declared].to_vec();
    buf.drain(..4 + declared);
    Ok(Some(payload))
}

// ---------------------------------------------------------------------
// Error codes
// ---------------------------------------------------------------------

/// Typed error codes carried in error frames (`docs/PROTOCOL.md` is the
/// authoritative list).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// The frame was not valid UTF-8 JSON, or declared an oversized
    /// length. The connection closes after this error.
    BadFrame,
    /// The JSON was well-formed but violated the request schema.
    BadRequest,
    /// The route key is not in the registry.
    UnknownRoute,
    /// Shed at the admission gate (global or per-route budget).
    RejectedOverload,
    /// The server is draining; no new work is accepted.
    ShuttingDown,
    /// The backend failed while executing the request.
    Internal,
}

impl ErrorCode {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadFrame => "bad_frame",
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownRoute => "unknown_route",
            ErrorCode::RejectedOverload => "rejected_overload",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::Internal => "internal",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "bad_frame" => ErrorCode::BadFrame,
            "bad_request" => ErrorCode::BadRequest,
            "unknown_route" => ErrorCode::UnknownRoute,
            "rejected_overload" => ErrorCode::RejectedOverload,
            "shutting_down" => ErrorCode::ShuttingDown,
            "internal" => ErrorCode::Internal,
            _ => return None,
        })
    }
}

impl fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

// ---------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------

/// A decoded request frame: client-chosen correlation id, route key and
/// the twin request itself.
#[derive(Debug, Clone)]
pub struct WireRequest {
    /// Client-chosen correlation id (echoed on every response; must fit
    /// in 2^53 so it survives the f64 JSON number).
    pub id: u64,
    /// Route key, e.g. `"lorenz96/analog"`.
    pub route: String,
    pub req: TwinRequest,
}

/// Why a request frame failed to decode. `id` is the correlation id if
/// it could be extracted (so the error frame can still be correlated);
/// `code` is `BadFrame` for non-JSON payloads and `BadRequest` for
/// schema violations.
#[derive(Debug, Clone)]
pub struct RequestError {
    pub id: Option<u64>,
    pub code: ErrorCode,
    pub msg: String,
}

fn seed_json(seed: u64) -> Json {
    Json::Str(seed.to_string())
}

fn seed_from_json(j: &Json) -> Option<u64> {
    match j {
        Json::Str(s) => s.parse().ok(),
        Json::Num(x)
            if x.is_finite()
                && *x >= 0.0
                && *x < MAX_EXACT_INT
                && *x == x.trunc() =>
        {
            Some(*x as u64)
        }
        _ => None,
    }
}

fn stimulus_json(w: &Waveform) -> Json {
    match *w {
        Waveform::Sine { amp, freq, phase } => Json::obj(vec![
            ("amp", Json::Num(amp)),
            ("freq", Json::Num(freq)),
            ("kind", Json::Str("sine".into())),
            ("phase", Json::Num(phase)),
        ]),
        Waveform::Triangular { amp, freq } => Json::obj(vec![
            ("amp", Json::Num(amp)),
            ("freq", Json::Num(freq)),
            ("kind", Json::Str("triangular".into())),
        ]),
        Waveform::Rectangular { amp, freq, duty } => Json::obj(vec![
            ("amp", Json::Num(amp)),
            ("duty", Json::Num(duty)),
            ("freq", Json::Num(freq)),
            ("kind", Json::Str("rectangular".into())),
        ]),
        Waveform::ModulatedSine { amp, freq, mod_freq } => Json::obj(vec![
            ("amp", Json::Num(amp)),
            ("freq", Json::Num(freq)),
            ("kind", Json::Str("modulated".into())),
            ("mod_freq", Json::Num(mod_freq)),
        ]),
    }
}

fn ensemble_json(s: &EnsembleSpec) -> Json {
    let mut pairs = vec![
        ("members", Json::Num(s.members as f64)),
        ("percentiles", Json::arr_f64(&s.percentiles)),
        ("return_members", Json::Bool(s.return_members)),
    ];
    if let Some(c) = &s.fault_campaign {
        pairs.push((
            "fault_campaign",
            Json::obj(vec![
                ("age_s", Json::Num(c.age_s)),
                ("fault_fraction", Json::Num(c.fault_fraction)),
                ("yield_seed", seed_json(c.yield_seed)),
            ]),
        ));
    }
    Json::obj(pairs)
}

/// Encode a request to its canonical JSON payload (sorted keys; absent
/// optionals omitted). Frame it with [`encode_frame`] before sending.
pub fn encode_request(w: &WireRequest) -> String {
    let mut pairs = vec![
        ("h0", Json::arr_f64(&w.req.h0)),
        ("id", Json::Num(w.id as f64)),
        ("route", Json::Str(w.route.clone())),
        ("steps", Json::Num(w.req.n_points as f64)),
    ];
    if let Some(seed) = w.req.seed {
        pairs.push(("seed", seed_json(seed)));
    }
    if let Some(stim) = &w.req.stimulus {
        pairs.push(("stimulus", stimulus_json(stim)));
    }
    if let Some(spec) = &w.req.ensemble {
        pairs.push(("ensemble", ensemble_json(spec)));
    }
    Json::obj(pairs).to_string()
}

fn decode_stimulus(j: &Json) -> Result<Waveform, String> {
    let kind = j
        .get("kind")
        .and_then(Json::as_str)
        .ok_or("stimulus needs a 'kind' string")?;
    let num = |key: &str| -> Result<f64, String> {
        j.get(key)
            .and_then(Json::as_f64)
            .filter(|x| x.is_finite())
            .ok_or_else(|| format!("stimulus '{kind}' needs finite '{key}'"))
    };
    let opt = |key: &str, default: f64| -> Result<f64, String> {
        match j.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_f64()
                .filter(|x| x.is_finite())
                .ok_or_else(|| format!("stimulus '{key}' must be finite")),
        }
    };
    match kind {
        "sine" => Ok(Waveform::Sine {
            amp: num("amp")?,
            freq: num("freq")?,
            phase: opt("phase", 0.0)?,
        }),
        "triangular" => Ok(Waveform::Triangular {
            amp: num("amp")?,
            freq: num("freq")?,
        }),
        "rectangular" => Ok(Waveform::Rectangular {
            amp: num("amp")?,
            freq: num("freq")?,
            duty: opt("duty", 0.5)?,
        }),
        "modulated" => Ok(Waveform::ModulatedSine {
            amp: num("amp")?,
            freq: num("freq")?,
            mod_freq: num("mod_freq")?,
        }),
        other => Err(format!(
            "unknown stimulus kind '{other}' \
             (sine|triangular|rectangular|modulated)"
        )),
    }
}

fn decode_ensemble(j: &Json) -> Result<EnsembleSpec, String> {
    let members = j
        .get("members")
        .and_then(Json::as_f64)
        .filter(|x| x.is_finite() && *x >= 0.0 && *x == x.trunc())
        .ok_or("ensemble needs an integer 'members'")?
        as usize;
    let percentiles = match j.get("percentiles") {
        None => Vec::new(),
        Some(v) => v
            .as_vec_f64()
            .ok_or("ensemble 'percentiles' must be a numeric array")?,
    };
    let return_members = match j.get("return_members") {
        None => false,
        Some(v) => v
            .as_bool()
            .ok_or("ensemble 'return_members' must be a boolean")?,
    };
    let fault_campaign = match j.get("fault_campaign") {
        None => None,
        Some(c) => {
            let yield_seed = c
                .get("yield_seed")
                .and_then(seed_from_json)
                .ok_or("fault_campaign needs a 'yield_seed' seed")?;
            let num_or = |key: &str| -> Result<f64, String> {
                match c.get(key) {
                    None => Ok(0.0),
                    Some(v) => {
                        v.as_f64().filter(|x| x.is_finite()).ok_or_else(
                            || format!("fault_campaign '{key}' must be finite"),
                        )
                    }
                }
            };
            Some(FaultCampaign {
                yield_seed,
                age_s: num_or("age_s")?,
                fault_fraction: num_or("fault_fraction")?,
            })
        }
    };
    Ok(EnsembleSpec { members, percentiles, return_members, fault_campaign })
}

/// Decode a request payload. On failure the error still carries the
/// correlation id whenever the frame got far enough to reveal one.
pub fn decode_request(payload: &[u8]) -> Result<WireRequest, RequestError> {
    let bad_frame = |msg: String| RequestError {
        id: None,
        code: ErrorCode::BadFrame,
        msg,
    };
    let text = std::str::from_utf8(payload)
        .map_err(|_| bad_frame("frame payload is not UTF-8".into()))?;
    let doc = json::parse(text)
        .map_err(|e| bad_frame(format!("frame payload is not JSON: {e}")))?;
    if !matches!(doc, Json::Obj(_)) {
        return Err(bad_frame("request must be a JSON object".into()));
    }
    let id = doc
        .get("id")
        .and_then(Json::as_f64)
        .filter(|x| {
            x.is_finite() && *x >= 0.0 && *x < MAX_EXACT_INT && *x == x.trunc()
        })
        .map(|x| x as u64);
    let bad = |msg: String| RequestError {
        id,
        code: ErrorCode::BadRequest,
        msg,
    };
    let id = id.ok_or_else(|| {
        bad("request needs an integer 'id' below 2^53".into())
    })?;
    let route = doc
        .get("route")
        .and_then(Json::as_str)
        .ok_or_else(|| bad("request needs a 'route' string".into()))?
        .to_owned();
    let steps = doc
        .get("steps")
        .and_then(Json::as_f64)
        .filter(|x| x.is_finite() && *x >= 1.0 && *x == x.trunc())
        .ok_or_else(|| bad("request needs an integer 'steps' >= 1".into()))?
        as usize;
    let h0 = match doc.get("h0") {
        None => Vec::new(),
        Some(v) => v
            .as_vec_f64()
            .ok_or_else(|| bad("'h0' must be a numeric array".into()))?,
    };
    let seed = match doc.get("seed") {
        None => None,
        Some(v) => Some(seed_from_json(v).ok_or_else(|| {
            bad("'seed' must be a decimal string or an \
                 integer below 2^53"
                .into())
        })?),
    };
    let stimulus = match doc.get("stimulus") {
        None => None,
        Some(v) => Some(decode_stimulus(v).map_err(&bad)?),
    };
    let ensemble = match doc.get("ensemble") {
        None => None,
        Some(v) => Some(decode_ensemble(v).map_err(&bad)?),
    };
    Ok(WireRequest {
        id,
        route,
        req: TwinRequest { h0, n_points: steps, stimulus, seed, ensemble },
    })
}

// ---------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------

fn mat_json(t: &Trajectory) -> Json {
    Json::Arr(t.iter().map(Json::arr_f64).collect())
}

fn stats_json(e: &EnsembleStats) -> Json {
    let mut pairs = vec![
        ("mean", mat_json(&e.mean)),
        ("members", Json::Num(e.members as f64)),
        ("nan_samples", Json::Num(e.nan_samples as f64)),
        (
            "percentiles",
            Json::Arr(
                e.percentiles
                    .iter()
                    .map(|(p, t)| {
                        Json::obj(vec![
                            ("p", Json::Num(*p)),
                            ("trajectory", mat_json(t)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("std", mat_json(&e.std)),
    ];
    if !e.member_trajectories.is_empty() {
        pairs.push((
            "member_trajectories",
            Json::Arr(e.member_trajectories.iter().map(mat_json).collect()),
        ));
    }
    Json::obj(pairs)
}

/// Encode a success response. `wait_us`/`exec_us` are the job's queue
/// wait and backend execution time in integer microseconds.
pub fn encode_response(
    id: u64,
    r: &TwinResponse,
    wait_us: u64,
    exec_us: u64,
) -> String {
    let mut pairs = vec![
        ("backend", Json::Str(r.backend.to_string())),
        ("degraded", Json::Bool(r.degraded)),
        ("exec_us", Json::Num(exec_us as f64)),
        ("id", Json::Num(id as f64)),
        ("ok", Json::Bool(true)),
        ("seed", seed_json(r.seed)),
        ("trajectory", mat_json(&r.trajectory)),
        ("wait_us", Json::Num(wait_us as f64)),
    ];
    if let Some(e) = &r.ensemble {
        pairs.push(("ensemble", stats_json(e)));
    }
    Json::obj(pairs).to_string()
}

/// Encode an error response. `id` is omitted when the frame never
/// revealed one; `seed` carries the request's (possibly server-stamped)
/// replay seed so even rejected requests are replayable.
pub fn encode_error(
    id: Option<u64>,
    code: ErrorCode,
    message: &str,
    seed: Option<u64>,
) -> String {
    let mut pairs = vec![
        (
            "error",
            Json::obj(vec![
                ("code", Json::Str(code.as_str().into())),
                ("message", Json::Str(message.into())),
            ]),
        ),
        ("ok", Json::Bool(false)),
    ];
    if let Some(id) = id {
        pairs.push(("id", Json::Num(id as f64)));
    }
    if let Some(seed) = seed {
        pairs.push(("seed", seed_json(seed)));
    }
    Json::obj(pairs).to_string()
}

// ---------------------------------------------------------------------
// Client-side response decoding
// ---------------------------------------------------------------------

/// A decoded response frame: success or a typed error.
#[derive(Debug, Clone)]
pub enum WireResponse {
    Ok(WireOk),
    Err(WireError),
}

/// A decoded success response.
#[derive(Debug, Clone)]
pub struct WireOk {
    pub id: u64,
    pub backend: String,
    /// Replay seed (resubmit with `"seed": "<this>"` for a bit-exact
    /// rerun).
    pub seed: u64,
    pub degraded: bool,
    pub trajectory: Vec<Vec<f64>>,
    pub ensemble: Option<WireEnsemble>,
    pub wait_us: u64,
    pub exec_us: u64,
}

/// Ensemble statistics on the wire (nested row form).
#[derive(Debug, Clone)]
pub struct WireEnsemble {
    pub members: usize,
    pub mean: Vec<Vec<f64>>,
    pub std: Vec<Vec<f64>>,
    pub percentiles: Vec<(f64, Vec<Vec<f64>>)>,
    pub member_trajectories: Vec<Vec<Vec<f64>>>,
    pub nan_samples: u64,
}

/// A decoded error response.
#[derive(Debug, Clone)]
pub struct WireError {
    pub id: Option<u64>,
    pub code: ErrorCode,
    pub message: String,
    /// Present when the server stamped a replay seed before rejecting.
    pub seed: Option<u64>,
}

/// Numeric matrix that tolerates `null` entries (they decode to NaN —
/// the encoder's image of non-finite samples).
fn mat_lossy(j: &Json) -> Option<Vec<Vec<f64>>> {
    j.as_arr()?
        .iter()
        .map(|row| {
            row.as_arr()?
                .iter()
                .map(|v| match v {
                    Json::Null => Some(f64::NAN),
                    other => other.as_f64(),
                })
                .collect()
        })
        .collect()
}

fn u64_field(doc: &Json, key: &str) -> Result<u64, String> {
    doc.get(key)
        .and_then(Json::as_f64)
        .filter(|x| {
            x.is_finite() && *x >= 0.0 && *x < MAX_EXACT_INT && *x == x.trunc()
        })
        .map(|x| x as u64)
        .ok_or_else(|| format!("response needs an integer '{key}'"))
}

fn decode_stats(j: &Json) -> Result<WireEnsemble, String> {
    let mat = |key: &str| -> Result<Vec<Vec<f64>>, String> {
        j.get(key)
            .and_then(mat_lossy)
            .ok_or_else(|| format!("ensemble '{key}' must be a matrix"))
    };
    let percentiles = j
        .get("percentiles")
        .and_then(Json::as_arr)
        .ok_or("ensemble 'percentiles' must be an array")?
        .iter()
        .map(|entry| {
            let p = entry.get("p").and_then(Json::as_f64)?;
            let t = entry.get("trajectory").and_then(mat_lossy)?;
            Some((p, t))
        })
        .collect::<Option<Vec<_>>>()
        .ok_or("ensemble percentile entries need 'p' and 'trajectory'")?;
    let member_trajectories = match j.get("member_trajectories") {
        None => Vec::new(),
        Some(v) => v
            .as_arr()
            .ok_or("'member_trajectories' must be an array")?
            .iter()
            .map(mat_lossy)
            .collect::<Option<Vec<_>>>()
            .ok_or("'member_trajectories' entries must be matrices")?,
    };
    Ok(WireEnsemble {
        members: u64_field(j, "members")? as usize,
        mean: mat("mean")?,
        std: mat("std")?,
        percentiles,
        member_trajectories,
        nan_samples: u64_field(j, "nan_samples")?,
    })
}

/// Decode a response payload (client side of the protocol).
pub fn decode_response(payload: &[u8]) -> Result<WireResponse, String> {
    let text = std::str::from_utf8(payload)
        .map_err(|_| "response payload is not UTF-8".to_string())?;
    let doc = json::parse(text)
        .map_err(|e| format!("response payload is not JSON: {e}"))?;
    let ok = doc
        .get("ok")
        .and_then(Json::as_bool)
        .ok_or("response needs a boolean 'ok'")?;
    if !ok {
        let err = doc.get("error").ok_or("error response needs 'error'")?;
        let code = err
            .get("code")
            .and_then(Json::as_str)
            .ok_or("error response needs 'error.code'")?;
        let message = err
            .get("message")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_owned();
        return Ok(WireResponse::Err(WireError {
            id: doc.get("id").and_then(seed_from_json),
            // Unknown codes (a newer server) degrade to `internal`
            // rather than failing the decode.
            code: ErrorCode::parse(code).unwrap_or(ErrorCode::Internal),
            message,
            seed: doc.get("seed").and_then(seed_from_json),
        }));
    }
    let ensemble = match doc.get("ensemble") {
        None => None,
        Some(e) => Some(decode_stats(e)?),
    };
    Ok(WireResponse::Ok(WireOk {
        id: u64_field(&doc, "id")?,
        backend: doc
            .get("backend")
            .and_then(Json::as_str)
            .ok_or("response needs a 'backend' string")?
            .to_owned(),
        seed: doc
            .get("seed")
            .and_then(seed_from_json)
            .ok_or("response needs a 'seed'")?,
        degraded: doc
            .get("degraded")
            .and_then(Json::as_bool)
            .ok_or("response needs a boolean 'degraded'")?,
        trajectory: doc
            .get("trajectory")
            .and_then(mat_lossy)
            .ok_or("response needs a 'trajectory' matrix")?,
        ensemble,
        wait_us: u64_field(&doc, "wait_us")?,
        exec_us: u64_field(&doc, "exec_us")?,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_layout_is_four_byte_be_length_plus_payload() {
        assert_eq!(encode_frame("{}"), vec![0, 0, 0, 2, 0x7b, 0x7d]);
    }

    #[test]
    fn extract_frame_is_incremental() {
        let mut buf = Vec::new();
        assert_eq!(extract_frame(&mut buf, 64).unwrap(), None);
        let frame = encode_frame(r#"{"a":1}"#);
        // Feed the frame one byte at a time: no partial extraction.
        for &b in &frame[..frame.len() - 1] {
            buf.push(b);
            assert_eq!(extract_frame(&mut buf, 64).unwrap(), None);
        }
        buf.push(*frame.last().unwrap());
        let payload = extract_frame(&mut buf, 64).unwrap().unwrap();
        assert_eq!(payload, br#"{"a":1}"#);
        assert!(buf.is_empty());
    }

    #[test]
    fn extract_frame_handles_back_to_back_frames() {
        let mut buf = encode_frame("{}");
        buf.extend_from_slice(&encode_frame("[1]"));
        assert_eq!(extract_frame(&mut buf, 64).unwrap().unwrap(), b"{}");
        assert_eq!(extract_frame(&mut buf, 64).unwrap().unwrap(), b"[1]");
        assert_eq!(extract_frame(&mut buf, 64).unwrap(), None);
    }

    #[test]
    fn oversized_frame_is_a_protocol_error() {
        let mut buf = encode_frame(&"x".repeat(100));
        let err = extract_frame(&mut buf, 64).unwrap_err();
        assert_eq!(err, FrameTooBig { declared: 100, limit: 64 });
        assert!(err.to_string().contains("exceeds"));
    }

    #[test]
    fn error_codes_roundtrip_their_names() {
        for code in [
            ErrorCode::BadFrame,
            ErrorCode::BadRequest,
            ErrorCode::UnknownRoute,
            ErrorCode::RejectedOverload,
            ErrorCode::ShuttingDown,
            ErrorCode::Internal,
        ] {
            assert_eq!(ErrorCode::parse(code.as_str()), Some(code));
        }
        assert_eq!(ErrorCode::parse("nope"), None);
    }

    #[test]
    fn plain_request_roundtrips() {
        let w = WireRequest {
            id: 1,
            route: "lorenz96/digital".into(),
            req: TwinRequest::autonomous(vec![], 32).with_seed(7),
        };
        let payload = encode_request(&w);
        assert_eq!(
            payload,
            r#"{"h0":[],"id":1,"route":"lorenz96/digital","seed":"7","steps":32}"#
        );
        let back = decode_request(payload.as_bytes()).unwrap();
        assert_eq!(back.id, 1);
        assert_eq!(back.route, "lorenz96/digital");
        assert_eq!(back.req.n_points, 32);
        assert_eq!(back.req.seed, Some(7));
        assert!(back.req.h0.is_empty());
        assert!(back.req.stimulus.is_none());
        assert!(back.req.ensemble.is_none());
    }

    #[test]
    fn full_request_roundtrips() {
        let spec = EnsembleSpec::new(8)
            .with_percentiles(vec![5.0, 95.0])
            .with_member_trajectories()
            .with_fault_campaign(
                FaultCampaign::new(u64::MAX).aged(3600.0),
            );
        let w = WireRequest {
            id: 42,
            route: "lorenz96/analog-aged".into(),
            req: TwinRequest::driven(
                vec![0.5, -1.0],
                16,
                Waveform::Rectangular { amp: 1.0, freq: 2.0, duty: 0.25 },
            )
            .with_seed(u64::MAX - 1)
            .with_ensemble(spec.clone()),
        };
        let back = decode_request(encode_request(&w).as_bytes()).unwrap();
        assert_eq!(back.id, 42);
        assert_eq!(back.req.h0, vec![0.5, -1.0]);
        // Full-range u64 seeds survive the string encoding exactly.
        assert_eq!(back.req.seed, Some(u64::MAX - 1));
        assert_eq!(
            back.req.stimulus,
            Some(Waveform::Rectangular { amp: 1.0, freq: 2.0, duty: 0.25 })
        );
        assert_eq!(back.req.ensemble, Some(spec));
    }

    #[test]
    fn every_stimulus_kind_roundtrips() {
        for stim in [
            Waveform::Sine { amp: 1.0, freq: 2.0, phase: 0.5 },
            Waveform::Triangular { amp: 0.3, freq: 1.5 },
            Waveform::Rectangular { amp: 1.0, freq: 4.0, duty: 0.75 },
            Waveform::ModulatedSine { amp: 1.0, freq: 8.0, mod_freq: 0.5 },
        ] {
            let w = WireRequest {
                id: 1,
                route: "r".into(),
                req: TwinRequest::driven(vec![], 4, stim),
            };
            let back =
                decode_request(encode_request(&w).as_bytes()).unwrap();
            assert_eq!(back.req.stimulus, Some(stim));
        }
    }

    #[test]
    fn stimulus_defaults_fill_in_on_decode() {
        let payload = br#"{"id":1,"route":"r","steps":2,
            "stimulus":{"kind":"sine","amp":1,"freq":2}}"#;
        let w = decode_request(payload).unwrap();
        assert_eq!(
            w.req.stimulus,
            Some(Waveform::Sine { amp: 1.0, freq: 2.0, phase: 0.0 })
        );
        let payload = br#"{"id":1,"route":"r","steps":2,
            "stimulus":{"kind":"rectangular","amp":1,"freq":2}}"#;
        let w = decode_request(payload).unwrap();
        assert_eq!(
            w.req.stimulus,
            Some(Waveform::Rectangular { amp: 1.0, freq: 2.0, duty: 0.5 })
        );
    }

    #[test]
    fn schema_violations_are_typed_and_keep_the_id() {
        // Non-JSON: bad_frame, no id.
        let e = decode_request(b"not json").unwrap_err();
        assert_eq!(e.code, ErrorCode::BadFrame);
        assert_eq!(e.id, None);
        // Invalid UTF-8: bad_frame.
        let e = decode_request(&[0xff, 0xfe]).unwrap_err();
        assert_eq!(e.code, ErrorCode::BadFrame);
        // Missing id: bad_request without correlation.
        let e = decode_request(br#"{"route":"r","steps":2}"#).unwrap_err();
        assert_eq!(e.code, ErrorCode::BadRequest);
        assert_eq!(e.id, None);
        // Later violations still surface the id for correlation.
        for payload in [
            br#"{"id":9,"steps":2}"#.as_slice(),
            br#"{"id":9,"route":"r"}"#.as_slice(),
            br#"{"id":9,"route":"r","steps":0}"#.as_slice(),
            br#"{"id":9,"route":"r","steps":2,"seed":1.5}"#.as_slice(),
            br#"{"id":9,"route":"r","steps":2,"h0":"x"}"#.as_slice(),
            br#"{"id":9,"route":"r","steps":2,
                "stimulus":{"kind":"saw","amp":1,"freq":1}}"#
                .as_slice(),
            br#"{"id":9,"route":"r","steps":2,"ensemble":{}}"#.as_slice(),
        ] {
            let e = decode_request(payload).unwrap_err();
            assert_eq!(e.code, ErrorCode::BadRequest, "{}", e.msg);
            assert_eq!(e.id, Some(9), "{}", e.msg);
        }
    }

    #[test]
    fn ok_response_roundtrips() {
        let resp = TwinResponse {
            trajectory: Trajectory::from_nested(&[
                vec![1.0, 2.0],
                vec![3.0, 4.0],
            ]),
            backend: "digital",
            seed: u64::MAX,
            ensemble: None,
            degraded: true,
        };
        let payload = encode_response(5, &resp, 120, 4200);
        match decode_response(payload.as_bytes()).unwrap() {
            WireResponse::Ok(ok) => {
                assert_eq!(ok.id, 5);
                assert_eq!(ok.backend, "digital");
                assert_eq!(ok.seed, u64::MAX);
                assert!(ok.degraded);
                assert_eq!(
                    ok.trajectory,
                    vec![vec![1.0, 2.0], vec![3.0, 4.0]]
                );
                assert_eq!(ok.wait_us, 120);
                assert_eq!(ok.exec_us, 4200);
                assert!(ok.ensemble.is_none());
            }
            other => panic!("expected ok, got {other:?}"),
        }
    }

    #[test]
    fn non_finite_samples_survive_as_nan() {
        let resp = TwinResponse {
            trajectory: Trajectory::from_nested(&[vec![
                f64::NAN,
                f64::INFINITY,
                1.0,
            ]]),
            backend: "digital",
            seed: 1,
            ensemble: None,
            degraded: false,
        };
        let payload = encode_response(1, &resp, 0, 0);
        assert!(payload.contains("[null,null,1]"), "{payload}");
        match decode_response(payload.as_bytes()).unwrap() {
            WireResponse::Ok(ok) => {
                assert!(ok.trajectory[0][0].is_nan());
                assert!(ok.trajectory[0][1].is_nan());
                assert_eq!(ok.trajectory[0][2], 1.0);
            }
            other => panic!("expected ok, got {other:?}"),
        }
    }

    #[test]
    fn ensemble_response_roundtrips() {
        let stats = EnsembleStats {
            members: 2,
            mean: Trajectory::from_nested(&[vec![1.0], vec![2.0]]),
            std: Trajectory::from_nested(&[vec![0.1], vec![0.2]]),
            percentiles: vec![(
                95.0,
                Trajectory::from_nested(&[vec![1.5], vec![2.5]]),
            )],
            member_trajectories: vec![
                Trajectory::from_nested(&[vec![0.9], vec![1.8]]),
                Trajectory::from_nested(&[vec![1.1], vec![2.2]]),
            ],
            nan_samples: 3,
        };
        let resp = TwinResponse {
            trajectory: Trajectory::from_nested(&[vec![1.0], vec![2.0]]),
            backend: "analog",
            seed: 9,
            ensemble: Some(stats),
            degraded: false,
        };
        let payload = encode_response(2, &resp, 10, 20);
        match decode_response(payload.as_bytes()).unwrap() {
            WireResponse::Ok(ok) => {
                let e = ok.ensemble.expect("ensemble present");
                assert_eq!(e.members, 2);
                assert_eq!(e.mean, vec![vec![1.0], vec![2.0]]);
                assert_eq!(e.std, vec![vec![0.1], vec![0.2]]);
                assert_eq!(e.percentiles.len(), 1);
                assert_eq!(e.percentiles[0].0, 95.0);
                assert_eq!(e.member_trajectories.len(), 2);
                assert_eq!(e.nan_samples, 3);
            }
            other => panic!("expected ok, got {other:?}"),
        }
    }

    #[test]
    fn error_response_roundtrips_with_seed_echo() {
        let payload = encode_error(
            Some(9),
            ErrorCode::RejectedOverload,
            "overloaded: 128 requests in flight (global limit 128)",
            Some(u64::MAX - 3),
        );
        match decode_response(payload.as_bytes()).unwrap() {
            WireResponse::Err(e) => {
                assert_eq!(e.id, Some(9));
                assert_eq!(e.code, ErrorCode::RejectedOverload);
                assert!(e.message.contains("overloaded"));
                assert_eq!(e.seed, Some(u64::MAX - 3));
            }
            other => panic!("expected error, got {other:?}"),
        }
        // Frame-level errors may omit both id and seed.
        let payload =
            encode_error(None, ErrorCode::BadFrame, "not JSON", None);
        match decode_response(payload.as_bytes()).unwrap() {
            WireResponse::Err(e) => {
                assert_eq!(e.id, None);
                assert_eq!(e.seed, None);
                assert_eq!(e.code, ErrorCode::BadFrame);
            }
            other => panic!("expected error, got {other:?}"),
        }
    }

    #[test]
    fn unknown_error_codes_degrade_to_internal() {
        let payload = r#"{"error":{"code":"weird","message":"m"},"ok":false}"#;
        match decode_response(payload.as_bytes()).unwrap() {
            WireResponse::Err(e) => {
                assert_eq!(e.code, ErrorCode::Internal)
            }
            other => panic!("expected error, got {other:?}"),
        }
    }

    #[test]
    fn canonical_encoding_is_deterministic() {
        let w = WireRequest {
            id: 3,
            route: "hp/digital".into(),
            req: TwinRequest::driven(
                vec![0.0, 0.0],
                8,
                Waveform::Sine { amp: 0.5, freq: 2.0, phase: 0.0 },
            ),
        };
        let a = encode_request(&w);
        let b = encode_request(&w);
        assert_eq!(a, b);
        // Sorted keys: "h0" < "id" < "route" < "steps" < "stimulus".
        let h0 = a.find(r#""h0""#).unwrap();
        let id = a.find(r#""id""#).unwrap();
        let route = a.find(r#""route""#).unwrap();
        let stim = a.find(r#""stimulus""#).unwrap();
        assert!(h0 < id && id < route && route < stim);
    }
}
