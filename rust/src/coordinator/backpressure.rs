//! Global admission control: a bounded in-flight budget with fail-fast
//! rejection (shed load at the door rather than queue unboundedly — the
//! streaming-ingestion discipline a digital-twin front end needs when
//! sensor bursts exceed solver throughput).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Shared in-flight budget.
#[derive(Debug)]
pub struct Backpressure {
    in_flight: AtomicUsize,
    limit: usize,
}

/// RAII permit: releases its slot on drop.
pub struct Permit {
    ctrl: Arc<Backpressure>,
}

impl Backpressure {
    pub fn new(limit: usize) -> Arc<Self> {
        assert!(limit > 0, "backpressure limit must be positive");
        Arc::new(Self { in_flight: AtomicUsize::new(0), limit })
    }

    /// Try to admit one request; `None` means shed.
    pub fn try_acquire(self: &Arc<Self>) -> Option<Permit> {
        let mut cur = self.in_flight.load(Ordering::Relaxed);
        loop {
            if cur >= self.limit {
                return None;
            }
            match self.in_flight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(Permit { ctrl: Arc::clone(self) }),
                Err(now) => cur = now,
            }
        }
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    pub fn limit(&self) -> usize {
        self.limit
    }
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.ctrl.in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_limit_then_sheds() {
        let bp = Backpressure::new(2);
        let a = bp.try_acquire();
        let b = bp.try_acquire();
        assert!(a.is_some() && b.is_some());
        assert!(bp.try_acquire().is_none());
        drop(a);
        assert!(bp.try_acquire().is_some());
    }

    #[test]
    fn permits_release_on_drop() {
        let bp = Backpressure::new(1);
        {
            let _p = bp.try_acquire().unwrap();
            assert_eq!(bp.in_flight(), 1);
        }
        assert_eq!(bp.in_flight(), 0);
    }

    #[test]
    fn concurrent_admission_never_exceeds_limit() {
        let bp = Backpressure::new(8);
        let mut handles = Vec::new();
        for _ in 0..4 {
            let bp = Arc::clone(&bp);
            handles.push(std::thread::spawn(move || {
                let mut max_seen = 0usize;
                for _ in 0..10_000 {
                    if let Some(_p) = bp.try_acquire() {
                        max_seen = max_seen.max(bp.in_flight());
                    }
                }
                max_seen
            }));
        }
        for h in handles {
            let max_seen = h.join().unwrap();
            assert!(max_seen <= 8, "exceeded limit: {max_seen}");
        }
        assert_eq!(bp.in_flight(), 0);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_limit_rejected() {
        let _ = Backpressure::new(0);
    }
}
