//! Admission control: a bounded global in-flight budget plus optional
//! per-route bounds, with fail-fast rejection (shed load at the door
//! rather than queue unboundedly — the streaming-ingestion discipline a
//! digital-twin front end needs when sensor bursts exceed solver
//! throughput).
//!
//! Two gates stack:
//!
//! * the **global** gate caps total in-flight requests (a lock-free CAS
//!   counter — the hot path when per-route bounds are off);
//! * the **per-route** gate caps any single route's share, so one hot
//!   route saturating its twins cannot starve every other route out of
//!   the global budget.
//!
//! [`Backpressure::try_acquire_route`] reports *which* gate shed via
//! [`Shed`], so the serving layer can type its rejection responses.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Shared in-flight budget.
#[derive(Debug)]
pub struct Backpressure {
    in_flight: AtomicUsize,
    limit: usize,
    /// Per-route in-flight cap; `usize::MAX` disables the route gate
    /// (and its map bookkeeping) entirely.
    route_limit: usize,
    routes: Mutex<BTreeMap<String, usize>>,
}

/// Why an admission attempt was shed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Shed {
    /// The global in-flight budget is exhausted.
    Global { in_flight: usize, limit: usize },
    /// This route's share of the budget is exhausted (the global gate
    /// still had room).
    Route { route: String, in_flight: usize, limit: usize },
}

/// RAII permit: releases its slot(s) on drop.
pub struct Permit {
    ctrl: Arc<Backpressure>,
    /// `Some` iff this permit also holds a per-route slot.
    route: Option<String>,
}

impl Backpressure {
    /// Global gate only (per-route bounds disabled).
    pub fn new(limit: usize) -> Arc<Self> {
        Self::with_route_limit(limit, usize::MAX)
    }

    /// Global gate plus a per-route in-flight cap.
    pub fn with_route_limit(limit: usize, route_limit: usize) -> Arc<Self> {
        assert!(limit > 0, "backpressure limit must be positive");
        assert!(route_limit > 0, "route limit must be positive");
        Arc::new(Self {
            in_flight: AtomicUsize::new(0),
            limit,
            route_limit,
            routes: Mutex::new(BTreeMap::new()),
        })
    }

    /// Reserve one global slot (CAS loop); `None` means the budget is
    /// exhausted.
    fn acquire_global(self: &Arc<Self>) -> Option<Permit> {
        let mut cur = self.in_flight.load(Ordering::Relaxed);
        loop {
            if cur >= self.limit {
                return None;
            }
            match self.in_flight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    return Some(Permit {
                        ctrl: Arc::clone(self),
                        route: None,
                    })
                }
                Err(now) => cur = now,
            }
        }
    }

    /// Try to admit one request against the global gate only; `None`
    /// means shed. (The network layer uses this for its connection cap.)
    pub fn try_acquire(self: &Arc<Self>) -> Option<Permit> {
        self.acquire_global()
    }

    /// Try to admit one request on `route` against both gates. The error
    /// names the gate that shed, so rejections can be typed per scope.
    pub fn try_acquire_route(
        self: &Arc<Self>,
        route: &str,
    ) -> Result<Permit, Shed> {
        let mut permit = self.acquire_global().ok_or_else(|| {
            Shed::Global { in_flight: self.in_flight(), limit: self.limit }
        })?;
        if self.route_limit == usize::MAX {
            return Ok(permit);
        }
        let mut map = self.routes.lock().expect("backpressure lock");
        let count = map.entry(route.to_owned()).or_insert(0);
        if *count >= self.route_limit {
            let in_flight = *count;
            drop(map);
            // `permit` drops here, releasing the global slot.
            return Err(Shed::Route {
                route: route.to_owned(),
                in_flight,
                limit: self.route_limit,
            });
        }
        *count += 1;
        permit.route = Some(route.to_owned());
        Ok(permit)
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    pub fn limit(&self) -> usize {
        self.limit
    }

    /// Per-route cap (`usize::MAX` when the route gate is off).
    pub fn route_limit(&self) -> usize {
        self.route_limit
    }

    /// Current in-flight count on one route (0 when the route gate is
    /// off — only route-gated permits are tracked per route).
    pub fn route_in_flight(&self, route: &str) -> usize {
        self.routes
            .lock()
            .expect("backpressure lock")
            .get(route)
            .copied()
            .unwrap_or(0)
    }
}

impl Drop for Permit {
    fn drop(&mut self) {
        if let Some(route) = self.route.take() {
            let mut map = self.ctrl.routes.lock().expect("backpressure lock");
            if let Some(count) = map.get_mut(&route) {
                *count -= 1;
                if *count == 0 {
                    map.remove(&route);
                }
            }
        }
        self.ctrl.in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_limit_then_sheds() {
        let bp = Backpressure::new(2);
        let a = bp.try_acquire();
        let b = bp.try_acquire();
        assert!(a.is_some() && b.is_some());
        assert!(bp.try_acquire().is_none());
        drop(a);
        assert!(bp.try_acquire().is_some());
    }

    #[test]
    fn permits_release_on_drop() {
        let bp = Backpressure::new(1);
        {
            let _p = bp.try_acquire().unwrap();
            assert_eq!(bp.in_flight(), 1);
        }
        assert_eq!(bp.in_flight(), 0);
    }

    #[test]
    fn concurrent_admission_never_exceeds_limit() {
        let bp = Backpressure::new(8);
        let mut handles = Vec::new();
        for _ in 0..4 {
            let bp = Arc::clone(&bp);
            handles.push(std::thread::spawn(move || {
                let mut max_seen = 0usize;
                for _ in 0..10_000 {
                    if let Some(_p) = bp.try_acquire() {
                        max_seen = max_seen.max(bp.in_flight());
                    }
                }
                max_seen
            }));
        }
        for h in handles {
            let max_seen = h.join().unwrap();
            assert!(max_seen <= 8, "exceeded limit: {max_seen}");
        }
        assert_eq!(bp.in_flight(), 0);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_limit_rejected() {
        let _ = Backpressure::new(0);
    }

    #[test]
    #[should_panic(expected = "route limit")]
    fn zero_route_limit_rejected() {
        let _ = Backpressure::with_route_limit(4, 0);
    }

    #[test]
    fn route_gate_bounds_one_route_without_starving_others() {
        let bp = Backpressure::with_route_limit(8, 2);
        let a1 = bp.try_acquire_route("hot").unwrap();
        let _a2 = bp.try_acquire_route("hot").unwrap();
        // Third "hot" request sheds at the route gate, not the global one.
        match bp.try_acquire_route("hot") {
            Err(Shed::Route { route, in_flight, limit }) => {
                assert_eq!(route, "hot");
                assert_eq!(in_flight, 2);
                assert_eq!(limit, 2);
            }
            other => panic!("expected route shed, got {other:?}"),
        }
        // A route-gate shed must not leak its global slot.
        assert_eq!(bp.in_flight(), 2);
        // Other routes still admit.
        let _b = bp.try_acquire_route("cold").unwrap();
        assert_eq!(bp.route_in_flight("cold"), 1);
        // Releasing a "hot" permit reopens the route.
        drop(a1);
        assert_eq!(bp.route_in_flight("hot"), 1);
        assert!(bp.try_acquire_route("hot").is_ok());
    }

    #[test]
    fn global_gate_sheds_before_route_gate() {
        let bp = Backpressure::with_route_limit(2, 2);
        let _a = bp.try_acquire_route("a").unwrap();
        let _b = bp.try_acquire_route("b").unwrap();
        match bp.try_acquire_route("c") {
            Err(Shed::Global { limit, .. }) => assert_eq!(limit, 2),
            other => panic!("expected global shed, got {other:?}"),
        }
    }

    #[test]
    fn route_bookkeeping_empties_when_idle() {
        let bp = Backpressure::with_route_limit(4, 2);
        let p = bp.try_acquire_route("r").unwrap();
        assert_eq!(bp.route_in_flight("r"), 1);
        drop(p);
        assert_eq!(bp.route_in_flight("r"), 0);
        assert_eq!(bp.in_flight(), 0);
        // The map entry is removed, not left at zero.
        assert!(bp.routes.lock().unwrap().is_empty());
    }

    #[test]
    fn disabled_route_gate_skips_bookkeeping() {
        let bp = Backpressure::new(4);
        let _p = bp.try_acquire_route("r").unwrap();
        assert_eq!(bp.route_limit(), usize::MAX);
        assert_eq!(bp.route_in_flight("r"), 0);
        assert_eq!(bp.in_flight(), 1);
    }

    #[test]
    fn concurrent_route_admission_never_exceeds_route_limit() {
        let bp = Backpressure::with_route_limit(64, 4);
        let mut handles = Vec::new();
        for _ in 0..4 {
            let bp = Arc::clone(&bp);
            handles.push(std::thread::spawn(move || {
                let mut max_seen = 0usize;
                for _ in 0..5_000 {
                    if let Ok(_p) = bp.try_acquire_route("shared") {
                        max_seen = max_seen.max(bp.route_in_flight("shared"));
                    }
                }
                max_seen
            }));
        }
        for h in handles {
            let max_seen = h.join().unwrap();
            assert!(max_seen <= 4, "route limit exceeded: {max_seen}");
        }
        assert_eq!(bp.in_flight(), 0);
        assert_eq!(bp.route_in_flight("shared"), 0);
    }

    #[test]
    fn shed_rate_measured_at_the_gate() {
        // Drive a bounded gate past saturation and check the arithmetic
        // the serving layer reports: admitted + shed == offered, and the
        // shed fraction is exactly the overflow.
        let bp = Backpressure::with_route_limit(16, 4);
        let mut held = Vec::new();
        let (mut admitted, mut shed) = (0u64, 0u64);
        for _ in 0..10 {
            match bp.try_acquire_route("r") {
                Ok(p) => {
                    admitted += 1;
                    held.push(p);
                }
                Err(_) => shed += 1,
            }
        }
        assert_eq!(admitted, 4);
        assert_eq!(shed, 6);
        assert_eq!(admitted + shed, 10);
        let frac = shed as f64 / (admitted + shed) as f64;
        assert!((frac - 0.6).abs() < 1e-12);
    }
}
