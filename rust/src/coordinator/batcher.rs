//! Dynamic batcher: coalesces same-route jobs inside a time window.
//!
//! Twin state (deployed arrays, compiled executables, integrator charge) is
//! expensive to touch cold; grouping requests for the same route before
//! dispatch lets a worker execute them as **one batched rollout** on one
//! warm instance (`Twin::run_batch`: many trajectories per crossbar read,
//! GEMM instead of repeated GEMV). The policy is the standard serving
//! trade-off: dispatch when `max_batch` is reached OR the oldest job has
//! waited `window`. Capacity is counted in **effective lanes**
//! (`TwinRequest::lanes`): a Monte-Carlo ensemble job weighs its member
//! count, since it expands to that many trajectories in the twin's single
//! batched rollout — so `max_batch` bounds actual rollout width, not job
//! count. Requests inside a batch may still disagree on `n_points`; the
//! twin splits those into compatible sub-batches rather than padding.
//!
//! **Adaptive windows.** The maturity window is *per route*, sized from
//! the route's observed batch execution time (the EWMA scheduler workers
//! record into [`Telemetry`]) and clamped to
//! `[window_min, window_max]`: a route whose rollouts finish in
//! microseconds flushes near-immediately, while a heavy ensemble route
//! holds its window open long enough to saturate the lane cap. With the
//! default clamp (`window_min == window_max == window`) every route gets
//! the fixed window — exactly the pre-adaptive behaviour. A route's
//! window is sampled when its queue forms (first pending job) and rides
//! with the queue, so maturity checks and wake-up deadlines are
//! per-route: one short-window route never forces early flushes — or
//! busy polling — on the others.

use std::collections::btree_map::Entry;
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::telemetry::Telemetry;
use crate::coordinator::{Batch, Job};

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    /// Fixed window used for routes with no observed execution time yet
    /// (and, with the default clamp, for every route).
    pub window: Duration,
    /// Lower clamp of the adaptive per-route window.
    pub window_min: Duration,
    /// Upper clamp of the adaptive per-route window. Equal min and max
    /// pin every route to that fixed window, disabling adaptation.
    pub window_max: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        let window = Duration::from_millis(2);
        Self { max_batch: 32, window, window_min: window, window_max: window }
    }
}

impl BatchPolicy {
    /// A fixed-window policy (adaptation disabled): the historical
    /// constructor shape, used by tests and by configs that leave the
    /// clamp unset.
    pub fn fixed(max_batch: usize, window: Duration) -> Self {
        Self { max_batch, window, window_min: window, window_max: window }
    }
}

/// Resolve one route's maturity window: the telemetry execution-time
/// EWMA when available (else the fixed default), clamped to the policy
/// bounds. A free function so [`Batcher::push`] can call it while the
/// pending map is mutably borrowed.
fn route_window(
    policy: &BatchPolicy,
    telemetry: Option<&Telemetry>,
    route: &str,
) -> Duration {
    let lo = policy.window_min.min(policy.window_max);
    let hi = policy.window_min.max(policy.window_max);
    telemetry
        .and_then(|t| t.route_exec_ewma(route))
        .map(Duration::from_secs_f64)
        .unwrap_or(policy.window)
        .clamp(lo, hi)
}

/// Per-route pending queue: jobs plus their effective lane total.
#[derive(Default)]
struct RouteQueue {
    jobs: Vec<Job>,
    /// Sum of `TwinRequest::lanes()` across `jobs` — what `max_batch`
    /// caps (an ensemble job counts its member lanes, not 1).
    lanes: usize,
    /// This queue's maturity window, sampled from the route's execution
    /// EWMA when the queue formed.
    window: Duration,
}

/// The batcher thread's state machine (pure, testable without threads).
pub struct Batcher {
    policy: BatchPolicy,
    /// Execution-time source for adaptive windows; `None` (or the
    /// default equal clamp) falls back to the fixed window.
    telemetry: Option<Arc<Telemetry>>,
    pending: BTreeMap<String, RouteQueue>,
    /// Scratch for matured route keys: [`Batcher::flush`] runs on every
    /// tick of the hot dispatch loop, so it must not snapshot the whole
    /// key set per call — only matured routes are staged here (their key
    /// strings then move into the emitted batches), and the vector's
    /// capacity is reused across ticks.
    mature: Vec<String>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self::with_telemetry(policy, None)
    }

    /// A batcher that sizes per-route windows from the telemetry's
    /// execution-time EWMA (see the module docs for the clamp rule).
    pub fn with_telemetry(
        policy: BatchPolicy,
        telemetry: Option<Arc<Telemetry>>,
    ) -> Self {
        Self {
            policy,
            telemetry,
            pending: BTreeMap::new(),
            mature: Vec::new(),
        }
    }

    /// Add a job; returns a full batch immediately once the route's
    /// pending *lane* total reaches max_batch (a single wide-ensemble job
    /// can mature a batch by itself).
    pub fn push(&mut self, job: Job) -> Option<Batch> {
        let route = job.route.clone();
        let q = match self.pending.entry(route.clone()) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(v) => {
                // Window sampled once per queue generation: each batch
                // that flushes removes the queue, so the next job on the
                // route re-reads the (possibly updated) EWMA.
                let window = route_window(
                    &self.policy,
                    self.telemetry.as_deref(),
                    v.key(),
                );
                v.insert(RouteQueue { window, ..RouteQueue::default() })
            }
        };
        q.lanes = q.lanes.saturating_add(job.req.lanes());
        q.jobs.push(job);
        if q.lanes >= self.policy.max_batch {
            let jobs = std::mem::take(&mut q.jobs);
            self.pending.remove(&route);
            return Some(Batch { route, jobs });
        }
        None
    }

    /// Flush every route whose oldest job exceeded *that route's* window
    /// (or all with `force`). Returns the matured batches.
    ///
    /// The common tick — nothing matured — touches no key strings at all:
    /// matured keys are cloned once into the reusable `mature` scratch
    /// (each clone then *moves* into its emitted `Batch`, which needs an
    /// owned route anyway), instead of snapshotting every pending key into
    /// a fresh `Vec<String>` per tick.
    pub fn flush(&mut self, now: Instant, force: bool) -> Vec<Batch> {
        let mut out = Vec::new();
        let mut mature = std::mem::take(&mut self.mature);
        debug_assert!(mature.is_empty());
        for (route, q) in &self.pending {
            let is_mature = !q.jobs.is_empty()
                && (force
                    || q.jobs.first().is_some_and(|j| {
                        now.duration_since(j.enqueued) >= q.window
                    }));
            if is_mature {
                mature.push(route.clone());
            }
        }
        for route in mature.drain(..) {
            if let Some(q) = self.pending.remove(&route) {
                out.push(Batch { route, jobs: q.jobs });
            }
        }
        self.mature = mature;
        out
    }

    /// Time until the next per-route window deadline (for the event-loop
    /// sleep). Each route contributes its own deadline, so a short
    /// adaptive window on one route wakes the loop exactly when that
    /// route matures — not on some global cadence.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.pending
            .values()
            .filter_map(|q| {
                q.jobs.first().map(|j| {
                    q.window
                        .saturating_sub(now.duration_since(j.enqueued))
                })
            })
            .min()
    }

    pub fn pending_jobs(&self) -> usize {
        self.pending.values().map(|q| q.jobs.len()).sum()
    }

    /// Pending effective lanes across routes (ensemble-weighted).
    pub fn pending_lanes(&self) -> usize {
        self.pending.values().map(|q| q.lanes).sum()
    }
}

/// Spawn the batcher event loop: receives jobs, emits batches. Pass the
/// coordinator's [`Telemetry`] to enable adaptive per-route windows
/// (with the default equal clamp the telemetry is read but every window
/// resolves to the fixed one).
pub fn spawn(
    policy: BatchPolicy,
    telemetry: Option<Arc<Telemetry>>,
    jobs_rx: mpsc::Receiver<Job>,
    batches_tx: mpsc::Sender<Batch>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("batcher".into())
        .spawn(move || {
            let mut b = Batcher::with_telemetry(policy, telemetry);
            loop {
                let now = Instant::now();
                let timeout = b
                    .next_deadline(now)
                    .unwrap_or(Duration::from_millis(50));
                match jobs_rx.recv_timeout(timeout) {
                    Ok(job) => {
                        if let Some(batch) = b.push(job) {
                            if batches_tx.send(batch).is_err() {
                                return;
                            }
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        // Drain whatever is pending, then stop.
                        for batch in b.flush(Instant::now(), true) {
                            let _ = batches_tx.send(batch);
                        }
                        return;
                    }
                }
                for batch in b.flush(Instant::now(), false) {
                    if batches_tx.send(batch).is_err() {
                        return;
                    }
                }
            }
        })
        .expect("spawn batcher")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::twin::TwinRequest;

    fn job(route: &str) -> (Job, mpsc::Receiver<crate::coordinator::JobResult>) {
        let (tx, rx) = mpsc::channel();
        (
            Job {
                id: 0,
                route: route.into(),
                req: TwinRequest::autonomous(vec![], 1),
                enqueued: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn max_batch_triggers_immediate_dispatch() {
        let mut b = Batcher::new(BatchPolicy::fixed(
            3,
            Duration::from_secs(10),
        ));
        let (_keep1, _r1) = {
            let (j, r) = job("a");
            (b.push(j), r)
        };
        let (j2, _r2) = job("a");
        assert!(b.push(j2).is_none());
        let (j3, _r3) = job("a");
        let batch = b.push(j3).expect("third job completes the batch");
        assert_eq!(batch.jobs.len(), 3);
        assert_eq!(b.pending_jobs(), 0);
    }

    #[test]
    fn ensemble_jobs_count_lanes_against_max_batch() {
        use crate::twin::EnsembleSpec;
        let mut b = Batcher::new(BatchPolicy::fixed(
            8,
            Duration::from_secs(10),
        ));
        // A 3-lane ensemble + 4 plain jobs = 7 lanes: still pending.
        let (mut j, _r) = job("a");
        j.req = TwinRequest::autonomous(vec![], 1)
            .with_ensemble(EnsembleSpec::new(3));
        assert!(b.push(j).is_none());
        let mut keep = Vec::new();
        for _ in 0..4 {
            let (j, r) = job("a");
            assert!(b.push(j).is_none());
            keep.push(r);
        }
        assert_eq!(b.pending_jobs(), 5);
        assert_eq!(b.pending_lanes(), 7);
        // One more plain job reaches 8 lanes: the batch matures with 6
        // jobs even though max_batch (counted in jobs) was never hit.
        let (j6, _r6) = job("a");
        let batch = b.push(j6).expect("lane total matured the batch");
        assert_eq!(batch.jobs.len(), 6);
        assert_eq!(b.pending_lanes(), 0);
        // A single wide ensemble matures a batch by itself.
        let (mut wide, _rw) = job("a");
        wide.req = TwinRequest::autonomous(vec![], 1)
            .with_ensemble(EnsembleSpec::new(32));
        let batch = b.push(wide).expect("wide ensemble dispatches alone");
        assert_eq!(batch.jobs.len(), 1);
    }

    #[test]
    fn routes_batch_independently() {
        let mut b = Batcher::new(BatchPolicy::fixed(
            2,
            Duration::from_secs(10),
        ));
        let (ja, _ra) = job("a");
        let (jb, _rb) = job("b");
        assert!(b.push(ja).is_none());
        assert!(b.push(jb).is_none());
        assert_eq!(b.pending_jobs(), 2);
        let (ja2, _ra2) = job("a");
        let batch = b.push(ja2).unwrap();
        assert_eq!(batch.route, "a");
        assert_eq!(batch.jobs.len(), 2);
        assert_eq!(b.pending_jobs(), 1); // b still pending
    }

    #[test]
    fn window_flush_matures_old_jobs() {
        let mut b = Batcher::new(BatchPolicy::fixed(
            100,
            Duration::from_millis(1),
        ));
        let (j, _r) = job("a");
        b.push(j);
        let later = Instant::now() + Duration::from_millis(5);
        let batches = b.flush(later, false);
        assert_eq!(batches.len(), 1);
        assert_eq!(b.pending_jobs(), 0);
    }

    #[test]
    fn flush_scratch_is_reused_across_ticks() {
        let mut b = Batcher::new(BatchPolicy::fixed(
            100,
            Duration::from_millis(1),
        ));
        let (j, _r) = job("a");
        b.push(j);
        let later = Instant::now() + Duration::from_millis(5);
        assert_eq!(b.flush(later, false).len(), 1);
        let cap = b.mature.capacity();
        assert!(cap >= 1);
        // Idle ticks emit nothing and keep the staged capacity.
        for _ in 0..3 {
            assert!(b.flush(Instant::now(), false).is_empty());
        }
        assert_eq!(b.mature.capacity(), cap);
    }

    #[test]
    fn force_flush_empties_everything() {
        let mut b = Batcher::new(BatchPolicy::default());
        let (j1, _r1) = job("a");
        let (j2, _r2) = job("b");
        b.push(j1);
        b.push(j2);
        let batches = b.flush(Instant::now(), true);
        assert_eq!(batches.len(), 2);
    }

    #[test]
    fn next_deadline_reflects_oldest() {
        let mut b = Batcher::new(BatchPolicy::fixed(
            10,
            Duration::from_millis(100),
        ));
        assert!(b.next_deadline(Instant::now()).is_none());
        let (j, _r) = job("a");
        b.push(j);
        let d = b.next_deadline(Instant::now()).unwrap();
        assert!(d <= Duration::from_millis(100));
    }

    /// An adaptive policy: fixed 2 ms default, clamp [1 ms, 10 ms].
    fn adaptive_policy() -> BatchPolicy {
        BatchPolicy {
            max_batch: 100,
            window: Duration::from_millis(2),
            window_min: Duration::from_millis(1),
            window_max: Duration::from_millis(10),
        }
    }

    #[test]
    fn adaptive_window_tracks_route_exec_ewma_with_clamp() {
        let t = Arc::new(Telemetry::new());
        // "heavy" observed at 50 ms -> clamped to window_max = 10 ms;
        // "light" observed at 0.1 ms -> clamped to window_min = 1 ms;
        // "fresh" has no observations -> fixed default 2 ms.
        t.record_route_exec("heavy", 50e-3);
        t.record_route_exec("light", 0.1e-3);
        let mut b = Batcher::with_telemetry(adaptive_policy(), Some(t));
        let t0 = Instant::now();
        for route in ["heavy", "light", "fresh"] {
            let (mut j, _r) = job(route);
            j.enqueued = t0;
            assert!(b.push(j).is_none());
        }
        // The wake-up deadline is the shortest pending window (light's).
        let d = b.next_deadline(t0).unwrap();
        assert!(d <= Duration::from_millis(1), "{d:?}");
        // At +1.5 ms only "light" matured.
        let batches = b.flush(t0 + Duration::from_micros(1500), false);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].route, "light");
        // At +5 ms "fresh" (2 ms default) matured; "heavy" still waits.
        let batches = b.flush(t0 + Duration::from_millis(5), false);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].route, "fresh");
        assert_eq!(b.pending_jobs(), 1);
        // At +11 ms "heavy" finally matures at the clamp ceiling.
        let batches = b.flush(t0 + Duration::from_millis(11), false);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].route, "heavy");
    }

    #[test]
    fn default_equal_clamp_reproduces_the_fixed_window() {
        // Even with a wild EWMA on record, the default policy's equal
        // clamp pins every route to the fixed 2 ms window — unset knobs
        // must reproduce pre-adaptive behaviour exactly.
        let t = Arc::new(Telemetry::new());
        t.record_route_exec("a", 10.0);
        let mut b =
            Batcher::with_telemetry(BatchPolicy::default(), Some(t));
        let t0 = Instant::now();
        let (mut j, _r) = job("a");
        j.enqueued = t0;
        b.push(j);
        assert!(b
            .flush(t0 + Duration::from_millis(1), false)
            .is_empty());
        assert_eq!(
            b.flush(t0 + Duration::from_millis(3), false).len(),
            1
        );
    }

    #[test]
    fn route_window_resamples_on_each_queue_generation() {
        let t = Arc::new(Telemetry::new());
        let mut b =
            Batcher::with_telemetry(adaptive_policy(), Some(Arc::clone(&t)));
        let t0 = Instant::now();
        // First generation: no EWMA yet -> 2 ms default window.
        let (mut j, _r) = job("a");
        j.enqueued = t0;
        b.push(j);
        assert_eq!(b.flush(t0 + Duration::from_millis(3), false).len(), 1);
        // The route turns out to be slow; the next queue generation
        // samples the updated EWMA and holds its window open longer.
        t.record_route_exec("a", 8e-3);
        let t1 = Instant::now();
        let (mut j, _r2) = job("a");
        j.enqueued = t1;
        b.push(j);
        assert!(b.flush(t1 + Duration::from_millis(3), false).is_empty());
        assert_eq!(b.flush(t1 + Duration::from_millis(9), false).len(), 1);
    }

    #[test]
    fn spawned_loop_batches_and_flushes() {
        let (jtx, jrx) = mpsc::channel();
        let (btx, brx) = mpsc::channel();
        let handle = spawn(
            BatchPolicy::fixed(2, Duration::from_millis(5)),
            None,
            jrx,
            btx,
        );
        let (j1, _r1) = job("x");
        let (j2, _r2) = job("x");
        jtx.send(j1).unwrap();
        jtx.send(j2).unwrap();
        let batch = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.jobs.len(), 2);
        // Window path: single job flushes after ~5 ms.
        let (j3, _r3) = job("y");
        jtx.send(j3).unwrap();
        let batch = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.route, "y");
        drop(jtx);
        handle.join().unwrap();
    }
}
