//! Dynamic batcher: coalesces same-route jobs inside a time window.
//!
//! Twin state (deployed arrays, compiled executables, integrator charge) is
//! expensive to touch cold; grouping requests for the same route before
//! dispatch lets a worker execute them as **one batched rollout** on one
//! warm instance (`Twin::run_batch`: many trajectories per crossbar read,
//! GEMM instead of repeated GEMV). The policy is the standard serving
//! trade-off: dispatch when `max_batch` is reached OR the oldest job has
//! waited `window`. Capacity is counted in **effective lanes**
//! (`TwinRequest::lanes`): a Monte-Carlo ensemble job weighs its member
//! count, since it expands to that many trajectories in the twin's single
//! batched rollout — so `max_batch` bounds actual rollout width, not job
//! count. Requests inside a batch may still disagree on `n_points`; the
//! twin splits those into compatible sub-batches rather than padding.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::coordinator::{Batch, Job};

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub window: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 32, window: Duration::from_millis(2) }
    }
}

/// Per-route pending queue: jobs plus their effective lane total.
#[derive(Default)]
struct RouteQueue {
    jobs: Vec<Job>,
    /// Sum of `TwinRequest::lanes()` across `jobs` — what `max_batch`
    /// caps (an ensemble job counts its member lanes, not 1).
    lanes: usize,
}

/// The batcher thread's state machine (pure, testable without threads).
pub struct Batcher {
    policy: BatchPolicy,
    pending: BTreeMap<String, RouteQueue>,
    /// Scratch for matured route keys: [`Batcher::flush`] runs on every
    /// tick of the hot dispatch loop, so it must not snapshot the whole
    /// key set per call — only matured routes are staged here (their key
    /// strings then move into the emitted batches), and the vector's
    /// capacity is reused across ticks.
    mature: Vec<String>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self { policy, pending: BTreeMap::new(), mature: Vec::new() }
    }

    /// Add a job; returns a full batch immediately once the route's
    /// pending *lane* total reaches max_batch (a single wide-ensemble job
    /// can mature a batch by itself).
    pub fn push(&mut self, job: Job) -> Option<Batch> {
        let route = job.route.clone();
        let q = self.pending.entry(route.clone()).or_default();
        q.lanes = q.lanes.saturating_add(job.req.lanes());
        q.jobs.push(job);
        if q.lanes >= self.policy.max_batch {
            let jobs = std::mem::take(&mut q.jobs);
            self.pending.remove(&route);
            return Some(Batch { route, jobs });
        }
        None
    }

    /// Flush every route whose oldest job exceeded the window (or all with
    /// `force`). Returns the matured batches.
    ///
    /// The common tick — nothing matured — touches no key strings at all:
    /// matured keys are cloned once into the reusable `mature` scratch
    /// (each clone then *moves* into its emitted `Batch`, which needs an
    /// owned route anyway), instead of snapshotting every pending key into
    /// a fresh `Vec<String>` per tick.
    pub fn flush(&mut self, now: Instant, force: bool) -> Vec<Batch> {
        let mut out = Vec::new();
        let mut mature = std::mem::take(&mut self.mature);
        debug_assert!(mature.is_empty());
        for (route, q) in &self.pending {
            let is_mature = !q.jobs.is_empty()
                && (force
                    || q.jobs.first().is_some_and(|j| {
                        now.duration_since(j.enqueued) >= self.policy.window
                    }));
            if is_mature {
                mature.push(route.clone());
            }
        }
        for route in mature.drain(..) {
            if let Some(q) = self.pending.remove(&route) {
                out.push(Batch { route, jobs: q.jobs });
            }
        }
        self.mature = mature;
        out
    }

    /// Time until the next window deadline (for the event-loop sleep).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.pending
            .values()
            .filter_map(|q| q.jobs.first())
            .map(|j| {
                self.policy
                    .window
                    .saturating_sub(now.duration_since(j.enqueued))
            })
            .min()
    }

    pub fn pending_jobs(&self) -> usize {
        self.pending.values().map(|q| q.jobs.len()).sum()
    }

    /// Pending effective lanes across routes (ensemble-weighted).
    pub fn pending_lanes(&self) -> usize {
        self.pending.values().map(|q| q.lanes).sum()
    }
}

/// Spawn the batcher event loop: receives jobs, emits batches.
pub fn spawn(
    policy: BatchPolicy,
    jobs_rx: mpsc::Receiver<Job>,
    batches_tx: mpsc::Sender<Batch>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("batcher".into())
        .spawn(move || {
            let mut b = Batcher::new(policy);
            loop {
                let now = Instant::now();
                let timeout = b
                    .next_deadline(now)
                    .unwrap_or(Duration::from_millis(50));
                match jobs_rx.recv_timeout(timeout) {
                    Ok(job) => {
                        if let Some(batch) = b.push(job) {
                            if batches_tx.send(batch).is_err() {
                                return;
                            }
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        // Drain whatever is pending, then stop.
                        for batch in b.flush(Instant::now(), true) {
                            let _ = batches_tx.send(batch);
                        }
                        return;
                    }
                }
                for batch in b.flush(Instant::now(), false) {
                    if batches_tx.send(batch).is_err() {
                        return;
                    }
                }
            }
        })
        .expect("spawn batcher")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::twin::TwinRequest;

    fn job(route: &str) -> (Job, mpsc::Receiver<crate::coordinator::JobResult>) {
        let (tx, rx) = mpsc::channel();
        (
            Job {
                id: 0,
                route: route.into(),
                req: TwinRequest::autonomous(vec![], 1),
                enqueued: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn max_batch_triggers_immediate_dispatch() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 3,
            window: Duration::from_secs(10),
        });
        let (_keep1, _r1) = {
            let (j, r) = job("a");
            (b.push(j), r)
        };
        let (j2, _r2) = job("a");
        assert!(b.push(j2).is_none());
        let (j3, _r3) = job("a");
        let batch = b.push(j3).expect("third job completes the batch");
        assert_eq!(batch.jobs.len(), 3);
        assert_eq!(b.pending_jobs(), 0);
    }

    #[test]
    fn ensemble_jobs_count_lanes_against_max_batch() {
        use crate::twin::EnsembleSpec;
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 8,
            window: Duration::from_secs(10),
        });
        // A 3-lane ensemble + 4 plain jobs = 7 lanes: still pending.
        let (mut j, _r) = job("a");
        j.req = TwinRequest::autonomous(vec![], 1)
            .with_ensemble(EnsembleSpec::new(3));
        assert!(b.push(j).is_none());
        let mut keep = Vec::new();
        for _ in 0..4 {
            let (j, r) = job("a");
            assert!(b.push(j).is_none());
            keep.push(r);
        }
        assert_eq!(b.pending_jobs(), 5);
        assert_eq!(b.pending_lanes(), 7);
        // One more plain job reaches 8 lanes: the batch matures with 6
        // jobs even though max_batch (counted in jobs) was never hit.
        let (j6, _r6) = job("a");
        let batch = b.push(j6).expect("lane total matured the batch");
        assert_eq!(batch.jobs.len(), 6);
        assert_eq!(b.pending_lanes(), 0);
        // A single wide ensemble matures a batch by itself.
        let (mut wide, _rw) = job("a");
        wide.req = TwinRequest::autonomous(vec![], 1)
            .with_ensemble(EnsembleSpec::new(32));
        let batch = b.push(wide).expect("wide ensemble dispatches alone");
        assert_eq!(batch.jobs.len(), 1);
    }

    #[test]
    fn routes_batch_independently() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 2,
            window: Duration::from_secs(10),
        });
        let (ja, _ra) = job("a");
        let (jb, _rb) = job("b");
        assert!(b.push(ja).is_none());
        assert!(b.push(jb).is_none());
        assert_eq!(b.pending_jobs(), 2);
        let (ja2, _ra2) = job("a");
        let batch = b.push(ja2).unwrap();
        assert_eq!(batch.route, "a");
        assert_eq!(batch.jobs.len(), 2);
        assert_eq!(b.pending_jobs(), 1); // b still pending
    }

    #[test]
    fn window_flush_matures_old_jobs() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 100,
            window: Duration::from_millis(1),
        });
        let (j, _r) = job("a");
        b.push(j);
        let later = Instant::now() + Duration::from_millis(5);
        let batches = b.flush(later, false);
        assert_eq!(batches.len(), 1);
        assert_eq!(b.pending_jobs(), 0);
    }

    #[test]
    fn flush_scratch_is_reused_across_ticks() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 100,
            window: Duration::from_millis(1),
        });
        let (j, _r) = job("a");
        b.push(j);
        let later = Instant::now() + Duration::from_millis(5);
        assert_eq!(b.flush(later, false).len(), 1);
        let cap = b.mature.capacity();
        assert!(cap >= 1);
        // Idle ticks emit nothing and keep the staged capacity.
        for _ in 0..3 {
            assert!(b.flush(Instant::now(), false).is_empty());
        }
        assert_eq!(b.mature.capacity(), cap);
    }

    #[test]
    fn force_flush_empties_everything() {
        let mut b = Batcher::new(BatchPolicy::default());
        let (j1, _r1) = job("a");
        let (j2, _r2) = job("b");
        b.push(j1);
        b.push(j2);
        let batches = b.flush(Instant::now(), true);
        assert_eq!(batches.len(), 2);
    }

    #[test]
    fn next_deadline_reflects_oldest() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 10,
            window: Duration::from_millis(100),
        });
        assert!(b.next_deadline(Instant::now()).is_none());
        let (j, _r) = job("a");
        b.push(j);
        let d = b.next_deadline(Instant::now()).unwrap();
        assert!(d <= Duration::from_millis(100));
    }

    #[test]
    fn spawned_loop_batches_and_flushes() {
        let (jtx, jrx) = mpsc::channel();
        let (btx, brx) = mpsc::channel();
        let handle = spawn(
            BatchPolicy {
                max_batch: 2,
                window: Duration::from_millis(5),
            },
            jrx,
            btx,
        );
        let (j1, _r1) = job("x");
        let (j2, _r2) = job("x");
        jtx.send(j1).unwrap();
        jtx.send(j2).unwrap();
        let batch = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.jobs.len(), 2);
        // Window path: single job flushes after ~5 ms.
        let (j3, _r3) = job("y");
        jtx.send(j3).unwrap();
        let batch = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.route, "y");
        drop(jtx);
        handle.join().unwrap();
    }
}
