//! The L3 coordinator: the serving layer that routes twin-inference
//! requests to backends.
//!
//! Architecture (std-thread + mpsc; tokio is not available offline):
//!
//! ```text
//!   TCP clients ──> Net ──> Router ──> Batcher ──> Scheduler ──> Workers
//!   (wire proto)    │ conn     │ admission          (least-loaded) │ own
//!                   │ cap      └ Backpressure                      │ twins
//!                   └────────────> Telemetry <────────────────────┘
//! ```
//!
//! * [`net`]          — non-blocking TCP front door (poll loop over
//!   `std::net`) with a connection cap and graceful drain
//! * [`wire`]         — the length-prefixed JSON protocol
//!   (`docs/PROTOCOL.md`), shared by server and client
//! * [`client`]       — blocking protocol client (loadgen, CLI, tests)
//! * [`loadgen`]      — closed-loop load generator reporting
//!   p50/p99/p999 + rejected fraction into `BENCH_serve.json`
//! * [`router`]       — route-key validation + admission control
//! * [`batcher`]      — groups same-route requests within a time window up
//!   to `max_batch`
//! * [`scheduler`]    — least-loaded dispatch onto the worker pool; each
//!   worker executes a whole batch as **one `Twin::run_batch` call**, so
//!   batched backends roll all coalesced trajectories out together (one
//!   multi-vector crossbar read / GEMM per step) instead of looping jobs
//! * [`backpressure`] — global + per-route in-flight caps with fail-fast,
//!   typed admission
//! * [`telemetry`]    — counters + latency distributions
//! * [`service`]      — wires everything; public submit/blocking API
//!
//! In-process callers use [`service::Coordinator`] directly; network
//! callers speak the wire protocol to [`net::NetServer`], which is a
//! thin translation layer onto the same `try_submit` path (one
//! admission discipline, whichever door a request came through).

pub mod backpressure;
pub mod batcher;
pub mod client;
pub mod loadgen;
pub mod net;
pub mod router;
pub mod scheduler;
pub mod service;
pub mod telemetry;
pub mod wire;

use std::sync::mpsc;
use std::time::Instant;

use crate::twin::{TwinRequest, TwinResponse};

/// A unit of work flowing through the coordinator.
///
/// `req.seed` is always `Some` past the router: requests without an
/// explicit noise seed are stamped with one derived from the job id, so
/// every admitted job's noisy rollout is replayable (the twin echoes the
/// seed in `TwinResponse::seed`, and workers record it in telemetry).
pub struct Job {
    pub id: u64,
    /// Route key, e.g. "lorenz96/analog".
    pub route: String,
    pub req: TwinRequest,
    pub enqueued: Instant,
    /// Where the worker sends the outcome.
    pub reply: mpsc::Sender<JobResult>,
}

/// Outcome delivered to the submitter.
pub struct JobResult {
    pub id: u64,
    pub result: anyhow::Result<TwinResponse>,
    /// Queue + batch wait (s).
    pub wait_s: f64,
    /// Backend execution time (s).
    pub exec_s: f64,
}

/// A batch of same-route jobs.
pub struct Batch {
    pub route: String,
    pub jobs: Vec<Job>,
}
