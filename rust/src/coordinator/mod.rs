//! The L3 coordinator: the serving layer that routes twin-inference
//! requests to backends.
//!
//! Architecture (std-thread + mpsc; tokio is not available offline):
//!
//! ```text
//!   clients ──> Router ──> Batcher ──> Scheduler ──> Worker pool
//!                 │ admission           (least-loaded)   │ owns twin
//!                 └ Backpressure                          │ instances
//!                        Telemetry <──────────────────────┘
//! ```
//!
//! * [`router`]       — route-key validation + admission control
//! * [`batcher`]      — groups same-route requests within a time window up
//!   to `max_batch`
//! * [`scheduler`]    — least-loaded dispatch onto the worker pool; each
//!   worker executes a whole batch as **one `Twin::run_batch` call**, so
//!   batched backends roll all coalesced trajectories out together (one
//!   multi-vector crossbar read / GEMM per step) instead of looping jobs
//! * [`backpressure`] — global in-flight cap with fail-fast admission
//! * [`telemetry`]    — counters + latency distributions
//! * [`service`]      — wires everything; public submit/blocking API

pub mod backpressure;
pub mod batcher;
pub mod router;
pub mod scheduler;
pub mod service;
pub mod telemetry;

use std::sync::mpsc;
use std::time::Instant;

use crate::twin::{TwinRequest, TwinResponse};

/// A unit of work flowing through the coordinator.
///
/// `req.seed` is always `Some` past the router: requests without an
/// explicit noise seed are stamped with one derived from the job id, so
/// every admitted job's noisy rollout is replayable (the twin echoes the
/// seed in `TwinResponse::seed`, and workers record it in telemetry).
pub struct Job {
    pub id: u64,
    /// Route key, e.g. "lorenz96/analog".
    pub route: String,
    pub req: TwinRequest,
    pub enqueued: Instant,
    /// Where the worker sends the outcome.
    pub reply: mpsc::Sender<JobResult>,
}

/// Outcome delivered to the submitter.
pub struct JobResult {
    pub id: u64,
    pub result: anyhow::Result<TwinResponse>,
    /// Queue + batch wait (s).
    pub wait_s: f64,
    /// Backend execution time (s).
    pub exec_s: f64,
}

/// A batch of same-route jobs.
pub struct Batch {
    pub route: String,
    pub jobs: Vec<Job>,
}
