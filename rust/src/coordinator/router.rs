//! Request router: route-key validation + admission control.
//!
//! The router is the coordinator's front door: it checks the route exists,
//! applies backpressure, stamps the job and forwards it to the batcher. It
//! is deliberately synchronous and cheap — everything heavier happens
//! behind the batcher.
//!
//! Rejections are **typed** ([`SubmitError`]) so upstream layers — the
//! TCP front end in [`crate::coordinator::net`] in particular — can map
//! them onto protocol error codes (`rejected_overload`, `unknown_route`,
//! ...) instead of string-matching error text.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::backpressure::{Backpressure, Permit, Shed};
use crate::coordinator::telemetry::Telemetry;
use crate::coordinator::{Job, JobResult};
use crate::twin::registry::TwinRegistry;
use crate::twin::TwinRequest;
use crate::util::rng::derive_stream_seed;

/// Root of the router's auto-derived noise seeds. A fixed constant on
/// purpose: seeds exist for *replay*, not secrecy, and a deterministic
/// family (keyed by job id) means a serving log alone identifies every
/// rollout's noise stream. Requests that pin their own seed pass through
/// untouched (the network layer stamps seedless requests *before*
/// admission, so its requests always arrive pinned).
const ROUTER_SEED_ROOT: u64 = 0xc0de_5eed_0a11_0001;

/// Typed submission failure — the router's half of the wire protocol's
/// error codes.
#[derive(Debug)]
pub enum SubmitError {
    /// The route key is not in the registry. `available` enumerates the
    /// registered routes, annotated with their state dimension where the
    /// registry carries [`crate::twin::registry::RouteInfo`].
    UnknownRoute { route: String, available: String },
    /// The request failed validation (today: a bad ensemble spec).
    InvalidRequest(String),
    /// The request's explicit `y0` does not match the route's state
    /// dimension (known from the registry's `RouteInfo`). Caught at
    /// submit time so a malformed request never burns an admission slot
    /// or a worker twin instantiation.
    BadDimension { route: String, got: usize, want: usize },
    /// Shed at the admission gate; `scope` names the gate ("global" or
    /// "route") per [`Shed`].
    Overloaded { scope: &'static str, in_flight: usize, limit: usize },
    /// The coordinator's pipeline has shut down.
    Stopped,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::UnknownRoute { route, available } => {
                write!(f, "unknown route '{route}' (available: {available})")
            }
            SubmitError::InvalidRequest(msg) => {
                write!(f, "invalid ensemble spec: {msg}")
            }
            SubmitError::BadDimension { route, got, want } => write!(
                f,
                "bad request: y0 has dim {got} but route '{route}' \
                 integrates dim {want}"
            ),
            SubmitError::Overloaded { scope, in_flight, limit } => write!(
                f,
                "overloaded: {in_flight} requests in flight \
                 ({scope} limit {limit})"
            ),
            SubmitError::Stopped => write!(f, "coordinator stopped"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A submitted request: await the result on `rx`; dropping `permit`
/// releases the admission slot (hold it until the reply is consumed).
pub struct Submitted {
    pub id: u64,
    pub rx: mpsc::Receiver<JobResult>,
    permit: Permit,
}

impl Submitted {
    /// Block for the result, releasing admission afterwards.
    pub fn wait(self) -> Result<JobResult> {
        let r = self
            .rx
            .recv()
            .map_err(|_| anyhow!("coordinator dropped the job"));
        drop(self.permit);
        r
    }
}

/// The router.
pub struct Router {
    registry: TwinRegistry,
    jobs_tx: mpsc::Sender<Job>,
    backpressure: Arc<Backpressure>,
    telemetry: Arc<Telemetry>,
    next_id: AtomicU64,
}

impl Router {
    pub fn new(
        registry: TwinRegistry,
        jobs_tx: mpsc::Sender<Job>,
        backpressure: Arc<Backpressure>,
        telemetry: Arc<Telemetry>,
    ) -> Self {
        Self {
            registry,
            jobs_tx,
            backpressure,
            telemetry,
            next_id: AtomicU64::new(1),
        }
    }

    /// Submit a request; fails fast on unknown routes, invalid ensemble
    /// specs, or saturation — with a typed error. Requests without an
    /// explicit noise seed are stamped with one derived from the job id,
    /// so every admitted job is replayable (the twin echoes the seed in
    /// its response; ensemble member `k` replays under
    /// [`crate::twin::ensemble_member_seed`]`(seed, k)`).
    pub fn submit(
        &self,
        route: &str,
        req: TwinRequest,
    ) -> Result<Submitted, SubmitError> {
        if !self.registry.contains(route) {
            return Err(SubmitError::UnknownRoute {
                route: route.to_owned(),
                available: self.registry.describe_routes().join(", "),
            });
        }
        // Pre-admission y0 validation: an explicit initial state must
        // match the route's registered dimension. Empty `h0` means "use
        // the twin's default" and always passes; routes registered
        // without metadata (unit-test registries) are not checked.
        if !req.h0.is_empty() {
            if let Some(info) = self.registry.info(route) {
                if req.h0.len() != info.dim {
                    return Err(SubmitError::BadDimension {
                        route: route.to_owned(),
                        got: req.h0.len(),
                        want: info.dim,
                    });
                }
            }
        }
        if let Some(spec) = &req.ensemble {
            spec.validate()
                .map_err(|e| SubmitError::InvalidRequest(e.to_string()))?;
        }
        let permit = self
            .backpressure
            .try_acquire_route(route)
            .map_err(|shed| {
                self.telemetry.rejected.fetch_add(1, Ordering::Relaxed);
                self.telemetry.record_shed(route);
                match shed {
                    Shed::Global { in_flight, limit } => {
                        SubmitError::Overloaded {
                            scope: "global",
                            in_flight,
                            limit,
                        }
                    }
                    Shed::Route { in_flight, limit, .. } => {
                        SubmitError::Overloaded {
                            scope: "route",
                            in_flight,
                            limit,
                        }
                    }
                }
            })?;
        self.telemetry.record_admitted(route);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = req;
        if req.seed.is_none() {
            req.seed = Some(derive_stream_seed(ROUTER_SEED_ROOT, id));
        }
        let (reply, rx) = mpsc::channel();
        self.telemetry.submitted.fetch_add(1, Ordering::Relaxed);
        self.jobs_tx
            .send(Job {
                id,
                route: route.to_string(),
                req,
                enqueued: Instant::now(),
                reply,
            })
            .map_err(|_| SubmitError::Stopped)?;
        Ok(Submitted { id, rx, permit })
    }

    pub fn routes(&self) -> Vec<String> {
        self.registry.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::twin::{Twin, TwinResponse};

    struct NullTwin;
    impl Twin for NullTwin {
        fn name(&self) -> &str {
            "null"
        }
        fn state_dim(&self) -> usize {
            1
        }
        fn dt(&self) -> f64 {
            1.0
        }
        fn default_h0(&self) -> Vec<f64> {
            vec![]
        }
        fn run(&mut self, r: &TwinRequest) -> Result<TwinResponse> {
            Ok(TwinResponse {
                trajectory: crate::util::tensor::Trajectory::new(1),
                backend: "null",
                seed: r.seed.unwrap_or(0),
                ensemble: None,
                degraded: false,
            })
        }
    }

    fn setup(limit: usize) -> (Router, mpsc::Receiver<Job>) {
        let mut reg = TwinRegistry::new();
        reg.register("null", || Box::new(NullTwin));
        let (tx, rx) = mpsc::channel();
        let router = Router::new(
            reg,
            tx,
            Backpressure::new(limit),
            Arc::new(Telemetry::new()),
        );
        (router, rx)
    }

    #[test]
    fn submit_forwards_job() {
        let (router, rx) = setup(4);
        let s = router
            .submit("null", TwinRequest::autonomous(vec![], 1))
            .unwrap();
        let job = rx.recv().unwrap();
        assert_eq!(job.id, s.id);
        assert_eq!(job.route, "null");
    }

    #[test]
    fn submit_stamps_replay_seed_and_keeps_explicit_ones() {
        let (router, rx) = setup(4);
        router.submit("null", TwinRequest::autonomous(vec![], 1)).unwrap();
        let auto = rx.recv().unwrap();
        let stamped = auto.req.seed.expect("auto seed stamped");
        // Deterministic per job id: resubmitting derives the same family.
        assert_eq!(
            stamped,
            derive_stream_seed(ROUTER_SEED_ROOT, auto.id)
        );
        router
            .submit(
                "null",
                TwinRequest::autonomous(vec![], 1).with_seed(77),
            )
            .unwrap();
        let pinned = rx.recv().unwrap();
        assert_eq!(pinned.req.seed, Some(77), "explicit seed overwritten");
    }

    #[test]
    fn invalid_ensemble_spec_rejected_before_admission() {
        use crate::twin::EnsembleSpec;
        let (router, _rx) = setup(4);
        let bad = TwinRequest::autonomous(vec![], 1)
            .with_ensemble(EnsembleSpec::new(0));
        let err = match router.submit("null", bad) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("zero-member ensemble accepted"),
        };
        assert!(err.contains("ensemble"), "{err}");
        let bad_p = TwinRequest::autonomous(vec![], 1).with_ensemble(
            EnsembleSpec::new(4).with_percentiles(vec![120.0]),
        );
        assert!(matches!(
            router.submit("null", bad_p),
            Err(SubmitError::InvalidRequest(_))
        ));
        // A valid spec passes through untouched.
        let ok = TwinRequest::autonomous(vec![], 1)
            .with_ensemble(EnsembleSpec::new(8));
        assert!(router.submit("null", ok).is_ok());
    }

    #[test]
    fn bad_y0_dimension_rejected_before_admission() {
        use crate::twin::registry::RouteInfo;
        let mut reg = TwinRegistry::new();
        reg.register_info(
            "null",
            RouteInfo {
                dim: 1,
                dt: 1.0,
                backend: "null",
                aged: false,
                synthetic: false,
            },
            || Box::new(NullTwin),
        );
        let (tx, _rx) = mpsc::channel();
        let router = Router::new(
            reg,
            tx,
            Backpressure::new(4),
            Arc::new(Telemetry::new()),
        );
        let bad = TwinRequest::autonomous(vec![0.0, 1.0, 2.0], 1);
        let err = match router.submit("null", bad) {
            Err(e @ SubmitError::BadDimension { .. }) => e.to_string(),
            other => panic!("wrong-dim y0 not rejected: {other:?}"),
        };
        assert!(err.contains("dim 3"), "{err}");
        assert!(err.contains("dim 1"), "{err}");
        // Empty y0 (twin default) and the right dimension both pass.
        assert!(router
            .submit("null", TwinRequest::autonomous(vec![], 1))
            .is_ok());
        assert!(router
            .submit("null", TwinRequest::autonomous(vec![0.5], 1))
            .is_ok());
    }

    #[test]
    fn unknown_route_errors_enumerate_dims_where_known() {
        use crate::twin::registry::RouteInfo;
        let mut reg = TwinRegistry::new();
        reg.register_info(
            "hp/analog",
            RouteInfo {
                dim: 1,
                dt: 1e-3,
                backend: "analog",
                aged: false,
                synthetic: false,
            },
            || Box::new(NullTwin),
        );
        reg.register("bare", || Box::new(NullTwin));
        let (tx, _rx) = mpsc::channel();
        let router = Router::new(
            reg,
            tx,
            Backpressure::new(4),
            Arc::new(Telemetry::new()),
        );
        let err = match router
            .submit("ghost", TwinRequest::autonomous(vec![], 1))
        {
            Err(e) => e.to_string(),
            Ok(_) => panic!("ghost route accepted"),
        };
        assert!(err.contains("hp/analog (dim 1)"), "{err}");
        assert!(err.contains("bare"), "{err}");
    }

    #[test]
    fn unknown_route_rejected_before_admission() {
        let (router, _rx) = setup(1);
        let err = match router
            .submit("ghost", TwinRequest::autonomous(vec![], 1))
        {
            Err(e) => {
                assert!(matches!(e, SubmitError::UnknownRoute { .. }));
                e.to_string()
            }
            Ok(_) => panic!("ghost route accepted"),
        };
        assert!(err.contains("unknown route"));
        // Admission slot untouched.
        assert!(router
            .submit("null", TwinRequest::autonomous(vec![], 1))
            .is_ok());
    }

    #[test]
    fn saturation_sheds_with_typed_overload() {
        let (router, _rx) = setup(1);
        let _held = router
            .submit("null", TwinRequest::autonomous(vec![], 1))
            .unwrap();
        match router.submit("null", TwinRequest::autonomous(vec![], 1)) {
            Err(e @ SubmitError::Overloaded { scope, limit, .. }) => {
                assert_eq!(scope, "global");
                assert_eq!(limit, 1);
                assert!(e.to_string().contains("overloaded"));
            }
            other => panic!("admission not enforced: {other:?}"),
        }
    }

    #[test]
    fn route_scoped_overload_is_typed() {
        let mut reg = TwinRegistry::new();
        reg.register("null", || Box::new(NullTwin));
        let (tx, _rx) = mpsc::channel();
        let router = Router::new(
            reg,
            tx,
            Backpressure::with_route_limit(8, 1),
            Arc::new(Telemetry::new()),
        );
        let _held = router
            .submit("null", TwinRequest::autonomous(vec![], 1))
            .unwrap();
        match router.submit("null", TwinRequest::autonomous(vec![], 1)) {
            Err(SubmitError::Overloaded { scope, limit, .. }) => {
                assert_eq!(scope, "route");
                assert_eq!(limit, 1);
            }
            other => panic!("route gate not enforced: {other:?}"),
        }
    }

    #[test]
    fn admission_gate_records_per_route_load() {
        use crate::coordinator::telemetry::RouteLoad;
        let mut reg = TwinRegistry::new();
        reg.register("null", || Box::new(NullTwin));
        let (tx, _rx) = mpsc::channel();
        let tel = Arc::new(Telemetry::new());
        let router =
            Router::new(reg, tx, Backpressure::new(1), tel.clone());
        let _held = router
            .submit("null", TwinRequest::autonomous(vec![], 1))
            .unwrap();
        assert!(router
            .submit("null", TwinRequest::autonomous(vec![], 1))
            .is_err());
        let s = tel.snapshot();
        assert_eq!(
            s.route_load,
            vec![("null".to_string(), RouteLoad { admitted: 1, shed: 1 })]
        );
        assert!((s.route_load[0].1.shed_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ids_are_unique_and_increasing() {
        let (router, _rx) = setup(10);
        let a = router
            .submit("null", TwinRequest::autonomous(vec![], 1))
            .unwrap();
        let b = router
            .submit("null", TwinRequest::autonomous(vec![], 1))
            .unwrap();
        assert!(b.id > a.id);
    }
}
