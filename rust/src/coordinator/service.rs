//! The assembled coordinator: router -> batcher -> scheduler -> workers.
//!
//! The request path is batched end to end: the batcher coalesces same-route
//! jobs, the dispatcher hands each `Batch` to the least-loaded worker, and
//! the worker executes it as a single `Twin::run_batch` call — so analogue
//! twins amortise device reads across every coalesced trajectory and
//! digital twins run one GEMM per layer per step for the whole batch.

use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::Result;

use crate::config::ServeConfig;
use crate::coordinator::backpressure::Backpressure;
use crate::coordinator::batcher::{self, BatchPolicy};
use crate::coordinator::router::{Router, SubmitError, Submitted};
use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::telemetry::{Telemetry, TelemetrySnapshot};
use crate::twin::registry::TwinRegistry;
use crate::twin::{TwinRequest, TwinResponse};

/// The running coordinator service.
pub struct Coordinator {
    router: Router,
    telemetry: Arc<Telemetry>,
    // Held for lifetime/teardown order: batcher drains into the scheduler.
    _batcher: std::thread::JoinHandle<()>,
    _dispatcher: std::thread::JoinHandle<()>,
    _scheduler: Arc<Scheduler>,
}

impl Coordinator {
    /// Start the full pipeline over a twin registry.
    pub fn start(registry: TwinRegistry, cfg: &ServeConfig) -> Self {
        Self::start_with_telemetry(registry, cfg, Arc::new(Telemetry::new()))
    }

    /// Start the pipeline over a caller-owned [`Telemetry`]. This is how
    /// tile-sharded twins share the serving metrics: build the telemetry
    /// first, let sharded twin factories capture a clone (their shard
    /// workers report `shard_rollouts` / `shard_steps` into it), then hand
    /// the same instance to the coordinator.
    pub fn start_with_telemetry(
        registry: TwinRegistry,
        cfg: &ServeConfig,
        telemetry: Arc<Telemetry>,
    ) -> Self {
        let backpressure = Backpressure::with_route_limit(
            cfg.queue_depth,
            cfg.route_queue_depth,
        );
        let (jobs_tx, jobs_rx) = mpsc::channel();
        let (batches_tx, batches_rx) = mpsc::channel();
        let batcher = batcher::spawn(
            BatchPolicy {
                max_batch: cfg.max_batch,
                window: Duration::from_secs_f64(cfg.batch_window_s),
                window_min: Duration::from_secs_f64(cfg.batch_window_min_s),
                window_max: Duration::from_secs_f64(cfg.batch_window_max_s),
            },
            Some(Arc::clone(&telemetry)),
            jobs_rx,
            batches_tx,
        );
        let scheduler = Arc::new(Scheduler::start_with_stealing(
            cfg.workers,
            registry.clone(),
            Arc::clone(&telemetry),
            cfg.steal,
        ));
        // Dispatcher: batches -> least-loaded worker.
        let sched2 = Arc::clone(&scheduler);
        let dispatcher = std::thread::Builder::new()
            .name("dispatcher".into())
            .spawn(move || {
                while let Ok(batch) = batches_rx.recv() {
                    if sched2.dispatch(batch).is_err() {
                        return;
                    }
                }
            })
            .expect("spawn dispatcher");
        let router = Router::new(
            registry,
            jobs_tx,
            backpressure,
            Arc::clone(&telemetry),
        );
        Self {
            router,
            telemetry,
            _batcher: batcher,
            _dispatcher: dispatcher,
            _scheduler: scheduler,
        }
    }

    /// Non-blocking submit (await via [`Submitted::wait`]).
    pub fn submit(&self, route: &str, req: TwinRequest) -> Result<Submitted> {
        Ok(self.router.submit(route, req)?)
    }

    /// Non-blocking submit with a typed rejection — what the network
    /// front end uses to map failures onto protocol error codes.
    pub fn try_submit(
        &self,
        route: &str,
        req: TwinRequest,
    ) -> Result<Submitted, SubmitError> {
        self.router.submit(route, req)
    }

    /// Blocking call: submit + wait + unwrap the twin response.
    pub fn call(&self, route: &str, req: TwinRequest) -> Result<TwinResponse> {
        self.submit(route, req)?.wait()?.result
    }

    pub fn routes(&self) -> Vec<String> {
        self.router.routes()
    }

    pub fn stats(&self) -> TelemetrySnapshot {
        self.telemetry.snapshot()
    }

    /// The coordinator's shared telemetry (the network layer records its
    /// connection/frame counters into the same instance).
    pub fn telemetry(&self) -> Arc<Telemetry> {
        Arc::clone(&self.telemetry)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::twin::Twin;
    use crate::util::tensor::Trajectory;

    struct CounterTwin {
        calls: u64,
    }

    impl Twin for CounterTwin {
        fn name(&self) -> &str {
            "counter"
        }
        fn state_dim(&self) -> usize {
            1
        }
        fn dt(&self) -> f64 {
            1.0
        }
        fn default_h0(&self) -> Vec<f64> {
            vec![0.0]
        }
        fn run(&mut self, req: &TwinRequest) -> Result<TwinResponse> {
            self.calls += 1;
            Ok(TwinResponse {
                trajectory: Trajectory::repeat_row(
                    &[self.calls as f64],
                    req.n_points,
                ),
                backend: "counter",
                seed: req.seed.unwrap_or(0),
                ensemble: None,
                degraded: false,
            })
        }
    }

    fn cfg() -> ServeConfig {
        ServeConfig {
            workers: 2,
            max_batch: 4,
            batch_window_s: 1e-3,
            batch_window_min_s: 1e-3,
            batch_window_max_s: 1e-3,
            queue_depth: 64,
            route_queue_depth: 64,
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_call() {
        let mut reg = TwinRegistry::new();
        reg.register("counter", || Box::new(CounterTwin { calls: 0 }));
        let coord = Coordinator::start(reg, &cfg());
        let resp = coord
            .call("counter", TwinRequest::autonomous(vec![], 3))
            .unwrap();
        assert_eq!(resp.trajectory.len(), 3);
        let s = coord.stats();
        assert_eq!(s.submitted, 1);
        assert_eq!(s.completed, 1);
    }

    #[test]
    fn many_concurrent_calls_complete() {
        let mut reg = TwinRegistry::new();
        reg.register("counter", || Box::new(CounterTwin { calls: 0 }));
        let coord = Arc::new(Coordinator::start(reg, &cfg()));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = Arc::clone(&coord);
            handles.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    c.call("counter", TwinRequest::autonomous(vec![], 2))
                        .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = coord.stats();
        assert_eq!(s.completed, 80);
        assert_eq!(s.failed, 0);
        // Batching actually coalesced (fewer batches than jobs).
        assert!(s.batches <= 80);
    }

    #[test]
    fn twin_instances_are_warm_per_worker() {
        // The counter increments across calls on the same worker: with one
        // worker, the counter must reach the number of calls (instance
        // reused, not recreated).
        let mut reg = TwinRegistry::new();
        reg.register("counter", || Box::new(CounterTwin { calls: 0 }));
        let coord = Coordinator::start(
            reg,
            &ServeConfig { workers: 1, ..cfg() },
        );
        for _ in 0..4 {
            coord
                .call("counter", TwinRequest::autonomous(vec![], 1))
                .unwrap();
        }
        let resp = coord
            .call("counter", TwinRequest::autonomous(vec![], 1))
            .unwrap();
        assert_eq!(resp.trajectory.row(0)[0], 5.0);
    }

    #[test]
    fn every_job_flows_through_run_batch() {
        use std::sync::atomic::{AtomicU64, Ordering};

        struct BatchProbe {
            calls: Arc<AtomicU64>,
        }
        impl Twin for BatchProbe {
            fn name(&self) -> &str {
                "probe"
            }
            fn state_dim(&self) -> usize {
                1
            }
            fn dt(&self) -> f64 {
                1.0
            }
            fn default_h0(&self) -> Vec<f64> {
                vec![0.0]
            }
            fn run(
                &mut self,
                req: &TwinRequest,
            ) -> Result<TwinResponse> {
                Ok(TwinResponse {
                    trajectory: Trajectory::repeat_row(
                        &[0.0],
                        req.n_points,
                    ),
                    backend: "probe",
                    seed: req.seed.unwrap_or(0),
                    ensemble: None,
                    degraded: false,
                })
            }
            fn run_batch(
                &mut self,
                reqs: &[TwinRequest],
            ) -> Vec<Result<TwinResponse>> {
                self.calls.fetch_add(1, Ordering::Relaxed);
                reqs.iter().map(|r| self.run(r)).collect()
            }
        }

        let calls: Arc<AtomicU64> = Arc::default();
        let mut reg = TwinRegistry::new();
        let c2 = Arc::clone(&calls);
        reg.register("probe", move || {
            Box::new(BatchProbe { calls: Arc::clone(&c2) })
        });
        let coord = Coordinator::start(reg, &cfg());
        for _ in 0..3 {
            coord
                .call("probe", TwinRequest::autonomous(vec![], 2))
                .unwrap();
        }
        // Every dispatched batch (size >= 1) went through run_batch.
        let n = calls.load(std::sync::atomic::Ordering::Relaxed);
        assert!((1..=3).contains(&n), "run_batch calls: {n}");
        assert_eq!(coord.stats().completed, 3);
    }

    #[test]
    fn seeds_are_stamped_echoed_and_recorded() {
        let mut reg = TwinRegistry::new();
        reg.register("counter", || Box::new(CounterTwin { calls: 0 }));
        let coord = Coordinator::start(reg, &cfg());
        // Auto-stamped seed comes back non-zero and lands in telemetry.
        let resp = coord
            .call("counter", TwinRequest::autonomous(vec![], 2))
            .unwrap();
        assert_ne!(resp.seed, 0, "router did not stamp a seed");
        // Explicit seed round-trips untouched.
        let pinned = coord
            .call(
                "counter",
                TwinRequest::autonomous(vec![], 2).with_seed(4242),
            )
            .unwrap();
        assert_eq!(pinned.seed, 4242);
        let seeds = coord.stats().recent_seeds;
        assert!(
            seeds.iter().any(|&(_, s)| s == 4242),
            "seed not recorded in telemetry: {seeds:?}"
        );
    }

    #[test]
    fn ensemble_request_served_end_to_end() {
        use crate::analog::system::AnalogNoise;
        use crate::device::taox::DeviceConfig;
        use crate::models::loader::decay_mlp_weights;
        use crate::twin::lorenz96::Lorenz96Twin;
        use crate::twin::EnsembleSpec;

        let mut reg = TwinRegistry::new();
        reg.register("l96/analog", || {
            let quiet = DeviceConfig {
                fault_rate: 0.0,
                pulse_sigma: 0.0,
                ..Default::default()
            };
            Box::new(Lorenz96Twin::analog(
                &decay_mlp_weights(3),
                &quiet,
                AnalogNoise { read: 0.05, prog: 0.0 },
                7,
            ))
        });
        let coord = Coordinator::start(reg, &cfg());
        let resp = coord
            .call(
                "l96/analog",
                TwinRequest::autonomous(vec![0.5, -0.2, 0.1], 6)
                    .with_ensemble(
                        EnsembleSpec::new(4)
                            .with_percentiles(vec![5.0, 95.0]),
                    ),
            )
            .unwrap();
        let ens = resp.ensemble.expect("ensemble stats in response");
        assert_eq!(ens.members, 4);
        assert_eq!(ens.mean.len(), 6);
        assert_eq!(ens.percentiles.len(), 2);
        assert!(ens.member_trajectories.is_empty());
        // The router stamped a replayable family seed.
        assert_ne!(resp.seed, 0);
        let s = coord.stats();
        assert_eq!(s.ensemble_rollouts, 1);
        assert_eq!(s.ensemble_members, 4);
        // An invalid spec is rejected at the front door.
        assert!(coord
            .call(
                "l96/analog",
                TwinRequest::autonomous(vec![0.0; 3], 4)
                    .with_ensemble(EnsembleSpec::new(0)),
            )
            .is_err());
    }

    #[test]
    fn unknown_route_fails_fast() {
        let reg = TwinRegistry::new();
        let coord = Coordinator::start(reg, &cfg());
        assert!(coord
            .call("ghost", TwinRequest::autonomous(vec![], 1))
            .is_err());
    }
}
