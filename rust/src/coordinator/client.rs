//! A small blocking client for the wire protocol — what the load
//! generator, the CLI and the socket tests speak to a running server.
//!
//! One [`WireClient`] is one TCP connection. Requests can be pipelined:
//! `send` several, then `recv` responses as they arrive (the server
//! answers per-request, so responses are matched by `id`, not order —
//! batching and scheduling may reorder completions).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::wire::{self, WireRequest, WireResponse};

/// Default per-read timeout: a stuck server fails the client loudly
/// instead of hanging it.
const DEFAULT_TIMEOUT: Duration = Duration::from_secs(30);

/// A blocking protocol client over one TCP connection.
pub struct WireClient {
    stream: TcpStream,
}

impl WireClient {
    /// Connect to `addr` (e.g. `"127.0.0.1:7171"`) with the default
    /// read timeout.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to {addr}"))?;
        stream
            .set_read_timeout(Some(DEFAULT_TIMEOUT))
            .context("setting the read timeout")?;
        let _ = stream.set_nodelay(true);
        Ok(Self { stream })
    }

    /// Override the per-read timeout (`None` blocks forever).
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.stream
            .set_read_timeout(timeout)
            .context("setting the read timeout")
    }

    /// Encode + frame + send one request (non-blocking submit is the
    /// server's job; this just writes the bytes).
    pub fn send(&mut self, req: &WireRequest) -> Result<()> {
        self.send_raw(&wire::encode_request(req))
    }

    /// Send a raw payload verbatim (protocol tests use this to send
    /// malformed frames).
    pub fn send_raw(&mut self, payload: &str) -> Result<()> {
        self.stream
            .write_all(&wire::encode_frame(payload))
            .context("writing a frame")
    }

    /// Block for the next response frame and decode it.
    pub fn recv(&mut self) -> Result<WireResponse> {
        let mut header = [0u8; 4];
        self.stream
            .read_exact(&mut header)
            .context("reading a frame header")?;
        let len = u32::from_be_bytes(header) as usize;
        if len > wire::MAX_FRAME_BYTES {
            bail!(
                "server sent a {len}-byte frame (limit {})",
                wire::MAX_FRAME_BYTES
            );
        }
        let mut payload = vec![0u8; len];
        self.stream
            .read_exact(&mut payload)
            .context("reading a frame payload")?;
        wire::decode_response(&payload)
            .map_err(|e| anyhow::anyhow!("decoding a response: {e}"))
    }

    /// Send one request and block for one response (the common
    /// request/reply pattern; responses to pipelined requests should be
    /// matched by `id` instead).
    pub fn call(&mut self, req: &WireRequest) -> Result<WireResponse> {
        self.send(req)?;
        self.recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn connect_to_nothing_fails_loudly() {
        // Port 1 on localhost is essentially never listening.
        let err = WireClient::connect("127.0.0.1:1").unwrap_err();
        assert!(err.to_string().contains("127.0.0.1:1"));
    }
}
