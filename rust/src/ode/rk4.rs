//! Classic RK4 — the paper's ODESolve (Methods: "a fourth-order
//! Runge-Kutta solver (RK4) method serving as the ODESolve").
//!
//! Allocation-free inner loop *and* outer loop: stage scratch lives in a
//! reusable [`Rk4`] stepper, samples append to a flat
//! [`Trajectory`](crate::util::tensor::Trajectory) (each new sample starts
//! as a copy of the previous row and is advanced in place), so a warm
//! stepper + output pair performs zero heap allocations per solve. This is
//! the digital-twin-on-digital-hardware reference the analogue loop and
//! the PJRT artifacts are validated against.

use crate::ode::batch::{BatchVectorField, Flattened};
use crate::ode::func::VectorField;
use crate::util::tensor::Trajectory;

/// Reusable RK4 stepper.
pub struct Rk4 {
    k1: Vec<f64>,
    k2: Vec<f64>,
    k3: Vec<f64>,
    k4: Vec<f64>,
    tmp: Vec<f64>,
}

impl Rk4 {
    pub fn new(dim: usize) -> Self {
        Self {
            k1: vec![0.0; dim],
            k2: vec![0.0; dim],
            k3: vec![0.0; dim],
            k4: vec![0.0; dim],
            tmp: vec![0.0; dim],
        }
    }

    /// Dimension the stepper's scratch was allocated for.
    pub fn dim(&self) -> usize {
        self.k1.len()
    }

    /// Retarget the stage scratch to `dim`. Buffers are kept (Vec capacity
    /// never shrinks), so a warm stepper reused across batch sizes or state
    /// dimensions reallocates only when it sees a new maximum.
    pub fn ensure_dim(&mut self, dim: usize) {
        if self.k1.len() != dim {
            self.k1.resize(dim, 0.0);
            self.k2.resize(dim, 0.0);
            self.k3.resize(dim, 0.0);
            self.k4.resize(dim, 0.0);
            self.tmp.resize(dim, 0.0);
        }
    }

    /// One in-place RK4 step x <- x + dt * phi(t, x).
    ///
    /// Panics with an explicit message when the state or field dimension
    /// does not match the scratch this stepper was constructed with
    /// (previously an opaque out-of-bounds index deep in the stage loop).
    pub fn step(
        &mut self,
        f: &mut dyn VectorField,
        t: f64,
        x: &mut [f64],
        dt: f64,
    ) {
        let n = x.len();
        assert_eq!(
            n,
            self.k1.len(),
            "Rk4::step [{}]: state dim {} does not match stepper scratch \
             dim {} (construct with Rk4::new(dim) for this state)",
            f.label(),
            n,
            self.k1.len()
        );
        assert_eq!(
            f.dim(),
            n,
            "Rk4::step [{}]: field dim {} does not match state dim {}",
            f.label(),
            f.dim(),
            n
        );
        f.eval_into(t, x, &mut self.k1);
        for i in 0..n {
            self.tmp[i] = x[i] + 0.5 * dt * self.k1[i];
        }
        f.eval_into(t + 0.5 * dt, &self.tmp, &mut self.k2);
        for i in 0..n {
            self.tmp[i] = x[i] + 0.5 * dt * self.k2[i];
        }
        f.eval_into(t + 0.5 * dt, &self.tmp, &mut self.k3);
        for i in 0..n {
            self.tmp[i] = x[i] + dt * self.k3[i];
        }
        f.eval_into(t + dt, &self.tmp, &mut self.k4);
        for i in 0..n {
            x[i] += dt / 6.0
                * (self.k1[i]
                    + 2.0 * self.k2[i]
                    + 2.0 * self.k3[i]
                    + self.k4[i]);
        }
    }
}

/// Allocation-free fixed-step RK4: `n_points` samples spaced `dt` (first
/// is x0), `substeps` RK4 steps per sample, appended to `out` (reset to
/// row width `f.dim()`). With a warm `stepper` and `out` this performs
/// zero heap allocations.
pub fn solve_into(
    f: &mut dyn VectorField,
    x0: &[f64],
    dt: f64,
    n_points: usize,
    substeps: usize,
    stepper: &mut Rk4,
    out: &mut Trajectory,
) {
    assert!(substeps >= 1);
    let n = f.dim();
    assert_eq!(
        x0.len(),
        n,
        "rk4::solve [{}]: x0 dim {} does not match field dim {}",
        f.label(),
        x0.len(),
        n
    );
    stepper.ensure_dim(n);
    let hd = dt / substeps as f64;
    out.reset(n);
    out.reserve_rows(n_points.max(1));
    out.push_row(x0);
    let mut t = 0.0;
    for p in 1..n_points {
        out.push_copy_of_last();
        let x = out.row_mut(p);
        for _ in 0..substeps {
            stepper.step(f, t, x, hd);
            t += hd;
        }
    }
}

/// Allocating convenience wrapper around [`solve_into`].
pub fn solve(
    f: &mut dyn VectorField,
    x0: &[f64],
    dt: f64,
    n_points: usize,
    substeps: usize,
) -> Trajectory {
    let mut stepper = Rk4::new(f.dim());
    let mut out = Trajectory::new(f.dim());
    solve_into(f, x0, dt, n_points, substeps, &mut stepper, &mut out);
    out
}

/// Batched fixed-step RK4 over a flat `[batch * dim]` state; `out`
/// receives `n_points` rows of width `batch * dim` (first is `x0s`). The
/// stage combinations are element-wise, so each trajectory of the result
/// is bit-identical to a serial [`solve`] of the same field — this is the
/// digital half of the batched-vs-serial equivalence contract.
pub fn solve_batch_into(
    f: &mut dyn BatchVectorField,
    x0s: &[f64],
    dt: f64,
    n_points: usize,
    substeps: usize,
    stepper: &mut Rk4,
    out: &mut Trajectory,
) {
    assert_eq!(
        x0s.len(),
        f.batch() * f.dim(),
        "rk4::solve_batch [{}]: x0s length {} != batch {} * dim {}",
        f.label(),
        x0s.len(),
        f.batch(),
        f.dim()
    );
    solve_into(
        &mut Flattened { field: f },
        x0s,
        dt,
        n_points,
        substeps,
        stepper,
        out,
    );
}

/// Allocating convenience wrapper around [`solve_batch_into`].
pub fn solve_batch(
    f: &mut dyn BatchVectorField,
    x0s: &[f64],
    dt: f64,
    n_points: usize,
    substeps: usize,
) -> Trajectory {
    let dim = f.batch() * f.dim();
    let mut stepper = Rk4::new(dim);
    let mut out = Trajectory::new(dim);
    solve_batch_into(f, x0s, dt, n_points, substeps, &mut stepper, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::func::FnField;

    #[test]
    fn fourth_order_accuracy_on_decay() {
        let mut f =
            FnField::new(1, |_t, x: &[f64], o: &mut [f64]| o[0] = -x[0]);
        let traj = solve(&mut f, &[1.0], 0.1, 11, 1);
        let exact = (-1.0f64).exp();
        assert!(
            (traj[10][0] - exact).abs() < 1e-6,
            "err {}",
            (traj[10][0] - exact).abs()
        );
    }

    #[test]
    fn harmonic_oscillator_conserves_energy() {
        let mut f = FnField::new(2, |_t, x: &[f64], o: &mut [f64]| {
            o[0] = x[1];
            o[1] = -x[0];
        });
        let traj = solve(&mut f, &[1.0, 0.0], 0.01, 1001, 1);
        for row in &traj {
            let e = row[0] * row[0] + row[1] * row[1];
            assert!((e - 1.0).abs() < 1e-8, "energy drift {e}");
        }
        // x(t) = cos(t): check after 10 s.
        assert!((traj[1000][0] - (10.0f64).cos()).abs() < 1e-6);
    }

    #[test]
    fn rk4_beats_euler_at_same_step() {
        let mut f =
            FnField::new(1, |_t, x: &[f64], o: &mut [f64]| o[0] = -x[0]);
        let rk = solve(&mut f, &[1.0], 0.2, 6, 1);
        let eu = crate::ode::euler::solve(&mut f, &[1.0], 0.2, 6, 1);
        let exact = (-1.0f64).exp();
        assert!(
            (rk[5][0] - exact).abs() * 100.0 < (eu[5][0] - exact).abs(),
            "rk4 {} euler {}",
            rk[5][0],
            eu[5][0]
        );
    }

    #[test]
    fn nonautonomous_field_uses_stage_times() {
        // dx/dt = cos(t) -> x(pi/2) = 1; correct stage times matter.
        let mut f =
            FnField::new(1, |t, _x: &[f64], o: &mut [f64]| o[0] = t.cos());
        let dt = std::f64::consts::FRAC_PI_2;
        let traj = solve(&mut f, &[0.0], dt, 2, 4);
        assert!((traj[1][0] - 1.0).abs() < 1e-4, "x={}", traj[1][0]);
    }

    #[test]
    #[should_panic(expected = "stepper scratch dim")]
    fn step_rejects_wrong_state_dim_with_clear_message() {
        let mut f =
            FnField::new(3, |_t, _x: &[f64], o: &mut [f64]| o.fill(0.0));
        let mut stepper = Rk4::new(2);
        let mut x = [0.0; 3];
        stepper.step(&mut f, 0.0, &mut x, 0.1);
    }

    #[test]
    #[should_panic(expected = "field dim")]
    fn step_rejects_field_state_mismatch() {
        let mut f =
            FnField::new(3, |_t, _x: &[f64], o: &mut [f64]| o.fill(0.0));
        let mut stepper = Rk4::new(2);
        let mut x = [0.0; 2];
        stepper.step(&mut f, 0.0, &mut x, 0.1);
    }

    #[test]
    fn batch_solve_matches_serial_bitwise() {
        use crate::ode::batch::{BatchVectorField, Lifted};
        // A 2-trajectory harmonic oscillator batch vs two serial solves.
        struct Osc {
            batch: usize,
        }
        impl BatchVectorField for Osc {
            fn dim(&self) -> usize {
                2
            }
            fn batch(&self) -> usize {
                self.batch
            }
            fn eval_batch_into(
                &mut self,
                _t: f64,
                xs: &[f64],
                out: &mut [f64],
            ) {
                for b in 0..self.batch {
                    out[2 * b] = xs[2 * b + 1];
                    out[2 * b + 1] = -xs[2 * b];
                }
            }
        }
        let x0s = [1.0, 0.0, 0.25, -0.5];
        let flat = solve_batch(&mut Osc { batch: 2 }, &x0s, 0.05, 41, 2);
        for b in 0..2 {
            let mut f = FnField::new(2, |_t, x: &[f64], o: &mut [f64]| {
                o[0] = x[1];
                o[1] = -x[0];
            });
            let serial =
                solve(&mut f, &x0s[2 * b..2 * b + 2], 0.05, 41, 2);
            for (row, srow) in flat.iter().zip(&serial) {
                assert_eq!(&row[2 * b..2 * b + 2], &srow[..], "traj {b}");
            }
        }
        // A lifted serial field is a batch of one.
        let mut lifted = Lifted::new(FnField::new(
            1,
            |_t, x: &[f64], o: &mut [f64]| o[0] = -x[0],
        ));
        let a = solve_batch(&mut lifted, &[1.0], 0.1, 6, 1);
        let mut f =
            FnField::new(1, |_t, x: &[f64], o: &mut [f64]| o[0] = -x[0]);
        let b = solve(&mut f, &[1.0], 0.1, 6, 1);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "rk4::solve_batch [l96d64/analog shard 1/2]")]
    fn batched_dim_assert_reports_route_and_shard_label() {
        use crate::ode::batch::BatchVectorField;
        struct Labeled;
        impl BatchVectorField for Labeled {
            fn dim(&self) -> usize {
                4
            }
            fn batch(&self) -> usize {
                2
            }
            fn label(&self) -> &str {
                "l96d64/analog shard 1/2"
            }
            fn eval_batch_into(
                &mut self,
                _t: f64,
                _xs: &[f64],
                out: &mut [f64],
            ) {
                out.fill(0.0);
            }
        }
        // 7 values for a 2 x 4 batch: the assert must name the route/shard.
        let _ = solve_batch(&mut Labeled, &[0.0; 7], 0.1, 3, 1);
    }

    #[test]
    fn solve_into_warm_scratch_bit_identical_to_fresh() {
        // The zero-allocation path must not change values: a reused
        // stepper/output pair reproduces a fresh solve exactly.
        let mut stepper = Rk4::new(0);
        let mut out = Trajectory::new(0);
        let mut f = FnField::new(2, |_t, x: &[f64], o: &mut [f64]| {
            o[0] = x[1];
            o[1] = -x[0];
        });
        // Warm with a *larger* problem first, then solve the real one.
        solve_into(&mut f, &[3.0, -1.0], 0.02, 50, 2, &mut stepper, &mut out);
        solve_into(&mut f, &[1.0, 0.0], 0.05, 21, 1, &mut stepper, &mut out);
        let fresh = solve(&mut f, &[1.0, 0.0], 0.05, 21, 1);
        assert_eq!(out, fresh);
    }

    #[test]
    fn matches_lorenz96_generator() {
        // The workload generator embeds its own RK4; the generic solver
        // must agree with it on the same grid.
        use crate::ode::func::Lorenz96Field;
        use crate::workload::lorenz96 as l96;
        let mut f = Lorenz96Field { dim: 6, forcing: l96::FORCING };
        let a = solve(&mut f, &l96::Y0, l96::DT, 100, 4);
        let b = l96::simulate(&l96::Y0, 100, l96::DT, l96::FORCING, 4);
        for (ra, rb) in a.iter().zip(&b) {
            for (&x, &y) in ra.iter().zip(rb) {
                assert!((x - y).abs() < 1e-9, "{x} vs {y}");
            }
        }
    }
}
