//! Batched vector fields: B independent trajectories advanced in lockstep.
//!
//! The batched execution engine flattens B states of dimension d into one
//! row-major `[b * d]` vector; a [`BatchVectorField`] evaluates all B
//! derivatives in one call (one GEMM through the models instead of B
//! gemv's). Because every fixed-step solver update is element-wise, a
//! fixed-step integration of the flat state is **bit-identical**, per
//! trajectory, to B independent serial integrations of the same field —
//! the equivalence tests in `rust/tests/batched.rs` pin this down.
//!
//! Two adapters close the loop with the serial world:
//!
//! * [`Lifted`] auto-lifts any [`VectorField`] to a `B = 1` batch field, so
//!   serial fields plug into batched call sites unchanged;
//! * [`Flattened`] views a batch field as one big serial [`VectorField`] of
//!   dimension `b * d`, so the existing `euler` / `rk4` / `dopri5` solver
//!   loops run batched without duplication (their `solve_batch` wrappers
//!   are built on it).

use crate::ode::func::VectorField;
use crate::util::tensor::Trajectory;

/// A batch of B independent vector fields dx_b/dt = f(t, x_b), evaluated
/// together over a flat row-major `[batch * dim]` state.
///
/// `eval_batch_into` is `&mut self` for the same reason as
/// [`VectorField::eval_into`]: implementations carry scratch buffers and
/// RNG state (noisy analogue reads).
pub trait BatchVectorField {
    /// Per-trajectory state dimension d.
    fn dim(&self) -> usize;

    /// Number of trajectories B.
    fn batch(&self) -> usize;

    /// Diagnostic label for solver error messages (see
    /// [`VectorField::label`]); the batched twins report their route key
    /// here so batched dim asserts name the offending route/shard.
    fn label(&self) -> &str {
        "batched field"
    }

    /// Evaluate all B derivatives: `xs` and `out` are flat `[batch * dim]`.
    fn eval_batch_into(&mut self, t: f64, xs: &[f64], out: &mut [f64]);
}

/// Auto-lift of a serial [`VectorField`] to a batch of one.
pub struct Lifted<F: VectorField> {
    pub inner: F,
}

impl<F: VectorField> Lifted<F> {
    pub fn new(inner: F) -> Self {
        Self { inner }
    }
}

impl<F: VectorField> BatchVectorField for Lifted<F> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn batch(&self) -> usize {
        1
    }

    fn label(&self) -> &str {
        self.inner.label()
    }

    fn eval_batch_into(&mut self, t: f64, xs: &[f64], out: &mut [f64]) {
        self.inner.eval_into(t, xs, out)
    }
}

/// View a batch field as one serial field of dimension `batch * dim`.
///
/// This is what lets the fixed-step solvers integrate batched state with
/// their existing loops: the flat state *is* a valid serial state, and the
/// element-wise stage combinations act on each trajectory independently.
pub struct Flattened<'a> {
    pub field: &'a mut dyn BatchVectorField,
}

impl VectorField for Flattened<'_> {
    fn dim(&self) -> usize {
        self.field.dim() * self.field.batch()
    }

    fn label(&self) -> &str {
        self.field.label()
    }

    fn eval_into(&mut self, t: f64, x: &[f64], out: &mut [f64]) {
        self.field.eval_batch_into(t, x, out)
    }
}

/// Copy trajectory `b` out of a flat batched solve (rows of width
/// `batch * dim`) into `out` (reset to row width `dim`). Allocation-free
/// with a warm `out` — the twins use this with pooled trajectories to
/// fan one batched rollout back out to per-request responses.
pub fn unbatch_into(
    flat: &Trajectory,
    batch: usize,
    dim: usize,
    b: usize,
    out: &mut Trajectory,
) {
    assert_eq!(
        flat.dim(),
        batch * dim,
        "unbatch: flat row width {} != batch {batch} * dim {dim}",
        flat.dim()
    );
    assert!(b < batch, "unbatch: trajectory {b} >= batch {batch}");
    out.reset(dim);
    out.reserve_rows(flat.len());
    for row in flat {
        out.push_row(&row[b * dim..(b + 1) * dim]);
    }
}

/// Reassemble a flat batched solve (rows of width `batch * dim`) into
/// per-trajectory [`Trajectory`]s (the twin-facing layout).
pub fn unbatch_trajectories(
    flat: &Trajectory,
    batch: usize,
    dim: usize,
) -> Vec<Trajectory> {
    (0..batch)
        .map(|b| {
            let mut t = Trajectory::new(dim);
            unbatch_into(flat, batch, dim, b, &mut t);
            t
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::func::FnField;
    use crate::ode::rk4;

    #[test]
    fn lifted_field_is_batch_of_one() {
        let mut f = Lifted::new(FnField::new(
            2,
            |_t, x: &[f64], o: &mut [f64]| {
                o[0] = x[1];
                o[1] = -x[0];
            },
        ));
        assert_eq!(f.batch(), 1);
        assert_eq!(f.dim(), 2);
        let mut out = [0.0; 2];
        f.eval_batch_into(0.0, &[1.0, 2.0], &mut out);
        assert_eq!(out, [2.0, -1.0]);
    }

    #[test]
    fn flattened_batch_integrates_each_trajectory_independently() {
        // Two decoupled decay trajectories in one flat state: the batched
        // RK4 solution must equal two serial solutions bit-for-bit.
        struct Decay {
            batch: usize,
        }
        impl BatchVectorField for Decay {
            fn dim(&self) -> usize {
                1
            }
            fn batch(&self) -> usize {
                self.batch
            }
            fn eval_batch_into(
                &mut self,
                _t: f64,
                xs: &[f64],
                out: &mut [f64],
            ) {
                for (o, &x) in out.iter_mut().zip(xs) {
                    *o = -x;
                }
            }
        }
        let mut bf = Decay { batch: 2 };
        let flat = rk4::solve(
            &mut Flattened { field: &mut bf },
            &[1.0, -0.5],
            0.1,
            11,
            1,
        );
        for (b, &x0) in [1.0, -0.5].iter().enumerate() {
            let mut f =
                FnField::new(1, |_t, x: &[f64], o: &mut [f64]| o[0] = -x[0]);
            let serial = rk4::solve(&mut f, &[x0], 0.1, 11, 1);
            for (row, srow) in flat.iter().zip(&serial) {
                assert_eq!(row[b], srow[0], "traj {b}");
            }
        }
    }

    #[test]
    fn unbatch_roundtrip() {
        let flat = Trajectory::from_nested(&[
            vec![1.0, 2.0, 3.0, 4.0],
            vec![5.0, 6.0, 7.0, 8.0],
        ]);
        let per = unbatch_trajectories(&flat, 2, 2);
        assert_eq!(per.len(), 2);
        assert_eq!(
            per[0],
            Trajectory::from_nested(&[vec![1.0, 2.0], vec![5.0, 6.0]])
        );
        assert_eq!(
            per[1],
            Trajectory::from_nested(&[vec![3.0, 4.0], vec![7.0, 8.0]])
        );
    }

    #[test]
    fn unbatch_into_reuses_warm_output() {
        let flat = Trajectory::from_nested(&[
            vec![1.0, 2.0, 3.0, 4.0],
            vec![5.0, 6.0, 7.0, 8.0],
        ]);
        let mut out = Trajectory::new(0);
        unbatch_into(&flat, 2, 2, 1, &mut out);
        assert_eq!(out.dim(), 2);
        assert_eq!(out.row(0), [3.0, 4.0]);
        assert_eq!(out.row(1), [7.0, 8.0]);
        // Reuse for a different trajectory: no stale rows.
        unbatch_into(&flat, 2, 2, 0, &mut out);
        assert_eq!(out.len(), 2);
        assert_eq!(out.row(1), [5.0, 6.0]);
    }
}
