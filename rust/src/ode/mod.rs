//! Digital ODE solvers (the "neural ODE on digital hardware" baseline and
//! the verification reference for the analogue loop).
//!
//! * [`func`]   — the [`func::VectorField`] trait all solvers integrate
//! * [`batch`]  — [`batch::BatchVectorField`]: B trajectories in one flat
//!   `[b * d]` state (serial fields auto-lift at B = 1); every solver has a
//!   `solve_batch` built on it
//! * [`euler`]  — forward Euler (the recurrent-ResNet-equivalent update)
//! * [`rk4`]    — classic fourth-order Runge-Kutta (the paper's ODESolve)
//! * [`dopri5`] — adaptive Dormand-Prince 5(4) with PI step control (the
//!   black-box solver of Chen et al. 2018; extension feature)

pub mod batch;
pub mod dopri5;
pub mod euler;
pub mod func;
pub mod rk4;

pub use batch::BatchVectorField;
pub use func::VectorField;
