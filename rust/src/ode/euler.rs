//! Forward Euler — the discrete update a recurrent ResNet parameterises
//! (h_{t+1} = h_t + f(h_t)); included both as a baseline solver and to
//! quantify the truncation-error gap the paper attributes to discrete-time
//! digital twins.

use crate::ode::batch::{BatchVectorField, Flattened};
use crate::ode::func::VectorField;
use crate::util::tensor::Trajectory;

/// Reusable forward-Euler stepper (derivative scratch only).
pub struct Euler {
    k: Vec<f64>,
}

impl Euler {
    pub fn new(dim: usize) -> Self {
        Self { k: vec![0.0; dim] }
    }

    /// Dimension the scratch is currently sized for.
    pub fn dim(&self) -> usize {
        self.k.len()
    }

    /// Retarget the scratch to `dim`; the buffer is kept, so a warm
    /// stepper never reallocates for dimensions it has already seen.
    pub fn ensure_dim(&mut self, dim: usize) {
        if self.k.len() != dim {
            self.k.resize(dim, 0.0);
        }
    }

    /// One in-place Euler step x <- x + dt * phi(t, x).
    pub fn step(
        &mut self,
        f: &mut dyn VectorField,
        t: f64,
        x: &mut [f64],
        dt: f64,
    ) {
        let n = x.len();
        assert_eq!(
            n,
            self.k.len(),
            "Euler::step: state dim {} does not match stepper scratch dim {}",
            n,
            self.k.len()
        );
        f.eval_into(t, x, &mut self.k);
        for i in 0..n {
            x[i] += dt * self.k[i];
        }
    }
}

/// Allocation-free fixed-step forward Euler: `n_points` samples spaced
/// `dt` (first sample = x0) appended to `out` (which is reset to row width
/// `f.dim()`), with `substeps` Euler steps per sample. State lives in the
/// trajectory itself (each new sample starts as a copy of the previous
/// row and is advanced in place), so a warm `stepper` + `out` pair incurs
/// zero heap allocations.
pub fn solve_into(
    f: &mut dyn VectorField,
    x0: &[f64],
    dt: f64,
    n_points: usize,
    substeps: usize,
    stepper: &mut Euler,
    out: &mut Trajectory,
) {
    assert!(substeps >= 1);
    let n = f.dim();
    assert_eq!(
        x0.len(),
        n,
        "euler::solve: x0 dim {} does not match field dim {}",
        x0.len(),
        n
    );
    stepper.ensure_dim(n);
    let hd = dt / substeps as f64;
    out.reset(n);
    out.reserve_rows(n_points.max(1));
    out.push_row(x0);
    let mut t = 0.0;
    for p in 1..n_points {
        out.push_copy_of_last();
        let x = out.row_mut(p);
        for _ in 0..substeps {
            stepper.step(f, t, x, hd);
            t += hd;
        }
    }
}

/// Allocating convenience wrapper around [`solve_into`].
pub fn solve(
    f: &mut dyn VectorField,
    x0: &[f64],
    dt: f64,
    n_points: usize,
    substeps: usize,
) -> Trajectory {
    let mut stepper = Euler::new(f.dim());
    let mut out = Trajectory::new(f.dim());
    solve_into(f, x0, dt, n_points, substeps, &mut stepper, &mut out);
    out
}

/// Batched fixed-step forward Euler over a flat `[batch * dim]` state;
/// `out` receives `n_points` rows of width `batch * dim`. The Euler update
/// is element-wise, so each trajectory of the result is bit-identical to a
/// serial [`solve`] of the same field.
pub fn solve_batch_into(
    f: &mut dyn BatchVectorField,
    x0s: &[f64],
    dt: f64,
    n_points: usize,
    substeps: usize,
    stepper: &mut Euler,
    out: &mut Trajectory,
) {
    assert_eq!(
        x0s.len(),
        f.batch() * f.dim(),
        "euler::solve_batch: x0s length {} != batch {} * dim {}",
        x0s.len(),
        f.batch(),
        f.dim()
    );
    solve_into(
        &mut Flattened { field: f },
        x0s,
        dt,
        n_points,
        substeps,
        stepper,
        out,
    );
}

/// Allocating convenience wrapper around [`solve_batch_into`].
pub fn solve_batch(
    f: &mut dyn BatchVectorField,
    x0s: &[f64],
    dt: f64,
    n_points: usize,
    substeps: usize,
) -> Trajectory {
    let dim = f.batch() * f.dim();
    let mut stepper = Euler::new(dim);
    let mut out = Trajectory::new(dim);
    solve_batch_into(f, x0s, dt, n_points, substeps, &mut stepper, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::func::FnField;

    #[test]
    fn exponential_decay_first_order_accuracy() {
        let mut f = FnField::new(1, |_t, x: &[f64], o: &mut [f64]| o[0] = -x[0]);
        let coarse = solve(&mut f, &[1.0], 0.1, 11, 1);
        let fine = solve(&mut f, &[1.0], 0.1, 11, 100);
        let exact = (-1.0f64).exp();
        let e_coarse = (coarse[10][0] - exact).abs();
        let e_fine = (fine[10][0] - exact).abs();
        // Halving step size ~halves error; 100x substeps ~100x better.
        assert!(e_fine < e_coarse / 50.0, "{e_coarse} vs {e_fine}");
    }

    #[test]
    fn time_is_threaded_to_field() {
        // dx/dt = t  ->  x(1) = 0.5 (from 0).
        let mut f = FnField::new(1, |t, _x: &[f64], o: &mut [f64]| o[0] = t);
        let traj = solve(&mut f, &[0.0], 1.0, 2, 1000);
        assert!((traj[1][0] - 0.5).abs() < 1e-3);
    }

    #[test]
    fn output_shape() {
        let mut f = FnField::new(3, |_t, _x: &[f64], o: &mut [f64]| o.fill(0.0));
        let traj = solve(&mut f, &[1.0, 2.0, 3.0], 0.1, 5, 2);
        assert_eq!(traj.len(), 5);
        assert_eq!(traj.dim(), 3);
        assert_eq!(traj[0], [1.0, 2.0, 3.0]);
        assert_eq!(traj[4], [1.0, 2.0, 3.0]);
    }

    #[test]
    fn solve_into_reuses_scratch_across_dims() {
        // A warm stepper/output pair must be reusable across calls and
        // state dimensions without stale rows leaking through.
        let mut stepper = Euler::new(0);
        let mut out = Trajectory::new(0);
        let mut f2 = FnField::new(2, |_t, x: &[f64], o: &mut [f64]| {
            o[0] = -x[0];
            o[1] = -x[1];
        });
        solve_into(&mut f2, &[1.0, 2.0], 0.1, 4, 1, &mut stepper, &mut out);
        assert_eq!(out.len(), 4);
        assert_eq!(out.dim(), 2);
        let mut f1 =
            FnField::new(1, |_t, x: &[f64], o: &mut [f64]| o[0] = -x[0]);
        solve_into(&mut f1, &[1.0], 0.1, 6, 1, &mut stepper, &mut out);
        assert_eq!(out.len(), 6);
        assert_eq!(out.dim(), 1);
        assert_eq!(out[0], [1.0]);
        let direct = solve(&mut f1, &[1.0], 0.1, 6, 1);
        assert_eq!(out, direct, "reused scratch must not change values");
    }
}
