//! Forward Euler — the discrete update a recurrent ResNet parameterises
//! (h_{t+1} = h_t + f(h_t)); included both as a baseline solver and to
//! quantify the truncation-error gap the paper attributes to discrete-time
//! digital twins.

use crate::ode::batch::{BatchVectorField, Flattened};
use crate::ode::func::VectorField;

/// Integrate with fixed-step forward Euler; returns `n_points` samples
/// spaced `dt` (first sample = x0), with `substeps` Euler steps per sample.
pub fn solve(
    f: &mut dyn VectorField,
    x0: &[f64],
    dt: f64,
    n_points: usize,
    substeps: usize,
) -> Vec<Vec<f64>> {
    assert!(substeps >= 1);
    let n = f.dim();
    assert_eq!(
        x0.len(),
        n,
        "euler::solve: x0 dim {} does not match field dim {}",
        x0.len(),
        n
    );
    let hd = dt / substeps as f64;
    let mut x = x0.to_vec();
    let mut k = vec![0.0; n];
    let mut out = Vec::with_capacity(n_points);
    out.push(x.clone());
    let mut t = 0.0;
    for _ in 1..n_points {
        for _ in 0..substeps {
            f.eval_into(t, &x, &mut k);
            for i in 0..n {
                x[i] += hd * k[i];
            }
            t += hd;
        }
        out.push(x.clone());
    }
    out
}

/// Batched forward Euler over a flat `[batch * dim]` state; returns
/// `n_points` flat samples. The Euler update is element-wise, so each
/// trajectory of the result is bit-identical to a serial [`solve`] of the
/// same field.
pub fn solve_batch(
    f: &mut dyn BatchVectorField,
    x0s: &[f64],
    dt: f64,
    n_points: usize,
    substeps: usize,
) -> Vec<Vec<f64>> {
    assert_eq!(
        x0s.len(),
        f.batch() * f.dim(),
        "euler::solve_batch: x0s length {} != batch {} * dim {}",
        x0s.len(),
        f.batch(),
        f.dim()
    );
    solve(&mut Flattened { field: f }, x0s, dt, n_points, substeps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::func::FnField;

    #[test]
    fn exponential_decay_first_order_accuracy() {
        let mut f = FnField::new(1, |_t, x: &[f64], o: &mut [f64]| o[0] = -x[0]);
        let coarse = solve(&mut f, &[1.0], 0.1, 11, 1);
        let fine = solve(&mut f, &[1.0], 0.1, 11, 100);
        let exact = (-1.0f64).exp();
        let e_coarse = (coarse[10][0] - exact).abs();
        let e_fine = (fine[10][0] - exact).abs();
        // Halving step size ~halves error; 100x substeps ~100x better.
        assert!(e_fine < e_coarse / 50.0, "{e_coarse} vs {e_fine}");
    }

    #[test]
    fn time_is_threaded_to_field() {
        // dx/dt = t  ->  x(1) = 0.5 (from 0).
        let mut f = FnField::new(1, |t, _x: &[f64], o: &mut [f64]| o[0] = t);
        let traj = solve(&mut f, &[0.0], 1.0, 2, 1000);
        assert!((traj[1][0] - 0.5).abs() < 1e-3);
    }

    #[test]
    fn output_shape() {
        let mut f = FnField::new(3, |_t, _x: &[f64], o: &mut [f64]| o.fill(0.0));
        let traj = solve(&mut f, &[1.0, 2.0, 3.0], 0.1, 5, 2);
        assert_eq!(traj.len(), 5);
        assert_eq!(traj[0], vec![1.0, 2.0, 3.0]);
        assert_eq!(traj[4], vec![1.0, 2.0, 3.0]);
    }
}
