//! Dormand-Prince 5(4) adaptive solver with PI step-size control.
//!
//! The black-box ODESolve of Chen et al. (2018), which the paper cites for
//! neural-ODE training; included as an extension feature so downstream
//! users can trade fixed-grid RK4 for error-controlled integration, and as
//! an independent accuracy oracle in the test suite.

use crate::ode::batch::{BatchVectorField, Flattened};
use crate::ode::func::VectorField;
use crate::util::tensor::Trajectory;

/// Butcher tableau of DOPRI5 (c, a, b5, b4).
const C: [f64; 7] = [0.0, 1.0 / 5.0, 3.0 / 10.0, 4.0 / 5.0, 8.0 / 9.0, 1.0, 1.0];
const A: [[f64; 6]; 7] = [
    [0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
    [1.0 / 5.0, 0.0, 0.0, 0.0, 0.0, 0.0],
    [3.0 / 40.0, 9.0 / 40.0, 0.0, 0.0, 0.0, 0.0],
    [44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0, 0.0, 0.0, 0.0],
    [
        19372.0 / 6561.0,
        -25360.0 / 2187.0,
        64448.0 / 6561.0,
        -212.0 / 729.0,
        0.0,
        0.0,
    ],
    [
        9017.0 / 3168.0,
        -355.0 / 33.0,
        46732.0 / 5247.0,
        49.0 / 176.0,
        -5103.0 / 18656.0,
        0.0,
    ],
    [
        35.0 / 384.0,
        0.0,
        500.0 / 1113.0,
        125.0 / 192.0,
        -2187.0 / 6784.0,
        11.0 / 84.0,
    ],
];
const B5: [f64; 7] = [
    35.0 / 384.0,
    0.0,
    500.0 / 1113.0,
    125.0 / 192.0,
    -2187.0 / 6784.0,
    11.0 / 84.0,
    0.0,
];
const B4: [f64; 7] = [
    5179.0 / 57600.0,
    0.0,
    7571.0 / 16695.0,
    393.0 / 640.0,
    -92097.0 / 339200.0,
    187.0 / 2100.0,
    1.0 / 40.0,
];

/// Adaptive integration options.
#[derive(Debug, Clone)]
pub struct Options {
    pub rtol: f64,
    pub atol: f64,
    pub h_init: f64,
    pub h_min: f64,
    pub h_max: f64,
    pub max_steps: usize,
}

impl Default for Options {
    fn default() -> Self {
        Self {
            rtol: 1e-6,
            atol: 1e-9,
            h_init: 1e-3,
            h_min: 1e-10,
            h_max: 1.0,
            max_steps: 1_000_000,
        }
    }
}

/// Integration statistics.
#[derive(Debug, Clone, Default)]
pub struct SolveStats {
    pub accepted: usize,
    pub rejected: usize,
    pub f_evals: usize,
}

/// Integrate from t0 to t1, sampling at the provided output times (must be
/// increasing, within [t0, t1]); dense output by cubic Hermite between
/// accepted steps. Returns (samples, stats); samples are a flat
/// [`Trajectory`] with one row per output time.
///
/// Unlike the fixed-step solvers, the adaptive path allocates its stage
/// scratch per call — it is the accuracy-oracle extension, not the
/// steady-state request path, so it stays out of the zero-allocation
/// contract documented in `lib.rs`.
pub fn solve(
    f: &mut dyn VectorField,
    x0: &[f64],
    t0: f64,
    t1: f64,
    t_out: &[f64],
    opts: &Options,
) -> (Trajectory, SolveStats) {
    let n = f.dim();
    assert_eq!(
        x0.len(),
        n,
        "dopri5::solve [{}]: x0 dim {} does not match field dim {} (the \
         stage scratch is sized from the field)",
        f.label(),
        x0.len(),
        n
    );
    assert!(t1 > t0, "dopri5::solve: t1 ({t1}) must exceed t0 ({t0})");
    for w in t_out.windows(2) {
        assert!(w[1] >= w[0], "t_out must be non-decreasing");
    }
    let mut stats = SolveStats::default();
    let mut t = t0;
    let mut x = x0.to_vec();
    let mut h = opts.h_init.clamp(opts.h_min, opts.h_max);
    let mut k: Vec<Vec<f64>> = (0..7).map(|_| vec![0.0; n]).collect();
    let mut x5 = vec![0.0; n];
    let mut x4 = vec![0.0; n];
    let mut tmp = vec![0.0; n];
    let mut row_buf = vec![0.0; n];
    let mut out = Trajectory::with_capacity(n, t_out.len());
    let mut out_idx = 0;
    // Emit any samples at exactly t0.
    while out_idx < t_out.len() && t_out[out_idx] <= t0 {
        out.push_row(&x);
        out_idx += 1;
    }
    // FSAL: k[0] = f(t, x).
    f.eval_into(t, &x, &mut k[0]);
    stats.f_evals += 1;
    let mut err_prev: f64 = 1.0;

    for _step in 0..opts.max_steps {
        if out_idx >= t_out.len() || t >= t1 {
            break;
        }
        let h_eff = h.min(t1 - t);
        // Stages.
        for s in 1..7 {
            for i in 0..n {
                let mut acc = 0.0;
                for (j, kj) in k.iter().enumerate().take(s) {
                    acc += A[s][j] * kj[i];
                }
                tmp[i] = x[i] + h_eff * acc;
            }
            f.eval_into(t + C[s] * h_eff, &tmp, &mut k[s]);
            stats.f_evals += 1;
        }
        // 5th and 4th order solutions.
        for i in 0..n {
            let mut a5 = 0.0;
            let mut a4 = 0.0;
            for (j, kj) in k.iter().enumerate() {
                a5 += B5[j] * kj[i];
                a4 += B4[j] * kj[i];
            }
            x5[i] = x[i] + h_eff * a5;
            x4[i] = x[i] + h_eff * a4;
        }
        // Error norm.
        let mut err = 0.0;
        for i in 0..n {
            let sc = opts.atol + opts.rtol * x[i].abs().max(x5[i].abs());
            let e = (x5[i] - x4[i]) / sc;
            err += e * e;
        }
        err = (err / n as f64).sqrt().max(1e-16);

        if err <= 1.0 {
            // Accept; dense output for samples inside (t, t + h_eff].
            let t_new = t + h_eff;
            while out_idx < t_out.len() && t_out[out_idx] <= t_new + 1e-14 {
                let ts = t_out[out_idx].clamp(t, t_new);
                let theta = if h_eff > 0.0 { (ts - t) / h_eff } else { 1.0 };
                // Cubic Hermite with endpoint derivatives k[0] / k[6].
                let h00 = (1.0 + 2.0 * theta)
                    * (1.0 - theta)
                    * (1.0 - theta);
                let h10 = theta * (1.0 - theta) * (1.0 - theta);
                let h01 = theta * theta * (3.0 - 2.0 * theta);
                let h11 = theta * theta * (theta - 1.0);
                for (i, rv) in row_buf.iter_mut().enumerate() {
                    *rv = h00 * x[i]
                        + h10 * h_eff * k[0][i]
                        + h01 * x5[i]
                        + h11 * h_eff * k[6][i];
                }
                out.push_row(&row_buf);
                out_idx += 1;
            }
            t = t_new;
            std::mem::swap(&mut x, &mut x5);
            // FSAL: last stage is f at the new point.
            k.swap(0, 6);
            stats.accepted += 1;
            // PI controller.
            let fac = 0.9 * err.powf(-0.7 / 5.0) * err_prev.powf(0.4 / 5.0);
            h = (h_eff * fac.clamp(0.2, 5.0)).clamp(opts.h_min, opts.h_max);
            err_prev = err;
        } else {
            stats.rejected += 1;
            h = (h_eff * (0.9 * err.powf(-0.2)).clamp(0.1, 1.0))
                .max(opts.h_min);
        }
    }
    // Any trailing samples (t_out beyond t1): hold the final state.
    while out_idx < t_out.len() {
        out.push_row(&x);
        out_idx += 1;
    }
    (out, stats)
}

/// Batched adaptive integration over a flat `[batch * dim]` state.
///
/// Unlike the fixed-step `solve_batch` wrappers, the step-size controller
/// here is **joint**: the error norm spans every trajectory, so the whole
/// batch advances on one accepted-step sequence (the stiffest trajectory
/// sets the pace). That makes the result *accuracy-equivalent* but not
/// bit-identical to per-trajectory serial solves — use `rk4::solve_batch`
/// where exact batched-vs-serial reproduction is required.
pub fn solve_batch(
    f: &mut dyn BatchVectorField,
    x0s: &[f64],
    t0: f64,
    t1: f64,
    t_out: &[f64],
    opts: &Options,
) -> (Trajectory, SolveStats) {
    assert_eq!(
        x0s.len(),
        f.batch() * f.dim(),
        "dopri5::solve_batch [{}]: x0s length {} != batch {} * dim {}",
        f.label(),
        x0s.len(),
        f.batch(),
        f.dim()
    );
    solve(&mut Flattened { field: f }, x0s, t0, t1, t_out, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::func::FnField;

    #[test]
    fn decay_high_accuracy() {
        let mut f =
            FnField::new(1, |_t, x: &[f64], o: &mut [f64]| o[0] = -x[0]);
        let t_out: Vec<f64> = (0..=10).map(|k| k as f64 * 0.1).collect();
        let (ys, stats) =
            solve(&mut f, &[1.0], 0.0, 1.0, &t_out, &Options::default());
        assert_eq!(ys.len(), 11);
        for (k, row) in ys.iter().enumerate() {
            let want = (-(k as f64) * 0.1).exp();
            assert!(
                (row[0] - want).abs() < 1e-5,
                "t={k}: {} vs {want}",
                row[0]
            );
        }
        assert!(stats.accepted > 0);
    }

    #[test]
    fn adaptivity_rejects_on_stiff_transient() {
        // A fast transient forces step rejections with a large h_init.
        let mut f = FnField::new(1, |_t, x: &[f64], o: &mut [f64]| {
            o[0] = -50.0 * x[0]
        });
        let opts = Options { h_init: 0.5, ..Default::default() };
        let (_, stats) = solve(&mut f, &[1.0], 0.0, 1.0, &[1.0], &opts);
        assert!(stats.rejected > 0, "no rejections: {stats:?}");
    }

    #[test]
    fn agrees_with_rk4_on_lorenz96_short_horizon() {
        use crate::ode::func::Lorenz96Field;
        use crate::workload::lorenz96 as l96;
        let t_out: Vec<f64> = (0..50).map(|k| k as f64 * l96::DT).collect();
        let mut f1 = Lorenz96Field { dim: 6, forcing: l96::FORCING };
        let (a, _) = solve(
            &mut f1,
            &l96::Y0,
            0.0,
            1.0,
            &t_out,
            &Options { rtol: 1e-9, atol: 1e-12, ..Default::default() },
        );
        let b = l96::simulate(&l96::Y0, 50, l96::DT, l96::FORCING, 8);
        for (ra, rb) in a.iter().zip(&b) {
            for (&x, &y) in ra.iter().zip(rb) {
                assert!((x - y).abs() < 1e-5, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn fewer_evals_than_fixed_rk4_for_same_accuracy_on_smooth_problem() {
        // Smooth slow problem: adaptivity should take big steps.
        let mut f =
            FnField::new(1, |t, _x: &[f64], o: &mut [f64]| o[0] = t.sin());
        let opts = Options { rtol: 1e-6, h_max: 10.0, ..Default::default() };
        let (ys, stats) = solve(&mut f, &[0.0], 0.0, 10.0, &[10.0], &opts);
        // x(10) = 1 - cos(10)
        let want = 1.0 - (10.0f64).cos();
        assert!((ys[0][0] - want).abs() < 1e-4);
        assert!(stats.f_evals < 700, "too many evals {}", stats.f_evals);
    }

    #[test]
    #[should_panic(expected = "does not match field dim")]
    fn x0_dim_mismatch_has_clear_message() {
        let mut f =
            FnField::new(2, |_t, _x: &[f64], o: &mut [f64]| o.fill(0.0));
        let _ = solve(&mut f, &[1.0], 0.0, 1.0, &[1.0], &Options::default());
    }

    #[test]
    fn batched_decay_is_accuracy_equivalent_to_serial() {
        use crate::ode::batch::BatchVectorField;
        struct Decay {
            batch: usize,
        }
        impl BatchVectorField for Decay {
            fn dim(&self) -> usize {
                1
            }
            fn batch(&self) -> usize {
                self.batch
            }
            fn eval_batch_into(
                &mut self,
                _t: f64,
                xs: &[f64],
                out: &mut [f64],
            ) {
                for (o, &x) in out.iter_mut().zip(xs) {
                    *o = -x;
                }
            }
        }
        let t_out: Vec<f64> = (0..=10).map(|k| k as f64 * 0.1).collect();
        let (ys, stats) = solve_batch(
            &mut Decay { batch: 3 },
            &[1.0, 2.0, -0.5],
            0.0,
            1.0,
            &t_out,
            &Options::default(),
        );
        assert!(stats.accepted > 0);
        for (k, row) in ys.iter().enumerate() {
            let e = (-(k as f64) * 0.1).exp();
            for (b, &x0) in [1.0, 2.0, -0.5].iter().enumerate() {
                assert!(
                    (row[b] - x0 * e).abs() < 1e-5,
                    "t={k} traj {b}: {} vs {}",
                    row[b],
                    x0 * e
                );
            }
        }
    }

    #[test]
    fn t0_samples_emitted() {
        let mut f =
            FnField::new(1, |_t, _x: &[f64], o: &mut [f64]| o[0] = 1.0);
        let (ys, _) = solve(
            &mut f,
            &[5.0],
            0.0,
            1.0,
            &[0.0, 0.5, 1.0],
            &Options::default(),
        );
        assert_eq!(ys.len(), 3);
        assert_eq!(ys[0][0], 5.0);
        assert!((ys[1][0] - 5.5).abs() < 1e-6);
        assert!((ys[2][0] - 6.0).abs() < 1e-6);
    }
}
