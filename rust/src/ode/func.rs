//! The vector-field abstraction shared by every digital solver.

/// A (possibly time-dependent, possibly stateful) vector field
/// dx/dt = f(t, x).
///
/// `eval_into` is `&mut self` because implementations may carry scratch
/// buffers or RNG state (e.g. noisy analogue evaluations wrapped as a
/// digital field for cross-validation).
pub trait VectorField {
    /// State dimension.
    fn dim(&self) -> usize;

    /// Diagnostic label carried into solver error messages (route, shard,
    /// or model identity). Twins set this to their route key so a
    /// dimension mismatch deep in a batched solve names the offender
    /// instead of reporting raw lengths only.
    fn label(&self) -> &str {
        "vector field"
    }

    /// Evaluate f(t, x) into `out` (len == dim()).
    fn eval_into(&mut self, t: f64, x: &[f64], out: &mut [f64]);

    /// Allocating convenience.
    fn eval(&mut self, t: f64, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.eval_into(t, x, &mut out);
        out
    }
}

/// A vector field defined by a closure (tests, toy systems).
pub struct FnField<F: FnMut(f64, &[f64], &mut [f64])> {
    pub dim: usize,
    pub f: F,
}

impl<F: FnMut(f64, &[f64], &mut [f64])> FnField<F> {
    pub fn new(dim: usize, f: F) -> Self {
        Self { dim, f }
    }
}

impl<F: FnMut(f64, &[f64], &mut [f64])> VectorField for FnField<F> {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval_into(&mut self, t: f64, x: &[f64], out: &mut [f64]) {
        (self.f)(t, x, out)
    }
}

/// The Lorenz96 ground-truth field as a [`VectorField`].
pub struct Lorenz96Field {
    pub dim: usize,
    pub forcing: f64,
}

impl VectorField for Lorenz96Field {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval_into(&mut self, _t: f64, x: &[f64], out: &mut [f64]) {
        crate::workload::lorenz96::field_into(x, self.forcing, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fn_field_evaluates_closure() {
        let mut f = FnField::new(2, |_t, x: &[f64], out: &mut [f64]| {
            out[0] = x[1];
            out[1] = -x[0];
        });
        assert_eq!(f.eval(0.0, &[1.0, 2.0]), vec![2.0, -1.0]);
    }

    #[test]
    fn lorenz_field_wrapper_matches_module() {
        let mut f = Lorenz96Field { dim: 6, forcing: 8.0 };
        let x = [1.0, -0.5, 0.25, 2.0, -1.0, 0.1];
        let got = f.eval(0.0, &x);
        let want = crate::workload::lorenz96::field(&x, 8.0);
        assert_eq!(got, want);
    }
}
