//! `memode` — the leader binary.
//!
//! Subcommands:
//!
//! * `characterize` — regenerate the Fig. 2 device experiments (states,
//!   retention, letters/yield, programming-error histogram)
//! * `run-twin`     — one twin inference on a chosen route, printing the
//!   trajectory head and basic accuracy vs ground truth
//! * `serve`        — start the coordinator; `--listen` binds the TCP
//!   front door (`docs/SERVING.md`), otherwise an in-process synthetic
//!   load prints latency/throughput telemetry
//! * `loadgen`      — drive a running server over TCP and report
//!   p50/p99/p99.9 latency + rejected fraction (`BENCH_serve.json`)
//! * `lifetime`     — scripted device-lifetime scenario: aging drift,
//!   health probes, recalibration, forced faults, graceful degradation
//! * `scenario`     — `scenario check <files...>` parse-lints `*.twin`
//!   scenario files, printing byte-span diagnostics (`docs/SCENARIOS.md`)
//! * `routes`       — list available twin routes
//! * `config`       — print the effective configuration as JSON
//!
//! `memode <cmd> --help` lists per-command flags.

use anyhow::Result;

use memode::analog::system::AnalogNoise;
use memode::config::SystemConfig;
use memode::coordinator::net::{NetConfig, NetServer};
use memode::coordinator::service::Coordinator;
use memode::device::taox::DeviceConfig;
use memode::device::{programming, retention, taox, yield_model};
use memode::runtime::service::PjrtService;
use memode::twin::setup::{build_registry, TrainedWeights};
use memode::twin::{EnsembleSpec, TwinRequest};
use memode::util::cli::Args;
use memode::util::rng::Pcg64;
use memode::util::stats;
use memode::workload::{lorenz96, stimuli::Waveform};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.is_empty() || argv[0].starts_with("--") {
        "help".to_string()
    } else {
        argv.remove(0)
    };
    match cmd.as_str() {
        "characterize" => characterize(argv),
        "run-twin" => run_twin(argv),
        "serve" => serve(argv),
        "loadgen" => {
            memode::coordinator::loadgen::cli("memode loadgen", argv)
        }
        "lifetime" => lifetime(argv),
        "scenario" => scenario_cmd(argv),
        "routes" => routes(argv),
        "config" => config_cmd(argv),
        "help" | "-h" | "--help" => {
            println!(
                "memode {} — continuous-time digital twins on an analogue \
                 memristive neural-ODE solver\n\n\
                 Usage: memode <command> [flags]\n\n\
                 Commands:\n\
                 \x20 characterize   Fig. 2 device experiments\n\
                 \x20 run-twin       one twin inference\n\
                 \x20 serve          coordinator (--listen = TCP front door)\n\
                 \x20 loadgen        drive a running server over TCP\n\
                 \x20 lifetime       device aging / recalibration scenario\n\
                 \x20 scenario       check *.twin scenario files\n\
                 \x20 routes         list twin routes\n\
                 \x20 config         print effective config JSON\n",
                memode::VERSION
            );
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (try --help)"),
    }
}

fn load_config(args: &Args) -> Result<SystemConfig> {
    let path = args.get("config");
    if path.is_empty() {
        Ok(SystemConfig::default())
    } else {
        SystemConfig::from_file(std::path::Path::new(&path))
    }
}

// ---------------------------------------------------------------------------
// characterize — Fig. 2 experiments
// ---------------------------------------------------------------------------

fn characterize(argv: Vec<String>) -> Result<()> {
    let args = Args::new("memode characterize", "Fig. 2 device experiments")
        .opt("config", "", "config JSON path")
        .opt("what", "all", "states | retention | letters | prog-error | all")
        .opt("seed", "42", "random seed")
        .parse(argv)
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    let cfg = load_config(&args)?;
    let what = args.get("what");
    let seed = args.get_u64("seed");
    let dev = cfg.device.clone();

    if what == "states" || what == "all" {
        println!("== Fig. 2h: multi-level programming ({} states) ==", dev.levels);
        let mut rng = Pcg64::seeded(seed);
        let mut cell = taox::Memristor::new(&dev);
        let mut errs = Vec::new();
        for k in (0..dev.levels).step_by(7) {
            let g = dev.level_conductance(k);
            let r = programming::program_cell(&mut cell, &dev, g, &mut rng);
            errs.push(r.rel_error);
            println!(
                "  level {k:>2}: target {:>7.2} µS -> {:>7.2} µS ({} iters)",
                g * 1e6,
                cell.g * 1e6,
                r.iters
            );
        }
        println!(
            "  mean relative error {:.3} %",
            stats::summary(&errs).mean * 100.0
        );
    }

    if what == "retention" || what == "all" {
        println!("\n== Fig. 2i: retention (1e5 s) ==");
        let mut rng = Pcg64::seeded(seed + 1);
        for target in [20e-6, 50e-6, 80e-6] {
            let mut cell = taox::Memristor::new(&dev);
            programming::program_cell(&mut cell, &dev, target, &mut rng);
            let trace =
                retention::retention_trace(&mut cell, &dev, 1e5, 1e4, &mut rng);
            let first = trace.first().unwrap().1;
            let last = trace.last().unwrap().1;
            println!(
                "  {:>5.1} µS: after 1e5 s -> {:>5.1} µS (drift {:+.2} %)",
                first * 1e6,
                last * 1e6,
                (last / first - 1.0) * 100.0
            );
        }
    }

    if what == "letters" || what == "all" {
        println!("\n== Fig. 2j: letter programming + yield ==");
        let (exps, pooled) = yield_model::run_letters_experiment(&dev, seed);
        for e in &exps {
            println!(
                "  '{}': yield {:.1} %, mean err {:.2} %, var {:.2} (%^2)",
                e.letter,
                e.stats.yield_frac * 100.0,
                e.stats.mean_rel_error * 100.0,
                e.stats.var_rel_error_pct
            );
            println!("{}", yield_model::render_map(&e.g_map, &dev));
        }
        println!(
            "  pooled yield {:.1} % (paper: 97.3 %)",
            pooled * 100.0
        );
    }

    if what == "prog-error" || what == "all" {
        println!("\n== Fig. 2k: programming-error distribution ==");
        let mut rng = Pcg64::seeded(seed + 2);
        let mut signed_pct = Vec::new();
        for _ in 0..3072 {
            let mut cell = taox::Memristor::sample(&dev, &mut rng);
            let g = rng.uniform_in(20e-6, 100e-6);
            let r = programming::program_cell(&mut cell, &dev, g, &mut rng);
            if r.converged {
                let signed = (cell.g - cell.g_target) / cell.g_target * 100.0;
                signed_pct.push(signed);
            }
        }
        let mut hist = stats::Histogram::new(-8.0, 8.0, 17);
        hist.add_all(&signed_pct);
        print!("{}", hist.ascii(40));
        let s = stats::summary(&signed_pct);
        println!(
            "  variance {:.2} (%^2) over {} responsive devices (paper: 4.36)",
            s.var, s.n
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// run-twin
// ---------------------------------------------------------------------------

fn run_twin(argv: Vec<String>) -> Result<()> {
    let args = Args::new("memode run-twin", "one twin inference")
        .opt("config", "", "config JSON path")
        .opt("route", "lorenz96/analog", "twin route (see `memode routes`)")
        .opt("steps", "200", "output samples")
        .opt("stimulus", "sine", "hp twins: sine|triangular|rectangular|modulated")
        .opt("seed", "", "noise-lane seed (replay a response's seed bit-exactly)")
        .opt(
            "ensemble",
            "0",
            "Monte-Carlo ensemble members (one batched rollout; 0 = plain)",
        )
        .opt(
            "scenario",
            "",
            "run a *.twin scenario file (route/steps/seed/stimulus/ensemble \
             come from the file; overrides those flags)",
        )
        .flag(
            "synthetic",
            "use the synthetic fixture registry (no artifacts needed)",
        )
        .flag("pjrt", "start the PJRT runtime (needed for */pjrt routes)")
        .parse(argv)
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    let cfg = load_config(&args)?;
    let synthetic = args.get_bool("synthetic");
    let service = if args.get_bool("pjrt") {
        anyhow::ensure!(
            !synthetic,
            "--pjrt needs trained artifacts (drop --synthetic)"
        );
        Some(PjrtService::start(&cfg.artifacts_dir)?)
    } else {
        None
    };
    let reg = if synthetic {
        memode::twin::setup::build_synthetic_registry(None)
    } else {
        let weights = TrainedWeights::load(&cfg)?;
        build_registry(
            &cfg,
            &weights,
            service.as_ref().map(|s| s.handle()),
        )?
    };
    // --scenario: the declarative file pins the whole request.
    let scenario_path = args.get("scenario");
    let scenario = if scenario_path.is_empty() {
        None
    } else {
        let src = std::fs::read_to_string(&scenario_path)
            .map_err(|e| anyhow::anyhow!("reading {scenario_path}: {e}"))?;
        let sc = memode::twin::scenario::Scenario::parse(&src)
            .map_err(|e| {
                anyhow::anyhow!("{}", e.render(&src, &scenario_path))
            })?;
        Some(sc)
    };
    let (route, steps, req, ensemble) = match &scenario {
        Some(sc) => {
            let members = sc.ensemble.unwrap_or(0);
            (sc.twin.clone(), sc.steps, sc.to_request(), members)
        }
        None => {
            let route = args.get("route");
            let steps = args.get_usize("steps");
            let mut req = if route.starts_with("hp/") {
                let wave = match args.get("stimulus").as_str() {
                    "sine" => Waveform::sine(1.0, 4.0),
                    "triangular" => Waveform::triangular(1.0, 4.0),
                    "rectangular" => Waveform::rectangular(1.0, 4.0),
                    "modulated" => Waveform::modulated(1.0, 4.0, 1.0),
                    other => anyhow::bail!("unknown stimulus '{other}'"),
                };
                TwinRequest::driven(vec![], steps, wave)
            } else {
                TwinRequest::autonomous(vec![], steps)
            };
            let seed_arg = args.get("seed");
            if !seed_arg.is_empty() {
                let seed = seed_arg
                    .parse::<u64>()
                    .map_err(|e| anyhow::anyhow!("--seed {seed_arg}: {e}"))?;
                req = req.with_seed(seed);
            }
            let ensemble = args.get_usize("ensemble");
            if ensemble > 0 {
                req = req.with_ensemble(
                    EnsembleSpec::new(ensemble)
                        .with_percentiles(vec![5.0, 95.0]),
                );
            }
            (route, steps, req, ensemble)
        }
    };
    let mut twin = reg.create(&route)?;
    let t0 = std::time::Instant::now();
    let resp = twin.run(&req)?;
    let dt_wall = t0.elapsed();
    println!(
        "route {route} backend {} -> {} samples in {:?}{}",
        resp.backend,
        resp.trajectory.len(),
        dt_wall,
        if resp.degraded {
            " [DEGRADED: digital fallback]"
        } else {
            ""
        }
    );
    // The replay command must pin everything the rollout depended on:
    // seed, the stimulus for driven twins, the ensemble width, and the
    // runtime flags that register the route (config is assumed equal).
    match &scenario {
        Some(sc) => {
            let synth_flag = if synthetic { " --synthetic" } else { "" };
            let seed_note = if sc.seed.is_none() {
                format!(" after adding `seed {}` to the file", resp.seed)
            } else {
                String::new()
            };
            println!(
                "noise seed {} (replay: memode run-twin --scenario \
                 {scenario_path}{synth_flag}{seed_note})",
                resp.seed
            );
        }
        None => {
            let mut replay_flags = String::new();
            if route.starts_with("hp/") {
                replay_flags.push_str(" --stimulus ");
                replay_flags.push_str(&args.get("stimulus"));
            }
            if ensemble > 0 {
                replay_flags.push_str(&format!(" --ensemble {ensemble}"));
            }
            if synthetic {
                replay_flags.push_str(" --synthetic");
            }
            if args.get_bool("pjrt") {
                replay_flags.push_str(" --pjrt");
            }
            println!(
                "noise seed {} (replay: memode run-twin --route {route} \
                 --steps {steps}{replay_flags} --seed {})",
                resp.seed, resp.seed
            );
        }
    }
    if let Some(ens) = &resp.ensemble {
        println!(
            "ensemble: {} members, one batched rollout; trajectory below \
             is the per-timestep mean ({} percentile envelope(s), {} NaN \
             samples skipped)",
            ens.members,
            ens.percentiles.len(),
            ens.nan_samples
        );
        if let (Some(m), Some(s)) = (ens.mean.last(), ens.std.last()) {
            println!(
                "  final sample mean±std: {:?}",
                m.iter()
                    .zip(s)
                    .map(|(a, b)| format!("{a:.3}±{b:.3}"))
                    .collect::<Vec<_>>()
            );
        }
    }
    for (k, row) in resp.trajectory.iter().take(5).enumerate() {
        println!(
            "  t={:?}s: {:?}",
            k as f64 * twin.dt(),
            row.iter().map(|v| (v * 1000.0).round() / 1000.0).collect::<Vec<_>>()
        );
    }
    // Ground-truth comparison for the Lorenz96 twin (normalized space).
    if route.starts_with("lorenz96/") {
        let truth = lorenz96::simulate_normalized(resp.trajectory.len());
        let l1 = memode::metrics::l1::mean_l1_multi(
            &resp.trajectory.to_nested(),
            &truth,
        );
        println!("  mean L1 vs ground truth over horizon: {l1:.4}");
    }
    // Scenario acceptance: every `expect` assertion must hold.
    if let Some(sc) = &scenario {
        let failures = sc.check(&resp);
        if failures.is_empty() {
            println!(
                "scenario: all {} expectation(s) hold",
                sc.expectations.len()
            );
        } else {
            for f in &failures {
                eprintln!("scenario FAIL: {f}");
            }
            anyhow::bail!(
                "{} scenario expectation(s) failed",
                failures.len()
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// scenario — *.twin file tooling
// ---------------------------------------------------------------------------

fn scenario_cmd(argv: Vec<String>) -> Result<()> {
    let args = Args::new(
        "memode scenario",
        "scenario tooling: `memode scenario check <files...>` parse-lints \
         *.twin files, printing byte-span diagnostics on failure",
    )
    .parse(argv)
    .map_err(|m| anyhow::anyhow!("{m}"))?;
    let pos = args.positionals();
    let Some((action, files)) = pos.split_first() else {
        anyhow::bail!("usage: memode scenario check <file.twin>...");
    };
    anyhow::ensure!(
        action.as_str() == "check",
        "unknown scenario action '{action}' (try 'check')"
    );
    anyhow::ensure!(
        !files.is_empty(),
        "no scenario files given (usage: memode scenario check \
         <file.twin>...)"
    );
    let mut failed = 0usize;
    for path in files {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        match memode::twin::scenario::Scenario::parse(&src) {
            Ok(sc) => println!(
                "{path}: ok (twin {}, {} steps, {} expectation(s))",
                sc.twin,
                sc.steps,
                sc.expectations.len()
            ),
            Err(e) => {
                eprintln!("{}", e.render(&src, path));
                failed += 1;
            }
        }
    }
    anyhow::ensure!(
        failed == 0,
        "{failed} scenario file(s) failed to parse"
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------------

fn serve(argv: Vec<String>) -> Result<()> {
    let args = Args::new(
        "memode serve",
        "coordinator + TCP front door or in-process synthetic load",
    )
    .opt("config", "", "config JSON path")
    .opt(
        "listen",
        "",
        "bind the TCP front door at host:port (also $MEMODE_LISTEN; \
         port 0 picks a free one); empty = in-process load only",
    )
    .opt(
        "duration",
        "0",
        "with --listen: seconds to serve before draining (0 = forever)",
    )
    .opt(
        "stats-every",
        "5",
        "with --listen: telemetry print period (s; 0 = quiet)",
    )
    .flag(
        "synthetic",
        "serve synthetic fixture weights (no artifacts needed)",
    )
    .opt("requests", "64", "synthetic requests to issue")
    .opt("steps", "100", "samples per request")
    .opt("route", "lorenz96/digital", "route to load-test")
    .opt(
        "ensemble",
        "0",
        "ensemble members per synthetic request (0 = plain)",
    )
    .flag("pjrt", "start the PJRT runtime")
    .parse(argv)
    .map_err(|m| anyhow::anyhow!("{m}"))?;
    let mut cfg = load_config(&args)?;
    cfg.serve.apply_env();
    let synthetic = args.get_bool("synthetic");
    let service = if args.get_bool("pjrt") {
        anyhow::ensure!(
            !synthetic,
            "--pjrt needs trained artifacts (drop --synthetic)"
        );
        Some(PjrtService::start(&cfg.artifacts_dir)?)
    } else {
        None
    };
    // Shared serving telemetry: sharded-route shard workers, the health
    // monitor and the network front door all report into the same
    // counters the coordinator snapshots.
    let telemetry = std::sync::Arc::new(
        memode::coordinator::telemetry::Telemetry::new(),
    );
    let reg = if synthetic {
        memode::twin::setup::build_synthetic_registry(Some(
            std::sync::Arc::clone(&telemetry),
        ))
    } else {
        let weights = TrainedWeights::load(&cfg)?;
        memode::twin::setup::build_registry_with_telemetry(
            &cfg,
            &weights,
            service.as_ref().map(|s| s.handle()),
            Some(std::sync::Arc::clone(&telemetry)),
        )?
    };
    print_route_table(&reg);
    let coord = std::sync::Arc::new(Coordinator::start_with_telemetry(
        reg, &cfg.serve, telemetry,
    ));

    // --listen (or $MEMODE_LISTEN): real TCP serving instead of the
    // in-process synthetic load.
    let listen = {
        let l = args.get("listen");
        if l.is_empty() {
            std::env::var("MEMODE_LISTEN").unwrap_or_default()
        } else {
            l
        }
    };
    if !listen.is_empty() {
        let mut ncfg = NetConfig { addr: listen, ..NetConfig::default() };
        ncfg.apply_env();
        let handle =
            NetServer::start(std::sync::Arc::clone(&coord), ncfg.clone())?;
        println!(
            "listening on {} ({} workers, max batch {}, {} connection \
             cap){}",
            handle.addr(),
            cfg.serve.workers,
            cfg.serve.max_batch,
            ncfg.max_conns,
            if synthetic { " [synthetic routes]" } else { "" }
        );
        let duration = args.get_f64("duration");
        let every = args.get_f64("stats-every");
        let started = std::time::Instant::now();
        loop {
            let tick = if every > 0.0 { every } else { 1.0 };
            let sleep = if duration > 0.0 {
                let left = duration - started.elapsed().as_secs_f64();
                if left <= 0.0 {
                    break;
                }
                tick.min(left)
            } else {
                tick
            };
            std::thread::sleep(std::time::Duration::from_secs_f64(sleep));
            if every > 0.0 {
                println!("telemetry: {}", coord.stats());
            }
        }
        let net = handle.shutdown();
        println!(
            "drained: {} connections ({} refused), {} frames in / {} \
             out, {} protocol errors",
            net.connections,
            net.conns_rejected,
            net.frames_in,
            net.frames_out,
            net.protocol_errors
        );
        report_stats(&coord.stats());
        return Ok(());
    }

    let route = args.get("route");
    let n = args.get_usize("requests");
    let steps = args.get_usize("steps");
    let ensemble = args.get_usize("ensemble");
    println!(
        "serving {n} requests on {route} ({} workers, max batch {} — \
         counted in lanes{})",
        cfg.serve.workers,
        cfg.serve.max_batch,
        if ensemble > 0 {
            format!("; {ensemble}-member ensembles")
        } else {
            String::new()
        }
    );
    let t0 = std::time::Instant::now();
    let pending: Vec<_> = (0..n)
        .filter_map(|_| {
            let mut req = TwinRequest::autonomous(vec![], steps);
            if ensemble > 0 {
                req = req.with_ensemble(
                    EnsembleSpec::new(ensemble)
                        .with_percentiles(vec![5.0, 95.0]),
                );
            }
            coord.submit(&route, req).ok()
        })
        .collect();
    let accepted = pending.len();
    let mut ok = 0;
    for p in pending {
        if p.wait()?.result.is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "accepted {accepted}/{n}, completed {ok} in {wall:.3}s \
         ({:.1} req/s)",
        ok as f64 / wall
    );
    let stats = coord.stats();
    report_stats(&stats);
    // Replay handles: every served rollout's noise seed is recorded, so
    // any noisy trajectory can be reproduced bit-exactly offline
    // (recent_seeds is chronological; the tail is the newest). Ensemble
    // jobs replay with the same family seed and --ensemble width.
    let pjrt_flag =
        if route.ends_with("/pjrt") { " --pjrt" } else { "" };
    let ens_flag = if ensemble > 0 {
        format!(" --ensemble {ensemble}")
    } else {
        String::new()
    };
    for &(job, seed) in stats.recent_seeds.iter().rev().take(3) {
        println!(
            "replay job {job}: memode run-twin --route {route} --steps \
             {steps}{ens_flag}{pjrt_flag} --seed {seed}"
        );
    }
    Ok(())
}

/// Startup route table: one line per registered route with its
/// [`memode::twin::registry::RouteInfo`] metadata where known.
fn print_route_table(reg: &memode::twin::registry::TwinRegistry) {
    println!("routes ({}):", reg.len());
    for key in reg.keys() {
        match reg.info(&key) {
            Some(i) => println!(
                "  {key:<26} dim {:>3}  dt {:>9.2e} s  backend {}{}{}",
                i.dim,
                i.dt,
                i.backend,
                if i.aged { " [aged]" } else { "" },
                if i.synthetic { " [synthetic]" } else { "" }
            ),
            None => println!("  {key}"),
        }
    }
}

/// Shared end-of-run observability for both serving modes: telemetry
/// line, admission gates, device-lifetime status, ensemble totals.
fn report_stats(stats: &memode::coordinator::telemetry::TelemetrySnapshot) {
    println!("telemetry: {stats}");
    // Admission-gate observability: per-route admitted/shed counts plus
    // the pooled rejected fraction (NaN-free only once traffic arrived).
    let shed = stats.rejected_fraction();
    if shed.is_finite() {
        println!("admission: rejected fraction {shed:.3}");
    }
    for (r, load) in &stats.route_load {
        println!(
            "  route {r}: admitted {} shed {} (shed fraction {:.3})",
            load.admitted,
            load.shed,
            load.shed_fraction()
        );
    }
    // Device-lifetime status of health-monitored routes.
    for (r, lt) in &stats.lifetime {
        println!(
            "lifetime {r}: age {:.3e}s health {:.3} probes {} (last MRE \
             {:.2e}) recals {} ({:.2e} J){}",
            lt.age_s,
            lt.array_health,
            lt.probes,
            lt.last_probe_mre,
            lt.recalibrations,
            lt.recal_energy_j,
            if lt.degraded { " DEGRADED" } else { "" }
        );
    }
    if stats.ensemble_rollouts > 0 {
        println!(
            "ensembles: {} rollouts, {} members total (mean width {:.1})",
            stats.ensemble_rollouts,
            stats.ensemble_members,
            stats.ensemble_members as f64
                / stats.ensemble_rollouts as f64
        );
    }
}

// ---------------------------------------------------------------------------
// lifetime — scripted device-aging scenario
// ---------------------------------------------------------------------------

fn lifetime(argv: Vec<String>) -> Result<()> {
    use memode::twin::health::{LifetimeConfig, MonitoredTwin};
    use memode::twin::{FaultCampaign, Twin};

    let args = Args::new(
        "memode lifetime",
        "device-lifetime scenario: drift, recalibration, degradation",
    )
    .opt("seed", "11", "deployment seed (hardware sampling + noise lanes)")
    .opt("rollouts", "8", "served rollouts in the healthy stage")
    .opt("campaign", "6", "fault-campaign members (0 = skip the campaign)")
    .parse(argv)
    .map_err(|m| anyhow::anyhow!("{m}"))?;
    let seed = args.get_u64("seed");
    let rollouts = args.get_usize("rollouts");
    let campaign = args.get_usize("campaign");

    // Self-contained: the synthetic decaying MLP (f(h) = -h) stands in
    // for trained weights so the scenario runs without artifacts. Quiet
    // programming/read noise keeps the probe floor at the circuit-vs-RK4
    // integrator mismatch, far below the recalibration threshold, so
    // every stage transition below is driven by aging alone.
    let weights = memode::models::loader::decay_mlp_weights(3);
    let device = DeviceConfig {
        fault_rate: 0.0,
        pulse_sigma: 0.0,
        read_noise: 0.0,
        ..Default::default()
    };
    let lcfg = LifetimeConfig {
        age_per_rollout_s: 1.0,
        probe_every: 4,
        probe_points: 50,
        mre_threshold: 0.005,
        max_retries: 2,
        max_recal_failures: 1,
        backoff_s: 60.0,
        ..Default::default()
    };
    println!(
        "monitored route: probe every {} rollouts, recalibrate above \
         MRE {}, degrade after {} failed episode(s)",
        lcfg.probe_every, lcfg.mre_threshold, lcfg.max_recal_failures
    );
    let mut twin = MonitoredTwin::lorenz96(
        &weights, &device, AnalogNoise::off(), seed, 100, lcfg,
    );
    fn status(twin: &MonitoredTwin) {
        let s = twin.lifetime();
        println!(
            "  age {:>10.3e} s | health {:.3} | probes {} (last MRE \
             {:.2e}) | recals {} ({} pulses, {:.2e} J) | failures {} | \
             degraded {}",
            s.age_s,
            s.array_health,
            s.probes,
            s.last_probe_mre,
            s.recalibrations,
            s.recal_pulses,
            s.recal_energy_j,
            s.recal_failures,
            s.degraded
        );
    }

    println!("\n== stage 1: healthy service ({rollouts} rollouts) ==");
    let req = TwinRequest::autonomous(vec![], 40).with_seed(seed);
    for _ in 0..rollouts {
        let resp = twin.run(&req)?;
        anyhow::ensure!(!resp.degraded, "healthy stage degraded early");
    }
    status(&twin);

    println!("\n== stage 2: accelerated aging (+1e10 s virtual) ==");
    twin.advance_age(1e10);
    let drifted = twin.probe_now()?;
    let s = twin.lifetime();
    println!(
        "  probe crossed the threshold, recalibration ran: final MRE \
         {drifted:.2e} after {} recalibration(s), {:.2e} J of write pulses",
        s.recalibrations, s.recal_energy_j
    );
    status(&twin);

    println!("\n== stage 3: forced fault storm (60% stuck cells) ==");
    twin.inject_stuck_faults(0.6);
    let _ = twin.probe_now()?;
    status(&twin);
    anyhow::ensure!(
        twin.is_degraded(),
        "stuck-heavy array unexpectedly recovered"
    );
    let resp = twin.run(&req)?;
    println!(
        "  degraded service: backend {} (degraded flag {}), {} samples",
        resp.backend,
        resp.degraded,
        resp.trajectory.len()
    );

    if campaign > 0 {
        println!(
            "\n== stage 4: fault-injection campaign ({campaign} sampled \
             devices, 1e7 s horizon, 5% extra stuck) =="
        );
        // A fresh monitor: campaigns model a device *population*, not the
        // degraded unit above.
        let mut fleet = MonitoredTwin::lorenz96(
            &weights,
            &device,
            AnalogNoise::off(),
            seed,
            100,
            LifetimeConfig::default(),
        );
        let creq = TwinRequest::autonomous(vec![], 40)
            .with_seed(seed)
            .with_ensemble(
                EnsembleSpec::new(campaign).with_fault_campaign(
                    FaultCampaign::new(seed ^ 0x77)
                        .aged(1e7)
                        .with_fault_fraction(0.05),
                ),
            );
        let cresp = fleet.run(&creq)?;
        let s = fleet.lifetime();
        println!(
            "  backend {}: {} members pooled, {} above the degradation \
             threshold (replay: same --seed and yield seed)",
            cresp.backend, s.campaign_members, s.campaign_degraded
        );
    }
    Ok(())
}

fn routes(argv: Vec<String>) -> Result<()> {
    let args = Args::new("memode routes", "list twin routes")
        .opt("config", "", "config JSON path")
        .flag("pjrt", "include PJRT routes")
        .parse(argv)
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    let cfg = load_config(&args)?;
    let weights = TrainedWeights::load(&cfg)?;
    let service = if args.get_bool("pjrt") {
        Some(PjrtService::start(&cfg.artifacts_dir)?)
    } else {
        None
    };
    let reg = build_registry(
        &cfg,
        &weights,
        service.as_ref().map(|s| s.handle()),
    )?;
    for r in reg.keys() {
        println!("{r}");
    }
    Ok(())
}

fn config_cmd(argv: Vec<String>) -> Result<()> {
    let args = Args::new("memode config", "print effective config")
        .opt("config", "", "config JSON path")
        .parse(argv)
        .map_err(|m| anyhow::anyhow!("{m}"))?;
    let cfg = load_config(&args)?;
    println!("{}", cfg.to_json().to_string());
    Ok(())
}

// Quiet the unused-import warning for types only used in some branches.
#[allow(unused)]
fn _type_anchors(_: DeviceConfig, _: AnalogNoise) {}
