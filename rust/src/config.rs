//! Typed configuration for the whole system, with JSON round-trip.
//!
//! One [`SystemConfig`] drives the CLI, the examples and the coordinator:
//! device statistics, noise operating point, solver resolutions, artifact
//! location and serving parameters. `memode --config path.json` loads it;
//! every field has a paper-calibrated default so an empty config works.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::analog::system::AnalogNoise;
use crate::device::taox::DeviceConfig;
use crate::util::json::{self, Json};

/// Serving-layer parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Worker threads (each owns private twin instances).
    pub workers: usize,
    /// Maximum batch the batcher will coalesce.
    pub max_batch: usize,
    /// Batching window (s): wait this long to fill a batch. Routes with
    /// no observed execution time use this fixed window; it is also the
    /// effective window for every route while the adaptive clamp below
    /// is left at its (equal) defaults.
    pub batch_window_s: f64,
    /// Lower clamp (s) of the adaptive per-route batch window. The
    /// batcher sizes each route's window from its observed execution
    /// EWMA, clamped to `[batch_window_min_s, batch_window_max_s]`.
    /// Defaults equal `batch_window_s`, which disables adaptation.
    pub batch_window_min_s: f64,
    /// Upper clamp (s) of the adaptive per-route batch window.
    pub batch_window_max_s: f64,
    /// Work stealing between scheduler workers: an idle worker takes a
    /// whole queued batch from the most-loaded peer, so light requests
    /// are never stranded behind a wide ensemble campaign. Off by
    /// default (today's strict least-loaded dispatch).
    pub steal: bool,
    /// Multi-trajectory shard co-scheduling: the tile-sharded backend
    /// fuses the sub-batches of one dispatch into a single barrier
    /// group, so shard workers hide exchange-barrier latency behind
    /// other trajectories' work. Off by default.
    pub coschedule: bool,
    /// Global in-flight cap at the admission gate (backpressure
    /// threshold).
    pub queue_depth: usize,
    /// Per-route in-flight cap at the admission gate: one hot route can
    /// claim at most this many of the `queue_depth` slots, so it cannot
    /// starve every other route out of the global budget.
    pub route_queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 32,
            batch_window_s: 2e-3,
            batch_window_min_s: 2e-3,
            batch_window_max_s: 2e-3,
            steal: false,
            coschedule: false,
            queue_depth: 128,
            route_queue_depth: 64,
        }
    }
}

impl ServeConfig {
    /// Apply `MEMODE_*` environment overrides on top of the configured
    /// values — the operator knobs `memode serve` documents in
    /// `docs/SERVING.md`: `MEMODE_WORKERS`, `MEMODE_QUEUE_DEPTH`,
    /// `MEMODE_ROUTE_QUEUE_DEPTH`, the adaptive-window clamp
    /// `MEMODE_BATCH_WINDOW_MIN` / `MEMODE_BATCH_WINDOW_MAX` (seconds),
    /// and the scheduler toggles `MEMODE_STEAL` / `MEMODE_COSCHEDULE`
    /// (`1`/`true`/`on` enable, `0`/`false`/`off` disable). Unset or
    /// unparsable variables keep the current value.
    pub fn apply_env(&mut self) {
        let read = |name: &str| -> Option<usize> {
            std::env::var(name).ok()?.trim().parse().ok()
        };
        let read_f64 = |name: &str| -> Option<f64> {
            std::env::var(name).ok()?.trim().parse().ok()
        };
        if let Some(v) = read("MEMODE_WORKERS") {
            self.workers = v;
        }
        if let Some(v) = read("MEMODE_QUEUE_DEPTH") {
            self.queue_depth = v;
        }
        if let Some(v) = read("MEMODE_ROUTE_QUEUE_DEPTH") {
            self.route_queue_depth = v;
        }
        if let Some(v) = read_f64("MEMODE_BATCH_WINDOW_MIN") {
            self.batch_window_min_s = v;
        }
        if let Some(v) = read_f64("MEMODE_BATCH_WINDOW_MAX") {
            self.batch_window_max_s = v;
        }
        if let Some(v) = env_bool("MEMODE_STEAL") {
            self.steal = v;
        }
        if let Some(v) = env_bool("MEMODE_COSCHEDULE") {
            self.coschedule = v;
        }
    }
}

/// Parse a boolean `MEMODE_*` toggle: `1`/`true`/`on`/`yes` enable,
/// `0`/`false`/`off`/`no` disable (case-insensitive); anything else —
/// including unset — is `None` (keep the configured value).
pub fn env_bool(name: &str) -> Option<bool> {
    match std::env::var(name).ok()?.trim().to_ascii_lowercase().as_str() {
        "1" | "true" | "on" | "yes" => Some(true),
        "0" | "false" | "off" | "no" => Some(false),
        _ => None,
    }
}

/// Top-level configuration.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Directory containing `*.hlo.txt`, `manifest.json`, `weights/`.
    pub artifacts_dir: PathBuf,
    /// Device statistics for the simulated hardware.
    pub device: DeviceConfig,
    /// Noise operating point for analogue twins.
    pub noise: AnalogNoise,
    /// Circuit substeps per output sample (analogue solver resolution).
    pub analog_substeps: usize,
    /// Master seed for all stochastic components.
    pub seed: u64,
    /// Serving parameters.
    pub serve: ServeConfig,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: PathBuf::from(crate::DEFAULT_ARTIFACTS_DIR),
            device: DeviceConfig::default(),
            noise: AnalogNoise::hardware(),
            analog_substeps: 20,
            seed: 42,
            serve: ServeConfig::default(),
        }
    }
}

impl SystemConfig {
    /// Load from a JSON file; missing keys keep their defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let doc = json::from_file(path)?;
        Ok(Self::from_json(&doc))
    }

    /// Build from parsed JSON (missing keys -> defaults).
    pub fn from_json(doc: &Json) -> Self {
        let mut cfg = Self::default();
        let f = |j: Option<&Json>, d: f64| {
            j.and_then(Json::as_f64).unwrap_or(d)
        };
        let u = |j: Option<&Json>, d: usize| {
            j.and_then(Json::as_usize).unwrap_or(d)
        };
        if let Some(s) = doc.get("artifacts_dir").and_then(Json::as_str) {
            cfg.artifacts_dir = PathBuf::from(s);
        }
        if let Some(d) = doc.get("device") {
            cfg.device.g_min = f(d.get("g_min"), cfg.device.g_min);
            cfg.device.g_max = f(d.get("g_max"), cfg.device.g_max);
            cfg.device.levels =
                u(d.get("levels"), cfg.device.levels as usize) as u32;
            cfg.device.pulse_sigma =
                f(d.get("pulse_sigma"), cfg.device.pulse_sigma);
            cfg.device.verify_tol =
                f(d.get("verify_tol"), cfg.device.verify_tol);
            cfg.device.read_noise =
                f(d.get("read_noise"), cfg.device.read_noise);
            cfg.device.fault_rate =
                f(d.get("fault_rate"), cfg.device.fault_rate);
        }
        if let Some(n) = doc.get("noise") {
            cfg.noise.read = f(n.get("read"), cfg.noise.read);
            cfg.noise.prog = f(n.get("prog"), cfg.noise.prog);
        }
        cfg.analog_substeps =
            u(doc.get("analog_substeps"), cfg.analog_substeps);
        cfg.seed = f(doc.get("seed"), cfg.seed as f64) as u64;
        if let Some(s) = doc.get("serve") {
            cfg.serve.workers = u(s.get("workers"), cfg.serve.workers);
            cfg.serve.max_batch = u(s.get("max_batch"), cfg.serve.max_batch);
            cfg.serve.batch_window_s =
                f(s.get("batch_window_s"), cfg.serve.batch_window_s);
            // An old config that sets only batch_window_s keeps the
            // clamp pinned to it (adaptation stays off).
            cfg.serve.batch_window_min_s = f(
                s.get("batch_window_min_s"),
                cfg.serve.batch_window_s,
            );
            cfg.serve.batch_window_max_s = f(
                s.get("batch_window_max_s"),
                cfg.serve.batch_window_s,
            );
            cfg.serve.steal = s
                .get("steal")
                .and_then(Json::as_bool)
                .unwrap_or(cfg.serve.steal);
            cfg.serve.coschedule = s
                .get("coschedule")
                .and_then(Json::as_bool)
                .unwrap_or(cfg.serve.coschedule);
            cfg.serve.queue_depth =
                u(s.get("queue_depth"), cfg.serve.queue_depth);
            cfg.serve.route_queue_depth = u(
                s.get("route_queue_depth"),
                cfg.serve.route_queue_depth,
            );
        }
        cfg
    }

    /// Serialise to JSON (full round-trip of every field).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "artifacts_dir",
                Json::Str(self.artifacts_dir.display().to_string()),
            ),
            (
                "device",
                Json::obj(vec![
                    ("g_min", Json::Num(self.device.g_min)),
                    ("g_max", Json::Num(self.device.g_max)),
                    ("levels", Json::Num(self.device.levels as f64)),
                    ("pulse_sigma", Json::Num(self.device.pulse_sigma)),
                    ("verify_tol", Json::Num(self.device.verify_tol)),
                    ("read_noise", Json::Num(self.device.read_noise)),
                    ("fault_rate", Json::Num(self.device.fault_rate)),
                ]),
            ),
            (
                "noise",
                Json::obj(vec![
                    ("read", Json::Num(self.noise.read)),
                    ("prog", Json::Num(self.noise.prog)),
                ]),
            ),
            ("analog_substeps", Json::Num(self.analog_substeps as f64)),
            ("seed", Json::Num(self.seed as f64)),
            (
                "serve",
                Json::obj(vec![
                    ("workers", Json::Num(self.serve.workers as f64)),
                    ("max_batch", Json::Num(self.serve.max_batch as f64)),
                    (
                        "batch_window_s",
                        Json::Num(self.serve.batch_window_s),
                    ),
                    (
                        "batch_window_min_s",
                        Json::Num(self.serve.batch_window_min_s),
                    ),
                    (
                        "batch_window_max_s",
                        Json::Num(self.serve.batch_window_max_s),
                    ),
                    ("steal", Json::Bool(self.serve.steal)),
                    ("coschedule", Json::Bool(self.serve.coschedule)),
                    (
                        "queue_depth",
                        Json::Num(self.serve.queue_depth as f64),
                    ),
                    (
                        "route_queue_depth",
                        Json::Num(self.serve.route_queue_depth as f64),
                    ),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_calibrated() {
        let c = SystemConfig::default();
        assert_eq!(c.device.levels, 64);
        assert!((c.device.fault_rate - 0.027).abs() < 1e-12);
        assert_eq!(c.noise, AnalogNoise::hardware());
    }

    #[test]
    fn json_roundtrip_preserves_fields() {
        let mut c = SystemConfig::default();
        c.noise.read = 0.05;
        c.serve.workers = 7;
        c.seed = 99;
        let j = c.to_json();
        let c2 = SystemConfig::from_json(&j);
        assert_eq!(c2.noise.read, 0.05);
        assert_eq!(c2.serve.workers, 7);
        assert_eq!(c2.seed, 99);
        assert_eq!(c2.device.levels, c.device.levels);
    }

    #[test]
    fn route_queue_depth_roundtrips_and_defaults() {
        let mut c = SystemConfig::default();
        assert_eq!(c.serve.route_queue_depth, 64);
        c.serve.route_queue_depth = 9;
        let c2 = SystemConfig::from_json(&c.to_json());
        assert_eq!(c2.serve.route_queue_depth, 9);
        // Old configs without the key keep the default.
        let doc = crate::util::json::parse(
            r#"{"serve": {"queue_depth": 3}}"#,
        )
        .unwrap();
        let c3 = SystemConfig::from_json(&doc);
        assert_eq!(c3.serve.queue_depth, 3);
        assert_eq!(c3.serve.route_queue_depth, 64);
    }

    #[test]
    fn scheduler_knobs_roundtrip_and_default() {
        let mut c = SystemConfig::default();
        assert_eq!(c.serve.batch_window_min_s, 2e-3);
        assert_eq!(c.serve.batch_window_max_s, 2e-3);
        assert!(!c.serve.steal);
        assert!(!c.serve.coschedule);
        c.serve.batch_window_min_s = 0.5e-3;
        c.serve.batch_window_max_s = 12e-3;
        c.serve.steal = true;
        c.serve.coschedule = true;
        let c2 = SystemConfig::from_json(&c.to_json());
        assert_eq!(c2.serve.batch_window_min_s, 0.5e-3);
        assert_eq!(c2.serve.batch_window_max_s, 12e-3);
        assert!(c2.serve.steal);
        assert!(c2.serve.coschedule);
        // Old configs with only batch_window_s pin the clamp to it,
        // so adaptation stays off, and the toggles keep defaults.
        let doc = crate::util::json::parse(
            r#"{"serve": {"batch_window_s": 0.005}}"#,
        )
        .unwrap();
        let c3 = SystemConfig::from_json(&doc);
        assert_eq!(c3.serve.batch_window_s, 0.005);
        assert_eq!(c3.serve.batch_window_min_s, 0.005);
        assert_eq!(c3.serve.batch_window_max_s, 0.005);
        assert!(!c3.serve.steal);
        assert!(!c3.serve.coschedule);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let doc =
            crate::util::json::parse(r#"{"noise": {"read": 0.02}}"#).unwrap();
        let c = SystemConfig::from_json(&doc);
        assert_eq!(c.noise.read, 0.02);
        assert_eq!(c.noise.prog, AnalogNoise::hardware().prog);
        assert_eq!(c.serve.workers, ServeConfig::default().workers);
    }

    #[test]
    fn file_roundtrip() {
        let c = SystemConfig::default();
        let mut path = std::env::temp_dir();
        path.push(format!("memode_cfg_{}.json", std::process::id()));
        crate::util::json::to_file(&path, &c.to_json()).unwrap();
        let c2 = SystemConfig::from_file(&path).unwrap();
        assert_eq!(c2.seed, c.seed);
        std::fs::remove_file(path).ok();
    }
}
