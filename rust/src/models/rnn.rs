//! Vanilla RNN baseline and the shared recurrent-model interface.
//!
//! All three recurrent baselines predict the *next state* of the physical
//! system with a residual head: x_{t+1} = x_t + Wo h_{t+1} + bo, mirroring
//! `compile.train.rnn_rollout`. Evaluation is autoregressive from the
//! initial condition (the model sees only its own predictions), which is
//! how the paper's Fig. 4g interpolation/extrapolation errors are scored.

use crate::models::loader::RnnWeights;
#[cfg(test)]
use crate::util::tensor::Mat;

/// Common interface of the recurrent baselines.
pub trait Recurrent {
    /// Reset hidden state.
    fn reset(&mut self);

    /// One step: consume the current observed/predicted state `x`, return
    /// the next-state prediction.
    fn step(&mut self, x: &[f64]) -> Vec<f64>;

    /// State (input/output) dimension.
    fn d_in(&self) -> usize;

    /// Trainable parameter count (for the energy model).
    fn n_params(&self) -> usize;

    /// Autoregressive rollout: from `x0`, emit `n` successive predictions
    /// (result[0] == x0).
    fn rollout(&mut self, x0: &[f64], n: usize) -> Vec<Vec<f64>> {
        self.reset();
        let mut out = Vec::with_capacity(n);
        out.push(x0.to_vec());
        let mut x = x0.to_vec();
        for _ in 1..n {
            x = self.step(&x);
            out.push(x.clone());
        }
        out
    }

    /// Batched autoregressive rollout: B independent trajectories advanced
    /// in lockstep, returning `[batch][n][d_in]`. The default falls back to
    /// per-trajectory serial rollouts; the concrete cells override it with
    /// a true batched implementation (one gate GEMM per step shared across
    /// the batch) that is bit-identical to the serial path.
    fn rollout_batch(
        &mut self,
        x0s: &[Vec<f64>],
        n: usize,
    ) -> Vec<Vec<Vec<f64>>> {
        x0s.iter().map(|x0| self.rollout(x0, n)).collect()
    }
}

/// Gate-stack helper shared by the cells: z = x Wx + h Wh + b.
pub(crate) fn gates_into(
    w: &RnnWeights,
    x: &[f64],
    h: &[f64],
    z: &mut [f64],
) {
    w.wx.vecmat_into(x, z);
    // z += h Wh  (accumulate without a second buffer)
    for (r, &hv) in h.iter().enumerate() {
        if hv == 0.0 {
            continue;
        }
        let row = w.wh.row(r);
        for (zv, &a) in z.iter_mut().zip(row) {
            *zv += hv * a;
        }
    }
    for (zv, &b) in z.iter_mut().zip(&w.b) {
        *zv += b;
    }
}

/// Residual output head: pred = x + h Wo + bo.
pub(crate) fn head(w: &RnnWeights, x: &[f64], h: &[f64]) -> Vec<f64> {
    let mut y = w.wo.vecmat(h);
    for ((yv, &bv), &xv) in y.iter_mut().zip(&w.bo).zip(x) {
        *yv += bv + xv;
    }
    y
}

/// Batched gate stack: `zs[b] = xs[b] Wx + hs[b] Wh + b` for `batch`
/// stacked rows. Wx is applied as one GEMM; the Wh accumulation mirrors
/// [`gates_into`]'s loop (including the zero-hidden skip) per trajectory,
/// so each row is bit-identical to a serial [`gates_into`] call.
pub(crate) fn gates_batch_into(
    w: &RnnWeights,
    xs: &[f64],
    hs: &[f64],
    batch: usize,
    zs: &mut [f64],
) {
    let gates = w.wx.cols;
    let hidden = w.wh.rows;
    debug_assert_eq!(zs.len(), batch * gates);
    debug_assert_eq!(hs.len(), batch * hidden);
    w.wx.vecmat_batch_into(xs, batch, zs);
    for b in 0..batch {
        let h = &hs[b * hidden..(b + 1) * hidden];
        let z = &mut zs[b * gates..(b + 1) * gates];
        for (r, &hv) in h.iter().enumerate() {
            if hv == 0.0 {
                continue;
            }
            let row = w.wh.row(r);
            for (zv, &a) in z.iter_mut().zip(row) {
                *zv += hv * a;
            }
        }
        for (zv, &bias) in z.iter_mut().zip(&w.b) {
            *zv += bias;
        }
    }
}

/// Batched residual head: `ys[b] = xs[b] + hs[b] Wo + bo`, bit-identical
/// per trajectory to [`head`].
pub(crate) fn head_batch_into(
    w: &RnnWeights,
    xs: &[f64],
    hs: &[f64],
    batch: usize,
    ys: &mut [f64],
) {
    let d = w.wo.cols;
    debug_assert_eq!(ys.len(), batch * d);
    debug_assert_eq!(xs.len(), batch * d);
    w.wo.vecmat_batch_into(hs, batch, ys);
    for b in 0..batch {
        let y = &mut ys[b * d..(b + 1) * d];
        let x = &xs[b * d..(b + 1) * d];
        for ((yv, &bv), &xv) in y.iter_mut().zip(&w.bo).zip(x) {
            *yv += bv + xv;
        }
    }
}

/// Vanilla RNN: h' = tanh(x Wx + h Wh + b).
pub struct VanillaRnn {
    pub w: RnnWeights,
    h: Vec<f64>,
    z: Vec<f64>,
}

impl VanillaRnn {
    pub fn new(w: RnnWeights) -> Self {
        assert_eq!(w.wx.cols, w.hidden, "rnn expects 1 gate block");
        let h = vec![0.0; w.hidden];
        let z = vec![0.0; w.wx.cols];
        Self { w, h, z }
    }
}

impl Recurrent for VanillaRnn {
    fn reset(&mut self) {
        self.h.fill(0.0);
    }

    fn step(&mut self, x: &[f64]) -> Vec<f64> {
        gates_into(&self.w, x, &self.h, &mut self.z);
        for (hv, &zv) in self.h.iter_mut().zip(&self.z) {
            *hv = zv.tanh();
        }
        head(&self.w, x, &self.h)
    }

    fn rollout_batch(
        &mut self,
        x0s: &[Vec<f64>],
        n: usize,
    ) -> Vec<Vec<Vec<f64>>> {
        let batch = x0s.len();
        let d = self.w.d_in;
        for x0 in x0s {
            assert_eq!(x0.len(), d, "rollout_batch: x0 dim != d_in");
        }
        let gates = self.w.wx.cols;
        let hidden = self.w.hidden;
        // Local batch state: the serial per-instance hidden state is left
        // untouched (rnn gates == hidden, so the flat tanh update below is
        // the serial update applied per trajectory).
        let mut x: Vec<f64> = x0s.iter().flatten().copied().collect();
        let mut h = vec![0.0; batch * hidden];
        let mut z = vec![0.0; batch * gates];
        let mut y = vec![0.0; batch * d];
        let mut out: Vec<Vec<Vec<f64>>> = x0s
            .iter()
            .map(|x0| {
                let mut t = Vec::with_capacity(n);
                t.push(x0.clone());
                t
            })
            .collect();
        for _ in 1..n {
            gates_batch_into(&self.w, &x, &h, batch, &mut z);
            for (hv, &zv) in h.iter_mut().zip(&z) {
                *hv = zv.tanh();
            }
            head_batch_into(&self.w, &x, &h, batch, &mut y);
            x.copy_from_slice(&y);
            for (b, traj) in out.iter_mut().enumerate() {
                traj.push(x[b * d..(b + 1) * d].to_vec());
            }
        }
        out
    }

    fn d_in(&self) -> usize {
        self.w.d_in
    }

    fn n_params(&self) -> usize {
        let w = &self.w;
        w.wx.rows * w.wx.cols
            + w.wh.rows * w.wh.cols
            + w.b.len()
            + w.wo.rows * w.wo.cols
            + w.bo.len()
    }
}

/// Construct toy weights for tests (also used by gru/lstm test modules).
#[cfg(test)]
pub(crate) fn toy_weights(d_in: usize, hidden: usize, gates: usize) -> RnnWeights {
    RnnWeights {
        wx: Mat::from_fn(d_in, gates * hidden, |r, c| {
            0.1 * ((r + c) % 3) as f64 - 0.1
        }),
        wh: Mat::from_fn(hidden, gates * hidden, |r, c| {
            0.05 * ((r * 2 + c) % 5) as f64 - 0.1
        }),
        b: vec![0.01; gates * hidden],
        wo: Mat::from_fn(hidden, d_in, |r, c| 0.1 * ((r + c) % 2) as f64),
        bo: vec![0.0; d_in],
        hidden,
        d_in,
        dt: 0.02,
        kind: "test".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rollout_shape_and_start() {
        let mut m = VanillaRnn::new(toy_weights(3, 4, 1));
        let traj = m.rollout(&[1.0, 2.0, 3.0], 10);
        assert_eq!(traj.len(), 10);
        assert_eq!(traj[0], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn reset_makes_rollouts_deterministic() {
        let mut m = VanillaRnn::new(toy_weights(2, 3, 1));
        let a = m.rollout(&[0.5, -0.5], 20);
        let b = m.rollout(&[0.5, -0.5], 20);
        assert_eq!(a, b);
    }

    #[test]
    fn hidden_state_is_bounded_by_tanh() {
        let mut m = VanillaRnn::new(toy_weights(2, 3, 1));
        m.step(&[100.0, -100.0]);
        assert!(m.h.iter().all(|&v| v.abs() <= 1.0));
    }

    #[test]
    fn zero_weights_identity_rollout() {
        let mut w = toy_weights(2, 3, 1);
        w.wx = Mat::zeros(2, 3);
        w.wh = Mat::zeros(3, 3);
        w.b = vec![0.0; 3];
        w.wo = Mat::zeros(3, 2);
        let mut m = VanillaRnn::new(w);
        let traj = m.rollout(&[1.0, -2.0], 5);
        for row in &traj {
            assert_eq!(row, &vec![1.0, -2.0]);
        }
    }

    #[test]
    fn n_params_counts_all_blocks() {
        let m = VanillaRnn::new(toy_weights(2, 3, 1));
        assert_eq!(m.n_params(), 2 * 3 + 3 * 3 + 3 + 3 * 2 + 2);
    }

    #[test]
    fn rollout_batch_bit_identical_to_serial() {
        let mut m = VanillaRnn::new(toy_weights(3, 4, 1));
        let x0s = vec![
            vec![1.0, 2.0, 3.0],
            vec![-0.5, 0.25, 0.0],
            vec![0.1, -0.1, 0.7],
        ];
        let batched = m.rollout_batch(&x0s, 12);
        for (b, x0) in x0s.iter().enumerate() {
            let serial = m.rollout(x0, 12);
            assert_eq!(batched[b], serial, "traj {b}");
        }
    }

    #[test]
    fn rollout_batch_leaves_serial_state_untouched() {
        let mut m = VanillaRnn::new(toy_weights(2, 3, 1));
        let a = m.rollout(&[0.5, -0.5], 10);
        let _ = m.rollout_batch(&[vec![9.0, 9.0]], 10);
        let b = m.rollout(&[0.5, -0.5], 10);
        assert_eq!(a, b);
    }
}
