//! Plain MLP inference (ReLU hidden layers, linear head) — the digital
//! realisation of the neural-ODE vector field and of the recurrent-ResNet
//! transition. Matches `compile.kernels.ref.mlp_field` exactly.
//!
//! [`Mlp::forward_batch_into`] runs B stacked inputs through the net with
//! one GEMM per layer ([`Mat::vecmat_batch_into`]); per trajectory it is
//! bit-identical to [`Mlp::forward_into`], which is what lets the batched
//! request path reproduce serial rollouts exactly. Both forwards inherit
//! the runtime-dispatched SIMD/threaded microkernels of
//! [`crate::util::kernel`] through `Mat` — no model code changes with the
//! CPU, and outputs are bit-identical across kernel choices.

use crate::models::loader::MlpWeights;
use crate::ode::batch::BatchVectorField;
use crate::ode::func::VectorField;
use crate::util::tensor::Mat;

/// Inference-ready MLP with preallocated activations.
#[derive(Debug, Clone)]
pub struct Mlp {
    layers: Vec<(Mat, Vec<f64>)>,
    /// Per-layer activation scratch.
    acts: Vec<Vec<f64>>,
    /// Per-layer batched activation scratch (grown on first batched call).
    bacts: Vec<Vec<f64>>,
}

impl Mlp {
    pub fn new(layers: Vec<(Mat, Vec<f64>)>) -> Self {
        assert!(!layers.is_empty());
        let acts: Vec<Vec<f64>> =
            layers.iter().map(|(w, _)| vec![0.0; w.cols]).collect();
        let bacts = vec![Vec::new(); layers.len()];
        Self { layers, acts, bacts }
    }

    pub fn from_weights(w: &MlpWeights) -> Self {
        Self::new(w.layers.clone())
    }

    pub fn d_in(&self) -> usize {
        self.layers[0].0.rows
    }

    pub fn d_out(&self) -> usize {
        self.layers.last().unwrap().0.cols
    }

    /// Total trainable parameter count (used by the energy model).
    pub fn n_params(&self) -> usize {
        self.layers
            .iter()
            .map(|(w, b)| w.rows * w.cols + b.len())
            .sum()
    }

    /// Forward pass into `out` (allocation-free).
    pub fn forward_into(&mut self, u: &[f64], out: &mut [f64]) {
        let n_layers = self.layers.len();
        for l in 0..n_layers {
            let (w, b) = &self.layers[l];
            // Split-borrow the previous activation and the current one.
            let (src, dst): (&[f64], &mut Vec<f64>) = if l == 0 {
                (u, &mut self.acts[0])
            } else {
                let (a, bslice) = self.acts.split_at_mut(l);
                (&a[l - 1], &mut bslice[0])
            };
            w.vecmat_into(src, dst);
            for (d, &bias) in dst.iter_mut().zip(b) {
                *d += bias;
            }
            if l + 1 < n_layers {
                for d in dst.iter_mut() {
                    if *d < 0.0 {
                        *d = 0.0;
                    }
                }
            }
        }
        out.copy_from_slice(&self.acts[n_layers - 1]);
    }

    /// Allocating forward pass.
    pub fn forward(&mut self, u: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.d_out()];
        self.forward_into(u, &mut out);
        out
    }

    /// Batched forward pass: `us` holds `batch` row-major stacked inputs
    /// (`[batch * d_in]`), `out` receives `[batch * d_out]`. One GEMM per
    /// layer; per trajectory bit-identical to [`Mlp::forward_into`].
    pub fn forward_batch_into(
        &mut self,
        us: &[f64],
        batch: usize,
        out: &mut [f64],
    ) {
        let n_layers = self.layers.len();
        assert_eq!(
            us.len(),
            batch * self.d_in(),
            "forward_batch: us length != batch * d_in"
        );
        assert_eq!(
            out.len(),
            batch * self.d_out(),
            "forward_batch: out length != batch * d_out"
        );
        for l in 0..n_layers {
            let mut act = std::mem::take(&mut self.bacts[l]);
            let (w, b) = &self.layers[l];
            act.resize(batch * w.cols, 0.0);
            {
                let src: &[f64] =
                    if l == 0 { us } else { &self.bacts[l - 1] };
                w.vecmat_batch_into(src, batch, &mut act);
            }
            for bi in 0..batch {
                let row = &mut act[bi * w.cols..(bi + 1) * w.cols];
                for (d, &bias) in row.iter_mut().zip(b) {
                    *d += bias;
                }
            }
            if l + 1 < n_layers {
                for d in act.iter_mut() {
                    if *d < 0.0 {
                        *d = 0.0;
                    }
                }
            }
            self.bacts[l] = act;
        }
        out.copy_from_slice(&self.bacts[n_layers - 1]);
    }

    /// Allocating batched forward pass.
    pub fn forward_batch(&mut self, us: &[f64], batch: usize) -> Vec<f64> {
        let mut out = vec![0.0; batch * self.d_out()];
        self.forward_batch_into(us, batch, &mut out);
        out
    }
}

/// An autonomous neural-ODE vector field dh/dt = mlp(h).
///
/// Borrows the twin's MLP instead of owning a clone, so constructing a
/// field per request costs nothing — part of the zero-allocation request
/// path.
pub struct MlpField<'a> {
    pub mlp: &'a mut Mlp,
    /// Route/model label surfaced by solver dim asserts.
    pub label: &'static str,
}

impl VectorField for MlpField<'_> {
    fn dim(&self) -> usize {
        self.mlp.d_out()
    }

    fn label(&self) -> &str {
        self.label
    }

    fn eval_into(&mut self, _t: f64, x: &[f64], out: &mut [f64]) {
        self.mlp.forward_into(x, out);
    }
}

/// A driven neural-ODE field dh/dt = mlp([x(t); h]) with a stimulus
/// closure. Borrows the MLP; the `[x; h]` staging buffer is owned (one
/// small allocation per construction — the serial path's only one).
pub struct DrivenMlpField<'a, F: FnMut(f64) -> f64> {
    pub mlp: &'a mut Mlp,
    pub drive: F,
    /// Route/model label surfaced by solver dim asserts.
    pub label: &'static str,
    /// Scratch [x; h].
    u: Vec<f64>,
}

impl<'a, F: FnMut(f64) -> f64> DrivenMlpField<'a, F> {
    /// Single-input drive (the HP twin's voltage stimulus).
    pub fn new(mlp: &'a mut Mlp, drive: F, label: &'static str) -> Self {
        let u = vec![0.0; mlp.d_in()];
        Self { mlp, drive, label, u }
    }
}

impl<F: FnMut(f64) -> f64> VectorField for DrivenMlpField<'_, F> {
    fn dim(&self) -> usize {
        self.mlp.d_out()
    }

    fn label(&self) -> &str {
        self.label
    }

    fn eval_into(&mut self, t: f64, x: &[f64], out: &mut [f64]) {
        self.u[0] = (self.drive)(t);
        self.u[1..].copy_from_slice(x);
        self.mlp.forward_into(&self.u, out);
    }
}

/// A batch of B autonomous neural-ODE trajectories sharing one (borrowed)
/// MLP: dh_b/dt = mlp(h_b), evaluated with one GEMM per layer.
pub struct BatchMlpField<'a> {
    pub mlp: &'a mut Mlp,
    pub batch: usize,
    /// Route/model label surfaced by batched solver dim asserts.
    pub label: &'static str,
}

impl BatchVectorField for BatchMlpField<'_> {
    fn dim(&self) -> usize {
        self.mlp.d_out()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn label(&self) -> &str {
        self.label
    }

    fn eval_batch_into(&mut self, _t: f64, xs: &[f64], out: &mut [f64]) {
        self.mlp.forward_batch_into(xs, self.batch, out);
    }
}

/// A batch of B driven neural-ODE trajectories dh_b/dt = mlp([x_b(t); h_b])
/// with a per-trajectory stimulus closure `drive(b, t)` (single drive line,
/// like [`DrivenMlpField`]). The shared MLP still runs one GEMM per layer;
/// only the stimulus differs per trajectory. Both the MLP and the stacked
/// `[x_b; h_b]` staging buffer are borrowed, so the twin's reusable scratch
/// makes field construction allocation-free.
pub struct BatchDrivenMlpField<'a, F: FnMut(usize, f64) -> f64> {
    pub mlp: &'a mut Mlp,
    pub batch: usize,
    pub drive: F,
    /// Route/model label surfaced by batched solver dim asserts.
    pub label: &'static str,
    /// Scratch: stacked [x_b; h_b] rows (caller-owned, resized in `new`).
    u: &'a mut Vec<f64>,
}

impl<'a, F: FnMut(usize, f64) -> f64> BatchDrivenMlpField<'a, F> {
    pub fn new(
        mlp: &'a mut Mlp,
        batch: usize,
        drive: F,
        u: &'a mut Vec<f64>,
        label: &'static str,
    ) -> Self {
        u.resize(batch * mlp.d_in(), 0.0);
        Self { mlp, batch, drive, label, u }
    }
}

impl<F: FnMut(usize, f64) -> f64> BatchVectorField
    for BatchDrivenMlpField<'_, F>
{
    fn dim(&self) -> usize {
        self.mlp.d_out()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn label(&self) -> &str {
        self.label
    }

    fn eval_batch_into(&mut self, t: f64, xs: &[f64], out: &mut [f64]) {
        let d_in = self.mlp.d_in();
        let d_s = d_in - 1;
        debug_assert_eq!(xs.len(), self.batch * d_s);
        for b in 0..self.batch {
            let row = &mut self.u[b * d_in..(b + 1) * d_in];
            row[0] = (self.drive)(b, t);
            row[1..].copy_from_slice(&xs[b * d_s..(b + 1) * d_s]);
        }
        self.mlp.forward_batch_into(&self.u[..], self.batch, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Mlp {
        // f(u) = relu(u1 - u2) - relu(u2 - u1)  == u1 - u2 via two units.
        let w1 = Mat::from_vec(2, 2, vec![1.0, -1.0, -1.0, 1.0]);
        let b1 = vec![0.0, 0.0];
        let w2 = Mat::from_vec(2, 1, vec![1.0, -1.0]);
        let b2 = vec![0.0];
        Mlp::new(vec![(w1, b1), (w2, b2)])
    }

    #[test]
    fn forward_computes_expected() {
        let mut m = toy();
        for (a, b) in [(1.0, 0.5), (-2.0, 3.0), (0.0, 0.0)] {
            let y = m.forward(&[a, b]);
            assert!((y[0] - (a - b)).abs() < 1e-12);
        }
    }

    #[test]
    fn relu_only_on_hidden() {
        // Last layer is linear: negative outputs must survive.
        let mut m = toy();
        let y = m.forward(&[0.0, 1.0]);
        assert!(y[0] < 0.0);
    }

    #[test]
    fn bias_applied() {
        let w = Mat::from_vec(1, 1, vec![2.0]);
        let mut m = Mlp::new(vec![(w, vec![0.5])]);
        assert!((m.forward(&[1.0])[0] - 2.5).abs() < 1e-12);
    }

    #[test]
    fn n_params_counts() {
        assert_eq!(toy().n_params(), 4 + 2 + 2 + 1);
    }

    #[test]
    fn field_wrappers() {
        use crate::ode::func::VectorField;
        let mut m = toy();
        let mut f = MlpField { mlp: &mut m, label: "toy" };
        assert_eq!(f.dim(), 1);
        // field gets [h1, h2]... dim mismatch: toy d_in = 2, d_out = 1, so
        // MlpField as autonomous is ill-typed for solving, but eval works
        // for shape checking.
        let mut out = [0.0];
        f.eval_into(0.0, &[1.0, 0.25], &mut out);
        assert!((out[0] - 0.75).abs() < 1e-12);

        let mut m2 = toy();
        let mut df = DrivenMlpField::new(&mut m2, |t| t, "toy");
        let mut out = [0.0];
        df.eval_into(2.0, &[0.5], &mut out);
        assert!((out[0] - 1.5).abs() < 1e-12); // x=2 (drive), h=0.5
    }

    #[test]
    fn forward_batch_bit_identical_to_serial() {
        let mut m = toy();
        let inputs = [[1.0, 0.5], [-2.0, 3.0], [0.0, 0.0], [0.3, -0.7]];
        let us: Vec<f64> = inputs.iter().flatten().copied().collect();
        let ys = m.forward_batch(&us, inputs.len());
        for (b, u) in inputs.iter().enumerate() {
            let want = m.forward(u);
            assert_eq!(&ys[b..b + 1], &want[..], "traj {b}");
        }
    }

    #[test]
    fn forward_batch_reuses_scratch_without_stale_state() {
        let mut m = toy();
        // Large batch first, then a smaller one: no stale tail.
        let big: Vec<f64> = (0..8).map(|k| k as f64 * 0.1).collect();
        let _ = m.forward_batch(&big, 4);
        let small = m.forward_batch(&[1.0, 0.0], 1);
        assert!((small[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn batch_driven_field_matches_serial_driven_field() {
        use crate::ode::batch::BatchVectorField;
        let mut m = toy();
        let mut u = Vec::new();
        let mut bf = BatchDrivenMlpField::new(
            &mut m,
            2,
            |b, t| (b as f64 + 1.0) * t,
            &mut u,
            "toy",
        );
        let mut out = [0.0; 2];
        bf.eval_batch_into(2.0, &[0.5, -0.25], &mut out);
        let mut m1 = toy();
        let mut d1 = DrivenMlpField::new(&mut m1, |t| t, "toy");
        let mut m2 = toy();
        let mut d2 = DrivenMlpField::new(&mut m2, |t| 2.0 * t, "toy");
        let mut o1 = [0.0];
        let mut o2 = [0.0];
        d1.eval_into(2.0, &[0.5], &mut o1);
        d2.eval_into(2.0, &[-0.25], &mut o2);
        assert_eq!(out[0], o1[0]);
        assert_eq!(out[1], o2[0]);
    }

    #[test]
    fn forward_into_no_stale_state() {
        let mut m = toy();
        let mut out = [99.0];
        m.forward_into(&[1.0, 0.0], &mut out);
        assert!((out[0] - 1.0).abs() < 1e-12);
        m.forward_into(&[0.0, 0.0], &mut out);
        assert_eq!(out[0], 0.0);
    }
}
