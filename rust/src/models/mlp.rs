//! Plain MLP inference (ReLU hidden layers, linear head) — the digital
//! realisation of the neural-ODE vector field and of the recurrent-ResNet
//! transition. Matches `compile.kernels.ref.mlp_field` exactly.

use crate::models::loader::MlpWeights;
use crate::ode::func::VectorField;
use crate::util::tensor::Mat;

/// Inference-ready MLP with preallocated activations.
#[derive(Debug, Clone)]
pub struct Mlp {
    layers: Vec<(Mat, Vec<f64>)>,
    /// Per-layer activation scratch.
    acts: Vec<Vec<f64>>,
}

impl Mlp {
    pub fn new(layers: Vec<(Mat, Vec<f64>)>) -> Self {
        assert!(!layers.is_empty());
        let acts = layers.iter().map(|(w, _)| vec![0.0; w.cols]).collect();
        Self { layers, acts }
    }

    pub fn from_weights(w: &MlpWeights) -> Self {
        Self::new(w.layers.clone())
    }

    pub fn d_in(&self) -> usize {
        self.layers[0].0.rows
    }

    pub fn d_out(&self) -> usize {
        self.layers.last().unwrap().0.cols
    }

    /// Total trainable parameter count (used by the energy model).
    pub fn n_params(&self) -> usize {
        self.layers
            .iter()
            .map(|(w, b)| w.rows * w.cols + b.len())
            .sum()
    }

    /// Forward pass into `out` (allocation-free).
    pub fn forward_into(&mut self, u: &[f64], out: &mut [f64]) {
        let n_layers = self.layers.len();
        for l in 0..n_layers {
            let (w, b) = &self.layers[l];
            // Split-borrow the previous activation and the current one.
            let (src, dst): (&[f64], &mut Vec<f64>) = if l == 0 {
                (u, &mut self.acts[0])
            } else {
                let (a, bslice) = self.acts.split_at_mut(l);
                (&a[l - 1], &mut bslice[0])
            };
            w.vecmat_into(src, dst);
            for (d, &bias) in dst.iter_mut().zip(b) {
                *d += bias;
            }
            if l + 1 < n_layers {
                for d in dst.iter_mut() {
                    if *d < 0.0 {
                        *d = 0.0;
                    }
                }
            }
        }
        out.copy_from_slice(&self.acts[n_layers - 1]);
    }

    /// Allocating forward pass.
    pub fn forward(&mut self, u: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.d_out()];
        self.forward_into(u, &mut out);
        out
    }
}

/// An autonomous neural-ODE vector field dh/dt = mlp(h).
pub struct MlpField {
    pub mlp: Mlp,
}

impl VectorField for MlpField {
    fn dim(&self) -> usize {
        self.mlp.d_out()
    }

    fn eval_into(&mut self, _t: f64, x: &[f64], out: &mut [f64]) {
        self.mlp.forward_into(x, out);
    }
}

/// A driven neural-ODE field dh/dt = mlp([x(t); h]) with a stimulus closure.
pub struct DrivenMlpField<F: FnMut(f64) -> f64> {
    pub mlp: Mlp,
    pub drive: F,
    /// Scratch [x; h].
    u: Vec<f64>,
}

impl<F: FnMut(f64) -> f64> DrivenMlpField<F> {
    /// Single-input drive (the HP twin's voltage stimulus).
    pub fn new(mlp: Mlp, drive: F) -> Self {
        let u = vec![0.0; mlp.d_in()];
        Self { mlp, drive, u }
    }
}

impl<F: FnMut(f64) -> f64> VectorField for DrivenMlpField<F> {
    fn dim(&self) -> usize {
        self.mlp.d_out()
    }

    fn eval_into(&mut self, t: f64, x: &[f64], out: &mut [f64]) {
        self.u[0] = (self.drive)(t);
        self.u[1..].copy_from_slice(x);
        self.mlp.forward_into(&self.u, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Mlp {
        // f(u) = relu(u1 - u2) - relu(u2 - u1)  == u1 - u2 via two units.
        let w1 = Mat::from_vec(2, 2, vec![1.0, -1.0, -1.0, 1.0]);
        let b1 = vec![0.0, 0.0];
        let w2 = Mat::from_vec(2, 1, vec![1.0, -1.0]);
        let b2 = vec![0.0];
        Mlp::new(vec![(w1, b1), (w2, b2)])
    }

    #[test]
    fn forward_computes_expected() {
        let mut m = toy();
        for (a, b) in [(1.0, 0.5), (-2.0, 3.0), (0.0, 0.0)] {
            let y = m.forward(&[a, b]);
            assert!((y[0] - (a - b)).abs() < 1e-12);
        }
    }

    #[test]
    fn relu_only_on_hidden() {
        // Last layer is linear: negative outputs must survive.
        let mut m = toy();
        let y = m.forward(&[0.0, 1.0]);
        assert!(y[0] < 0.0);
    }

    #[test]
    fn bias_applied() {
        let w = Mat::from_vec(1, 1, vec![2.0]);
        let mut m = Mlp::new(vec![(w, vec![0.5])]);
        assert!((m.forward(&[1.0])[0] - 2.5).abs() < 1e-12);
    }

    #[test]
    fn n_params_counts() {
        assert_eq!(toy().n_params(), 4 + 2 + 2 + 1);
    }

    #[test]
    fn field_wrappers() {
        use crate::ode::func::VectorField;
        let mut f = MlpField { mlp: toy() };
        assert_eq!(f.dim(), 1);
        // field gets [h1, h2]... dim mismatch: toy d_in = 2, d_out = 1, so
        // MlpField as autonomous is ill-typed for solving, but eval works
        // for shape checking.
        let mut out = [0.0];
        f.eval_into(0.0, &[1.0, 0.25], &mut out);
        assert!((out[0] - 0.75).abs() < 1e-12);

        let mut df = DrivenMlpField::new(toy(), |t| t);
        let mut out = [0.0];
        df.eval_into(2.0, &[0.5], &mut out);
        assert!((out[0] - 1.5).abs() < 1e-12); // x=2 (drive), h=0.5
    }

    #[test]
    fn forward_into_no_stale_state() {
        let mut m = toy();
        let mut out = [99.0];
        m.forward_into(&[1.0, 0.0], &mut out);
        assert!((out[0] - 1.0).abs() < 1e-12);
        m.forward_into(&[0.0, 0.0], &mut out);
        assert_eq!(out[0], 0.0);
    }
}
