//! LSTM baseline (gate order i | f | g | o — matches
//! `compile.train.rnn_cell` exactly).

use crate::models::loader::RnnWeights;
use crate::models::rnn::{
    gates_batch_into, gates_into, head, head_batch_into, Recurrent,
};

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// LSTM cell with residual next-state head.
pub struct Lstm {
    pub w: RnnWeights,
    h: Vec<f64>,
    c: Vec<f64>,
    z: Vec<f64>,
}

impl Lstm {
    pub fn new(w: RnnWeights) -> Self {
        assert_eq!(w.wx.cols, 4 * w.hidden, "lstm expects 4 gate blocks");
        let h = vec![0.0; w.hidden];
        let c = vec![0.0; w.hidden];
        let z = vec![0.0; 4 * w.hidden];
        Self { w, h, c, z }
    }

    /// Cell state (diagnostics/tests).
    pub fn cell_state(&self) -> &[f64] {
        &self.c
    }
}

impl Recurrent for Lstm {
    fn reset(&mut self) {
        self.h.fill(0.0);
        self.c.fill(0.0);
    }

    fn step(&mut self, x: &[f64]) -> Vec<f64> {
        let hn = self.w.hidden;
        gates_into(&self.w, x, &self.h, &mut self.z);
        for k in 0..hn {
            let i = sigmoid(self.z[k]);
            let f = sigmoid(self.z[hn + k]);
            let g = self.z[2 * hn + k].tanh();
            let o = sigmoid(self.z[3 * hn + k]);
            self.c[k] = f * self.c[k] + i * g;
            self.h[k] = o * self.c[k].tanh();
        }
        head(&self.w, x, &self.h)
    }

    fn rollout_batch(
        &mut self,
        x0s: &[Vec<f64>],
        n: usize,
    ) -> Vec<Vec<Vec<f64>>> {
        let batch = x0s.len();
        let d = self.w.d_in;
        for x0 in x0s {
            assert_eq!(x0.len(), d, "rollout_batch: x0 dim != d_in");
        }
        let hn = self.w.hidden;
        // Local batch state (serial h/c untouched); one gate GEMM per step
        // shared across the batch, element-wise gate math per trajectory.
        let mut x: Vec<f64> = x0s.iter().flatten().copied().collect();
        let mut h = vec![0.0; batch * hn];
        let mut c = vec![0.0; batch * hn];
        let mut z = vec![0.0; batch * 4 * hn];
        let mut y = vec![0.0; batch * d];
        let mut out: Vec<Vec<Vec<f64>>> = x0s
            .iter()
            .map(|x0| {
                let mut t = Vec::with_capacity(n);
                t.push(x0.clone());
                t
            })
            .collect();
        for _ in 1..n {
            gates_batch_into(&self.w, &x, &h, batch, &mut z);
            for b in 0..batch {
                let zb = &z[b * 4 * hn..(b + 1) * 4 * hn];
                for k in 0..hn {
                    let i = sigmoid(zb[k]);
                    let f = sigmoid(zb[hn + k]);
                    let g = zb[2 * hn + k].tanh();
                    let o = sigmoid(zb[3 * hn + k]);
                    let ck = &mut c[b * hn + k];
                    *ck = f * *ck + i * g;
                    h[b * hn + k] = o * ck.tanh();
                }
            }
            head_batch_into(&self.w, &x, &h, batch, &mut y);
            x.copy_from_slice(&y);
            for (b, traj) in out.iter_mut().enumerate() {
                traj.push(x[b * d..(b + 1) * d].to_vec());
            }
        }
        out
    }

    fn d_in(&self) -> usize {
        self.w.d_in
    }

    fn n_params(&self) -> usize {
        let w = &self.w;
        w.wx.rows * w.wx.cols
            + w.wh.rows * w.wh.cols
            + w.b.len()
            + w.wo.rows * w.wo.cols
            + w.bo.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::rnn::toy_weights;

    #[test]
    fn rollout_shape_and_determinism() {
        let mut m = Lstm::new(toy_weights(3, 4, 4));
        let a = m.rollout(&[0.1, 0.2, 0.3], 12);
        let b = m.rollout(&[0.1, 0.2, 0.3], 12);
        assert_eq!(a.len(), 12);
        assert_eq!(a, b);
    }

    #[test]
    fn forget_gate_zero_clears_cell() {
        // Large negative forget bias: cell state becomes i*g only.
        let mut w = toy_weights(2, 3, 4);
        for i in 0..3 {
            w.b[3 + i] = -50.0; // forget block
        }
        let mut m = Lstm::new(w);
        m.step(&[1.0, 1.0]);
        let c1 = m.cell_state().to_vec();
        m.step(&[1.0, 1.0]);
        let c2 = m.cell_state().to_vec();
        // With f = 0, c2 is i*g of step 2 alone -> same magnitude class as
        // c1, not accumulated.
        for (a, b) in c1.iter().zip(&c2) {
            assert!((a - b).abs() < 0.5);
        }
    }

    #[test]
    fn hidden_bounded_by_tanh() {
        let mut m = Lstm::new(toy_weights(2, 4, 4));
        for _ in 0..200 {
            m.step(&[5.0, -5.0]);
        }
        assert!(m.h.iter().all(|&v| v.abs() <= 1.0));
    }

    #[test]
    fn reset_clears_both_states() {
        let mut m = Lstm::new(toy_weights(2, 3, 4));
        m.step(&[1.0, 2.0]);
        m.reset();
        assert!(m.h.iter().all(|&v| v == 0.0));
        assert!(m.c.iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic(expected = "4 gate blocks")]
    fn wrong_gate_count_panics() {
        let _ = Lstm::new(toy_weights(2, 4, 3));
    }

    #[test]
    fn rollout_batch_bit_identical_to_serial() {
        let mut m = Lstm::new(toy_weights(3, 4, 4));
        let x0s = vec![
            vec![0.1, 0.2, 0.3],
            vec![1.0, -1.0, 0.5],
            vec![-0.3, 0.0, 0.8],
        ];
        let batched = m.rollout_batch(&x0s, 9);
        for (b, x0) in x0s.iter().enumerate() {
            let serial = m.rollout(x0, 9);
            assert_eq!(batched[b], serial, "traj {b}");
        }
    }
}
