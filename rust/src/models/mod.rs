//! Digital baseline models (the comparators of Fig. 3j and Fig. 4g-i).
//!
//! Rust-native inference implementations matching the JAX training code in
//! `python/compile/train.py` gate-for-gate; weights load from
//! `artifacts/weights/*.json`.
//!
//! * [`mlp`]    — the plain MLP vector field (shared by neural-ODE digital
//!   inference and the recurrent-ResNet baseline)
//! * [`resnet`] — recurrent ResNet: h_{t+1} = h_t + f([x_t; h_t]) (Fig. 3j)
//! * [`rnn`]    — vanilla RNN with residual next-state head
//! * [`gru`]    — GRU (gate order z | r | n, reset-gated candidate)
//! * [`lstm`]   — LSTM (gate order i | f | g | o)
//! * [`loader`] — weight deserialisation from the artifact JSON format

pub mod gru;
pub mod loader;
pub mod lstm;
pub mod mlp;
pub mod resnet;
pub mod rnn;

pub use loader::{load_mlp_weights, load_rnn_weights, MlpWeights, RnnWeights};
pub use mlp::Mlp;
