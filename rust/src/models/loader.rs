//! Weight deserialisation from `artifacts/weights/*.json`.
//!
//! Two schemas, both produced by `python/compile/train.py`:
//!
//! * MLP (neural ODE / ResNet): `{"meta": {...}, "layers": [{"w": [[..]],
//!   "b": [..]}, ...]}` with `w: [fan_in][fan_out]`;
//! * recurrent cells: `{"meta": {...}, "wx": [[..]], "wh": [[..]],
//!   "b": [..], "wo": [[..]], "bo": [..]}`.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};
use crate::util::tensor::Mat;

/// Parsed MLP weights + metadata.
#[derive(Debug, Clone)]
pub struct MlpWeights {
    /// Per-layer (w: [fan_in, fan_out], b: [fan_out]).
    pub layers: Vec<(Mat, Vec<f64>)>,
    /// Sampling interval the model was trained for.
    pub dt: f64,
    /// "node" | "resnet".
    pub kind: String,
    /// "hp" | "l96".
    pub task: String,
}

/// Parsed recurrent-cell weights + metadata.
#[derive(Debug, Clone)]
pub struct RnnWeights {
    pub wx: Mat,
    pub wh: Mat,
    pub b: Vec<f64>,
    pub wo: Mat,
    pub bo: Vec<f64>,
    pub hidden: usize,
    pub d_in: usize,
    pub dt: f64,
    /// "rnn" | "gru" | "lstm".
    pub kind: String,
}

/// Synthetic weights implementing f(h) = -h *exactly*, element-wise, for
/// any dimension `d`: hidden = relu([h_i, -h_i]) pairs, out_i =
/// -hidden_{2i} + hidden_{2i+1}. The shared fixture of the sharding /
/// batching / allocation test suites (one definition, so what those
/// suites exercise cannot silently diverge); with d > 32 the deployed
/// layers span several physical tile column-groups.
pub fn decay_mlp_weights(d: usize) -> MlpWeights {
    let mut w1 = Mat::zeros(d, 2 * d);
    for i in 0..d {
        *w1.at_mut(i, 2 * i) = 1.0;
        *w1.at_mut(i, 2 * i + 1) = -1.0;
    }
    let b1 = vec![0.0; 2 * d];
    let mut w2 = Mat::zeros(2 * d, d);
    for i in 0..d {
        *w2.at_mut(2 * i, i) = -1.0;
        *w2.at_mut(2 * i + 1, i) = 1.0;
    }
    let b2 = vec![0.0; d];
    MlpWeights {
        layers: vec![(w1, b1), (w2, b2)],
        dt: 0.02,
        kind: "node".into(),
        task: "l96".into(),
    }
}

fn mat_from(v: &Json, what: &str) -> Result<Mat> {
    let rows = v
        .as_mat_f64()
        .ok_or_else(|| anyhow!("{what}: expected 2-D numeric array"))?;
    Ok(Mat::from_rows(&rows))
}

fn vec_from(v: &Json, what: &str) -> Result<Vec<f64>> {
    v.as_vec_f64()
        .ok_or_else(|| anyhow!("{what}: expected 1-D numeric array"))
}

fn meta_str(meta: &Json, key: &str) -> String {
    meta.get(key).and_then(Json::as_str).unwrap_or("?").to_string()
}

/// Load an MLP weight file.
pub fn load_mlp_weights(path: &Path) -> Result<MlpWeights> {
    let doc = json::from_file(path)?;
    let meta = doc.req("meta").context("weights meta")?;
    let layers_json = doc
        .req("layers")?
        .as_arr()
        .ok_or_else(|| anyhow!("layers must be an array"))?;
    let mut layers = Vec::with_capacity(layers_json.len());
    for (i, l) in layers_json.iter().enumerate() {
        let w = mat_from(l.req("w")?, &format!("layer {i} w"))?;
        let b = vec_from(l.req("b")?, &format!("layer {i} b"))?;
        if w.cols != b.len() {
            return Err(anyhow!(
                "layer {i}: w cols {} != b len {}",
                w.cols,
                b.len()
            ));
        }
        layers.push((w, b));
    }
    // Consecutive layers must chain.
    for i in 1..layers.len() {
        if layers[i - 1].0.cols != layers[i].0.rows {
            return Err(anyhow!(
                "layer {} fan-out {} != layer {} fan-in {}",
                i - 1,
                layers[i - 1].0.cols,
                i,
                layers[i].0.rows
            ));
        }
    }
    Ok(MlpWeights {
        layers,
        dt: meta.get("dt").and_then(Json::as_f64).unwrap_or(0.0),
        kind: meta_str(meta, "kind"),
        task: meta_str(meta, "task"),
    })
}

/// Load a recurrent-cell weight file.
pub fn load_rnn_weights(path: &Path) -> Result<RnnWeights> {
    let doc = json::from_file(path)?;
    let meta = doc.req("meta").context("weights meta")?;
    let wx = mat_from(doc.req("wx")?, "wx")?;
    let wh = mat_from(doc.req("wh")?, "wh")?;
    let b = vec_from(doc.req("b")?, "b")?;
    let wo = mat_from(doc.req("wo")?, "wo")?;
    let bo = vec_from(doc.req("bo")?, "bo")?;
    let hidden = wh.rows;
    let d_in = wx.rows;
    if wh.cols != wx.cols || b.len() != wx.cols {
        return Err(anyhow!("gate width mismatch"));
    }
    if wo.rows != hidden || wo.cols != bo.len() {
        return Err(anyhow!("output head shape mismatch"));
    }
    Ok(RnnWeights {
        wx,
        wh,
        b,
        wo,
        bo,
        hidden,
        d_in,
        dt: meta.get("dt").and_then(Json::as_f64).unwrap_or(0.0),
        kind: meta_str(meta, "kind"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn tmpfile(content: &str) -> std::path::PathBuf {
        let mut path = std::env::temp_dir();
        path.push(format!(
            "memode_test_{}_{}.json",
            std::process::id(),
            content.len()
        ));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        path
    }

    #[test]
    fn loads_minimal_mlp() {
        let p = tmpfile(
            r#"{"meta":{"kind":"node","task":"hp","dt":0.001},
                "layers":[{"w":[[1,2],[3,4]],"b":[0.1,0.2]},
                           {"w":[[1],[1]],"b":[0]}]}"#,
        );
        let w = load_mlp_weights(&p).unwrap();
        assert_eq!(w.layers.len(), 2);
        assert_eq!(w.layers[0].0.rows, 2);
        assert_eq!(w.kind, "node");
        assert_eq!(w.dt, 0.001);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_mismatched_chain() {
        let p = tmpfile(
            r#"{"meta":{},
                "layers":[{"w":[[1,2]],"b":[0,0]},
                           {"w":[[1],[1],[1]],"b":[0]}]}"#,
        );
        assert!(load_mlp_weights(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_bias_mismatch() {
        let p = tmpfile(r#"{"meta":{},"layers":[{"w":[[1,2]],"b":[0]}]}"#);
        assert!(load_mlp_weights(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn loads_minimal_rnn() {
        let p = tmpfile(
            r#"{"meta":{"kind":"rnn","dt":0.02},
                "wx":[[1,0],[0,1]],"wh":[[0,0],[0,0]],"b":[0,0],
                "wo":[[1],[1]],"bo":[0]}"#,
        );
        let w = load_rnn_weights(&p).unwrap();
        assert_eq!(w.hidden, 2);
        assert_eq!(w.d_in, 2);
        assert_eq!(w.kind, "rnn");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rnn_gate_width_checked() {
        let p = tmpfile(
            r#"{"meta":{},"wx":[[1,0]],"wh":[[0],[0]],"b":[0,0],
                "wo":[[1],[1]],"bo":[0]}"#,
        );
        assert!(load_rnn_weights(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
