//! GRU baseline (gate order z | r | n; reset-gated candidate — matches
//! `compile.train.rnn_cell` exactly).

use crate::models::loader::RnnWeights;
use crate::models::rnn::{
    gates_batch_into, gates_into, head, head_batch_into, Recurrent,
};

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// GRU cell with residual next-state head.
pub struct Gru {
    pub w: RnnWeights,
    h: Vec<f64>,
    z: Vec<f64>,
    nx: Vec<f64>,
    rh: Vec<f64>,
    nh: Vec<f64>,
}

impl Gru {
    pub fn new(w: RnnWeights) -> Self {
        assert_eq!(w.wx.cols, 3 * w.hidden, "gru expects 3 gate blocks");
        let h = vec![0.0; w.hidden];
        let z = vec![0.0; 3 * w.hidden];
        let nx = vec![0.0; w.hidden];
        let rh = vec![0.0; w.hidden];
        let nh = vec![0.0; w.hidden];
        Self { w, h, z, nx, rh, nh }
    }
}

impl Recurrent for Gru {
    fn reset(&mut self) {
        self.h.fill(0.0);
    }

    fn step(&mut self, x: &[f64]) -> Vec<f64> {
        let hn = self.w.hidden;
        gates_into(&self.w, x, &self.h, &mut self.z);
        // Candidate recurrent term uses the *reset-gated* hidden state and
        // the third gate-block columns of wx/wh (recompute those columns:
        // z already holds x wx + h wh for all blocks, but block n must use
        // (r*h) wh, so rebuild it).
        // nx = x @ wx[:, 2H:]
        for c in 0..hn {
            let mut acc = 0.0;
            for (r, &xv) in x.iter().enumerate() {
                acc += xv * self.w.wx.at(r, 2 * hn + c);
            }
            self.nx[c] = acc;
        }
        // rh = r * h
        for i in 0..hn {
            let r_gate = sigmoid(self.z[hn + i]);
            self.rh[i] = r_gate * self.h[i];
        }
        // nh = (r*h) @ wh[:, 2H:]
        for c in 0..hn {
            let mut acc = 0.0;
            for (r, &hv) in self.rh.iter().enumerate() {
                acc += hv * self.w.wh.at(r, 2 * hn + c);
            }
            self.nh[c] = acc;
        }
        for i in 0..hn {
            let z_gate = sigmoid(self.z[i]);
            let n_gate =
                (self.nx[i] + self.nh[i] + self.w.b[2 * hn + i]).tanh();
            self.h[i] = (1.0 - z_gate) * n_gate + z_gate * self.h[i];
        }
        head(&self.w, x, &self.h)
    }

    fn rollout_batch(
        &mut self,
        x0s: &[Vec<f64>],
        n: usize,
    ) -> Vec<Vec<Vec<f64>>> {
        let batch = x0s.len();
        let d = self.w.d_in;
        for x0 in x0s {
            assert_eq!(x0.len(), d, "rollout_batch: x0 dim != d_in");
        }
        let hn = self.w.hidden;
        // Local batch state (the serial hidden state stays untouched); the
        // gate GEMM is shared across the batch, the candidate path below
        // replicates the serial loops per trajectory bit-for-bit.
        let mut x: Vec<f64> = x0s.iter().flatten().copied().collect();
        let mut h = vec![0.0; batch * hn];
        let mut z = vec![0.0; batch * 3 * hn];
        let mut y = vec![0.0; batch * d];
        let mut nx = vec![0.0; hn];
        let mut rh = vec![0.0; hn];
        let mut nh = vec![0.0; hn];
        let mut out: Vec<Vec<Vec<f64>>> = x0s
            .iter()
            .map(|x0| {
                let mut t = Vec::with_capacity(n);
                t.push(x0.clone());
                t
            })
            .collect();
        for _ in 1..n {
            gates_batch_into(&self.w, &x, &h, batch, &mut z);
            for b in 0..batch {
                let xb = &x[b * d..(b + 1) * d];
                let hb = &mut h[b * hn..(b + 1) * hn];
                let zb = &z[b * 3 * hn..(b + 1) * 3 * hn];
                for (c, nv) in nx.iter_mut().enumerate() {
                    let mut acc = 0.0;
                    for (r, &xv) in xb.iter().enumerate() {
                        acc += xv * self.w.wx.at(r, 2 * hn + c);
                    }
                    *nv = acc;
                }
                for i in 0..hn {
                    let r_gate = sigmoid(zb[hn + i]);
                    rh[i] = r_gate * hb[i];
                }
                for (c, nv) in nh.iter_mut().enumerate() {
                    let mut acc = 0.0;
                    for (r, &hv) in rh.iter().enumerate() {
                        acc += hv * self.w.wh.at(r, 2 * hn + c);
                    }
                    *nv = acc;
                }
                for i in 0..hn {
                    let z_gate = sigmoid(zb[i]);
                    let n_gate =
                        (nx[i] + nh[i] + self.w.b[2 * hn + i]).tanh();
                    hb[i] = (1.0 - z_gate) * n_gate + z_gate * hb[i];
                }
            }
            head_batch_into(&self.w, &x, &h, batch, &mut y);
            x.copy_from_slice(&y);
            for (b, traj) in out.iter_mut().enumerate() {
                traj.push(x[b * d..(b + 1) * d].to_vec());
            }
        }
        out
    }

    fn d_in(&self) -> usize {
        self.w.d_in
    }

    fn n_params(&self) -> usize {
        let w = &self.w;
        w.wx.rows * w.wx.cols
            + w.wh.rows * w.wh.cols
            + w.b.len()
            + w.wo.rows * w.wo.cols
            + w.bo.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::rnn::toy_weights;

    #[test]
    fn rollout_shape() {
        let mut m = Gru::new(toy_weights(3, 4, 3));
        let traj = m.rollout(&[0.1, 0.2, 0.3], 8);
        assert_eq!(traj.len(), 8);
        assert_eq!(traj[0], vec![0.1, 0.2, 0.3]);
    }

    #[test]
    fn deterministic_after_reset() {
        let mut m = Gru::new(toy_weights(2, 5, 3));
        let a = m.rollout(&[1.0, -1.0], 15);
        let b = m.rollout(&[1.0, -1.0], 15);
        assert_eq!(a, b);
    }

    #[test]
    fn z_gate_one_keeps_hidden_state() {
        // Huge positive z-gate bias: h' ~= h (update gate saturates at 1),
        // so with h0 = 0 the hidden state stays 0 and preds equal inputs.
        let mut w = toy_weights(2, 3, 3);
        for i in 0..3 {
            w.b[i] = 50.0;
        }
        let mut m = Gru::new(w);
        let y = m.step(&[0.7, -0.3]);
        assert!((y[0] - 0.7).abs() < 1e-6);
        assert!((y[1] + 0.3).abs() < 1e-6);
    }

    #[test]
    fn bounded_hidden_state() {
        let mut m = Gru::new(toy_weights(2, 4, 3));
        for _ in 0..100 {
            m.step(&[10.0, -10.0]);
        }
        assert!(m.h.iter().all(|&v| v.abs() <= 1.0 + 1e-12));
    }

    #[test]
    #[should_panic(expected = "3 gate blocks")]
    fn wrong_gate_count_panics() {
        let _ = Gru::new(toy_weights(2, 4, 1));
    }

    #[test]
    fn rollout_batch_bit_identical_to_serial() {
        let mut m = Gru::new(toy_weights(3, 5, 3));
        let x0s = vec![
            vec![0.1, 0.2, 0.3],
            vec![-1.0, 0.5, 0.0],
            vec![0.7, -0.2, 0.4],
        ];
        let batched = m.rollout_batch(&x0s, 10);
        for (b, x0) in x0s.iter().enumerate() {
            let serial = m.rollout(x0, 10);
            assert_eq!(batched[b], serial, "traj {b}");
        }
    }
}
