//! Recurrent ResNet baseline (Fig. 3j; paper Eq. 8).
//!
//! Parameterises a single *discrete* transition h_{t+1} = h_t + f([x_t;
//! h_t]) at the sampling interval — the conventional finite-depth digital
//! twin the paper compares against. Same parameter population as the
//! neural ODE, but no access to intermediate (half-step) stimulus samples:
//! truncation error is baked into the learned map.

use crate::models::mlp::Mlp;

/// Recurrent ResNet rollout engine.
pub struct RecurrentResNet {
    pub mlp: Mlp,
    /// Scratch [x; h].
    u: Vec<f64>,
    dh: Vec<f64>,
}

impl RecurrentResNet {
    pub fn new(mlp: Mlp) -> Self {
        let u = vec![0.0; mlp.d_in()];
        let dh = vec![0.0; mlp.d_out()];
        Self { mlp, u, dh }
    }

    /// State dimension.
    pub fn d_state(&self) -> usize {
        self.mlp.d_out()
    }

    /// Drive dimension.
    pub fn d_drive(&self) -> usize {
        self.mlp.d_in() - self.mlp.d_out()
    }

    /// One transition h <- h + f([x; h]).
    pub fn step(&mut self, h: &mut [f64], x: &[f64]) {
        debug_assert_eq!(x.len(), self.d_drive());
        self.u[..x.len()].copy_from_slice(x);
        self.u[x.len()..].copy_from_slice(h);
        self.mlp.forward_into(&self.u, &mut self.dh);
        for (hv, &d) in h.iter_mut().zip(&self.dh) {
            *hv += d;
        }
    }

    /// Roll out under a per-sample stimulus sequence xs: [n][d_drive];
    /// returns [n+1][d_state] starting from h0.
    pub fn rollout(&mut self, h0: &[f64], xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        let mut h = h0.to_vec();
        let mut out = Vec::with_capacity(xs.len() + 1);
        out.push(h.clone());
        for x in xs {
            self.step(&mut h, x);
            out.push(h.clone());
        }
        out
    }

    /// Batched rollout of `batch` trajectories in lockstep: `h0s` is the
    /// flat `[batch * d_state]` initial state, `xs[k]` the flat
    /// `[batch * d_drive]` stimulus of step k. Each transition runs the
    /// shared MLP as one GEMM per layer; per trajectory the result is
    /// bit-identical to [`RecurrentResNet::rollout`]. Returns
    /// `[batch][n+1][d_state]`.
    pub fn rollout_batch(
        &mut self,
        h0s: &[f64],
        batch: usize,
        xs: &[Vec<f64>],
    ) -> Vec<Vec<Vec<f64>>> {
        let d_s = self.d_state();
        let d_x = self.d_drive();
        let d_in = self.mlp.d_in();
        assert_eq!(
            h0s.len(),
            batch * d_s,
            "rollout_batch: h0s length != batch * d_state"
        );
        let mut h = h0s.to_vec();
        let mut u = vec![0.0; batch * d_in];
        let mut dh = vec![0.0; batch * d_s];
        let mut out: Vec<Vec<Vec<f64>>> = (0..batch)
            .map(|b| {
                let mut t = Vec::with_capacity(xs.len() + 1);
                t.push(h[b * d_s..(b + 1) * d_s].to_vec());
                t
            })
            .collect();
        for x in xs {
            assert_eq!(
                x.len(),
                batch * d_x,
                "rollout_batch: stimulus row length != batch * d_drive"
            );
            for b in 0..batch {
                let row = &mut u[b * d_in..(b + 1) * d_in];
                row[..d_x].copy_from_slice(&x[b * d_x..(b + 1) * d_x]);
                row[d_x..].copy_from_slice(&h[b * d_s..(b + 1) * d_s]);
            }
            self.mlp.forward_batch_into(&u, batch, &mut dh);
            for (hv, &d) in h.iter_mut().zip(&dh) {
                *hv += d;
            }
            for (b, traj) in out.iter_mut().enumerate() {
                traj.push(h[b * d_s..(b + 1) * d_s].to_vec());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::Mat;

    /// ResNet whose f([x; h]) = 0.5*x - 0.1*h (exact, via paired ReLUs).
    fn toy() -> RecurrentResNet {
        let w1 = Mat::from_vec(
            2,
            4,
            vec![
                0.5, -0.5, 0.0, 0.0, // x row
                0.0, 0.0, -0.1, 0.1, // h row
            ],
        );
        let b1 = vec![0.0; 4];
        let w2 = Mat::from_vec(4, 1, vec![1.0, -1.0, 1.0, -1.0]);
        let b2 = vec![0.0];
        RecurrentResNet::new(Mlp::new(vec![(w1, b1), (w2, b2)]))
    }

    #[test]
    fn step_applies_residual() {
        let mut m = toy();
        let mut h = vec![1.0];
        m.step(&mut h, &[2.0]);
        // h + 0.5*2 - 0.1*1 = 1.9
        assert!((h[0] - 1.9).abs() < 1e-12);
    }

    #[test]
    fn rollout_length_and_determinism() {
        let mut m = toy();
        let xs = vec![vec![1.0]; 10];
        let a = m.rollout(&[0.0], &xs);
        let b = m.rollout(&[0.0], &xs);
        assert_eq!(a.len(), 11);
        assert_eq!(a, b);
    }

    #[test]
    fn converges_to_fixed_point() {
        // h* satisfies 0.5*x = 0.1*h* -> h* = 5x.
        let mut m = toy();
        let xs = vec![vec![1.0]; 200];
        let traj = m.rollout(&[0.0], &xs);
        assert!((traj.last().unwrap()[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn dims_reported() {
        let m = toy();
        assert_eq!(m.d_state(), 1);
        assert_eq!(m.d_drive(), 1);
    }

    #[test]
    fn rollout_batch_bit_identical_to_serial() {
        let mut m = toy();
        let h0s = [0.0, 1.0, -0.5];
        // Per-step stimulus rows: traj b gets drive (b+1)*0.2*k.
        let xs: Vec<Vec<f64>> = (0..6)
            .map(|k| {
                (0..3).map(|b| (b as f64 + 1.0) * 0.2 * k as f64).collect()
            })
            .collect();
        let batched = m.rollout_batch(&h0s, 3, &xs);
        for b in 0..3 {
            let xs_b: Vec<Vec<f64>> =
                xs.iter().map(|row| vec![row[b]]).collect();
            let serial = m.rollout(&h0s[b..b + 1], &xs_b);
            assert_eq!(batched[b], serial, "traj {b}");
        }
    }
}
